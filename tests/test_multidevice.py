"""Multi-device semantics (subprocess: needs fake devices before jax init).

Validates on an 8-device host mesh that:
 * the sparse ppermute gossip (shard_map) EXACTLY matches the dense einsum
   mixing for a circulant ring C;
 * a sharded DFL round (pjit, stacked node dim over 'data') matches the
   single-device reference bit-for-bit-ish.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core import ring, mixing, DFLConfig, init_state, make_round_fn
from repro.optim import sgd

mesh = jax.make_mesh((8,), ("data",))
N = 8
topo = ring(N)
x = jax.random.normal(jax.random.key(0), (N, 4, 33))
params = {"w": x}

# dense reference
dense = mixing.mix_dense(params, topo)["w"]

# sparse ppermute path under shard_map
shifts = topo.shifts()
self_w = float(topo.self_weights[0])
def sparse_fn(p):
    return mixing.mix_ppermute_shifts(p, shifts, self_w, "data")
sharded = shard_map(
    sparse_fn, mesh=mesh,
    in_specs=({"w": P("data")},), out_specs={"w": P("data")})(params)["w"]
err = float(jnp.max(jnp.abs(dense - sharded)))
assert err < 1e-5, f"ppermute vs dense mismatch: {err}"
print("PPERMUTE_OK", err)

# sharded DFL round == unsharded DFL round
def loss_fn(p, b, k=None):
    return jnp.mean((p["w"] - b) ** 2)
cfg = DFLConfig(tau1=2, tau2=3, topology=topo)
opt = sgd(0.1)
st0 = init_state({"w": jnp.zeros((4, 33))}, N, opt, jax.random.key(1))
batches = jax.random.normal(jax.random.key(2), (2, N, 4, 33))
rf = make_round_fn(cfg, loss_fn, opt)
ref_state, ref_m = jax.jit(rf)(st0, batches)

sh = NamedSharding(mesh, P("data"))
st_sharded = st0._replace(
    params={"w": jax.device_put(st0.params["w"], sh)},
    opt_state=jax.tree_util.tree_map(lambda t: t, st0.opt_state))
out_state, out_m = jax.jit(
    rf, in_shardings=(None, NamedSharding(mesh, P(None, "data"))))(
    st_sharded, batches)
err2 = float(jnp.max(jnp.abs(ref_state.params["w"] - out_state.params["w"])))
assert err2 < 1e-5, f"sharded round mismatch: {err2}"
print("SHARDED_ROUND_OK", err2)

# production sparse round (shard_map + ppermute) == dense reference.
# NOTE: per-node rng keys differ between engines, so use a deterministic
# (noise-free) loss for the equivalence check.
from repro.core.sharded import make_sharded_round_fn
targets = jnp.linspace(-1, 1, N)[:, None] * jnp.ones((N, 33))
def det_loss(p, b, k=None):
    return jnp.mean((p["w"] - b) ** 2)
det_batches = jnp.broadcast_to(targets[None], (2, N, 33)) * 1.0
det_batches = det_batches[:, :, None, :] * jnp.ones((2, N, 4, 33))
def det_loss2(p, b, k=None):
    return jnp.mean((p["w"][None] - b) ** 2)
cfg2 = DFLConfig(tau1=2, tau2=3, topology=topo)
st0b = init_state({"w": jnp.zeros((33,))}, N, opt, jax.random.key(5))
ref2, _ = jax.jit(make_round_fn(cfg2, det_loss2, opt))(st0b, det_batches)
sharded_fn = make_sharded_round_fn(cfg2, det_loss2, opt, mesh,
                                   node_axes=("data",))
out2, m2 = jax.jit(sharded_fn)(st0b, det_batches)
err3 = float(jnp.max(jnp.abs(ref2.params["w"] - out2.params["w"])))
assert err3 < 1e-5, f"production sharded round mismatch: {err3}"
assert float(m2["consensus_sq"]) >= 0
print("PROD_SHARDED_OK", err3)
"""


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PPERMUTE_OK" in out.stdout
    assert "SHARDED_ROUND_OK" in out.stdout
    assert "PROD_SHARDED_OK" in out.stdout
