"""Multi-device semantics (subprocess: needs fake devices before jax init).

Validates on an 8-device host mesh that:
 * the sparse ppermute gossip (shard_map) EXACTLY matches the dense einsum
   mixing for a circulant ring C;
 * a sharded DFL round (pjit, stacked node dim over 'data') matches the
   single-device reference bit-for-bit-ish;
 * the sparse engine (make_round_fn(engine="sparse")) matches the dense
   engine for plain DFL, for stochastic losses (unified RNG folding), and
   for C-DFL (shared CHOCO-G step), with and without the Pallas kernel hot
   path (interpret mode on CPU).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core import ring, mixing, DFLConfig, init_state, make_round_fn
from repro.optim import sgd

mesh = jax.make_mesh((8,), ("data",))
N = 8
topo = ring(N)
x = jax.random.normal(jax.random.key(0), (N, 4, 33))
params = {"w": x}

# dense reference
dense = mixing.mix_dense(params, topo)["w"]

# sparse ppermute path under shard_map
shifts = topo.shifts()
self_w = float(topo.self_weights[0])
def sparse_fn(p):
    return mixing.mix_ppermute_shifts(p, shifts, self_w, "data")
sharded = shard_map(
    sparse_fn, mesh=mesh,
    in_specs=({"w": P("data")},), out_specs={"w": P("data")})(params)["w"]
err = float(jnp.max(jnp.abs(dense - sharded)))
assert err < 1e-5, f"ppermute vs dense mismatch: {err}"
print("PPERMUTE_OK", err)

# sharded DFL round == unsharded DFL round
def loss_fn(p, b, k=None):
    return jnp.mean((p["w"] - b) ** 2)
cfg = DFLConfig(tau1=2, tau2=3, topology=topo)
opt = sgd(0.1)
st0 = init_state({"w": jnp.zeros((4, 33))}, N, opt, jax.random.key(1))
batches = jax.random.normal(jax.random.key(2), (2, N, 4, 33))
rf = make_round_fn(cfg, loss_fn, opt)
ref_state, ref_m = jax.jit(rf)(st0, batches)

sh = NamedSharding(mesh, P("data"))
st_sharded = st0._replace(
    params={"w": jax.device_put(st0.params["w"], sh)},
    opt_state=jax.tree_util.tree_map(lambda t: t, st0.opt_state))
out_state, out_m = jax.jit(
    rf, in_shardings=(None, NamedSharding(mesh, P(None, "data"))))(
    st_sharded, batches)
err2 = float(jnp.max(jnp.abs(ref_state.params["w"] - out_state.params["w"])))
assert err2 < 1e-5, f"sharded round mismatch: {err2}"
print("SHARDED_ROUND_OK", err2)

# production sparse round (shard_map + ppermute) == dense reference.
from repro.core import make_compressor, sparse_engine_eligible
from repro.core.sharded import make_sharded_round_fn
targets = jnp.linspace(-1, 1, N)[:, None] * jnp.ones((N, 33))
det_batches = jnp.broadcast_to(targets[None], (2, N, 33)) * 1.0
det_batches = det_batches[:, :, None, :] * jnp.ones((2, N, 4, 33))
def det_loss2(p, b, k=None):
    return jnp.mean((p["w"][None] - b) ** 2)
cfg2 = DFLConfig(tau1=2, tau2=3, topology=topo)
st0b = init_state({"w": jnp.zeros((33,))}, N, opt, jax.random.key(5))
ref2, _ = jax.jit(make_round_fn(cfg2, det_loss2, opt))(st0b, det_batches)
sharded_fn = make_sharded_round_fn(cfg2, det_loss2, opt, mesh,
                                   node_axes=("data",))
out2, m2 = jax.jit(sharded_fn)(st0b, det_batches)
err3 = float(jnp.max(jnp.abs(ref2.params["w"] - out2.params["w"])))
assert err3 < 1e-5, f"production sharded round mismatch: {err3}"
assert float(m2["consensus_sq"]) >= 0
print("PROD_SHARDED_OK", err3)

# stochastic loss: the unified RNG folding (per-node key =
# fold_in(step_key, node)) makes dense and sparse draw identical noise.
def noisy_loss(p, b, k=None):
    jitter = 0.05 * jax.random.normal(k, p["w"].shape)
    return jnp.mean((p["w"][None] + jitter[None] - b) ** 2)
assert sparse_engine_eligible(cfg2, mesh, ("data",))
ref_n = init_state({"w": jnp.zeros((33,))}, N, opt, jax.random.key(9))
out_n = ref_n
dense_n = jax.jit(make_round_fn(cfg2, noisy_loss, opt))
sparse_n = jax.jit(make_round_fn(cfg2, noisy_loss, opt, engine="auto",
                                 mesh=mesh, node_axes=("data",)))
for _ in range(2):  # two rounds: exercises the round_idx key folding
    ref_n, mr = dense_n(ref_n, det_batches)
    out_n, ms = sparse_n(out_n, det_batches)
err_rng = float(jnp.max(jnp.abs(ref_n.params["w"] - out_n.params["w"])))
assert err_rng < 1e-5, f"stochastic-loss engine mismatch: {err_rng}"
assert abs(float(mr["loss"]) - float(ms["loss"])) < 1e-5
print("RNG_PARITY_OK", err_rng)

# C-DFL parity: the shared CHOCO-G step (incl. stochastic QSGD keys) agrees
# across engines, plain jnp and Pallas-kernel (interpret) hot paths both.
cfg3 = DFLConfig(tau1=2, tau2=2, topology=topo,
                 compression=make_compressor("qsgd"), gamma=0.5)
st0c = init_state({"w": jnp.zeros((33,))}, N, opt, jax.random.key(7),
                  compressed=True)
ref3, _ = jax.jit(make_round_fn(cfg3, det_loss2, opt))(st0c, det_batches)
out3, m3 = jax.jit(make_round_fn(cfg3, det_loss2, opt, engine="sparse",
                                 mesh=mesh, node_axes=("data",)))(
    st0c, det_batches)
err4 = max(float(jnp.max(jnp.abs(ref3.params["w"] - out3.params["w"]))),
           float(jnp.max(jnp.abs(ref3.hat_params["w"] -
                                 out3.hat_params["w"]))))
assert err4 < 1e-5, f"C-DFL engine mismatch: {err4}"
print("CDFL_PARITY_OK", err4)

out4, _ = jax.jit(make_round_fn(cfg3, det_loss2, opt, engine="sparse",
                                mesh=mesh, node_axes=("data",),
                                use_kernels=True))(st0c, det_batches)
err5 = float(jnp.max(jnp.abs(ref3.params["w"] - out4.params["w"])))
assert err5 < 1e-5, f"kernel hot path mismatch: {err5}"
print("KERNELS_OK", err5)

# TopK C-DFL: dense reference vs the sparse engine's FUSED
# compress-and-move kernel (choco_topk_move) — the kernel-backed TopK is
# bitwise vs the library compressor, so engine parity matches the
# uncompressed case.
cfg4 = DFLConfig(tau1=2, tau2=2, topology=topo,
                 compression=make_compressor("top_k", frac=0.3), gamma=0.5)
st0d = init_state({"w": jnp.zeros((33,))}, N, opt, jax.random.key(11),
                  compressed=True)
ref4, _ = jax.jit(make_round_fn(cfg4, det_loss2, opt))(st0d, det_batches)
out5, _ = jax.jit(make_round_fn(cfg4, det_loss2, opt, engine="sparse",
                                mesh=mesh, node_axes=("data",),
                                use_kernels=True))(st0d, det_batches)
err6 = max(float(jnp.max(jnp.abs(ref4.params["w"] - out5.params["w"]))),
           float(jnp.max(jnp.abs(ref4.hat_params["w"] -
                                 out5.hat_params["w"]))))
assert err6 < 1e-5, f"fused TopK kernel engine mismatch: {err6}"
print("TOPK_KERNELS_OK", err6)
"""


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PPERMUTE_OK" in out.stdout
    assert "SHARDED_ROUND_OK" in out.stdout
    assert "PROD_SHARDED_OK" in out.stdout
    assert "RNG_PARITY_OK" in out.stdout
    assert "CDFL_PARITY_OK" in out.stdout
    assert "KERNELS_OK" in out.stdout
    assert "TOPK_KERNELS_OK" in out.stdout
