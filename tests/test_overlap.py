"""Overlapped supersteps: the double-buffered gossip/compute pipeline.

Pins the PR's contract from executor to planner:

  * ``overlap="none"`` is BITWISE the legacy executor — the knob's default
    must not move a single bit on either engine, plain or CHOCO;
  * ``overlap="pipeline"`` equals a pure-Python one-round-stale-mixing
    reference (round k's local phase + round k-1's exchange folded late,
    drained after the scan) to float tolerance, including the CHOCO hat
    chain and the metrics' realized schedule;
  * drain semantics: a dispatched superstep returns fully-drained state, so
    chunked dispatches match per-chunk references and a restart from a
    checkpointed state (a fresh executor) continues bitwise — no gossip
    ever crosses a superstep/checkpoint boundary;
  * zero recompiles across trajectories in pipeline mode (the audits check
    the same property on the compiled artifact);
  * the planner prices the pipeline: max-form round time degenerating to
    additive at "none", the staleness penalty via ``stale_mixing_zeta``,
    and the roofline's ``predict_overlap`` arithmetic.

Sparse-engine parity needs 8 fake devices → subprocess, like
tests/test_executor.py.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DFLConfig, RoundExecutor, init_state, make_compressor,
                        ring, stack_round_batches)
from repro.core.dfl import gossip_phase, local_phase, round_keys
from repro.core.substrate import DenseSubstrate
from repro.optim import sgd

N = 8
DIM = 5


def noisy_loss(p, b, k=None):
    jitter = 0.02 * jax.random.normal(k, p["w"].shape)
    return jnp.mean((p["w"] + jitter - b) ** 2)


def batches_for(tau1, seed=2):
    return jax.random.normal(jax.random.key(seed), (tau1, N, DIM))


def fresh_state(opt, compressed=False, seed=1):
    return init_state({"w": jnp.zeros((DIM,))}, N, opt, jax.random.key(seed),
                      compressed=compressed)


def assert_model_state_bitwise(a, b):
    """params / opt_state / hat_params bitwise (NOT rng: typed keys)."""
    for x, y in zip(
            jax.tree_util.tree_leaves((a.params, a.opt_state, a.hat_params)),
            jax.tree_util.tree_leaves((b.params, b.opt_state, b.hat_params))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def stale_reference(cfg, opt, state, round_batches, taus):
    """Pure-Python one-round-stale-mixing oracle, drain included.

    Round k runs its tau1 local steps with round k's local key, then folds
    round k-1's exchange (round k-1's comm key / tau2) into the post-local
    params; the final in-flight exchange drains after the loop. Built from
    the same ``local_phase``/``gossip_phase`` stages the legacy round
    composes, so it is a reference for the SCHEDULE, not the numerics.
    """
    sub = DenseSubstrate(cfg.topology)
    params, opt_state, hat = state.params, state.opt_state, state.hat_params
    rng, r0 = state.rng, int(state.round_idx)
    buf = prev_t2 = None
    losses = []
    for i, ((t1, t2), b) in enumerate(zip(taus, round_batches)):
        r = r0 + i
        lk, _ = round_keys(rng, r)
        bt = np.zeros((cfg.tau1,) + b.shape[1:], np.float32)
        bt[: b.shape[0]] = np.asarray(b)
        z, opt_state, loss = local_phase(
            cfg, noisy_loss, opt, sub, params, opt_state, lk,
            jnp.asarray(bt), tau1=jnp.asarray(int(t1), jnp.int32))
        losses.append(float(loss))
        if buf is not None:
            _, ck = round_keys(rng, r - 1)
            g, hat_g = gossip_phase(cfg, sub, buf, hat, ck, r - 1,
                                    tau2=jnp.asarray(prev_t2, jnp.int32))
            params = jax.tree_util.tree_map(
                lambda zl, gl, bl: zl + (gl - bl), z, g, buf)
            if cfg.is_compressed:
                hat = hat_g
        else:
            params = z
        buf = z
        prev_t2 = int(t2)
    r_end = r0 + len(taus)
    _, ck = round_keys(rng, r_end - 1)
    g, hat_d = gossip_phase(cfg, sub, buf, hat, ck, r_end - 1,
                            tau2=jnp.asarray(prev_t2, jnp.int32))
    params = jax.tree_util.tree_map(
        lambda pl, gl, bl: pl + (gl - bl), params, g, buf)
    if cfg.is_compressed:
        hat = hat_d
    return params, hat, losses


TAUS = np.array([[3, 2], [1, 1], [2, 2], [3, 0]], np.int32)


def _round_batches(taus, seed0=10):
    return [batches_for(int(t1), seed=seed0 + i)
            for i, (t1, _) in enumerate(taus)]


# ---------------------------------------------------------------------------
# overlap="none" is the legacy path, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comp", [None, "top_k"])
def test_overlap_none_bitwise_equals_legacy(comp):
    compressor = make_compressor(comp, frac=0.5) if comp else None
    cfg = DFLConfig(tau1=3, tau2=2, topology=ring(N),
                    compression=compressor, gamma=0.5)
    opt = sgd(0.1)
    rb = _round_batches(TAUS)
    batches = stack_round_batches(rb, cfg.tau1)
    c = compressor is not None
    legacy = RoundExecutor(cfg, noisy_loss, opt, donate=False)
    none = RoundExecutor(cfg, noisy_loss, opt, donate=False, overlap="none")
    sa, ma = legacy.dispatch_trajectory(fresh_state(opt, c), batches, TAUS)
    sb, mb = none.dispatch_trajectory(fresh_state(opt, c), batches, TAUS)
    assert_model_state_bitwise(sa, sb)
    np.testing.assert_array_equal(np.asarray(ma["loss"]),
                                  np.asarray(mb["loss"]))
    np.testing.assert_array_equal(np.asarray(ma["consensus_sq"]),
                                  np.asarray(mb["consensus_sq"]))
    # uniform dispatch rides the same executable in both executors too
    su, _ = legacy.dispatch(sa, batches, 2, 1)
    sv, _ = none.dispatch(sb, batches, 2, 1)
    assert_model_state_bitwise(su, sv)


# ---------------------------------------------------------------------------
# overlap="pipeline" == the one-round-stale-mixing reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comp", [None, "top_k"])
def test_pipeline_matches_stale_reference(comp):
    compressor = make_compressor(comp, frac=0.5) if comp else None
    cfg = DFLConfig(tau1=3, tau2=2, topology=ring(N),
                    compression=compressor, gamma=0.5)
    opt = sgd(0.1)
    rb = _round_batches(TAUS)
    batches = stack_round_batches(rb, cfg.tau1)
    c = compressor is not None
    ex = RoundExecutor(cfg, noisy_loss, opt, donate=False,
                       overlap="pipeline")
    out, m = ex.dispatch_trajectory(fresh_state(opt, c), batches, TAUS)
    ref_p, ref_hat, ref_losses = stale_reference(
        cfg, opt, fresh_state(opt, c), rb, TAUS)
    np.testing.assert_allclose(np.asarray(out.params["w"]),
                               np.asarray(ref_p["w"]),
                               rtol=2e-6, atol=1e-7)
    if c:
        np.testing.assert_allclose(np.asarray(out.hat_params["w"]),
                                   np.asarray(ref_hat["w"]),
                                   rtol=2e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(m["loss"]), np.asarray(ref_losses),
                               rtol=1e-5)
    # metrics still carry the realized schedule and rounds advanced K
    np.testing.assert_array_equal(np.asarray(m["tau1"]), TAUS[:, 0])
    np.testing.assert_array_equal(np.asarray(m["tau2"]), TAUS[:, 1])
    assert int(out.round_idx) == len(TAUS)


def test_pipeline_single_round_equals_legacy():
    """K=1: one local phase + one drained exchange IS the legacy round —
    the pipeline introduces staleness only BETWEEN rounds."""
    opt = sgd(0.1)
    cfg = DFLConfig(tau1=3, tau2=2, topology=ring(N))
    taus1 = np.array([[2, 2]], np.int32)
    b1 = stack_round_batches([batches_for(2, seed=33)], cfg.tau1)
    legacy = RoundExecutor(cfg, noisy_loss, opt, donate=False)
    pipe = RoundExecutor(cfg, noisy_loss, opt, donate=False,
                         overlap="pipeline")
    s_leg, _ = legacy.dispatch_trajectory(fresh_state(opt), b1, taus1)
    s_pipe, _ = pipe.dispatch_trajectory(fresh_state(opt), b1, taus1)
    np.testing.assert_allclose(np.asarray(s_pipe.params["w"]),
                               np.asarray(s_leg.params["w"]),
                               rtol=2e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# drain semantics at superstep / checkpoint boundaries
# ---------------------------------------------------------------------------


def test_pipeline_drains_at_superstep_boundary():
    """A dispatched superstep returns fully-drained state: chunked
    dispatches equal the per-chunk stale reference, and a FRESH executor
    restarted from the first chunk's output (checkpoint/restore) continues
    bitwise — nothing is in flight across the boundary."""
    opt = sgd(0.1)
    cfg = DFLConfig(tau1=3, tau2=2, topology=ring(N))
    rb = _round_batches(TAUS)
    chunk_a = stack_round_batches(rb[:2], cfg.tau1)
    chunk_b = stack_round_batches(rb[2:], cfg.tau1)
    ex = RoundExecutor(cfg, noisy_loss, opt, donate=False,
                       overlap="pipeline")
    mid, _ = ex.dispatch_trajectory(fresh_state(opt), chunk_a, TAUS[:2])
    end, _ = ex.dispatch_trajectory(mid, chunk_b, TAUS[2:])
    # per-chunk reference: each chunk drains, the next starts fresh
    p1, _, _ = stale_reference(cfg, opt, fresh_state(opt), rb[:2], TAUS[:2])
    np.testing.assert_allclose(np.asarray(mid.params["w"]),
                               np.asarray(p1["w"]), rtol=2e-6, atol=1e-7)
    ref_mid = fresh_state(opt)._replace(
        params=p1, round_idx=mid.round_idx)
    p2, _, _ = stale_reference(cfg, opt, ref_mid, rb[2:], TAUS[2:])
    np.testing.assert_allclose(np.asarray(end.params["w"]),
                               np.asarray(p2["w"]), rtol=4e-6, atol=1e-7)
    # restore: a brand-new executor picks up from `mid` identically
    ex2 = RoundExecutor(cfg, noisy_loss, opt, donate=False,
                        overlap="pipeline")
    end2, _ = ex2.dispatch_trajectory(mid, chunk_b, TAUS[2:])
    assert_model_state_bitwise(end, end2)


# ---------------------------------------------------------------------------
# zero recompiles / validation / participation
# ---------------------------------------------------------------------------


def test_pipeline_zero_recompiles_across_trajectories():
    opt = sgd(0.1)
    cfg = DFLConfig(tau1=3, tau2=2, topology=ring(N))
    ex = RoundExecutor(cfg, noisy_loss, opt, donate=False,
                       overlap="pipeline")
    batches = stack_round_batches(_round_batches(TAUS), cfg.tau1)
    st, _ = ex.dispatch_trajectory(fresh_state(opt), batches, TAUS)
    assert ex.compile_count == 1
    other = np.array([[1, 2], [3, 1], [2, 0], [1, 1]], np.int32)
    st, _ = ex.dispatch_trajectory(st, batches, other)
    st, _ = ex.dispatch(st, batches, 2, 2)   # uniform rides the same exe
    assert ex.compile_count == 1


def test_overlap_validation():
    opt = sgd(0.1)
    cfg = DFLConfig(tau1=3, tau2=2, topology=ring(N))
    with pytest.raises(ValueError, match="overlap"):
        RoundExecutor(cfg, noisy_loss, opt, overlap="bogus")
    with pytest.raises(ValueError, match="dynamic"):
        RoundExecutor(cfg, noisy_loss, opt, dynamic=False,
                      overlap="pipeline")
    from repro.core.dfl import make_pipeline_fns
    cfg_pow = DFLConfig(tau1=2, tau2=2, topology=ring(N),
                        mixing_impl="dense_power")
    with pytest.raises(ValueError, match="dense_power"):
        make_pipeline_fns(cfg_pow, noisy_loss, opt)
    from repro.planner import CostModel
    from repro.planner.cost import ComputeModel, LinkModel
    with pytest.raises(ValueError, match="overlap"):
        CostModel(compute=ComputeModel(1.0, 1.0), link=LinkModel(1.0),
                  topology=ring(N), model_bits=32.0, overlap="bogus")
    from repro.launch.steps import build_train_superstep
    with pytest.raises(ValueError, match="overlap"):
        build_train_superstep(None, "unused", None, overlap="bogus")


def test_participation_pipeline_all_ones_equals_plain():
    """Widened [K, 2+N+E] rows pipeline too: all-ones masks are bitwise
    the plain pipeline, and heterogeneous masks share the executable."""
    opt = sgd(0.1)
    cfg = DFLConfig(tau1=2, tau2=2, topology=ring(N))
    E = cfg.topology.num_edges
    K = 3
    rng = np.random.RandomState(0)
    rows = [[2, 2] + rng.binomial(1, 0.8, N).tolist()
            + rng.binomial(1, 0.8, E).tolist() for _ in range(K)]
    taus = np.asarray(rows, np.int32)
    rb = [batches_for(2, seed=10 + i) for i in range(K)]
    batches = stack_round_batches(rb, cfg.tau1)
    ex_p = RoundExecutor(cfg, noisy_loss, opt, participation=True,
                         overlap="pipeline", donate=False)
    st, _ = ex_p.dispatch_trajectory(fresh_state(opt), batches, taus)
    assert np.isfinite(np.asarray(st.params["w"])).all()
    ones = np.concatenate([taus[:, :2], np.ones((K, N + E), np.int32)],
                          axis=1)
    ex_plain = RoundExecutor(cfg, noisy_loss, opt, overlap="pipeline",
                             donate=False)
    s1, _ = ex_p.dispatch_trajectory(fresh_state(opt), batches, ones)
    s2, _ = ex_plain.dispatch_trajectory(fresh_state(opt), batches,
                                         taus[:, :2].copy())
    np.testing.assert_array_equal(np.asarray(s1.params["w"]),
                                  np.asarray(s2.params["w"]))
    n0 = ex_p.compile_count
    ex_p.dispatch_trajectory(st, batches, taus)
    assert ex_p.compile_count == n0


# ---------------------------------------------------------------------------
# observability: the gossip slice rides its own track
# ---------------------------------------------------------------------------


def test_pipeline_emits_overlap_events():
    from repro.obs import Telemetry
    from repro.obs.events import validate_events

    tel = Telemetry()
    opt = sgd(0.1)
    cfg = DFLConfig(tau1=3, tau2=2, topology=ring(N))
    ex = RoundExecutor(cfg, noisy_loss, opt, donate=False,
                       overlap="pipeline", telemetry=tel)
    batches = stack_round_batches(_round_batches(TAUS), cfg.tau1)
    ex.dispatch_trajectory(fresh_state(opt), batches, TAUS)
    ov = [e for e in tel.events if e["type"] == "overlap"]
    assert len(ov) == 1
    assert ov[0]["track"] == "overlap" and ov[0]["dur"] is not None
    assert ov[0]["data"]["mode"] == "pipeline"
    assert ov[0]["data"]["k"] == len(TAUS)
    assert validate_events(tel.events) == []
    # overlap="none" stays silent on the overlap track
    tel2 = Telemetry()
    ex_n = RoundExecutor(cfg, noisy_loss, opt, donate=False,
                         telemetry=tel2)
    ex_n.dispatch_trajectory(fresh_state(opt), batches, TAUS)
    assert not [e for e in tel2.events if e["type"] == "overlap"]


def test_run_report_aggregates_overlap():
    from repro.obs.events import make_event
    from repro.obs.report import format_report, run_report

    events = [
        make_event("run", 0.0, "run",
                   data={"schema": 3, "wall_start": 1.0}),
        make_event("overlap", 0.5, "overlap", name="gossip-inflight-k4",
                   dur=0.25, data={"mode": "pipeline", "k": 4,
                                   "dispatch": 1}),
        make_event("overlap", 1.0, "overlap", name="gossip-inflight-k4",
                   dur=0.15, data={"mode": "pipeline", "k": 4,
                                   "dispatch": 2}),
    ]
    rep = run_report(events)
    assert rep["overlap"] == {"supersteps": 2, "mode": "pipeline",
                              "inflight_s": pytest.approx(0.4)}
    assert "overlap: mode=pipeline over 2 superstep(s)" in format_report(rep)


# ---------------------------------------------------------------------------
# planner: the max-form round time and the staleness penalty
# ---------------------------------------------------------------------------


def test_cost_model_overlap_round_time():
    from repro.planner import unit_cost_model

    cm_none = unit_cost_model(ring(N), 4.0)
    cm_pipe = unit_cost_model(ring(N), 4.0, overlap="pipeline")
    t_c = cm_none.compute.t_step
    t_g = cm_none.t_gossip_step(None)
    for (t1, t2) in [(1, 1), (4, 2), (2, 4), (3, 0)]:
        none = cm_none.round_cost(t1, t2)
        pipe = cm_pipe.round_cost(t1, t2)
        assert none.time_s == pytest.approx(t1 * t_c + t2 * t_g)
        assert pipe.time_s == pytest.approx(
            t1 * t_c + max(0.0, t2 * t_g - t1 * t_c))
        # overlap hides time, never traffic or energy
        assert pipe.wire_bits == none.wire_bits
        assert pipe.time_s <= none.time_s
    # degeneration: no gossip, or no window, means additive exactly
    assert cm_pipe.round_cost(3, 0).time_s == cm_none.round_cost(3, 0).time_s
    assert cm_none.overlap_window(5) == 0.0
    assert cm_pipe.overlap_window(5) == pytest.approx(5 * t_c)


def test_masked_round_cost_overlap_window():
    """A fully-masked round computes nothing, so it hides nothing: the
    pipelined masked cost uses the MASKED compute window."""
    from repro.planner import unit_cost_model

    cm_none = unit_cost_model(ring(N), 4.0)
    cm_pipe = unit_cost_model(ring(N), 4.0, overlap="pipeline")
    # every node masked: zero compute window, so the pipelined price is
    # exactly the additive one — the wire is fully exposed
    dead_n = cm_none.masked_round_cost(2, 2, active_nodes=[])
    dead_p = cm_pipe.masked_round_cost(2, 2, active_nodes=[])
    assert dead_p.time_s == pytest.approx(dead_n.time_s)
    # unmasked: the pipeline hides up to the compute window
    live_n = cm_none.masked_round_cost(2, 2)
    live_p = cm_pipe.masked_round_cost(2, 2)
    t_c = cm_none.compute.t_step
    assert live_p.time_s == pytest.approx(
        2 * t_c + max(0.0, (live_n.time_s - 2 * t_c) - 2 * t_c))
    assert live_p.time_s <= live_n.time_s
    assert live_p.wire_bits == live_n.wire_bits


def test_stale_mixing_zeta():
    from repro.planner import stale_mixing_zeta
    from repro.planner.bounds import sporadic_zeta

    topo = ring(N)
    z0 = stale_mixing_zeta(topo, 0.0)
    assert z0 == pytest.approx(sporadic_zeta(topo, 1.0))
    z1 = stale_mixing_zeta(topo, 1.0)
    z3 = stale_mixing_zeta(topo, 3.0)
    assert z0 < z1 < z3 < 1.0
    with pytest.raises(ValueError, match="staleness"):
        stale_mixing_zeta(topo, -0.5)


def test_staleness_penalizes_loss_decrement():
    from repro.planner.bounds import predicted_loss_decrement

    kw = dict(T=200, f_gap=1.0)
    fresh = predicted_loss_decrement(4, 2, ring(N), 0.5, **kw)
    stale = predicted_loss_decrement(4, 2, ring(N), 0.5, staleness=1.0, **kw)
    assert stale.zeta > fresh.zeta
    assert stale.bound >= fresh.bound


def test_pipeline_plan_shifts_toward_compute():
    """On a gossip-dominated link the pipelined planner picks a schedule
    at least as tau1-heavy as the additive one — bigger local windows hide
    more wire, paying only the staleness penalty."""
    from repro.planner import (Budget, evaluate_grid, select_plan,
                               unit_cost_model)

    topo = ring(N)
    grid = [(1, 4), (1, 2), (1, 1), (2, 2), (2, 1), (4, 1), (8, 1)]
    sigma, f_gap = 0.5, 1.0
    cm_none = unit_cost_model(topo, 4.0)
    cm_pipe = unit_cost_model(topo, 4.0, overlap="pipeline")
    budget = Budget(wall_clock_s=cm_none.round_cost(2, 2).time_s * 60)
    p_none = select_plan(evaluate_grid(budget, cm_none, sigma=sigma,
                                       f_gap=f_gap, grid=grid))
    p_pipe = select_plan(evaluate_grid(budget, cm_pipe, sigma=sigma,
                                       f_gap=f_gap, grid=grid))
    ratio = lambda p: p.tau1 / max(p.tau2, 1)
    assert ratio(p_pipe) >= ratio(p_none)
    # and the pipelined winner's round really is cheaper than its additive
    # price — the planner is spending hidden seconds, not imaginary ones
    assert (cm_pipe.round_cost(p_pipe.tau1, p_pipe.tau2).time_s
            <= cm_none.round_cost(p_pipe.tau1, p_pipe.tau2).time_s)


def test_fitted_cost_model_preserves_overlap():
    from repro.planner import (AdaptiveController, Budget, unit_cost_model)

    cm = unit_cost_model(ring(N), 1.0, overlap="pipeline")
    ctrl = AdaptiveController(Budget(wall_clock_s=1e6), cm, sigma=0.5,
                              f_gap=1.0, grid=[(2, 2), (4, 1)])
    ctrl.initial_plan()
    ctrl.observe(2, 2, 1.0)
    ctrl.observe(4, 1, 1.3)
    assert ctrl.fitted_cost_model().overlap == "pipeline"


def test_predict_trajectory_matches_next_trajectory():
    """The controller's prediction contract (trajectory-mode prefetch):
    after observe_chunk and before new spend, predict_trajectory returns
    exactly what next_trajectory will emit — and mutates nothing."""
    from repro.planner import AdaptiveController, Budget, unit_cost_model

    cm = unit_cost_model(ring(N), 1.0)
    ctrl = AdaptiveController(Budget(wall_clock_s=1e5), cm, sigma=0.5,
                              f_gap=1.0, grid=[(1, 1), (2, 2), (4, 1)])
    ctrl.initial_plan()
    n_hist = len(ctrl.history)
    pred = ctrl.predict_trajectory(4)
    assert pred is not None
    pred2 = ctrl.predict_trajectory(4)
    np.testing.assert_array_equal(pred, pred2)       # pure read, stable
    assert len(ctrl.history) == n_hist               # no event emitted
    taus = ctrl.next_trajectory(4)
    np.testing.assert_array_equal(pred, taus)
    assert len(ctrl.history) == n_hist + 1           # commit DID emit
    # the contract survives a fit update: predict right after observing
    ctrl.observe_chunk([(int(a), int(b)) for a, b in taus], 12.0)
    pred = ctrl.predict_trajectory(4)
    taus2 = ctrl.next_trajectory(4, round_idx=4)
    np.testing.assert_array_equal(pred, taus2)


def test_predict_trajectory_exhaustion_returns_none():
    from repro.planner import AdaptiveController, Budget, unit_cost_model

    cm = unit_cost_model(ring(N), 1.0)
    ctrl = AdaptiveController(Budget(wall_clock_s=5.0), cm, sigma=0.5,
                              f_gap=1.0, grid=[(2, 2)])
    ctrl.initial_plan()
    ctrl.observe_chunk([(2, 2)] * 4, 100.0)          # budget gone
    assert ctrl.predict_trajectory(4) is None
    assert not ctrl.exhausted                        # prediction never sets it
    assert ctrl.next_trajectory(4, round_idx=4) is None
    assert ctrl.exhausted


# ---------------------------------------------------------------------------
# roofline: predicting the win before a round runs
# ---------------------------------------------------------------------------


def test_predict_overlap_arithmetic():
    from repro.launch.roofline import Roofline, predict_overlap

    local = Roofline(flops=2e12, hbm_bytes=1e9, collective_bytes=0.0,
                     chips=8)                         # compute-bound: 2.18ms
    gossip = Roofline(flops=0.0, hbm_bytes=0.0, collective_bytes=9e8,
                      chips=8)                        # 0.01s of wire
    p = predict_overlap(local, gossip, tau1=4, tau2=2)
    tl = max(local.compute_s, local.memory_s)
    tg = gossip.collective_s
    assert p.additive_s == pytest.approx(4 * tl + 2 * tg)
    assert p.pipelined_s == pytest.approx(4 * tl + max(0.0, 2 * tg - 4 * tl))
    assert p.hidden_s == pytest.approx(p.additive_s - p.pipelined_s)
    assert p.speedup == pytest.approx(p.additive_s / p.pipelined_s)
    assert p.hidden_s > 0                             # gossip-heavy: a win
    # measured-override calibration (what the bench does)
    pm = predict_overlap(local, gossip, tau1=4, tau2=2, t_local_step_s=0.5)
    assert pm.t_local_step_s == 0.5
    assert pm.t_gossip_step_s == pytest.approx(tg)
    # compute-dominated rounds degenerate: nothing left to hide
    big = predict_overlap(local, gossip, tau1=64, tau2=1)
    assert big.hidden_s == pytest.approx(big.tau2 * tg)
    assert big.pipelined_s == pytest.approx(64 * tl)
    d = p.as_dict()
    assert d["speedup"] == pytest.approx(p.speedup)


# ---------------------------------------------------------------------------
# sparse engine parity (8 fake devices -> subprocess)
# ---------------------------------------------------------------------------

OVERLAP_SPARSE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.core import (DFLConfig, RoundExecutor, init_state, make_compressor,
                        ring, stack_round_batches)
from repro.optim import sgd

N, DIM = 8, 17
mesh = jax.make_mesh((8,), ("data",))
opt = sgd(0.05)

def noisy_loss(p, b, k=None):
    jitter = 0.02 * jax.random.normal(k, p["w"].shape)
    return jnp.mean((p["w"] + jitter - b) ** 2)

def fresh(compressed=False):
    return init_state({"w": jnp.zeros((DIM,))}, N, opt, jax.random.key(1),
                      compressed=compressed)

taus = np.array([[2, 2], [1, 1], [2, 0]], np.int32)
rb = [jax.random.normal(jax.random.key(10 + i), (int(t1), N, DIM))
      for i, (t1, _) in enumerate(taus)]

for comp_name in (None, "top_k"):
    comp = make_compressor(comp_name, frac=0.5) if comp_name else None
    cfg = DFLConfig(tau1=2, tau2=2, topology=ring(N), compression=comp)
    batches = stack_round_batches(rb, cfg.tau1)
    c = comp_name is not None
    kw = dict(donate=False)
    # overlap="none" is bitwise the legacy SPARSE executor
    ex_none = RoundExecutor(cfg, noisy_loss, opt, engine="sparse", mesh=mesh,
                            overlap="none", **kw)
    ex_legacy = RoundExecutor(cfg, noisy_loss, opt, engine="sparse",
                              mesh=mesh, **kw)
    s_n, _ = ex_none.dispatch_trajectory(fresh(c), batches, taus)
    s_l, _ = ex_legacy.dispatch_trajectory(fresh(c), batches, taus)
    for x, y in zip(
            jax.tree_util.tree_leaves((s_n.params, s_n.opt_state,
                                       s_n.hat_params)),
            jax.tree_util.tree_leaves((s_l.params, s_l.opt_state,
                                       s_l.hat_params))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    print(f"SPARSE_NONE_BITWISE_OK[{comp_name}]")

    # sparse pipeline == dense pipeline (the numerical oracle)
    ex_dp = RoundExecutor(cfg, noisy_loss, opt, overlap="pipeline", **kw)
    ex_sp = RoundExecutor(cfg, noisy_loss, opt, engine="sparse", mesh=mesh,
                          overlap="pipeline", **kw)
    s_dp, m_dp = ex_dp.dispatch_trajectory(fresh(c), batches, taus)
    s_sp, m_sp = ex_sp.dispatch_trajectory(fresh(c), batches, taus)
    err = float(jnp.max(jnp.abs(s_dp.params["w"] - s_sp.params["w"])))
    assert err < 1e-5, f"sparse pipeline mismatch[{comp_name}]: {err}"
    np.testing.assert_allclose(np.asarray(m_sp["loss"]),
                               np.asarray(m_dp["loss"]), rtol=1e-5)
    # zero recompiles across trajectories on the sparse pipeline too
    n0 = ex_sp.compile_count
    taus2 = np.array([[1, 2], [2, 1], [1, 0]], np.int32)
    ex_sp.dispatch_trajectory(s_sp, batches, taus2)
    assert ex_sp.compile_count == n0, ex_sp.compile_count
    print(f"SPARSE_PIPELINE_OK[{comp_name}]", err)
print("ALL_OK")
"""


@pytest.mark.slow
def test_sparse_overlap_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", OVERLAP_SPARSE_SCRIPT],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ["SPARSE_NONE_BITWISE_OK[None]",
                "SPARSE_NONE_BITWISE_OK[top_k]",
                "SPARSE_PIPELINE_OK[None]", "SPARSE_PIPELINE_OK[top_k]",
                "ALL_OK"]:
        assert tag in out.stdout, (tag, out.stdout, out.stderr[-2000:])
