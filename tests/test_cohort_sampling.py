"""Property-based tests of ``repro.faults.CohortSampler``.

The sampler is the mega-scale run's only source of randomness outside
``DFLState.rng``, so its contract is load-bearing for determinism and
checkpoint restart: draws are a pure function of (seed, round) via
``np.random.SeedSequence([seed, round])`` (round r's cohort never
depends on which rounds were evaluated before it), uniform WITHOUT
replacement, exactly C-sized and sorted — and at full participation
(C == V) the sorted draw IS ``arange(V)``, so the cohort trajectory row
degenerates bitwise to the legacy participation row.

Runs under real hypothesis when installed, else the deterministic
fallback shim in tests/conftest.py.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.faults import CohortSampler, FaultPlan, SporadicParticipation
from repro.core.topology import ring


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       pop=st.integers(min_value=1, max_value=512),
       r=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=50, deadline=None)
def test_draw_shape_range_no_replacement(seed, pop, r):
    cohort = max(1, pop // 3)
    s = CohortSampler(population=pop, cohort=cohort, seed=seed)
    ids = s.draw(r)
    assert ids.shape == (cohort,) and ids.dtype == np.int32
    assert ids.min() >= 0 and ids.max() < pop
    assert len(np.unique(ids)) == cohort          # without replacement
    assert (np.sort(ids) == ids).all()            # sorted draw


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       r=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=25, deadline=None)
def test_draw_deterministic_and_round_local(seed, r):
    """Pure in (seed, round): re-draws agree across sampler instances,
    and drawing OTHER rounds first (the restart scenario) never shifts
    round r's cohort."""
    a = CohortSampler(population=100, cohort=10, seed=seed)
    b = CohortSampler(population=100, cohort=10, seed=seed)
    for other in (0, r + 1, max(0, r - 1)):
        b.draw(other)
    np.testing.assert_array_equal(a.draw(r), b.draw(r))
    want = np.sort(np.random.default_rng(
        np.random.SeedSequence([seed, r])).choice(
            100, size=10, replace=False)).astype(np.int32)
    np.testing.assert_array_equal(a.draw(r), want)


def test_draws_approximately_uniform():
    """Every node's inclusion frequency concentrates at C/V (a biased
    generator or an off-by-one in the id range shows up here)."""
    pop, cohort, rounds = 40, 8, 2000
    s = CohortSampler(population=pop, cohort=cohort, seed=5)
    counts = np.zeros(pop)
    for r in range(rounds):
        counts[s.draw(r)] += 1
    freq = counts / rounds
    rate = cohort / pop
    # 4-sigma band for a Bernoulli(rate) mean over `rounds` draws.
    tol = 4 * np.sqrt(rate * (1 - rate) / rounds)
    assert np.all(np.abs(freq - rate) < tol), (freq.min(), freq.max(), rate)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       pop=st.integers(min_value=1, max_value=64),
       r=st.integers(min_value=0, max_value=1000))
@settings(max_examples=25, deadline=None)
def test_full_population_draw_is_identity(seed, pop, r):
    s = CohortSampler(population=pop, cohort=pop, seed=seed)
    np.testing.assert_array_equal(s.draw(r),
                                  np.arange(pop, dtype=np.int32))


def test_full_population_row_reproduces_legacy_row_bitwise():
    """C == V: splicing the (identity) cohort into a fault plan's masked
    participation rows yields exactly [tau1, tau2, arange, legacy row
    tail] — the batched engine runs the legacy sporadic round bitwise."""
    topo = ring(8)
    plan = FaultPlan(topo, (SporadicParticipation(0.7, 0.6, 0, 50),),
                     seed=9)
    taus = np.tile(np.array([[2, 1]], np.int32), (5, 1))
    legacy = plan.mask_trajectory(taus, round0=3)
    s = CohortSampler(population=8, cohort=8, seed=123)
    rows = s.cohort_trajectory(legacy, round0=3, num_edges=topo.num_edges)
    assert rows.shape == (5, 2 + 2 * 8 + topo.num_edges)
    np.testing.assert_array_equal(rows[:, :2], legacy[:, :2])
    np.testing.assert_array_equal(rows[:, 2:10],
                                  np.tile(np.arange(8), (5, 1)))
    np.testing.assert_array_equal(rows[:, 10:], legacy[:, 2:])


def test_cohort_trajectory_plain_rows_pad_all_active():
    s = CohortSampler(population=20, cohort=4, seed=1)
    taus = np.array([[2, 1], [3, 0]], np.int32)
    rows = s.cohort_trajectory(taus, round0=7, num_edges=4)
    assert rows.shape == (2, 2 + 8 + 4)
    np.testing.assert_array_equal(rows[0, 2:6], s.draw(7))
    np.testing.assert_array_equal(rows[1, 2:6], s.draw(8))
    assert (rows[:, 6:] == 1).all()
    # empty trajectory keeps the widened row shape.
    assert s.cohort_trajectory(np.zeros((0, 2), np.int32),
                               num_edges=4).shape == (0, 14)


def test_spec_roundtrip_and_validation():
    s = CohortSampler(population=1000, cohort=32, seed=77)
    assert CohortSampler.from_spec(s.to_spec()) == s
    assert abs(s.rate - 0.032) < 1e-12
    import pytest
    with pytest.raises(ValueError):
        CohortSampler(population=4, cohort=5)
    with pytest.raises(ValueError):
        CohortSampler(population=4, cohort=0)
    with pytest.raises(ValueError):
        s.cohort_trajectory(np.zeros((2, 3), np.int32), num_edges=4)
