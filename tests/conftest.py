"""Shared test fixtures + a graceful fallback when hypothesis is absent.

The tier-1 suite must always COLLECT (``pytest -x`` aborts the whole run on
the first collection error, which once hid every later failure behind a
missing ``hypothesis`` wheel). Four modules use hypothesis property tests;
in environments without the package we install a minimal deterministic
stand-in into ``sys.modules`` before those modules import: ``@given`` runs
the test body over a fixed-seed sample of each strategy (bounded at 10
examples) instead of skipping the module — less thorough than real
hypothesis (no shrinking, no example database), but the properties still
execute. Install the real dependency via ``pip install -e .[test]``
(see pyproject.toml) to get full property-based testing.
"""

import random
import sys
import types


def _install_hypothesis_fallback() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))

    def booleans():
        return _Strategy(lambda r: bool(r.getrandbits(1)))

    def given(*strategies, **kw_strategies):
        def decorate(fn):
            def run(*args, **kwargs):
                rng = random.Random(0)
                n = min(getattr(run, "_max_examples", 10), 10)
                for _ in range(n):
                    drawn = [s.sample(rng) for s in strategies]
                    kw_drawn = {name: s.sample(rng)
                                for name, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **kw_drawn)

            # NOT functools.wraps: copying __wrapped__ would expose the
            # strategy-filled params as pytest fixture requests.
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            run._hypothesis_fallback = True
            return run

        return decorate

    def settings(max_examples=10, deadline=None, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn

        return decorate

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies_mod = types.ModuleType("hypothesis.strategies")
    strategies_mod.integers = integers
    strategies_mod.floats = floats
    strategies_mod.sampled_from = sampled_from
    strategies_mod.booleans = booleans
    mod.strategies = strategies_mod
    mod._is_repro_fallback = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies_mod


_install_hypothesis_fallback()
