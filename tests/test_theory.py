"""Proposition 1 bound holds numerically on the analytic quadratic."""
import numpy as np
import pytest

from benchmarks.theory_check import check
from repro.core.topology import fully_connected, ring
from repro.planner.bounds import lr_condition_19, max_eta_19


@pytest.mark.parametrize("tau1,tau2", [(4, 1), (4, 4), (8, 2)])
def test_bound_holds(tau1, tau2):
    m, b = check(tau1=tau1, tau2=tau2, topo=ring(8), rounds=150, seeds=3)
    assert m <= b, f"measured {m} exceeds bound {b}"


def test_sync_special_case():
    m, b = check(tau1=1, tau2=1, topo=fully_connected(8), rounds=150,
                 seeds=3)
    assert m <= b


def test_condition_19_monotone_in_eta():
    topo = ring(8)
    emax = max_eta_19(4, 4, topo)
    assert lr_condition_19(emax * 0.5, 4, 4, topo)
    assert not lr_condition_19(emax * 1.5, 4, 4, topo)


def test_remark1_measured_ordering():
    """Measured gradient average improves with tau2 (Remark 1)."""
    m1, _ = check(tau1=4, tau2=1, topo=ring(8), rounds=200, seeds=3)
    m8, _ = check(tau1=4, tau2=8, topo=ring(8), rounds=200, seeds=3)
    assert m8 < m1
