"""Non-IID partitioners (data/federated.py): exactness, skew, determinism.

This module previously had zero tests; these pin the three properties the
paper's Sec. VI-A setup relies on: every example is assigned exactly once,
alpha -> 0 increases label skew, and a fixed seed is reproducible.
"""
import numpy as np
import pytest

from repro.data.federated import dirichlet_partition, label_shard_partition

NUM = 1200
CLASSES = 10
NODES = 8


@pytest.fixture(scope="module")
def labels():
    rng = np.random.default_rng(42)
    return rng.integers(0, CLASSES, size=NUM).astype(np.int64)


def _assert_exact_partition(parts, n_examples):
    allidx = np.concatenate(parts)
    assert len(allidx) == n_examples
    np.testing.assert_array_equal(np.sort(allidx), np.arange(n_examples))


@pytest.mark.parametrize("alpha", [0.05, 0.5, 100.0])
def test_dirichlet_assigns_every_example_exactly_once(labels, alpha):
    parts = dirichlet_partition(labels, NODES, alpha=alpha, seed=1)
    assert len(parts) == NODES
    _assert_exact_partition(parts, NUM)


@pytest.mark.parametrize("shards", [1, 2, 5])
def test_label_shard_assigns_every_example_exactly_once(labels, shards):
    parts = label_shard_partition(labels, NODES, shards_per_node=shards,
                                  seed=1)
    assert len(parts) == NODES
    _assert_exact_partition(parts, NUM)


def _mean_max_class_fraction(labels, parts):
    """Mean over nodes of the largest single-class share: 1/CLASSES for
    perfectly IID splits, -> 1.0 for single-class nodes."""
    fracs = []
    for idx in parts:
        if len(idx) == 0:
            continue
        counts = np.bincount(labels[idx], minlength=CLASSES)
        fracs.append(counts.max() / counts.sum())
    return float(np.mean(fracs))


def test_dirichlet_skew_increases_as_alpha_shrinks(labels):
    skews = [
        np.mean([_mean_max_class_fraction(
            labels, dirichlet_partition(labels, NODES, alpha=a, seed=s))
            for s in range(5)])
        for a in (100.0, 1.0, 0.05)
    ]
    assert skews[0] < skews[1] < skews[2], skews
    # extremes: near-IID at alpha=100, heavily skewed at alpha=0.05
    assert skews[0] < 0.25
    assert skews[2] > 0.5


def test_label_shard_more_skewed_than_iid(labels):
    parts = label_shard_partition(labels, NODES, shards_per_node=2, seed=3)
    assert _mean_max_class_fraction(labels, parts) > 0.35


def test_deterministic_under_fixed_seed(labels):
    for fn in (lambda s: dirichlet_partition(labels, NODES, 0.3, seed=s),
               lambda s: label_shard_partition(labels, NODES, 2, seed=s)):
        a, b = fn(7), fn(7)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
        c = fn(8)
        assert any(len(x) != len(y) or not np.array_equal(x, y)
                   for x, y in zip(a, c))
