"""The lint layer of the invariant auditor (repro.analysis).

Two kinds of tests:

* the TREE check — ``lint_tree()`` over the shipped ``src/repro`` must be
  clean (no new violations; every suppression carries a reason). This IS
  the tier-1 enforcement: a PR that reintroduces a compat-boundary leak
  or an import-time backend probe fails here.
* per-rule unit tests via ``lint_source`` on small synthetic files with
  fake round-path names, including the deliberate-violation direction
  (each rule actually fires) and the escape hatches (pragma with reason
  suppresses, reasonless pragma is itself flagged, baseline fingerprints
  demote to 'baselined').
"""
import textwrap

from repro.analysis.lint import lint_source, lint_paths, lint_tree
from repro.analysis.rules import RULES


def lint_snippet(src, path):
    return lint_source(textwrap.dedent(src), path)


def rules_of(violations):
    return [v.rule for v in violations]


# ---------------------------------------------------------------------------
# the shipped tree
# ---------------------------------------------------------------------------


def test_source_tree_is_lint_clean():
    report = lint_tree()
    assert report.files_scanned > 30
    assert report.ok, "\n".join(v.render() for v in report.new)


def test_every_suppression_in_tree_has_reason():
    report = lint_tree()
    for s in report.suppressed:
        assert s.reason.strip(), f"reasonless suppression at {s.path}:{s.line}"


def test_registry_covers_shipped_rules():
    expected = {"compat-boundary", "no-import-time-backend-probe",
                "no-host-coercion-of-device-scalars", "rng-discipline",
                "no-disable-jit", "bad-pragma"}
    assert set(RULES) == expected
    for rule in RULES.values():
        assert rule.description


# ---------------------------------------------------------------------------
# compat-boundary
# ---------------------------------------------------------------------------


def test_compat_boundary_flags_shard_map_import_outside_substrate():
    v, _ = lint_snippet(
        """
        from jax.experimental.shard_map import shard_map
        """, "repro/core/mixing.py")
    assert rules_of(v) == ["compat-boundary"]


def test_compat_boundary_flags_axis_size_and_psum_shim_and_check_kwargs():
    v, _ = lint_snippet(
        """
        import jax

        def f(mesh):
            n = jax.lax.axis_size("data")
            m = jax.lax.psum(1, "data")
            g = jax.shard_map(f, mesh=mesh, check_vma=False)
            has = hasattr(jax, "shard_map")
            return n, m, g, has
        """, "repro/launch/steps.py")
    # 5 findings: axis_size, psum(1,..) shim, the jax.shard_map alias,
    # its check_vma kwarg, and the hasattr probe.
    assert sorted(rules_of(v)) == ["compat-boundary"] * 5


def test_compat_boundary_allows_substrate_itself():
    v, _ = lint_snippet(
        """
        import jax
        from jax.experimental.shard_map import shard_map

        def axis_size(axis):
            if hasattr(jax.lax, "axis_size"):
                return jax.lax.axis_size(axis)
            return jax.lax.psum(1, axis)
        """, "repro/core/substrate.py")
    assert v == []


def test_compat_boundary_ignores_psum_of_real_values():
    v, _ = lint_snippet(
        """
        import jax

        def f(x):
            return jax.lax.psum(x, "data")
        """, "repro/core/mixing.py")
    assert v == []


# ---------------------------------------------------------------------------
# no-import-time-backend-probe
# ---------------------------------------------------------------------------


def test_probe_rule_flags_module_scope_devices_call():
    v, _ = lint_snippet(
        """
        import jax
        N_DEV = len(jax.devices())
        """, "repro/kernels/registry.py")
    assert rules_of(v) == ["no-import-time-backend-probe"]


def test_probe_rule_flags_class_body_but_not_function_body():
    v, _ = lint_snippet(
        """
        import jax

        class Cfg:
            backend = jax.default_backend()

        def ok():
            return jax.default_backend()
        """, "repro/launch/train.py")
    assert rules_of(v) == ["no-import-time-backend-probe"]
    assert v[0].line == 5


# ---------------------------------------------------------------------------
# no-host-coercion-of-device-scalars
# ---------------------------------------------------------------------------


def test_host_coercion_flags_int_of_tau_on_round_path():
    v, _ = lint_snippet(
        """
        def round_body(tau2):
            return int(tau2) + 1
        """, "repro/core/dfl.py")
    assert rules_of(v) == ["no-host-coercion-of-device-scalars"]


def test_host_coercion_flags_item_and_np_asarray():
    v, _ = lint_snippet(
        """
        import numpy as np

        def f(taus, state):
            a = taus.item()
            b = np.asarray(state.round_idx)
            return a, b
        """, "repro/core/sharded.py")
    assert sorted(rules_of(v)) == ["no-host-coercion-of-device-scalars"] * 2


def test_host_coercion_ignores_jnp_asarray_and_non_tau_names():
    v, _ = lint_snippet(
        """
        import jax.numpy as jnp

        def f(tau1, lr):
            a = jnp.asarray(tau1)   # device-side: fine
            b = int(lr)             # not a tau name: fine
            return a, b
        """, "repro/core/dfl.py")
    assert v == []


def test_host_coercion_executor_scoped_to_traced_closures():
    # executor.py methods (depth 1) coerce legitimately; only nested
    # closures -- the functions jit traces -- are round code there.
    src = """
    class Ex:
        def dispatch(self, tau1):
            tau1 = int(tau1)          # host-side bounds check: fine

            def superstep(taus):
                return float(taus)    # traced closure: flagged
            return superstep
    """
    v, _ = lint_snippet(src, "repro/core/executor.py")
    assert rules_of(v) == ["no-host-coercion-of-device-scalars"]
    v2, _ = lint_snippet(src, "repro/launch/train.py")
    assert v2 == []  # rule only watches the round path + executor


# ---------------------------------------------------------------------------
# rng-discipline
# ---------------------------------------------------------------------------


def test_rng_rule_flags_raw_key_on_round_path_only():
    src = """
    import jax

    def f():
        return jax.random.PRNGKey(0)
    """
    v, _ = lint_snippet(src, "repro/core/compression.py")
    assert rules_of(v) == ["rng-discipline"]
    v2, _ = lint_snippet(src, "repro/launch/train.py")
    assert v2 == []


def test_rng_rule_allows_fold_in():
    v, _ = lint_snippet(
        """
        import jax

        def f(rng, t):
            return jax.random.fold_in(rng, t)
        """, "repro/core/dfl.py")
    assert v == []


# ---------------------------------------------------------------------------
# no-disable-jit
# ---------------------------------------------------------------------------


def test_disable_jit_rule_scoped_to_kernels():
    src = """
    import jax

    def f():
        with jax.disable_jit():
            pass
    """
    v, _ = lint_snippet(src, "repro/kernels/ops.py")
    assert rules_of(v) == ["no-disable-jit"]
    v2, _ = lint_snippet(src, "repro/core/dfl.py")
    assert v2 == []


# ---------------------------------------------------------------------------
# pragmas + baseline
# ---------------------------------------------------------------------------


def test_pragma_with_reason_suppresses_same_or_previous_line():
    v, s = lint_snippet(
        """
        def round_body(tau2):
            a = int(tau2)  # repro-lint: disable=no-host-coercion-of-device-scalars (static trace-time int)
            # repro-lint: disable=no-host-coercion-of-device-scalars (second form)
            b = int(tau2)
            return a + b
        """, "repro/core/dfl.py")
    assert v == []
    assert len(s) == 2
    assert {x.reason for x in s} == {"static trace-time int", "second form"}


def test_reasonless_pragma_is_bad_and_does_not_suppress():
    v, s = lint_snippet(
        """
        def round_body(tau2):
            return int(tau2)  # repro-lint: disable=no-host-coercion-of-device-scalars
        """, "repro/core/dfl.py")
    assert sorted(rules_of(v)) == ["bad-pragma",
                                   "no-host-coercion-of-device-scalars"]
    assert s == []


def test_pragma_naming_unknown_rule_is_bad():
    v, _ = lint_snippet(
        """
        x = 1  # repro-lint: disable=no-such-rule (because)
        """, "repro/core/dfl.py")
    assert rules_of(v) == ["bad-pragma"]
    assert "no-such-rule" in v[0].message


def test_pragma_does_not_reach_past_code_lines():
    v, _ = lint_snippet(
        """
        def round_body(tau2):
            # repro-lint: disable=no-host-coercion-of-device-scalars (meant for next line only)
            x = 1
            return int(tau2)
        """, "repro/core/dfl.py")
    assert rules_of(v) == ["no-host-coercion-of-device-scalars"]


def test_baseline_demotes_fingerprinted_violations(tmp_path):
    (tmp_path / "core").mkdir()
    bad = tmp_path / "core" / "dfl.py"
    bad.write_text("def round_body(tau2):\n    return int(tau2)\n")
    report = lint_paths([str(bad)], rel_to=str(tmp_path), baseline=set())
    assert len(report.new) == 1 and not report.ok
    fp = report.new[0].fingerprint
    report2 = lint_paths([str(bad)], rel_to=str(tmp_path), baseline={fp})
    assert report2.ok and len(report2.baselined) == 1


def test_shipped_baseline_is_empty():
    # the PR contract: pre-existing violations were fixed or pragma'd,
    # not baselined. A future rule may ship debt here -- visibly.
    from repro.analysis.lint import load_baseline

    assert load_baseline() == set()


def test_report_to_dict_lists_rules():
    report = lint_tree()
    d = report.to_dict()
    assert d["ok"] is True
    assert set(d["rules"]) == set(RULES)
