"""Step builders produce lowerable artifacts for reduced configs on a tiny
host mesh (no 512-device flag needed: 1x1 mesh, everything replicated)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)


def test_reduced_round_lowers_and_runs(mesh):
    arch = REGISTRY["qwen3-1.7b"]
    built = S.build_train_round(arch, "train_4k", mesh, tau1=2, tau2=2,
                                reduced=True)
    # abstract shapes exist
    assert built.meta["nodes"] >= 1
    lowered = built.lower()
    assert lowered is not None


def test_reduced_superstep_lowers(mesh):
    """The fused K-round superstep is a lowerable production artifact:
    donated state carry, a replicated [K, 2] int32 schedule TRAJECTORY
    scanned as xs (round k runs taus[k]), stacked [K] metrics tagged with
    the realized schedule."""
    arch = REGISTRY["qwen3-1.7b"]
    built = S.build_train_superstep(arch, "train_4k", mesh, rounds=2,
                                    tau1_max=3, tau2_max=2, reduced=True)
    assert built.meta["kind"] == "superstep"
    assert built.meta["rounds"] == 2 and built.meta["tau1_max"] == 3
    assert built.meta["schedule"] == "trajectory"
    taus_abs = built.args[-1]
    assert taus_abs.shape == (2, 2) and taus_abs.dtype == jnp.int32
    assert built.lower() is not None


def test_plan_train_schedule_roofline_measured(mesh):
    """use_roofline=True feeds the compiled local step's MEASURED FLOPs
    (and measured collective bytes, when the lowering has any) into the
    planner instead of the 6*P*tokens estimate."""
    arch = REGISTRY["qwen3-1.7b"]
    measured = S.roofline_cost_inputs(arch, "train_4k", mesh, reduced=True)
    assert measured["step_flops"] > 0
    # single-device host mesh mixes in registers: documented 0-collective
    # fallback to the analytic wire size
    assert measured["gossip_collective_bytes"] == 0.0
    p = S.plan_train_schedule(arch, "train_4k", mesh, budget_s=3600.0,
                              reduced=True, use_roofline=True)
    assert p.tau1 >= 1 and p.tau2 >= 1
    assert p.round_cost.time_s > 0


def test_reduced_decode_lowers(mesh):
    arch = REGISTRY["falcon-mamba-7b"]
    built = S.build_decode(arch, "decode_32k", mesh, reduced=True)
    assert built.lower() is not None


def test_reduced_prefill_lowers(mesh):
    arch = REGISTRY["seamless-m4t-medium"]
    built = S.build_prefill(arch, "prefill_32k", mesh, reduced=True)
    assert built.lower() is not None


def test_gossip_step_lowers_and_executes(mesh):
    arch = REGISTRY["granite-moe-1b-a400m"]
    built = S.build_gossip_step(arch, mesh, reduced=True)
    compiled = built.lower().compile()
    assert compiled is not None


def test_memory_tokens_scaling():
    from repro.configs.base import SHAPES

    audio = REGISTRY["seamless-m4t-medium"].model
    vlm = REGISTRY["llama-3.2-vision-90b"].model
    assert S.memory_tokens_for(audio, SHAPES["prefill_32k"]) == 32768 // 4
    assert S.memory_tokens_for(vlm, SHAPES["prefill_32k"]) == 4096


def test_batch_not_divisible_raises(mesh):
    """Global batch must cover the node count."""
    import dataclasses

    from repro.configs.base import InputShape
    arch = REGISTRY["qwen3-1.7b"]
    from repro.launch.sharding import num_nodes_for
    n = num_nodes_for(arch.sharding_mode, mesh, arch.fsdp_nodes)
    assert n >= 1  # on the 1x1 host mesh there's a single node — fine


def test_dryrun_runnable_combos_count():
    total = sum(len(a.shapes()) for a in REGISTRY.values())
    assert total == 33  # 40 assigned minus 7 documented long_500k skips
    skipped = sum(len(a.skip_shapes) for a in REGISTRY.values())
    assert skipped == 7
    for a in REGISTRY.values():
        if a.skip_shapes:
            assert a.skip_reason
