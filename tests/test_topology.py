import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as T


ALL_FAMILIES = [
    lambda: T.ring(10),
    lambda: T.quasi_ring(10),
    lambda: T.paper_quasi_ring(),
    lambda: T.fully_connected(10),
    lambda: T.disconnected(6),
    lambda: T.torus(4, 4),
    lambda: T.hypercube(4),
    lambda: T.star(8),
]


@pytest.mark.parametrize("make", ALL_FAMILIES)
def test_doubly_stochastic_symmetric(make):
    topo = make()
    c = topo.mixing
    assert np.allclose(c.sum(0), 1.0, atol=1e-9)
    assert np.allclose(c.sum(1), 1.0, atol=1e-9)
    assert np.allclose(c, c.T)
    assert (c >= -1e-12).all()


def test_paper_reported_zetas():
    # Sec. VI-A: ring zeta = 0.87, quasi-ring zeta = 0.85.
    assert abs(T.ring(10).zeta - 0.8727) < 5e-4
    assert abs(T.paper_quasi_ring().zeta - 0.85) < 1e-6


def test_zeta_extremes():
    assert T.fully_connected(10).zeta < 1e-12           # C = J
    assert abs(T.disconnected(10).zeta - 1.0) < 1e-12   # C = I


def test_ring_is_circulant_with_two_shifts():
    topo = T.ring(16)
    shifts = topo.shifts()
    assert len(shifts) == 2
    assert {s for s, _ in shifts} == {1, 15}
    assert all(abs(w - 1 / 3) < 1e-12 for _, w in shifts)


def test_torus_circulant_on_ici_mesh():
    topo = T.torus(4, 4)
    assert topo.max_degree == 4
    assert topo.zeta < T.ring(16).zeta  # denser -> better mixing


def test_beta_range():
    for make in ALL_FAMILIES:
        assert 0.0 <= make().beta <= 2.0 + 1e-9


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 20), st.integers(0, 2**31 - 1))
def test_random_graph_valid_confusion(n, seed):
    """Any connected random graph yields a valid C with zeta < 1."""
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n), dtype=np.int64)
    for i in range(n):  # ring backbone keeps it connected
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = 1
    extra = rng.integers(0, n, size=(3, 2))
    for a, b in extra:
        if a != b:
            adj[a, b] = adj[b, a] = 1
    for scheme in ("uniform", "metropolis"):
        topo = T.from_adjacency("rand", adj, scheme)
        topo.validate()
        assert topo.zeta < 1.0 - 1e-9


def test_spectral_gap_consistency():
    topo = T.ring(10)
    assert abs(topo.spectral_gap - (1 - topo.zeta)) < 1e-12
