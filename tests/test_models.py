"""Model-zoo correctness: decode/forward consistency, attention variants,
mamba scan equivalence, MoE vs dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    LayerSpec, ModelConfig, forward, init_params, prefill, decode_step,
)
from repro.models.transformer import _unembed

KW = dict(dtype=jnp.float32, attn_q_chunk=8, attn_kv_chunk=8,
          loss_seq_chunk=8, ssm_chunk=4)


def _dense_cfg(**over):
    base = dict(name="t", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=128, **KW)
    base.update(over)
    return ModelConfig(**base)


@pytest.mark.parametrize("cfg", [
    _dense_cfg(qk_norm=True),
    _dense_cfg(pattern=(LayerSpec(window=8), LayerSpec())),
    ModelConfig(name="ssm", arch_type="ssm", num_layers=2, d_model=64,
                num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0,
                vocab_size=128, ssm_state=8,
                pattern=(LayerSpec(mixer="mamba", ffn="none"),), **KW),
    ModelConfig(name="moe-nodrop", arch_type="moe", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=32,
                vocab_size=128, num_experts=4, experts_per_token=2,
                capacity_factor=8.0, **KW),
], ids=["qknorm", "window", "mamba", "moe"])
def test_decode_matches_forward(cfg):
    """prefill(s) + decode(s+1) logits == full forward logits."""
    params, _ = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 128)
    h, _ = forward(params, toks, cfg, checkpoint=False)
    full = _unembed(params, h, cfg)
    lg_pre, st = prefill(params, {"tokens": toks[:, :11]}, cfg, max_len=16)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(full[:, 10]),
                               rtol=2e-4, atol=2e-4)
    lg_dec, _ = decode_step(params, st, toks[:, 11:12], cfg)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full[:, 11]),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_restricts_attention():
    """A token beyond the window cannot influence the output."""
    cfg = _dense_cfg(num_layers=2, pattern=(LayerSpec(window=4),))
    params, _ = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 16), 0, 128)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % 128)  # mutate pos 0
    h1, _ = forward(params, toks, cfg, checkpoint=False)
    h2, _ = forward(params, toks2, cfg, checkpoint=False)
    # position 15 is > window*layers away only if 0 outside receptive field:
    # receptive field = 2 layers * (4-1) = 6; pos 15 unaffected.
    np.testing.assert_allclose(np.asarray(h1[0, 15]), np.asarray(h2[0, 15]),
                               atol=1e-5)
    # a nearby position IS affected.
    assert float(jnp.max(jnp.abs(h1[0, 2] - h2[0, 2]))) > 1e-6


def test_causality():
    """Future tokens never influence past positions."""
    cfg = _dense_cfg()
    params, _ = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 16), 0, 128)
    toks2 = toks.at[0, 10].set((toks[0, 10] + 1) % 128)
    h1, _ = forward(params, toks, cfg, checkpoint=False)
    h2, _ = forward(params, toks2, cfg, checkpoint=False)
    np.testing.assert_allclose(np.asarray(h1[0, :10]), np.asarray(h2[0, :10]),
                               atol=1e-5)


def test_mamba_chunked_equals_unchunked():
    """The chunked associative scan equals a single-chunk scan."""
    from repro.models import mamba as M

    cfg_small = ModelConfig(name="s", arch_type="ssm", num_layers=1,
                            d_model=32, num_heads=0, num_kv_heads=0,
                            head_dim=0, d_ff=0, vocab_size=64, ssm_state=4,
                            **{**KW, "ssm_chunk": 4})
    cfg_big = ModelConfig(name="s", arch_type="ssm", num_layers=1,
                          d_model=32, num_heads=0, num_kv_heads=0,
                          head_dim=0, d_ff=0, vocab_size=64, ssm_state=4,
                          **{**KW, "ssm_chunk": 16})
    from repro.models.common import ParamFactory, split_annotations
    f = ParamFactory(jax.random.key(0), jnp.float32)
    p, _ = split_annotations(M.mamba_params(f, cfg_small))
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    y1 = M.mamba_mixer(p, x, cfg_small)
    y2 = M.mamba_mixer(p, x, cfg_big)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)


def test_mamba_scan_matches_sequential_decode():
    """Running the full-seq mixer equals stepping the recurrence token by
    token (the decode path)."""
    from repro.models import mamba as M
    from repro.models.common import ParamFactory, split_annotations

    cfg = ModelConfig(name="s", arch_type="ssm", num_layers=1, d_model=32,
                      num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0,
                      vocab_size=64, ssm_state=4, **KW)
    f = ParamFactory(jax.random.key(0), jnp.float32)
    p, _ = split_annotations(M.mamba_params(f, cfg))
    x = jax.random.normal(jax.random.key(1), (1, 8, 32))
    y_full = M.mamba_mixer(p, x, cfg)
    state = M.init_mamba_state(cfg, 1)
    outs = []
    for t in range(8):
        y, state = M.mamba_decode(p, x[:, t:t + 1], cfg, state)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=1e-4, atol=1e-5)


def test_moe_matches_dense_reference_no_drops():
    from repro.models.moe import moe_ffn

    cfg = ModelConfig(name="m", arch_type="moe", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=32,
                      vocab_size=128, num_experts=4, experts_per_token=2,
                      capacity_factor=8.0, **KW)
    params, _ = init_params(cfg, jax.random.key(0))
    pm = {k: v[0] for k, v in params["blocks"][0]["ffn"].items()}
    x = jax.random.normal(jax.random.key(5), (2, 16, 64))
    out, aux = moe_ffn(pm, x, cfg)
    logits = x @ pm["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(4):
        h = jax.nn.silu(x @ pm["w_gate"][e]) * (x @ pm["w_up"][e])
        w = ((gi == e) * gv).sum(-1)
        ref = ref + w[..., None] * (h @ pm["w_down"][e])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 some tokens must be dropped (output norm
    strictly smaller than the undropped reference)."""
    from repro.models.moe import moe_ffn

    mk = lambda cf: ModelConfig(
        name="m", arch_type="moe", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=32, vocab_size=128, num_experts=4,
        experts_per_token=2, capacity_factor=cf, **KW)
    params, _ = init_params(mk(8.0), jax.random.key(0))
    pm = {k: v[0] for k, v in params["blocks"][0]["ffn"].items()}
    x = jax.random.normal(jax.random.key(5), (2, 64, 64))
    full, _ = moe_ffn(pm, x, mk(8.0))
    tight, _ = moe_ffn(pm, x, mk(0.25))
    n_full = float(jnp.sum(jnp.any(full != 0, -1)))
    n_tight = float(jnp.sum(jnp.any(tight != 0, -1)))
    assert n_tight < n_full


def test_checkpointed_forward_matches_uncheckpointed():
    cfg = _dense_cfg()
    params, _ = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 128)
    h1, _ = forward(params, toks, cfg, checkpoint=False)
    h2, _ = forward(params, toks, cfg, checkpoint=True)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5,
                               atol=1e-5)
