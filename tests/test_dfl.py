"""Behavioural tests of the DFL/C-DFL engine against the paper's claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DFLConfig, average_model, c_sgd_config, consensus_distance, d_sgd_config,
    fully_connected, init_state, make_compressor, make_round_fn, mixing,
    replicate, ring, sync_sgd_config,
)
from repro.core.dfl import _communicate_plain
from repro.optim import sgd

N = 8
TARGETS = jnp.linspace(-2.0, 2.0, N)          # non-IID per-node optima
GLOBAL_OPT = float(jnp.mean(TARGETS))


def quad_loss(params, batch, key=None):
    tgt, noise = batch
    return jnp.mean((params["w"] - tgt - noise) ** 2)


def make_batches(key, tau1, scale=0.05):
    noise = jax.random.normal(key, (tau1, N, 4)) * scale
    tgt = jnp.broadcast_to(TARGETS[None, :, None], (tau1, N, 4))
    return (tgt, noise)


def run(cfg, rounds=40, lr=0.1, seed=0, compressed=False):
    opt = sgd(lr)
    st = init_state({"w": jnp.zeros((4,))}, cfg.topology.num_nodes, opt,
                    jax.random.key(seed), compressed=compressed)
    rf = jax.jit(make_round_fn(cfg, quad_loss, opt))
    key = jax.random.key(seed + 1)
    metrics = None
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        st, metrics = rf(st, make_batches(sub, cfg.tau1))
    return st, metrics


def global_gap(st):
    avg = average_model(st.params)
    return float(jnp.mean((avg["w"] - GLOBAL_OPT) ** 2))


def test_dfl_reaches_global_optimum():
    cfg = DFLConfig(tau1=4, tau2=8, topology=ring(N))
    st, _ = run(cfg, rounds=60)
    assert global_gap(st) < 1e-2


def test_more_communication_improves_consensus():
    """Remark 1: consensus distance shrinks monotonically with tau2."""
    cons = []
    for tau2 in (1, 2, 8):
        cfg = DFLConfig(tau1=4, tau2=tau2, topology=ring(N))
        st, m = run(cfg, rounds=30)
        cons.append(float(m["consensus_sq"]))
    assert cons[0] > cons[1] > cons[2]


def test_zeta_zero_beats_sparse_topology_consensus():
    """Remark 2: C = J gives (near-)zero drift."""
    st_full, m_full = run(DFLConfig(tau1=4, tau2=1,
                                    topology=fully_connected(N)), rounds=20)
    st_ring, m_ring = run(DFLConfig(tau1=4, tau2=1, topology=ring(N)),
                          rounds=20)
    assert float(m_full["consensus_sq"]) < 1e-8
    assert float(m_ring["consensus_sq"]) > float(m_full["consensus_sq"])


def test_special_cases_construct():
    assert d_sgd_config(ring(N)).tau == 2
    assert c_sgd_config(5, ring(N)).tau1 == 5
    assert sync_sgd_config(N).topology.zeta < 1e-10


def test_communicate_then_compute_equivalence():
    """Sec. III-C3: both orders give the same averaged-model update."""
    topo = ring(N)
    params = replicate({"w": jnp.arange(4.0)}, N)
    params = jax.tree_util.tree_map(
        lambda x: x + jnp.arange(N)[:, None].astype(x.dtype), params)
    grads = replicate({"w": jnp.ones(4) * 0.1}, N)
    eta = 0.5
    # compute-then-communicate: (X - eta G) C
    a = mixing.mix_dense(
        jax.tree_util.tree_map(lambda p, g: p - eta * g, params, grads),
        topo)
    # communicate-then-compute: X C - eta G
    b = jax.tree_util.tree_map(
        lambda p, g: p - eta * g, mixing.mix_dense(params, topo), grads)
    ua = average_model(a)["w"]
    ub = average_model(b)["w"]
    np.testing.assert_allclose(np.asarray(ua), np.asarray(ub), rtol=1e-6)


def test_dense_power_equals_iterated_dense():
    topo = ring(N)
    params = replicate({"w": jnp.arange(6.0)}, N)
    params = jax.tree_util.tree_map(
        lambda x: x * (1 + jnp.arange(N)[:, None].astype(x.dtype)), params)
    it = params
    for _ in range(5):
        it = mixing.mix_dense(it, topo)
    pw = mixing.mix_dense_power(params, topo, 5)
    np.testing.assert_allclose(np.asarray(it["w"]), np.asarray(pw["w"]),
                               rtol=1e-5)


def test_mixing_preserves_average():
    """C doubly stochastic => the node-average is invariant (eq. 16)."""
    topo = ring(N)
    params = {"w": jax.random.normal(jax.random.key(0), (N, 16))}
    mixed = mixing.mix_dense(params, topo)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(params["w"], 0)),
        np.asarray(jnp.mean(mixed["w"], 0)), atol=1e-5)


@pytest.mark.parametrize("comp", ["qsgd", "top_k", "rand_gossip"])
def test_cdfl_converges(comp):
    cfg = DFLConfig(tau1=2, tau2=4, topology=ring(N),
                    compression=make_compressor(comp), gamma=0.4)
    st, m = run(cfg, rounds=80, lr=0.05, compressed=True)
    assert global_gap(st) < 5e-2
    assert np.isfinite(float(m["loss"]))


def test_cdfl_requires_hat_state():
    cfg = DFLConfig(tau1=1, tau2=1, topology=ring(N),
                    compression=make_compressor("qsgd"))
    opt = sgd(0.1)
    st = init_state({"w": jnp.zeros((4,))}, N, opt, jax.random.key(0),
                    compressed=False)
    rf = make_round_fn(cfg, quad_loss, opt)
    with pytest.raises(AssertionError):
        rf(st, make_batches(jax.random.key(1), 1))


def test_tau2_zero_means_no_mixing():
    cfg = DFLConfig(tau1=2, tau2=0, topology=ring(N))
    st, m = run(cfg, rounds=10)
    # nodes drift to their own targets: consensus distance stays large.
    assert float(m["consensus_sq"]) > 0.1


def test_topology_schedule_cycles():
    """Time-varying topologies: alternating matchings still converge, and
    their UNION being connected suffices even though each individual C is
    disconnected (beyond-paper extension)."""
    from repro.core.topology import from_adjacency
    import numpy as _np
    n = N
    # two perfect matchings whose union is the ring.
    def matching(offset):
        adj = _np.zeros((n, n), dtype=_np.int64)
        for i in range(offset, n, 2):
            j = (i + 1) % n
            adj[i, j] = adj[j, i] = 1
        return from_adjacency(f"match{offset}", adj)

    m0, m1 = matching(0), matching(1)
    assert m0.zeta >= 1.0 - 1e-9            # each alone: disconnected
    cfg = DFLConfig(tau1=2, tau2=2, topology=m0,
                    topology_schedule=(m0, m1))
    st, m = run(cfg, rounds=60, lr=0.08)
    assert global_gap(st) < 5e-2            # union connectivity saves it
    cfg_static = DFLConfig(tau1=2, tau2=2, topology=m0)
    st2, m2 = run(cfg_static, rounds=60, lr=0.08)
    # static disconnected matching never reaches consensus.
    assert float(m2["consensus_sq"]) > float(m["consensus_sq"]) * 5
