"""Optimizers, schedules, checkpointing, data partitioners."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.federated import dirichlet_partition, label_shard_partition
from repro.data.images import SyntheticImages
from repro.optim import (adamw, apply_updates, clip_by_global_norm,
                         momentum_sgd, sgd)
from repro.optim.schedules import cdfl_decay, constant, warmup_cosine


def _quad(opt, steps=200, lr_check=None):
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(steps):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    return float(jnp.linalg.norm(params["w"]))


@pytest.mark.parametrize("opt", [sgd(0.1), momentum_sgd(0.05),
                                 adamw(0.05)], ids=["sgd", "mom", "adamw"])
def test_optimizers_minimize_quadratic(opt):
    assert _quad(opt) < 1e-2


def test_optimizers_vmap_over_nodes():
    opt = momentum_sgd(0.1)
    params = {"w": jnp.ones((5, 3))}          # 5 nodes
    state = jax.vmap(opt.init)(params)
    grads = {"w": jnp.ones((5, 3))}
    updates, state = jax.vmap(opt.update)(grads, state, params)
    assert updates["w"].shape == (5, 3)


def test_schedules():
    s = warmup_cosine(1.0, warmup_steps=10, total_steps=110)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(110))) < 0.05
    d = cdfl_decay(mu=1.0, a=16.0)
    assert abs(float(d(jnp.asarray(0))) - 0.25) < 1e-6  # 4/(mu*a)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 10}
    c = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(c["a"])) - 1.0) < 1e-4


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    d = str(tmp_path)
    save_checkpoint(d, 7, tree, {"loss": 1.0})
    save_checkpoint(d, 12, tree, {"loss": 0.5})
    assert latest_step(d) == 12
    restored, step = restore_checkpoint(d, tree)
    assert step == 12
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": jnp.ones((3,))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"a": jnp.ones((4,))})


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 12), st.floats(0.05, 10.0))
def test_dirichlet_partition_covers_everything(n, alpha):
    labels = np.random.default_rng(0).integers(0, 10, size=500)
    parts = dirichlet_partition(labels, n, alpha, seed=1)
    allidx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(allidx, np.arange(500))


def test_label_shard_is_pathologically_noniid():
    labels = np.repeat(np.arange(10), 50)
    parts = label_shard_partition(labels, 5, shards_per_node=2, seed=0)
    for p in parts:
        assert len(np.unique(labels[p])) <= 4  # few classes per node


def test_synthetic_images_learnable_structure():
    data = SyntheticImages(flavor="mnist", train_size=400, test_size=100,
                           seed=0)
    assert data.train_x.shape == (400, 28, 28, 1)
    # nearest-template classification beats chance by a wide margin.
    t = data._templates.reshape(10, -1)
    x = data.test_x.reshape(100, -1)
    pred = np.argmax(x @ t.T, axis=1)
    assert (pred == data.test_y).mean() > 0.5


def test_metrics_fig3_variance_decays():
    """Fig. 3: coefficient variance decays monotonically with gossip."""
    from repro.core import ring
    from repro.core.metrics import coefficient_variance_trajectory

    v = coefficient_variance_trajectory(ring(5), node=2, steps=12)
    assert all(b <= a + 1e-12 for a, b in zip(v, v[1:]))
    assert v[-1] < v[0] * 0.2


def test_metrics_consensus_error_is_zeta_power():
    from repro.core import ring
    from repro.core.metrics import consensus_error_trajectory

    topo = ring(8)
    traj = consensus_error_trajectory(topo, 6)
    for t, val in enumerate(traj):
        assert abs(val - topo.zeta ** t) < 1e-9
