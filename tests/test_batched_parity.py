"""Differential parity harness: the node-batched engine vs its oracles.

The batched engine's correctness story is parity-by-construction —
``BatchedSubstrate`` gathers the cohort's state rows, runs the SAME
``core.dfl.round_body`` a ``DenseSubstrate`` would, and scatters back —
so at small N, where all three engines can run the same rounds, the
harness asserts it directly:

  * **batched == dense BITWISE** on model state (params / opt_state /
    hat_params), round metrics, and the RNG fold_in discipline, across
    {plain, CHOCO-QSGD, CHOCO-TopK} x {full cohort, sampled
    cohort-as-masks} x {ring, torus}. The loss is noisy (per-node
    jitter keys) so a wrong fold would diverge, not just drift.
  * **batched == sparse at 1e-5** via the existing 8-fake-device
    subprocess pattern (ring only — the sparse engine needs a
    circulant topology). The repo's own dense<->sparse parity is
    tolerance-based (XLA associates reductions differently across
    shard_map boundaries), so the sparse leg inherits that tolerance;
    bitwise is reserved for the dense oracle.
  * **population > cohort**: non-cohort state rows are bitwise FROZEN
    through a sampled round, cohort rows move, and
    ``BatchedSubstrate.node_keys`` folds GLOBAL ids (a slot-indexed
    fold would decouple a node's noise stream from its identity).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BatchedSubstrate, DFLConfig, RoundExecutor,
                        init_state, make_compressor, ring, torus)
from repro.core.substrate import DenseSubstrate
from repro.optim import sgd

DIM = 7
TAU1, TAU2 = 2, 1
K = 3


def noisy_loss(p, b, k=None):
    jitter = 0.05 * jax.random.normal(k, p["w"].shape)
    return jnp.mean((p["w"] + jitter - b) ** 2)


def _compressor(name):
    if name == "qsgd":
        return make_compressor("qsgd", levels=4)
    if name == "top_k":
        return make_compressor("top_k", frac=0.5)
    return None


def _run(engine, topo, taus, comp_name, population=None, seed=1):
    comp = _compressor(comp_name)
    opt = sgd(0.1)
    cfg = DFLConfig(tau1=TAU1, tau2=TAU2, topology=topo, compression=comp,
                    gamma=0.5)
    n = topo.num_nodes
    state = init_state({"w": jnp.zeros((DIM,))}, population or n, opt,
                       jax.random.key(seed), compressed=comp is not None)
    kw = dict(population=population or n) if engine == "batched" else {}
    ex = RoundExecutor(cfg, noisy_loss, opt, engine=engine,
                       participation=engine == "dense", **kw)
    batches = jax.random.normal(jax.random.key(7), (K, TAU1, n, DIM))
    state, metrics = ex.dispatch_trajectory(state, batches, taus)
    return state, metrics


def assert_bitwise(a, b, what=""):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


def _model_state(st):
    return (st.params, st.opt_state, st.hat_params)


def _rows(topo, sampled: bool):
    """(dense participation rows, batched cohort rows) for one matrix
    cell: full = plain [K, 2] both sides; sampled = identity cohort ids
    plus a seeded node-mask draw (cohort-as-masks — same round
    semantics both engines)."""
    n, e = topo.num_nodes, topo.num_edges
    plain = np.tile(np.array([[TAU1, TAU2]], np.int32), (K, 1))
    if not sampled:
        return plain, plain
    rng = np.random.default_rng(3)
    nm = rng.integers(0, 2, (K, n)).astype(np.int32)
    nm[:, 0] = 1   # never a fully-dead round
    ones_e = np.ones((K, e), np.int32)
    ids = np.tile(np.arange(n, dtype=np.int32), (K, 1))
    dense_rows = np.concatenate([plain, nm, ones_e], axis=1)
    batched_rows = np.concatenate([plain, ids, nm, ones_e], axis=1)
    return dense_rows, batched_rows


TOPOLOGIES = {"ring": lambda: ring(8), "torus": lambda: torus(2, 4)}


@pytest.mark.parametrize("comp_name", ["plain", "qsgd", "top_k"])
@pytest.mark.parametrize("sampled", [False, True],
                         ids=["full-cohort", "sampled-as-masks"])
@pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
def test_batched_equals_dense_bitwise(comp_name, sampled, topo_name):
    topo = TOPOLOGIES[topo_name]()
    dense_rows, batched_rows = _rows(topo, sampled)
    sd, md = _run("dense", topo, dense_rows, comp_name)
    sb, mb = _run("batched", topo, batched_rows, comp_name)
    assert_bitwise(_model_state(sd), _model_state(sb),
                   f"model state {topo_name}/{comp_name}")
    assert_bitwise(md, mb, f"metrics {topo_name}/{comp_name}")
    assert int(sb.round_idx) == K


def test_node_keys_fold_global_ids():
    """Cohort slot j's key must be fold_in(key, GLOBAL id), not slot
    index — a node's noise stream follows its identity across draws."""
    topo = ring(4)
    key = jax.random.key(11)
    ids = jnp.array([9, 2, 31, 17], jnp.int32)
    sub = BatchedSubstrate(topo, 32, ids)
    got = sub.node_keys(key)
    want = jnp.stack([jax.random.fold_in(key, int(i)) for i in ids])
    np.testing.assert_array_equal(
        jax.random.key_data(got), jax.random.key_data(want))
    # identity cohort degenerates to the dense fold exactly.
    full = BatchedSubstrate(topo, 4)
    np.testing.assert_array_equal(
        jax.random.key_data(full.node_keys(key)),
        jax.random.key_data(DenseSubstrate(topo).node_keys(key)))


def test_noncohort_rows_bitwise_frozen():
    """V > C: a sampled round must not touch (not even re-serialize
    through an op) any state row outside the cohort."""
    topo = ring(4)
    pop = 16
    opt = sgd(0.1)
    cfg = DFLConfig(tau1=TAU1, tau2=TAU2, topology=topo)
    state = init_state({"w": jnp.zeros((DIM,))}, pop, opt,
                       jax.random.key(2))
    # make rows distinguishable so "frozen" is a real claim.
    state = state._replace(params={"w": jnp.asarray(
        np.random.default_rng(0).normal(size=(pop, DIM)), jnp.float32)})
    before = np.asarray(state.params["w"]).copy()
    ex = RoundExecutor(cfg, noisy_loss, opt, engine="batched",
                       population=pop)
    ids = np.array([1, 5, 8, 14], np.int32)
    rows = np.concatenate([
        np.tile(np.array([[TAU1, TAU2]], np.int32), (K, 1)),
        np.tile(ids, (K, 1)),
        np.ones((K, topo.num_nodes + topo.num_edges), np.int32)], axis=1)
    batches = jax.random.normal(jax.random.key(7),
                                (K, TAU1, topo.num_nodes, DIM))
    out, _ = ex.dispatch_trajectory(state, batches, rows)
    after = np.asarray(out.params["w"])
    others = np.setdiff1d(np.arange(pop), ids)
    np.testing.assert_array_equal(after[others], before[others])
    assert not np.array_equal(after[ids], before[ids])


def test_cohort_trajectory_validation():
    topo = ring(4)
    opt = sgd(0.1)
    cfg = DFLConfig(tau1=TAU1, tau2=TAU2, topology=topo)
    ex = RoundExecutor(cfg, noisy_loss, opt, engine="batched",
                       population=8)
    assert ex.row_width == 2 + 2 * 4 + topo.num_edges
    base = np.tile(np.array([[TAU1, TAU2]], np.int32), (2, 1))
    masks = np.ones((2, 4 + topo.num_edges), np.int32)

    def rows_with(ids_row):
        ids = np.tile(np.asarray(ids_row, np.int32), (2, 1))
        return np.concatenate([base, ids, masks], axis=1)

    with pytest.raises(ValueError, match="unique"):
        ex._check_trajectory(rows_with([1, 1, 2, 3]), 2)
    with pytest.raises(ValueError, match="lie in"):
        ex._check_trajectory(rows_with([0, 1, 2, 8]), 2)
    # [K, 2] auto-pads to the identity cohort, all-active.
    padded = ex._check_trajectory(base, 2)
    np.testing.assert_array_equal(padded[:, 2:6],
                                  np.tile(np.arange(4), (2, 1)))
    assert (padded[:, 6:] == 1).all()
    with pytest.raises(ValueError, match="batched-engine parameter"):
        RoundExecutor(cfg, noisy_loss, opt, engine="dense", population=8)
    with pytest.raises(ValueError, match="population"):
        RoundExecutor(cfg, noisy_loss, opt, engine="batched")


# ---------------------------------------------------------------------------
# sparse leg: 8 fake devices -> subprocess (ring only: sparse needs a
# circulant topology). batched == dense BITWISE in-process there too;
# batched vs sparse inherits the repo's dense<->sparse 1e-5 tolerance.
# ---------------------------------------------------------------------------

SPARSE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.core import DFLConfig, RoundExecutor, init_state, ring
from repro.optim import sgd

N, DIM, TAU1, TAU2, K = 8, 7, 2, 1, 3
mesh = jax.make_mesh((8,), ("data",))
topo = ring(N)
opt = sgd(0.1)

def noisy_loss(p, b, k=None):
    jitter = 0.05 * jax.random.normal(k, p["w"].shape)
    return jnp.mean((p["w"] + jitter - b) ** 2)

def leaves(st):
    return jax.tree_util.tree_leaves((st.params, st.opt_state))

cfg = DFLConfig(tau1=TAU1, tau2=TAU2, topology=topo)
batches = jax.random.normal(jax.random.key(7), (K, TAU1, N, DIM))
taus = np.tile(np.array([[TAU1, TAU2]], np.int32), (K, 1))

def run(engine, **kw):
    st = init_state({"w": jnp.zeros((DIM,))}, N, opt, jax.random.key(1))
    ex = RoundExecutor(cfg, noisy_loss, opt, engine=engine, **kw)
    st, m = ex.dispatch_trajectory(st, batches, taus)
    return st, m

sd, md = run("dense", participation=True)
sb, mb = run("batched", population=N)
ss, ms = run("sparse", mesh=mesh, node_axes=("data",))

for x, y in zip(leaves(sd), leaves(sb)):
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
np.testing.assert_array_equal(np.asarray(md["loss"]), np.asarray(mb["loss"]))
print("BATCHED_DENSE_BITWISE_OK")

err = max(float(jnp.max(jnp.abs(x - y)))
          for x, y in zip(leaves(sb), leaves(ss)))
assert err < 1e-5, f"batched vs sparse: {err}"
merr = float(np.max(np.abs(np.asarray(mb["loss"]) - np.asarray(ms["loss"]))))
assert merr < 1e-5, f"metrics: {merr}"
print("BATCHED_SPARSE_TOL_OK", err)
"""


@pytest.mark.slow
def test_batched_parity_sparse_leg():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SPARSE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ["BATCHED_DENSE_BITWISE_OK", "BATCHED_SPARSE_TOL_OK"]:
        assert tag in out.stdout, (tag, out.stdout, out.stderr[-2000:])
