import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compression as C


OPS = [
    C.Identity(),
    C.TopK(frac=0.3),
    C.TopK(frac=0.7),
    C.RandK(frac=0.5),
    C.QSGD(levels=8),
    C.QSGD(levels=64),
    C.RandomizedGossip(p=0.8),
]


@pytest.mark.parametrize("comp", OPS, ids=lambda c: f"{c.name}")
def test_assumption2_in_expectation(comp):
    """E_Q ||Q(x)-x||^2 <= (1-delta) ||x||^2  (paper Assumption 2)."""
    key = jax.random.key(0)
    x = jax.random.normal(jax.random.key(1), (512,))
    nx2 = float(jnp.sum(x * x))
    d = x.size
    errs = []
    for i in range(40):
        q = comp(x, jax.random.fold_in(key, i))
        errs.append(float(jnp.sum((q - x) ** 2)))
    bound = (1.0 - comp.delta(d)) * nx2
    # 10% statistical slack for the stochastic operators.
    assert np.mean(errs) <= bound * 1.10 + 1e-6, (np.mean(errs), bound)


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.3, 4.0, -0.2, 0.05])
    q = C.TopK(frac=0.3)(x, None)  # k = ceil(1.8) = 2
    assert float(q[1]) == -5.0 and float(q[3]) == 4.0
    assert float(jnp.sum(q != 0)) == 2


def test_randk_keeps_exactly_k():
    x = jnp.ones((100,))
    q = C.RandK(frac=0.25)(x, jax.random.key(0))
    assert int(jnp.sum(q != 0)) == 25


def test_qsgd_unbiasedness_scaledown():
    """Rescaled QSGD contracts toward 0 but preserves sign & magnitude order."""
    x = jnp.asarray([1.0, -2.0, 4.0, -8.0] * 64)
    q = C.QSGD(levels=64)(x, jax.random.key(0))
    assert float(jnp.max(jnp.abs(q))) <= float(jnp.max(jnp.abs(x))) + 1e-5
    mask = jnp.abs(q) > 0
    assert bool(jnp.all(jnp.sign(q[mask]) == jnp.sign(x[mask])))


def test_rand_gossip_all_or_nothing():
    x = jnp.arange(16.0)
    seen = set()
    for i in range(30):
        q = C.RandomizedGossip(p=0.5)(x, jax.random.key(i))
        zero = bool(jnp.all(q == 0))
        full = bool(jnp.all(q == x))
        assert zero or full
        seen.add(zero)
    assert seen == {True, False}  # both outcomes occur


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 400), st.sampled_from(["top_k", "rand_k", "qsgd",
                                             "rand_gossip"]))
def test_delta_in_unit_interval(d, name):
    comp = C.make_compressor(name)
    assert 0.0 < comp.delta(d) <= 1.0


def test_wire_bits_ordering():
    """Compression must reduce wire bits vs fp32 identity."""
    tree = {"a": jnp.zeros((1000,)), "b": jnp.zeros((50, 50))}
    full = C.tree_wire_bits(C.Identity(), tree)
    assert C.tree_wire_bits(C.TopK(frac=0.1), tree) < full
    assert C.tree_wire_bits(C.QSGD(levels=16), tree) < full
    assert C.tree_wire_bits(C.RandomizedGossip(p=0.5), tree) == full * 0.5


def test_compress_tree_structure_preserved():
    tree = {"a": jnp.ones((7,)), "b": {"c": jnp.ones((3, 3))}}
    out = C.compress_tree(C.TopK(frac=0.5), tree, jax.random.key(0))
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(tree)
