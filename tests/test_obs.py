"""Telemetry subsystem (repro.obs): schema, sink, trace export, report.

Covers the three pillars end-to-end:

* events: the typed schema accepts well-formed records and rejects
  unknown types / missing per-type data keys; streams round-trip
  through JSONL; ``validate_stream`` enforces the run header.
* telemetry: spans stamp monotonic (perf_counter) times at scope ENTRY,
  emits are thread-safe, the optional JSONL file mirrors memory, and
  ``NullTelemetry`` is a true no-op with the same surface.
* trace/report/history: Chrome trace-event export keeps one named
  track per concern, the report aggregates spans and counters, and
  ``history_view`` derives the legacy --history-out contract.

The acceptance surface — an 8-node ring smoke session through the real
train CLI whose exported trace carries >= 4 named tracks and whose
round/plan/compile events survive the schema validator — runs last.
"""
import json
import subprocess
import sys
import threading
import time

import pytest

from repro.obs import (
    EVENT_TYPES, HISTORY_SCHEMA_VERSION, SCHEMA_VERSION, NullTelemetry,
    Telemetry, export_chrome_trace, history_view, make_event, read_events,
    run_report, format_report, to_chrome_trace, trace_track_names,
    validate_event, validate_events, validate_stream, write_events)


# ---------------------------------------------------------------------------
# event schema
# ---------------------------------------------------------------------------


def test_make_event_validates_and_round_trips():
    ev = make_event("round", 1.25, "rounds", name="round-3",
                    data={"round": 3, "tau1": 2, "tau2": 1, "round_s": 0.1})
    assert validate_event(ev) == []
    assert ev["type"] == "round" and ev["t"] == 1.25
    assert json.loads(json.dumps(ev)) == ev


def test_validate_event_rejects_unknown_type_and_missing_keys():
    bad_type = make_event("explosion", 0.0, "run")
    assert any("type" in p for p in validate_event(bad_type))
    # each type's REQUIRED_DATA keys are mandatory: a round without taus
    # is a malformed record, not a partial one.
    bad_data = make_event("round", 0.0, "rounds", data={"round": 1})
    probs = validate_event(bad_data)
    assert any("tau1" in p for p in probs)
    # spans additionally need a name and a duration.
    bad_span = make_event("span", 0.0, "dispatch")
    probs = validate_event(bad_span)
    assert any("name" in p for p in probs) and any("dur" in p for p in probs)


def test_validate_stream_requires_run_header():
    ev = make_event("superstep", 0.1, "dispatch", data={"k": 4})
    assert validate_stream([]) != []
    assert validate_stream([ev]) != []      # first record must be "run"
    run = make_event("run", 0.0, "run",
                     data={"schema": SCHEMA_VERSION,
                           "wall_start": 1700000000.0})
    assert validate_stream([run, ev]) == []
    stale = make_event("run", 0.0, "run",
                       data={"schema": SCHEMA_VERSION + 99,
                             "wall_start": 0.0})
    assert any("schema" in problem
               for _, problem in validate_stream([stale, ev]))


def test_jsonl_write_read_round_trip(tmp_path):
    evs = [make_event("run", 0.0, "run",
                      data={"schema": SCHEMA_VERSION, "wall_start": 1.0}),
           make_event("compile", 0.5, "dispatch", name="trace",
                      data={"count": 1})]
    p = tmp_path / "events.jsonl"
    write_events(str(p), evs)
    assert read_events(str(p)) == evs
    p.write_text(p.read_text() + "{not json\n")
    with pytest.raises(ValueError, match=r":3: malformed"):
        read_events(str(p))


# ---------------------------------------------------------------------------
# the sink
# ---------------------------------------------------------------------------


def test_telemetry_emits_run_header_and_monotonic_stamps():
    tel = Telemetry(meta={"run": "unit"})
    tel.emit("superstep", track="dispatch", name="superstep-k4", k=4)
    evs = tel.events
    assert evs[0]["type"] == "run"
    assert evs[0]["data"]["schema"] == SCHEMA_VERSION
    assert evs[0]["data"]["run"] == "unit"   # meta merges into the header
    assert validate_stream(evs) == []
    # t is seconds since the sink's perf_counter origin: small, not epoch.
    assert 0.0 <= evs[1]["t"] < 60.0


def test_telemetry_span_stamps_entry_time_and_duration():
    tel = Telemetry()
    with tel.span("gossip-flush", track="dispatch", rounds=4):
        time.sleep(0.02)
    ev = tel.events[-1]
    assert ev["type"] == "span" and ev["name"] == "gossip-flush"
    assert ev["dur"] >= 0.02
    assert ev["data"]["rounds"] == 4
    # t is the span START: the event lands at scope exit, stamped at entry.
    assert ev["t"] + ev["dur"] <= tel.now() + 1e-9


def test_telemetry_span_records_even_when_body_raises():
    tel = Telemetry()
    with pytest.raises(RuntimeError):
        with tel.span("doomed", track="run"):
            raise RuntimeError("boom")
    assert tel.events[-1]["name"] == "doomed"


def test_telemetry_jsonl_file_mirrors_memory(tmp_path):
    p = tmp_path / "tel.jsonl"
    with Telemetry(path=str(p)) as tel:
        tel.emit("checkpoint", track="checkpoint", round=2)
        in_memory = tel.events
    assert read_events(str(p)) == in_memory
    assert validate_stream(in_memory) == []


def test_telemetry_concurrent_emits_are_not_lost():
    tel = Telemetry()

    def worker(i):
        for j in range(50):
            tel.emit("prefetch", track="prefetch", name=f"w{i}",
                     action="build")

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tel.events
    assert len(evs) == 1 + 4 * 50
    assert validate_events(evs) == []


def test_null_telemetry_is_a_no_op_with_the_same_surface():
    tel = NullTelemetry()
    tel.emit("round", track="rounds", round=0, tau1=1, tau2=1, round_s=0.0)
    with tel.span("anything", track="run"):
        pass
    assert tel.events == []
    assert tel.now() >= 0.0
    tel.close()


# ---------------------------------------------------------------------------
# trace export + report + history view
# ---------------------------------------------------------------------------


def _sample_events():
    tel = Telemetry(meta={"run": "sample"})
    with tel.span("warmup", track="dispatch"):
        pass
    tel.emit("compile", track="dispatch", name="superstep-trace-dynamic",
             count=1)
    tel.emit("superstep", track="dispatch", name="superstep-k4",
             dur=0.2, k=4)
    tel.emit("plan", track="planner", name="initial", tau1=2, tau2=1,
             cause="initial", round=0)
    tel.emit("round", track="rounds", name="round-0", round=0, tau1=2,
             tau2=1, loss=2.0, consensus_sq=0.5, round_s=0.05)
    tel.emit("round", track="rounds", name="round-1", round=1, tau1=2,
             tau2=1, loss=1.5, consensus_sq=0.4, round_s=0.05)
    tel.emit("flush", track="metrics", name="metrics-flush", dur=0.01,
             rounds=2)
    tel.emit("counters", track="dispatch", name="superstep-counters",
             compile_count=1, kernel_pallas_calls=3)
    tel.emit("counters", track="run", name="run-summary",
             schedule_mode="fixed", compile_count_warmup=1,
             compile_count=1, kernel_pallas_calls=2)
    return tel.events


def test_chrome_trace_has_named_tracks_slices_and_instants():
    trace = to_chrome_trace(_sample_events())
    names = set(trace_track_names(trace))
    assert {"dispatch", "planner", "rounds", "metrics"} <= names
    assert len(names) >= 4
    slices = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert any(s["name"] == "superstep-k4" and s["dur"] == pytest.approx(2e5)
               for s in slices)
    instants = [e for e in trace["traceEvents"] if e["ph"] == "i"]
    assert any(i["name"] == "round-0" for i in instants)
    # every non-metadata event maps to a declared track tid.
    tids = {e["tid"] for e in trace["traceEvents"]
            if e["ph"] == "M" and e["name"] == "thread_name"}
    assert all(e["tid"] in tids for e in trace["traceEvents"])


def test_export_chrome_trace_writes_loadable_json(tmp_path):
    p = tmp_path / "trace.json"
    export_chrome_trace(_sample_events(), str(p))
    trace = json.loads(p.read_text())
    assert len(trace_track_names(trace)) >= 4


def test_run_report_aggregates_spans_counters_and_rounds():
    rep = run_report(_sample_events())
    assert rep["rounds"]["rounds"] == 2
    assert rep["rounds"]["loss_first"] == 2.0
    assert rep["rounds"]["loss_last"] == 1.5
    assert rep["plans"]["initial"] == 1
    # kernel_* counter keys SUM across snapshots; others are last-wins.
    assert rep["counters"]["kernel_pallas_calls"] == 5
    assert rep["counters"]["compile_count"] == 1
    text = format_report(rep)
    assert "rounds" in text and "kernel_pallas_calls" in text


def test_history_view_reproduces_legacy_contract():
    h = history_view(_sample_events())
    assert h["schema_version"] == HISTORY_SCHEMA_VERSION
    assert h["round"] == [1, 2]              # 1-based, like the old dict
    assert h["tau1"] == [2, 2] and h["tau2"] == [1, 1]
    assert h["loss"] == [2.0, 1.5]
    assert h["schedule"] == [[2, 1], [2, 1]]
    assert h["plan_events"][0]["cause"] == "initial"
    assert h["schedule_mode"] == "fixed"
    assert h["compile_count"] == 1 and h["compile_count_warmup"] == 1


# ---------------------------------------------------------------------------
# CLI: python -m repro.obs {validate, trace export, report}
# ---------------------------------------------------------------------------


def _run_obs_cli(args, cwd):
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run([sys.executable, "-m", "repro.obs", *args],
                          env=env, cwd=cwd, capture_output=True, text=True,
                          timeout=120)


def test_obs_cli_validate_trace_report(tmp_path):
    src = tmp_path / "events.jsonl"
    write_events(str(src), _sample_events())

    ok = _run_obs_cli(["validate", str(src), "--min-tracks", "4"], tmp_path)
    assert ok.returncode == 0, ok.stdout + ok.stderr

    out = tmp_path / "trace.json"
    tr = _run_obs_cli(["trace", "export", str(src), "--out", str(out)],
                      tmp_path)
    assert tr.returncode == 0, tr.stdout + tr.stderr
    assert len(trace_track_names(json.loads(out.read_text()))) >= 4

    rep_json = tmp_path / "report.json"
    rp = _run_obs_cli(["report", str(src), "--json", str(rep_json)],
                      tmp_path)
    assert rp.returncode == 0, rp.stdout + rp.stderr
    assert json.loads(rep_json.read_text())["rounds"]["rounds"] == 2


def test_obs_cli_validate_rejects_bad_stream(tmp_path):
    src = tmp_path / "bad.jsonl"
    # no run header: a truncated/hand-rolled stream must not validate.
    write_events(str(src), [make_event("superstep", 0.0, "dispatch",
                                       data={"k": 2})])
    bad = _run_obs_cli(["validate", str(src)], tmp_path)
    assert bad.returncode != 0


# ---------------------------------------------------------------------------
# acceptance: 8-ring smoke session through the real train CLI
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_train_cli_eight_ring_telemetry_session(tmp_path):
    """--telemetry-out on an 8-node ring session: the stream validates,
    the derived history matches the legacy contract, and the exported
    Chrome trace carries >= 4 named tracks."""
    from repro.launch import train as train_cli

    events_out = tmp_path / "events.jsonl"
    hist_out = tmp_path / "hist.json"
    train_cli.main([
        "--arch", "qwen3-1.7b", "--nodes", "8", "--topology", "ring",
        "--rounds", "3", "--batch", "1", "--seq", "16",
        "--plan-budget", "3600", "--replan-every", "1", "--log-every", "10",
        "--telemetry-out", str(events_out), "--history-out", str(hist_out)])

    evs = read_events(str(events_out))
    assert validate_stream(evs) == []
    types = {e["type"] for e in evs}
    # round/plan/compile all make the round trip through the validator.
    assert {"run", "round", "plan", "compile", "superstep",
            "counters"} <= types
    rounds = [e for e in evs if e["type"] == "round"]
    assert len(rounds) == 3
    assert all("wire_bits" in e["data"] for e in rounds)

    trace_out = tmp_path / "trace.json"
    export_chrome_trace(evs, str(trace_out))
    assert len(trace_track_names(json.loads(trace_out.read_text()))) >= 4

    # the --history-out file is the derived view of the same stream.
    h = json.loads(hist_out.read_text())
    assert h == history_view(evs)
    assert h["round"] == [1, 2, 3]
    assert h["compile_count"] >= 1
