"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SHAPES = [(64,), (1000,), (256, 128), (3, 5, 7), (32768,), (300, 70)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_qsgd_matches_ref(shape, dtype):
    k1, k2 = jax.random.split(jax.random.key(hash(shape) % 2**31))
    x = (jax.random.normal(k1, shape, jnp.float32) * 3).astype(dtype)
    noise = jax.random.uniform(k2, shape)
    d = int(np.prod(shape))
    s = 16.0
    c = 1.0 + min(d / (s * s), d**0.5 / s)
    got = ops.qsgd_quantize(x, noise, levels=16, interpret=True)
    want = ref.qsgd_ref(x, noise, levels=16, c=c)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("deg", [1, 2, 4])
def test_gossip_mix_matches_ref(shape, deg):
    key = jax.random.key(deg)
    x = jax.random.normal(jax.random.fold_in(key, 0), shape)
    nbrs = jax.random.normal(jax.random.fold_in(key, 1), (deg,) + shape)
    w = jnp.concatenate([jnp.asarray([0.5]),
                         jnp.full((deg,), 0.5 / deg)])
    got = ops.gossip_mix(x, nbrs, w, interpret=True)
    want = ref.gossip_mix_ref(x, nbrs, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_choco_move_matches_ref(shape, dtype):
    key = jax.random.key(7)
    x = jax.random.normal(jax.random.fold_in(key, 0), shape).astype(dtype)
    y = jax.random.normal(jax.random.fold_in(key, 1), shape).astype(dtype)
    my = jax.random.normal(jax.random.fold_in(key, 2), shape).astype(dtype)
    xg, dg = ops.choco_move(x, y, my, 0.37, interpret=True)
    xw, dw = ref.choco_move_ref(x, y, my, 0.37)
    # bf16 outputs can differ by one ulp from rounding order.
    tol = 1e-4 if dtype == jnp.float32 else 8e-3
    np.testing.assert_allclose(np.asarray(xg, np.float32),
                               np.asarray(xw, np.float32), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(dg, np.float32),
                               np.asarray(dw, np.float32), rtol=tol,
                               atol=tol)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5000), st.integers(0, 2**31 - 1))
def test_qsgd_property_random_sizes(n, seed):
    """Property sweep: arbitrary vector lengths (padding path) match ref."""
    k1, k2 = jax.random.split(jax.random.key(seed))
    x = jax.random.normal(k1, (n,))
    noise = jax.random.uniform(k2, (n,))
    s = 8.0
    c = 1.0 + min(n / (s * s), n**0.5 / s)
    got = ops.qsgd_quantize(x, noise, levels=8, interpret=True)
    want = ref.qsgd_ref(x, noise, levels=8, c=c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_qsgd_kernel_agrees_with_library_compressor():
    """The kernel implements the same Q as core.compression.QSGD (same
    noise => identical output)."""
    from repro.core.compression import QSGD

    n = 4096
    x = jax.random.normal(jax.random.key(0), (n,))
    key = jax.random.key(42)
    noise = jax.random.uniform(key, (n,))
    got = ops.qsgd_quantize(x, noise, levels=16, interpret=True)

    # re-derive library output with identical noise by monkey-path-free math
    want = ref.qsgd_ref(x, noise, levels=16,
                        c=1.0 + min(n / 256.0, n**0.5 / 16.0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    comp = QSGD(levels=16)
    assert abs(comp.delta(n) - 1.0 / (1.0 + min(n / 256.0, n**0.5 / 16.0))) < 1e-12


# ---------------------------------------------------------------------------
# TopK kernel (two-pass candidate select + mask)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_topk_bitwise_matches_ref(shape, dtype):
    x = jax.random.normal(
        jax.random.key(hash(shape) % 2**31), shape).astype(dtype)
    for k in {1, max(1, int(np.prod(shape)) // 3), int(np.prod(shape))}:
        got = ops.top_k_compress(x, k, interpret=True)
        want = ref.top_k_ref(x, k)
        assert jnp.array_equal(got, want), (shape, dtype, k)


def test_topk_matches_library_compressor_bitwise():
    """TopK(use_kernels=True) is the SAME operator as the reference
    TopK — flipping the flag can never change a trajectory."""
    from repro.core.compression import TopK

    for shape in [(1000,), (300, 70), (32769,)]:
        x = jax.random.normal(jax.random.key(3), shape)
        for frac in (0.01, 0.25, 1.0):
            want = TopK(frac=frac)(x, None)
            got = TopK(frac=frac, use_kernels=True)(x, None)
            assert jnp.array_equal(got, want), (shape, frac)


def test_topk_tie_handling():
    """Ties AT the threshold are kept inclusively, exactly like the
    reference (which may keep more than k coordinates)."""
    x = jnp.asarray([2.0, -2.0, 2.0, 0.5, -0.25, 2.0, 0.0, -2.0, 1.0])
    for k in range(1, x.size + 1):
        got = ops.top_k_compress(x, k, interpret=True)
        want = ref.top_k_ref(x, k)
        assert jnp.array_equal(got, want), k
    # all-tied vector: any k keeps everything
    t = jnp.full((300,), -1.5)
    assert jnp.array_equal(ops.top_k_compress(t, 7, interpret=True), t)


def test_topk_k_equals_d_is_identity():
    x = jax.random.normal(jax.random.key(5), (257,))
    assert jnp.array_equal(ops.top_k_compress(x, 257, interpret=True), x)


def test_topk_zero_vector_and_threshold_zero():
    z = jnp.zeros((100,))
    assert jnp.array_equal(ops.top_k_compress(z, 10, interpret=True), z)
    # true threshold 0: zeros padding can't perturb the selection
    x = jnp.concatenate([jnp.asarray([3.0, -2.0]), jnp.zeros((98,))])
    got = ops.top_k_compress(x, 50, interpret=True)
    assert jnp.array_equal(got, ref.top_k_ref(x, 50))


def test_topk_out_of_range_k_raises():
    x = jnp.ones((8,))
    with pytest.raises(ValueError, match="out of range"):
        ops.top_k_compress(x, 9, interpret=True)
    with pytest.raises(ValueError, match="out of range"):
        ops.top_k_compress(x, 0, interpret=True)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5000), st.integers(0, 2**31 - 1))
def test_topk_property_random_sizes(n, seed):
    """Non-tile-multiple sizes (padding path) stay bitwise vs ref."""
    key = jax.random.key(seed)
    x = jax.random.normal(key, (n,))
    k = 1 + seed % n
    got = ops.top_k_compress(x, k, interpret=True)
    want = ref.top_k_ref(x, k)
    assert jnp.array_equal(got, want), (n, k)


# ---------------------------------------------------------------------------
# Fused CHOCO compress-and-move
# ---------------------------------------------------------------------------


def _fused_inputs(shape, dtype, seed=5):
    key = jax.random.key(seed)
    x, y, my = (jax.random.normal(jax.random.fold_in(key, i),
                                  shape).astype(dtype) for i in range(3))
    noise = jax.random.uniform(jax.random.fold_in(key, 9), shape)
    return x, y, my, noise


@pytest.mark.parametrize("shape", [(64,), (1000,), (3, 5, 7), (32769,)])
def test_choco_qsgd_fused_equals_unfused_f32(shape):
    """The fused kernel reproduces the unfused
    choco_move -> qsgd_quantize -> add chain: x_new bitwise, y_new to
    one f32 ulp (the final sign*norm*lvl/(s*c) multiply chain rounds
    differently across separately-compiled kernels on XLA:CPU — the
    quantization LEVEL picked is identical, only the last bit of the
    reconstruction can differ)."""
    x, y, my, noise = _fused_inputs(shape, jnp.float32)

    @jax.jit
    def fused(x, y, my, noise):
        return ops.choco_qsgd_move(x, y, my, 0.5, noise, levels=16,
                                   interpret=True)

    @jax.jit
    def unfused(x, y, my, noise):
        x_new, d = ops.choco_move(x, y, my, 0.5, interpret=True)
        q = ops.qsgd_quantize(d, noise, levels=16, interpret=True)
        return x_new, y + q

    xf, yf = fused(x, y, my, noise)
    xu, yu = unfused(x, y, my, noise)
    assert jnp.array_equal(xf, xu)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yu), rtol=3e-7,
                               atol=3e-7)


@pytest.mark.parametrize("shape", [(64,), (1000,), (3, 5, 7), (32769,)])
def test_choco_topk_fused_equals_unfused_f32(shape):
    """Bitwise: the fused TopK kernel masks the SAME materialized diff
    tensor its threshold was selected from, so the kept set cannot drift
    (see choco_fused.choco_topk_2d)."""
    x, y, my, _ = _fused_inputs(shape, jnp.float32)
    k = max(1, int(np.prod(shape)) // 4)

    @jax.jit
    def fused(x, y, my):
        return ops.choco_topk_move(x, y, my, 0.5, k, interpret=True)

    @jax.jit
    def unfused(x, y, my):
        x_new, d = ops.choco_move(x, y, my, 0.5, interpret=True)
        return x_new, y + ops.top_k_compress(d, k, interpret=True)

    xf, yf = fused(x, y, my)
    xu, yu = unfused(x, y, my)
    assert jnp.array_equal(xf, xu)
    assert jnp.array_equal(yf, yu)


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_choco_fused_matches_oracle(dtype):
    shape = (300, 70)
    x, y, my, noise = _fused_inputs(shape, dtype)
    d = int(np.prod(shape))
    s = 16.0
    c = 1.0 + min(d / (s * s), d**0.5 / s)
    tol = 1e-5 if dtype == jnp.float32 else 1e-2
    got = ops.choco_qsgd_move(x, y, my, 0.5, noise, levels=16,
                              interpret=True)
    want = ref.choco_qsgd_ref(x, y, my, 0.5, noise, levels=16, c=c)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), rtol=tol,
                                   atol=tol)
    got = ops.choco_topk_move(x, y, my, 0.5, d // 4, interpret=True)
    want = ref.choco_topk_ref(x, y, my, 0.5, d // 4)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), rtol=tol,
                                   atol=tol)


def test_fused_choco_fewer_buffer_passes():
    """The reason the fused kernel exists: fewer pad round-trips AND
    fewer kernel launches than the unfused composition (counted on the
    un-jitted wrapper bodies, where the counters tick per call)."""
    x, y, my, noise = _fused_inputs((3, 5, 7), jnp.float32)

    with ops.op_stats_delta() as fused:
        ops.eager_impl("choco_qsgd_move")(x, y, my, 0.5, noise, levels=16,
                                          interpret=True)
    with ops.op_stats_delta() as unfused:
        _, d = ops.eager_impl("choco_move")(x, y, my, 0.5, interpret=True)
        ops.eager_impl("qsgd_quantize")(d, noise, levels=16, interpret=True)
    assert fused["pallas_calls"] < unfused["pallas_calls"], (
        fused.as_dict(), unfused.as_dict())
    assert fused["pad_roundtrips"] < unfused["pad_roundtrips"], (
        fused.as_dict(), unfused.as_dict())

    with ops.op_stats_delta() as fused:
        ops.eager_impl("choco_topk_move")(x, y, my, 0.5, k=26,
                                          tmode="interpret", interpret=True)
    with ops.op_stats_delta() as unfused:
        _, d = ops.eager_impl("choco_move")(x, y, my, 0.5, interpret=True)
        ops.eager_impl("top_k_compress")(d, k=26, tmode="interpret",
                                         imask=True)
    assert fused["pallas_calls"] < unfused["pallas_calls"], (
        fused.as_dict(), unfused.as_dict())
    assert fused["pad_roundtrips"] < unfused["pad_roundtrips"], (
        fused.as_dict(), unfused.as_dict())


def test_op_stats_delta_scoping_and_reset_deprecation():
    """Snapshot/delta attribution: nested scopes each see their own
    window, reading an open scope raises, and the old global
    ``reset_op_stats`` warns (it races concurrent scopes)."""
    x, y, my, _noise = _fused_inputs((2, 3), jnp.float32)
    with ops.op_stats_delta() as outer:
        ops.eager_impl("choco_move")(x, y, my, 0.5, interpret=True)
        with pytest.raises(RuntimeError, match="still open"):
            outer.as_dict()
        with ops.op_stats_delta() as inner:
            ops.eager_impl("choco_move")(x, y, my, 0.5, interpret=True)
    # choco_move pads x, y, mixed_y: 3 round-trips, 1 launch per call.
    assert inner.as_dict() == {"pad_roundtrips": 3, "pallas_calls": 1}
    assert outer.pad_roundtrips == 6 and outer.pallas_calls == 2
    before = ops.op_stats()
    with pytest.warns(DeprecationWarning, match="op_stats_delta"):
        ops.reset_op_stats()
    assert ops.op_stats() == {k: 0 for k in before}


# ---------------------------------------------------------------------------
# Registry: lazy backend detection + per-op dispatch guards
# ---------------------------------------------------------------------------


def test_backend_detection_is_lazy(monkeypatch):
    """The ISSUE-5 fix: backend choice is read at CALL time, so a backend
    that initializes after `import repro.kernels` still gets Mosaic
    dispatch (the old ops.ON_TPU import-time constant pinned interpret
    mode forever)."""
    from repro.kernels import registry

    try:
        registry.reset_backend_cache()
        monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
        assert registry.on_tpu()
        assert registry.resolve_mode("qsgd_quantize", None) == "mosaic"
        assert registry.resolve_mode("choco_qsgd", None) == "mosaic"
        # ops Mosaic can't lower fall back to plain XLA on TPU
        assert registry.resolve_mode("topk_partials", None) == "fallback"
        # the cache holds until reset
        monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
        assert registry.on_tpu()
        registry.reset_backend_cache()
        assert not registry.on_tpu()
        assert registry.resolve_mode("qsgd_quantize", None) == "interpret"
        # explicit interpret always wins
        assert registry.resolve_mode("qsgd_quantize", True) == "interpret"
        assert registry.resolve_mode("topk_partials", False) == "mosaic"
    finally:
        registry.reset_backend_cache()


def test_topk_tpu_fallback_mode_is_bitwise():
    """The plain-XLA threshold fallback (what a TPU host runs for the
    candidate pass) produces the same compressed output bit-for-bit."""
    from repro.kernels.ops import _top_k_compress

    x = jax.random.normal(jax.random.key(2), (5000,))
    a = _top_k_compress(x, k=500, tmode="fallback", imask=True)
    b = _top_k_compress(x, k=500, tmode="interpret", imask=True)
    assert jnp.array_equal(a, b)
    assert jnp.array_equal(a, ref.top_k_ref(x, 500))


def test_on_tpu_constant_is_deprecated():
    from repro.kernels import ops as ops_mod

    with pytest.warns(DeprecationWarning, match="lazy"):
        val = ops_mod.ON_TPU
    assert isinstance(val, bool)


def test_registry_lists_all_ops_with_oracles():
    from repro.kernels import registry

    names = {op.name for op in registry.list_ops()}
    assert {"qsgd_quantize", "gossip_mix", "choco_move", "topk_partials",
            "topk_mask", "choco_qsgd", "choco_topk"} <= names
    with pytest.raises(ValueError, match="unknown kernel op"):
        registry.get_op("nope")


def test_parity_suite_all_ok():
    """The reference-parity harness (what bench_kernels asserts in CI):
    every registered op agrees with its oracle; bitwise ops EXACTLY."""
    from repro.kernels import registry

    records = registry.parity_suite(shapes=[(64,), (1000,), (300, 70)],
                                    dtypes=[jnp.float32, jnp.bfloat16])
    bad = [r for r in records if not r["ok"]]
    assert not bad, bad
    topk_recs = [r for r in records if r["op"] in ("topk_partials",
                                                   "topk_mask")]
    assert topk_recs and all(r["max_err"] == 0.0 for r in topk_recs)


# ---------------------------------------------------------------------------
# jax.disable_jit vs pallas interpret kernels (why eager_impl exists)
# ---------------------------------------------------------------------------

DISABLE_JIT_SCRIPT = r"""
import sys
sys.setrecursionlimit(600)   # bound the blowup: fail fast, not a core dump
import jax, jax.numpy as jnp
from repro.kernels import ops, ref

x = jax.random.normal(jax.random.key(0), (1000,))
want = ref.top_k_ref(x, 100)
try:
    with jax.disable_jit():
        got = ops.top_k_compress(x, 100, interpret=True)
except RecursionError:
    # the pinned jaxlib: pallas interpret mode re-enters itself under
    # disable_jit. This is WHY ops.eager_impl exists and why the
    # no-disable-jit lint rule bans disable_jit in kernels/.
    print("RECURSION_PINNED")
else:
    # a future jax may fix the recursion; then it must also be correct.
    assert jnp.array_equal(got, want)
    print("DISABLE_JIT_OK")

# eager_impl is the supported un-jitted path either way — same bits.
eager = ops.eager_impl("top_k_compress")(x, k=100, tmode="interpret",
                                         imask=True)
assert jnp.array_equal(eager, want)
print("EAGER_IMPL_OK")
"""


@pytest.mark.slow
def test_disable_jit_recursion_pinned_and_eager_impl_escape():
    """Pins the disable_jit/pallas interaction the no-disable-jit lint
    rule (repro.analysis) guards: on the pinned jaxlib interpret-mode
    kernels RECURSE under jax.disable_jit (a newer jax may instead
    succeed — then bitwise-correctly), while ops.eager_impl stays the
    supported un-jitted instrumentation path on every version."""
    import os
    import subprocess
    import sys as _sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([_sys.executable, "-c", DISABLE_JIT_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert ("RECURSION_PINNED" in out.stdout
            or "DISABLE_JIT_OK" in out.stdout), out.stdout
    assert "EAGER_IMPL_OK" in out.stdout, out.stdout
