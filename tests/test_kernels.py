"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SHAPES = [(64,), (1000,), (256, 128), (3, 5, 7), (32768,), (300, 70)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_qsgd_matches_ref(shape, dtype):
    k1, k2 = jax.random.split(jax.random.key(hash(shape) % 2**31))
    x = (jax.random.normal(k1, shape, jnp.float32) * 3).astype(dtype)
    noise = jax.random.uniform(k2, shape)
    d = int(np.prod(shape))
    s = 16.0
    c = 1.0 + min(d / (s * s), d**0.5 / s)
    got = ops.qsgd_quantize(x, noise, levels=16, interpret=True)
    want = ref.qsgd_ref(x, noise, levels=16, c=c)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("deg", [1, 2, 4])
def test_gossip_mix_matches_ref(shape, deg):
    key = jax.random.key(deg)
    x = jax.random.normal(jax.random.fold_in(key, 0), shape)
    nbrs = jax.random.normal(jax.random.fold_in(key, 1), (deg,) + shape)
    w = jnp.concatenate([jnp.asarray([0.5]),
                         jnp.full((deg,), 0.5 / deg)])
    got = ops.gossip_mix(x, nbrs, w, interpret=True)
    want = ref.gossip_mix_ref(x, nbrs, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_choco_move_matches_ref(shape, dtype):
    key = jax.random.key(7)
    x = jax.random.normal(jax.random.fold_in(key, 0), shape).astype(dtype)
    y = jax.random.normal(jax.random.fold_in(key, 1), shape).astype(dtype)
    my = jax.random.normal(jax.random.fold_in(key, 2), shape).astype(dtype)
    xg, dg = ops.choco_move(x, y, my, 0.37, interpret=True)
    xw, dw = ref.choco_move_ref(x, y, my, 0.37)
    # bf16 outputs can differ by one ulp from rounding order.
    tol = 1e-4 if dtype == jnp.float32 else 8e-3
    np.testing.assert_allclose(np.asarray(xg, np.float32),
                               np.asarray(xw, np.float32), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(dg, np.float32),
                               np.asarray(dw, np.float32), rtol=tol,
                               atol=tol)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5000), st.integers(0, 2**31 - 1))
def test_qsgd_property_random_sizes(n, seed):
    """Property sweep: arbitrary vector lengths (padding path) match ref."""
    k1, k2 = jax.random.split(jax.random.key(seed))
    x = jax.random.normal(k1, (n,))
    noise = jax.random.uniform(k2, (n,))
    s = 8.0
    c = 1.0 + min(n / (s * s), n**0.5 / s)
    got = ops.qsgd_quantize(x, noise, levels=8, interpret=True)
    want = ref.qsgd_ref(x, noise, levels=8, c=c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_qsgd_kernel_agrees_with_library_compressor():
    """The kernel implements the same Q as core.compression.QSGD (same
    noise => identical output)."""
    from repro.core.compression import QSGD

    n = 4096
    x = jax.random.normal(jax.random.key(0), (n,))
    key = jax.random.key(42)
    noise = jax.random.uniform(key, (n,))
    got = ops.qsgd_quantize(x, noise, levels=16, interpret=True)

    # re-derive library output with identical noise by monkey-path-free math
    want = ref.qsgd_ref(x, noise, levels=16,
                        c=1.0 + min(n / 256.0, n**0.5 / 16.0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    comp = QSGD(levels=16)
    assert abs(comp.delta(n) - 1.0 / (1.0 + min(n / 256.0, n**0.5 / 16.0))) < 1e-12
