"""The PR-4 sparse-engine safety guard, both halves, tested directly.

The sparse engine's node axes are shard_map-manual, but its non-node
(auto/GSPMD) axes run unconstrained: a ``constrain`` passed on a mesh
with a >1-sized auto axis would be silently dropped — re-opening the
scan-carry all-gather blowup the constraint exists to prevent. The
guard therefore has two cooperating halves:

* ``core.sharded.make_sharded_round_fn`` RAISES ``NotImplementedError``
  when given a constrain on such a mesh (loud, not silent), and
* ``launch.steps.select_engine("auto", ...)`` routes such meshes to the
  dense engine so production auto-selection never steers into the raise.

In-process tests use ``jax.sharding.AbstractMesh`` (no devices needed);
the concrete-mesh end is covered in a subprocess with 8 fake devices.
"""
import os
import subprocess
import sys

import pytest
from jax.sharding import AbstractMesh

from repro.core import DFLConfig, make_round_fn, ring
from repro.core.dfl import sparse_engine_eligible
from repro.launch.steps import select_engine
from repro.optim import sgd


def _loss(p, b, k=None):
    import jax.numpy as jnp

    return jnp.mean((p["w"][None] - b) ** 2)


def _mesh(*axes):
    return AbstractMesh(tuple(axes))


def test_select_engine_routes_partial_auto_mesh_dense():
    # 4 nodes on "data", a 2-sized "model" auto axis: eligible-looking,
    # but auto must pick dense (the constrain would be dropped in sparse).
    dcfg = DFLConfig(tau1=2, tau2=1, topology=ring(4))
    mesh = _mesh(("data", 4), ("model", 2))
    assert select_engine("auto", dcfg, mesh, "gossip-dp") == "dense"


def test_select_engine_picks_sparse_on_node_only_mesh():
    dcfg = DFLConfig(tau1=2, tau2=1, topology=ring(8))
    mesh = _mesh(("data", 8), ("model", 1))
    assert select_engine("auto", dcfg, mesh, "gossip-dp") == "sparse"
    assert select_engine("auto", dcfg, _mesh(("data", 8)),
                         "gossip-dp") == "sparse"


def test_select_engine_explicit_choice_is_respected():
    dcfg = DFLConfig(tau1=2, tau2=1, topology=ring(4))
    mesh = _mesh(("data", 4), ("model", 2))
    assert select_engine("dense", dcfg, mesh, "gossip-dp") == "dense"
    # explicit "sparse" passes through — the raise in make_sharded_round_fn
    # is then the (loud) guard.
    assert select_engine("sparse", dcfg, mesh, "gossip-dp") == "sparse"


def test_select_engine_dense_for_non_circulant_and_fsdp_modes():
    from repro.core.topology import star

    mesh = _mesh(("data", 8))
    assert select_engine(
        "auto", DFLConfig(tau1=2, tau2=1, topology=star(8)), mesh,
        "gossip-dp") == "dense"
    # gossip-fsdp on a podless mesh has no node axes at all.
    assert select_engine(
        "auto", DFLConfig(tau1=2, tau2=1, topology=ring(8)), mesh,
        "gossip-fsdp") == "dense"


def test_sparse_engine_eligible_accepts_abstract_mesh():
    dcfg = DFLConfig(tau1=2, tau2=1, topology=ring(8))
    assert sparse_engine_eligible(dcfg, _mesh(("data", 8)), ("data",))
    assert not sparse_engine_eligible(dcfg, _mesh(("data", 4)), ("data",))
    assert not sparse_engine_eligible(dcfg, _mesh(("data", 8)), ("nodes",))


GUARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.core import DFLConfig, make_round_fn, ring
from repro.core.sharded import make_sharded_round_fn
from repro.launch.steps import select_engine
from repro.optim import sgd

def loss(p, b, k=None):
    return jnp.mean((p["w"][None] - b) ** 2)

mesh42 = jax.make_mesh((4, 2), ("data", "model"))
cfg = DFLConfig(tau1=2, tau2=1, topology=ring(4))

# half 1: the sparse builder raises loudly on constrain + >1 auto axis,
# through both the direct and the make_round_fn entry points.
for builder in (
    lambda: make_sharded_round_fn(cfg, loss, sgd(0.1), mesh42,
                                  node_axes=("data",),
                                  constrain=lambda t: t),
    lambda: make_round_fn(cfg, loss, sgd(0.1), constrain=lambda t: t,
                          engine="sparse", mesh=mesh42,
                          node_axes=("data",)),
):
    try:
        builder()
        raise SystemExit("guard did not raise")
    except NotImplementedError as e:
        assert "constrain" in str(e), e
print("GUARD_RAISES_OK")

# without a constrain the same mesh builds fine (auto axes stay GSPMD).
make_sharded_round_fn(cfg, loss, sgd(0.1), mesh42, node_axes=("data",))
print("GUARD_NO_CONSTRAIN_OK")

# half 2: auto-selection on the CONCRETE mesh routes dense, so the
# production path (which always passes a constrain) never hits the raise.
assert select_engine("auto", cfg, mesh42, "gossip-dp") == "dense"
mesh8 = jax.make_mesh((8,), ("data",))
cfg8 = DFLConfig(tau1=2, tau2=1, topology=ring(8))
assert select_engine("auto", cfg8, mesh8, "gossip-dp") == "sparse"
print("GUARD_ROUTES_DENSE_OK")
"""


@pytest.mark.slow
def test_guard_on_concrete_mesh_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", GUARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ["GUARD_RAISES_OK", "GUARD_NO_CONSTRAIN_OK",
                "GUARD_ROUTES_DENSE_OK"]:
        assert tag in out.stdout, out.stdout
