import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY
from repro.models import init_params
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = REGISTRY["qwen3-1.7b"].reduced
    params, _ = init_params(cfg, jax.random.key(0))
    return ServingEngine(cfg, params, max_batch=4, bucket=16, max_len=96)


def test_serves_mixed_lengths(engine):
    for uid, n, gen in [(1, 5, 8), (2, 12, 4), (3, 30, 6), (4, 7, 8)]:
        engine.submit(Request(uid=uid, tokens=list(range(1, n + 1)),
                              max_new_tokens=gen))
    done = engine.run_until_drained()
    assert set(done) == {1, 2, 3, 4}
    assert len(done[1].tokens) == 8
    assert len(done[2].tokens) == 4
    assert len(done[3].tokens) == 6
    for c in done.values():
        assert all(0 <= t < 512 for t in c.tokens)


def test_greedy_is_deterministic(engine):
    engine.submit(Request(uid=10, tokens=[1, 2, 3, 4], max_new_tokens=6))
    a = engine.run_until_drained()[10].tokens
    engine.submit(Request(uid=11, tokens=[1, 2, 3, 4], max_new_tokens=6))
    b = engine.run_until_drained()[11].tokens
    assert a == b


def test_eos_stops_early():
    cfg = REGISTRY["qwen3-1.7b"].reduced
    params, _ = init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, max_batch=2, bucket=16, max_len=96)
    # find greedy first token, then use it as the "EOS" to force early stop
    eng.submit(Request(uid=1, tokens=[5, 6, 7], max_new_tokens=8))
    first = eng.run_until_drained()[1].tokens[0]
    eng.submit(Request(uid=2, tokens=[5, 6, 7], max_new_tokens=8,
                       eos_id=first))
    out = eng.run_until_drained()[2]
    assert len(out.tokens) == 1 and out.tokens[0] == first


def test_rejects_oversized_request(engine):
    with pytest.raises(AssertionError):
        engine.submit(Request(uid=99, tokens=[1] * 95, max_new_tokens=10))
