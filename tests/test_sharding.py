"""Unit tests of the logical-axis -> PartitionSpec rules (no devices)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as S


class FakeMesh:
    """Just enough of a Mesh for spec_for_param."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH_1POD = FakeMesh({"data": 16, "model": 16})
MESH_2POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_dp_mlp_weight():
    spec = S.spec_for_param(("layers", "embed", "mlp"), (16, 36, 4096, 12288),
                            "gossip-dp", MESH_1POD, node_dim=True)
    assert spec == P("data", None, None, "model")


def test_fsdp_mlp_weight_2d_sharded():
    spec = S.spec_for_param(("layers", "embed", "mlp"), (4, 36, 4096, 12288),
                            "gossip-fsdp", MESH_1POD, node_dim=True)
    # node dim (4) not divisible by nothing -> replicated; embed->data, mlp->model
    assert spec == P(None, None, "data", "model")


def test_expert_dim_wins_model_axis():
    spec = S.spec_for_param(("layers", "experts", "embed", "mlp"),
                            (4, 32, 16, 4096, 6400),
                            "gossip-fsdp", MESH_1POD, node_dim=True)
    assert spec == P(None, None, "model", "data", None)


def test_non_divisible_head_dim_replicated():
    # 56 heads don't divide 16.
    spec = S.spec_for_param(("embed", "heads", None), (7168, 56, 128),
                            "gossip-dp", MESH_1POD, node_dim=False)
    assert spec == P(None, None, None)


def test_head_dim_mode():
    spec = S.spec_for_param(("embed", None, "head_dim"), (7168, 56, 128),
                            "gossip-dp", MESH_1POD, node_dim=False)
    assert spec == P(None, None, "model")


def test_multipod_node_axes():
    assert S.node_axes_for("gossip-dp", MESH_2POD) == ("pod", "data")
    assert S.node_axes_for("gossip-fsdp", MESH_2POD) == ("pod",)
    assert S.node_axes_for("gossip-fsdp", MESH_1POD) == ()


def test_num_nodes():
    assert S.num_nodes_for("gossip-dp", MESH_1POD, 4) == 16
    assert S.num_nodes_for("gossip-dp", MESH_2POD, 4) == 32
    assert S.num_nodes_for("gossip-fsdp", MESH_1POD, 4) == 4
    assert S.num_nodes_for("gossip-fsdp", MESH_2POD, 4) == 2


def test_node_dim_spec_multipod():
    spec = S.spec_for_param(("embed",), (32, 4096), "gossip-dp", MESH_2POD,
                            node_dim=True)
    assert spec == P(("pod", "data"), None)


def test_vocab_sharding():
    spec = S.spec_for_param(("vocab", "embed"), (151936, 4096), "gossip-dp",
                            MESH_1POD, node_dim=False)
    assert spec == P("model", None)
    # fsdp: embed additionally over data.
    spec = S.spec_for_param(("vocab", "embed"), (151936, 4096), "gossip-fsdp",
                            MESH_1POD, node_dim=False)
    assert spec == P("model", "data")
