"""Fault injection + sporadic participation: the robustness contract.

What must hold (and is asserted here):

* all-ones masks are the IDENTITY — the participation executor's
  widened rows produce BITWISE the legacy round (plain and CHOCO) on
  the dense engine in-process and on the sparse engine in a
  subprocess. Participation must never tax a healthy deployment.
* masked mixing stays symmetric doubly stochastic (weight folds onto
  both endpoints' diagonals), and a crashed node (node + incident
  edges masked) keeps its params bitwise frozen while the others move.
* ``FaultPlan`` is deterministic (seeded per-round), composable
  (AND-composition, crash masks incident edges), validates its fault
  references, and round-trips through the JSON spec.
* ``FaultPlan.episodes`` prices OVERLAPPING link faults into
  piecewise-constant composed tariffs (no later-episode clobbering);
  ``masked_round_cost`` prices the sporadic round over the surviving
  sets only.
* the ``Availability`` planning hook degenerates exactly to the legacy
  bound at full participation and prices tau2 = 0 outage rounds with a
  finite resume-drift credit.
* degraded infrastructure is honest: atomic checkpoints fall back past
  torn files, the prefetcher retries transient build failures with
  backoff and ``close()`` joins its worker on every exit path.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import zipfile

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import (DFLConfig, RoundExecutor, init_state,  # noqa: E402
                        make_compressor, ring, stack_round_batches)
from repro.core.executor import HostPrefetcher  # noqa: E402
from repro.core.mixing import masked_mixing_matrix  # noqa: E402
from repro.core.topology import fully_connected  # noqa: E402
from repro.faults import (FaultPlan, LinkFlap, LinkOutage,  # noqa: E402
                          NodeCrash, SporadicParticipation, StragglerDelay,
                          load_fault_spec)
from repro.optim import sgd  # noqa: E402

N = 4
DIM = 9


def noisy_loss(p, b, k=None):
    jitter = 0.05 * jax.random.normal(k, p["w"].shape)
    return jnp.mean((p["w"][None] + jitter[None] - b) ** 2)


def fresh_state(opt, key=3, compressed=False):
    return init_state({"w": jnp.zeros((DIM,))}, N, opt,
                      jax.random.key(key), compressed=compressed)


def batches_for(tau1, rounds=2):
    targets = jnp.linspace(-1, 1, N)[:, None] * jnp.ones((N, DIM))
    per_round = [jnp.broadcast_to(targets[None, :, None, :],
                                  (tau1, N, 2, DIM))] * rounds
    return stack_round_batches(per_round, tau1)


def state_leaves(state):
    """The numerical state: params / opt_state / hat_params (the typed
    PRNG key leaf is compared separately by the caller when needed)."""
    trees = [state.params, state.opt_state]
    if state.hat_params is not None:
        trees.append(state.hat_params)
    leaves = []
    for t in trees:
        leaves += jax.tree_util.tree_leaves(t)
    return leaves


def assert_state_bitwise(a, b):
    la, lb = state_leaves(a), state_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), (
            "state leaves differ bitwise")


# ---------------------------------------------------------------------------
# all-ones masks == legacy round, bitwise (dense engine, in-process)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comp", [None, "qsgd"])
def test_all_ones_masks_bitwise_equal_legacy(comp):
    compressor = make_compressor(comp) if comp else None
    cfg = DFLConfig(tau1=3, tau2=2, topology=ring(N),
                    compression=compressor, gamma=0.5)
    opt = sgd(0.1)
    batches = batches_for(3)

    legacy = RoundExecutor(cfg, noisy_loss, opt, donate=False)
    part = RoundExecutor(cfg, noisy_loss, opt, donate=False,
                         participation=True)
    st = fresh_state(opt, compressed=comp is not None)

    ref, m_ref = legacy.dispatch(st, batches, 3, 2)
    rows = np.concatenate(
        [np.tile(np.array([[3, 2]], np.int32), (2, 1)),
         np.ones((2, part.row_width - 2), np.int32)], axis=1)
    out, m = part.dispatch_trajectory(st, batches, rows)

    assert_state_bitwise(ref, out)
    assert np.array_equal(np.asarray(m_ref["loss"]), np.asarray(m["loss"]))
    assert list(np.asarray(m["active_nodes"])) == [N, N]
    assert list(np.asarray(m["masked_edges"])) == [0, 0]


def test_all_ones_auto_padding_equals_explicit_masks():
    """[K, 2] rows through a participation executor auto-pad to all-ones
    — dispatch() and narrow trajectories work unchanged."""
    cfg = DFLConfig(tau1=2, tau2=1, topology=ring(N))
    opt = sgd(0.1)
    part = RoundExecutor(cfg, noisy_loss, opt, donate=False,
                         participation=True)
    st = fresh_state(opt)
    batches = batches_for(2)
    narrow, _ = part.dispatch_trajectory(
        st, batches, np.array([[2, 1], [2, 1]], np.int32))
    wide_rows = np.concatenate(
        [np.tile(np.array([[2, 1]], np.int32), (2, 1)),
         np.ones((2, part.row_width - 2), np.int32)], axis=1)
    wide, _ = part.dispatch_trajectory(st, batches, wide_rows)
    assert_state_bitwise(narrow, wide)


# ---------------------------------------------------------------------------
# masked semantics: crash freezes the node, masked mixing stays stochastic
# ---------------------------------------------------------------------------


def test_crashed_node_params_frozen_others_move():
    topo = ring(N)
    plan = FaultPlan(topo, (NodeCrash(node=2, r_start=0, r_stop=1),))
    cfg = DFLConfig(tau1=2, tau2=1, topology=topo)
    opt = sgd(0.1)
    part = RoundExecutor(cfg, noisy_loss, opt, donate=False,
                         participation=True)
    st = fresh_state(opt)
    rows = plan.mask_trajectory(np.array([[2, 1]], np.int32))
    out, m = part.dispatch_trajectory(st, batches_for(2, rounds=1), rows)

    before = np.asarray(st.params["w"])
    after = np.asarray(out.params["w"])
    # node 2: no local step AND all incident edges masked -> self-weight
    # folds to 1.0 -> params bitwise frozen. Everyone else learned.
    assert np.array_equal(before[2], after[2])
    for i in (0, 1, 3):
        assert not np.array_equal(before[i], after[i])
    assert int(np.asarray(m["active_nodes"])[0]) == N - 1
    assert int(np.asarray(m["masked_edges"])[0]) == 2


def test_masked_mixing_matrix_row_stochastic_and_identity():
    for topo in (ring(8), fully_connected(5)):
        e = topo.num_edges
        # all-ones: bitwise the static matrix.
        cm_on = masked_mixing_matrix(topo, jnp.ones((e,), jnp.int32),
                                     jnp.float32)
        assert np.array_equal(np.asarray(cm_on),
                              np.asarray(topo.mixing, np.float32))
        # arbitrary mask: symmetric doubly stochastic, masked edges zero.
        mask = np.ones(e, np.int32)
        mask[: e // 2] = 0
        cm = np.asarray(masked_mixing_matrix(
            topo, jnp.asarray(mask), jnp.float32))
        assert np.allclose(cm.sum(0), 1.0, atol=1e-6)
        assert np.allclose(cm.sum(1), 1.0, atol=1e-6)
        assert np.allclose(cm, cm.T, atol=1e-6)
        for (i, j), m in zip(topo.edges(), mask):
            if not m:
                assert cm[i, j] == 0.0 and cm[j, i] == 0.0


# ---------------------------------------------------------------------------
# FaultPlan: determinism, composition, validation, spec roundtrip
# ---------------------------------------------------------------------------


def test_fault_plan_deterministic_and_composed():
    topo = ring(8)
    plan = FaultPlan(topo, (
        NodeCrash(node=3, r_start=2, r_stop=5),
        LinkOutage(edges=((0, 1),), r_start=4, r_stop=6),
        SporadicParticipation(p_node=0.7, p_edge=0.6, r_start=6, r_stop=9),
    ), seed=11)

    # deterministic: same plan, same round -> same masks; rounds differ.
    for r in range(9):
        nm1, em1 = plan.masks(r)
        nm2, em2 = plan.masks(r)
        assert np.array_equal(nm1, nm2) and np.array_equal(em1, em2)
    nm6, _ = plan.masks(6)
    nm7, _ = plan.masks(7)
    nm8, _ = plan.masks(8)
    assert not (np.array_equal(nm6, nm7) and np.array_equal(nm7, nm8)), (
        "sporadic masks should vary across rounds")

    # round 1: nothing active.
    nm, em = plan.masks(1)
    assert nm.sum() == 8 and em.sum() == topo.num_edges

    # round 4: crash (node 3 + its 2 incident edges) AND the outage edge.
    nm, em = plan.masks(4)
    assert nm[3] == 0 and nm.sum() == 7
    down = {e for e, m in zip(topo.edges(), em) if not m}
    assert down == {(2, 3), (3, 4), (0, 1)}

    # seed changes the sporadic draw only.
    other = FaultPlan(topo, plan.faults, seed=12)
    assert np.array_equal(other.masks(4)[0], nm)
    assert any(not np.array_equal(other.masks(r)[0], plan.masks(r)[0])
               for r in range(6, 9))


def test_fault_plan_validation():
    topo = ring(4)
    with pytest.raises(ValueError, match="node"):
        FaultPlan(topo, (NodeCrash(node=9, r_start=0, r_stop=1),))
    with pytest.raises(ValueError, match="edge"):
        FaultPlan(topo, (LinkOutage(edges=((0, 2),), r_start=0, r_stop=1),))
    with pytest.raises(ValueError):
        NodeCrash(node=0, r_start=3, r_stop=3)   # empty window
    with pytest.raises(ValueError):
        LinkFlap(edge=(0, 1), period=2, up_rounds=2, r_start=0, r_stop=4)


def test_fault_plan_spec_roundtrip(tmp_path):
    topo = ring(8)
    plan = FaultPlan(topo, (
        NodeCrash(node=1, r_start=0, r_stop=3),
        StragglerDelay(node=2, slowdown=3, r_start=0, r_stop=9),
        LinkFlap(edge=(4, 5), period=3, up_rounds=1, r_start=2, r_stop=8),
    ), seed=5)
    spec = plan.to_spec()
    again = FaultPlan.from_spec(topo, spec)
    assert again.to_spec() == spec
    for r in range(9):
        a, b = plan.masks(r), again.masks(r)
        assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])

    # load_fault_spec: inline JSON and @file agree.
    inline = load_fault_spec(json.dumps(spec))
    path = tmp_path / "faults.json"
    path.write_text(json.dumps(spec))
    assert load_fault_spec(f"@{path}") == inline == spec
    with pytest.raises(ValueError, match="faults"):
        load_fault_spec("{}")


def test_mask_trajectory_widens_rows():
    topo = ring(4)
    plan = FaultPlan(topo, (NodeCrash(node=0, r_start=1, r_stop=2),))
    taus = np.array([[2, 1], [3, 0], [1, 1]], np.int32)
    rows = plan.mask_trajectory(taus)
    assert rows.shape == (3, 2 + 4 + topo.num_edges)
    assert np.array_equal(rows[:, :2], taus)
    assert rows[0, 2:].sum() == 4 + topo.num_edges      # round 0 healthy
    assert rows[1, 2 + 0] == 0                           # round 1 crash
    # round offset shifts the fault window.
    rows_off = plan.mask_trajectory(taus, round0=1)
    assert rows_off[0, 2 + 0] == 0


# ---------------------------------------------------------------------------
# pricing: composed episodes + masked_round_cost
# ---------------------------------------------------------------------------


def _unit_testbed():
    from repro.planner import (ComputeModel, CostModel, LinkModel,
                               WirelessLinks)
    topo = ring(8)
    model_bits = 32.0
    link = WirelessLinks(default=LinkModel(bytes_per_s=model_bits / 8.0))
    base = CostModel(compute=ComputeModel(step_flops=1.0, flops_per_s=1.0),
                     link=link, topology=topo, model_bits=model_bits)
    return topo, base


def test_episodes_compose_overlapping_link_faults():
    """Overlapping crash + flap windows must COMPOSE their tariffs (the
    naive one-episode-per-fault encoding lets the later link table
    clobber the earlier one)."""
    topo, base = _unit_testbed()
    plan = FaultPlan(topo, (
        NodeCrash(node=0, r_start=0, r_stop=10),
        LinkFlap(edge=(3, 4), period=2, up_rounds=1, r_start=5, r_stop=10),
    ))
    proc = plan.cost_process(base, seconds_per_round=1.0, residual=1e-3)
    base_t = base.round_cost(1, 1).time_s
    # inside the overlap, BOTH tariffs bite: a synchronous round pays the
    # crash's dead-edge residual (~1000x) regardless of the flap.
    overlap = proc.at(7.0).round_cost(1, 1).time_s
    crash_only = proc.at(2.0).round_cost(1, 1).time_s
    assert crash_only > base_t * 100
    assert overlap >= crash_only
    # after every window the base tariff returns.
    assert proc.at(11.0).round_cost(1, 1).time_s == pytest.approx(base_t)


def test_straggler_episode_scales_compute():
    topo, base = _unit_testbed()
    plan = FaultPlan(topo, (
        StragglerDelay(node=1, slowdown=4, r_start=2, r_stop=6),))
    proc = plan.cost_process(base, seconds_per_round=1.0)
    t_in = proc.at(3.0).round_cost(4, 0).time_s
    t_out = proc.at(8.0).round_cost(4, 0).time_s
    assert t_in == pytest.approx(4.0 * t_out)


def test_masked_round_cost_prices_surviving_sets():
    topo, base = _unit_testbed()
    full = base.round_cost(2, 1)
    same = base.masked_round_cost(2, 1, active_nodes=range(8),
                                  active_edges=topo.edges())
    assert same.time_s == pytest.approx(full.time_s)
    assert same.wire_bits == pytest.approx(full.wire_bits)

    # dead node: compute still runs (others), its edges priced out.
    edges = [e for e in topo.edges() if 0 not in e]
    rc = base.masked_round_cost(2, 1, active_nodes=range(1, 8),
                                active_edges=edges)
    assert rc.time_s == pytest.approx(full.time_s)  # max over active edges
    assert rc.wire_bits < full.wire_bits

    # nobody home: the round is free.
    empty = base.masked_round_cost(2, 1, active_nodes=[], active_edges=[])
    assert empty.time_s == 0.0 and empty.energy_j == 0.0

    # gossip-free masked round: no active edge -> no gossip time.
    comp_only = base.masked_round_cost(2, 1, active_nodes=range(8),
                                      active_edges=[])
    assert comp_only.time_s == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# planning: Availability degenerates exactly, prices outage rounds
# ---------------------------------------------------------------------------


def test_availability_bound_degenerates_and_prices_sporadic():
    from repro.planner.bounds import (Availability, expected_mixing,
                                      predicted_loss_decrement,
                                      sporadic_zeta)
    from repro.core.topology import zeta as spectral_zeta
    topo = ring(8)
    kw = dict(topology=topo, sigma=0.5, T=200, f_gap=1.0)

    legacy = predicted_loss_decrement(4, 2, **kw)
    full = predicted_loss_decrement(4, 2, availability=Availability(), **kw)
    assert legacy == full   # exact degeneration, same eta/terms

    degraded = predicted_loss_decrement(
        4, 2, availability=Availability(node_rate=0.6, edge_rate=0.5), **kw)
    assert degraded.bound > legacy.bound

    # tau2 = 0 in a DEGRADED regime with a resume credit is finite (the
    # legacy bound is inf for n > 1: a full-participation Availability
    # degenerates exactly, resume credit included), and still worse than
    # actually gossiping.
    outage = predicted_loss_decrement(
        4, 0, availability=Availability(edge_rate=0.9, resume_tau2=2.0),
        **kw)
    assert np.isfinite(outage.bound)
    # the credit RANKS: expecting fewer gossip steps on resume banks
    # more drift, so the bound must be monotonically worse.
    slower_resume = predicted_loss_decrement(
        4, 0, availability=Availability(edge_rate=0.9, resume_tau2=0.5),
        **kw)
    assert slower_resume.bound > outage.bound
    assert predicted_loss_decrement(
        4, 0, availability=Availability(resume_tau2=2.0), **kw
    ).bound == float("inf")

    # expected mixing: symmetric doubly stochastic at every rate; zeta
    # exact at rate 1, useless (1.0) at rate 0.
    for rate in (0.0, 0.3, 1.0):
        em = expected_mixing(topo, rate)
        assert np.allclose(em.sum(0), 1.0) and np.allclose(em, em.T)
    assert sporadic_zeta(topo, 1.0) == pytest.approx(
        spectral_zeta(topo.mixing))
    assert sporadic_zeta(topo, 0.0) == pytest.approx(1.0)


def test_controller_estimates_availability_from_masks():
    from repro.planner import AdaptiveController, Budget, unit_cost_model
    from repro.planner.bounds import Availability
    topo = ring(4)
    ctl = AdaptiveController(
        Budget(wall_clock_s=50.0),
        unit_cost_model(topo, 1.0, engine="dense", rep_dim=8),
        sigma=0.5, f_gap=1.0)
    assert ctl.availability() is None
    plan = FaultPlan(topo, (NodeCrash(node=1, r_start=0, r_stop=2),))
    for r in range(4):
        ctl.observe_participation(*plan.masks(r))
    avail = ctl.availability()
    assert isinstance(avail, Availability)
    assert avail.node_rate == pytest.approx((3 + 3 + 4 + 4) / 16)
    assert avail.edge_rate < 1.0
    # all-up observations only -> exact formulas (no availability hook).
    ctl2 = AdaptiveController(
        Budget(wall_clock_s=50.0),
        unit_cost_model(topo, 1.0, engine="dense", rep_dim=8),
        sigma=0.5, f_gap=1.0)
    ctl2.observe_participation(np.ones(4, np.int32), np.ones(4, np.int32))
    assert ctl2.availability() is None


# ---------------------------------------------------------------------------
# degraded infrastructure: atomic checkpoints, prefetcher retry/close
# ---------------------------------------------------------------------------


def test_restore_falls_back_past_torn_checkpoint(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    tree = {"w": np.arange(6, dtype=np.float32)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, {"w": tree["w"] * 2})
    # tear the newest file mid-archive (the pre-atomic failure mode).
    torn = tmp_path / "ckpt_00000002.npz"
    torn.write_bytes(torn.read_bytes()[:40])

    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 1
    assert np.array_equal(restored["w"], tree["w"])
    # an explicitly requested step is trusted -> loud failure.
    with pytest.raises((zipfile.BadZipFile, ValueError, OSError)):
        restore_checkpoint(str(tmp_path), tree, step=2)
    # nothing loadable at all -> FileNotFoundError naming the failures.
    (tmp_path / "ckpt_00000001.npz").write_bytes(b"junk")
    with pytest.raises(FileNotFoundError, match="step"):
        restore_checkpoint(str(tmp_path), tree)


def test_checkpoint_writes_are_atomic(tmp_path):
    from repro.checkpoint import save_checkpoint
    save_checkpoint(str(tmp_path), 7, {"w": np.zeros(3, np.float32)})
    names = sorted(os.listdir(tmp_path))
    assert names == ["ckpt_00000007.json", "ckpt_00000007.npz"], (
        "no temp files may survive a save")


def test_prefetcher_retries_transient_failures():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "data"

    pf = HostPrefetcher(retries=2, backoff_s=0.001)
    pf.schedule(flaky, meta="m")
    assert pf.take() == ("data", "m")
    assert pf.stats["retries"] == 2 and pf.stats["errors"] == 0

    # retries exhausted -> the LAST error surfaces on take().
    pf.schedule(lambda: (_ for _ in ()).throw(OSError("down")), meta="x")
    with pytest.raises(OSError, match="down"):
        pf.take()


def test_prefetcher_close_joins_and_refuses_new_work():
    pf = HostPrefetcher(retries=5, backoff_s=0.05)
    pf.schedule(lambda: (_ for _ in ()).throw(OSError("never up")))
    pf.close()   # wakes the backoff wait, joins the worker
    assert pf.pending_meta is None
    with pytest.raises(RuntimeError, match="closed"):
        pf.schedule(lambda: "late")
    pf.close()   # idempotent


# ---------------------------------------------------------------------------
# sparse engine (8 fake devices, subprocess): parity + zero recompiles
# ---------------------------------------------------------------------------

SPARSE_FAULTS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.core import (DFLConfig, RoundExecutor, init_state,
                        make_compressor, ring, stack_round_batches)
from repro.faults import FaultPlan, NodeCrash, LinkOutage
from repro.optim import sgd

mesh = jax.make_mesh((8,), ("data",))
N = 8
topo = ring(N)
opt = sgd(0.1)

def noisy_loss(p, b, k=None):
    jitter = 0.05 * jax.random.normal(k, p["w"].shape)
    return jnp.mean((p["w"][None] + jitter[None] - b) ** 2)

targets = jnp.linspace(-1, 1, N)[:, None] * jnp.ones((N, 17))
full = jnp.broadcast_to(targets[None, :, None, :], (3, N, 2, 17))
batches = stack_round_batches([full] * 2, tau1_max=3)
fresh = lambda k=5: init_state({"w": jnp.zeros((17,))}, N, opt,
                               jax.random.key(k))

cfg = DFLConfig(tau1=3, tau2=2, topology=topo)
plan = FaultPlan(topo, (NodeCrash(node=3, r_start=0, r_stop=1),
                        LinkOutage(edges=((6, 7),), r_start=1, r_stop=2)),
                 seed=0)
taus = np.array([[3, 2], [2, 1]], np.int32)
rows = plan.mask_trajectory(taus)

dense = RoundExecutor(cfg, noisy_loss, opt, donate=False,
                      participation=True)
sparse = RoundExecutor(cfg, noisy_loss, opt, engine="sparse", mesh=mesh,
                       node_axes=("data",), donate=False,
                       participation=True)

# masked trajectory: dense is the numerical oracle for sparse.
d_out, d_m = dense.dispatch_trajectory(fresh(), batches, rows)
s_out, s_m = sparse.dispatch_trajectory(fresh(), batches, rows)
err = float(jnp.max(jnp.abs(d_out.params["w"] - s_out.params["w"])))
assert err < 1e-5, f"masked sparse != dense: {err}"
assert list(np.asarray(s_m["active_nodes"])) == [7, 8]
assert list(np.asarray(s_m["masked_edges"])) == [2, 1]
print("SPARSE_MASKED_PARITY_OK", err)

# all-ones rows == legacy sparse executor, bitwise.
legacy = RoundExecutor(cfg, noisy_loss, opt, engine="sparse", mesh=mesh,
                       node_axes=("data",), donate=False)
ref, _ = legacy.dispatch(fresh(), batches, 3, 2)
ones = np.concatenate([np.tile(np.array([[3, 2]], np.int32), (2, 1)),
                       np.ones((2, sparse.row_width - 2), np.int32)], 1)
out, _ = sparse.dispatch_trajectory(fresh(), batches, ones)
assert np.array_equal(np.asarray(ref.params["w"]),
                      np.asarray(out.params["w"]))
print("SPARSE_ALLONES_BITWISE_OK")

# masks are schedule data: three different fault patterns, one compile.
assert sparse.compile_count == 1, sparse.compile_count
other = FaultPlan(topo, (NodeCrash(node=0, r_start=0, r_stop=2),), seed=1)
sparse.dispatch_trajectory(fresh(), batches, other.mask_trajectory(taus))
assert sparse.compile_count == 1, sparse.compile_count
print("SPARSE_MASKS_ZERO_RECOMPILE_OK")

# the masked executable still ships the full topology pair set (masks
# gate weights, not collectives).
from repro.analysis.audits import audit_collective_matching
low = sparse.lower_superstep(fresh(), batches, rows)
res = audit_collective_matching(low.compile().as_text(), topo)
assert res.ok, res.detail
print("SPARSE_MASKED_COLLECTIVES_OK")
"""


@pytest.mark.slow
def test_sparse_engine_fault_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SPARSE_FAULTS_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ["SPARSE_MASKED_PARITY_OK", "SPARSE_ALLONES_BITWISE_OK",
                "SPARSE_MASKS_ZERO_RECOMPILE_OK",
                "SPARSE_MASKED_COLLECTIVES_OK"]:
        assert tag in out.stdout, (tag, out.stdout, out.stderr[-2000:])
