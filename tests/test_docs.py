"""docs/ stay true: THEORY.md snippets run, ARCHITECTURE.md links hold.

The theory crossmap embeds runnable ``>>>`` snippets (paper equation ->
code object with live values); doctest-running them here makes the
tier-1 suite — and the explicit CI doctest step — fail the moment an API
or a constant drifts from what the docs claim.
"""
import doctest
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_theory_md_snippets_run():
    result = doctest.testfile(
        os.path.join(ROOT, "docs", "THEORY.md"),
        module_relative=False,
        optionflags=doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE,
    )
    assert result.attempted >= 25, (
        f"THEORY.md lost its snippets? only {result.attempted} examples")
    assert result.failed == 0, f"{result.failed} THEORY.md snippets failed"


def test_architecture_md_names_real_files():
    """Every `path/to/file.py` (or docs/*.md) ARCHITECTURE.md mentions
    must exist — the layer map may not drift from the tree."""
    text = open(os.path.join(ROOT, "docs", "ARCHITECTURE.md")).read()
    missing = []
    for m in set(re.findall(r"[\w/]+/[\w.]+\.(?:py|md|json)", text)):
        path = m if m.startswith(("src/", "docs/", "tests/",
                                  "benchmarks/")) else (
            os.path.join("src", "repro", m))
        if not os.path.exists(os.path.join(ROOT, path)):
            missing.append(m)
    assert not missing, f"ARCHITECTURE.md references missing files: {missing}"


def test_readme_links_docs_pages():
    text = open(os.path.join(ROOT, "README.md")).read()
    assert "docs/ARCHITECTURE.md" in text
    assert "docs/THEORY.md" in text


def test_architecture_md_documents_every_shipped_rule_and_audit():
    """The 'Invariants & enforcement' section must name every lint rule
    the analysis package ships (and the three compiled-artifact audits):
    an undocumented rule is an invariant nobody can look up."""
    from repro.analysis.rules import RULES

    text = open(os.path.join(ROOT, "docs", "ARCHITECTURE.md")).read()
    start = text.find("## Invariants & enforcement")
    assert start >= 0, "ARCHITECTURE.md lost its Invariants section"
    section = text[start:]
    missing = [name for name in RULES if f"`{name}`" not in section]
    assert not missing, f"rules undocumented in ARCHITECTURE.md: {missing}"
    for audit in ("donation", "recompile", "collective-matching",
                  "telemetry-neutrality"):
        assert f"`{audit}`" in section, f"audit {audit!r} undocumented"


def test_architecture_md_documents_every_event_type():
    """The Observability section must name every schema event type and
    the CLI verbs: an undocumented event kind is a record nobody can
    interpret from the docs."""
    from repro.obs import EVENT_TYPES

    text = open(os.path.join(ROOT, "docs", "ARCHITECTURE.md")).read()
    start = text.find("## Observability")
    assert start >= 0, "ARCHITECTURE.md lost its Observability section"
    section = text[start:]
    missing = [t for t in sorted(EVENT_TYPES) if f"`{t}`" not in section]
    assert not missing, f"event types undocumented: {missing}"
    for verb in ("validate", "trace export", "report"):
        assert verb in section, f"obs CLI verb {verb!r} undocumented"
    assert "--telemetry-out" in section and "--profile-dir" in section
