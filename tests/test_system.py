"""End-to-end system tests: the paper's experiment at miniature scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import RunSpec, run_dfl_cnn


@pytest.fixture(scope="module")
def dfl_run():
    return run_dfl_cnn(RunSpec(name="sys-dfl", tau1=4, tau2=4, rounds=14,
                               nodes=6), log_every=2)


def test_training_reduces_loss(dfl_run):
    h = dfl_run["history"]
    assert h["loss"][-1] < h["loss"][0] * 0.9


def test_accuracy_above_chance(dfl_run):
    assert dfl_run["history"]["test_acc"][-1] > 0.2  # 10 classes => 0.1


def test_consensus_bounded(dfl_run):
    h = dfl_run["history"]
    assert h["consensus"][-1] < 10.0
    assert all(np.isfinite(h["consensus"]))


def test_wire_accounting_positive(dfl_run):
    assert dfl_run["bits_per_round"] > 0
    gb = dfl_run["history"]["gbits"]
    assert all(b2 > b1 for b1, b2 in zip(gb, gb[1:]))


def test_cdfl_system_runs():
    out = run_dfl_cnn(RunSpec(name="sys-cdfl", tau1=2, tau2=2, rounds=10,
                              nodes=6, compression="top_k",
                              comp_kwargs={"frac": 0.5}, gamma=0.6),
                      log_every=2)
    h = out["history"]
    assert np.isfinite(h["loss"]).all()
    assert h["loss"][-1] < h["loss"][0]
    # compression halves the wire bytes (+ index overhead).
    base = run_dfl_cnn(RunSpec(name="sys-dfl2", tau1=2, tau2=2, rounds=2,
                               nodes=6), log_every=1)
    assert out["bits_per_round"] < base["bits_per_round"]


def test_checkpoint_roundtrip_ml_dtypes(tmp_path):
    """bf16 leaves survive the .npz round trip (numpy reloads ml_dtypes
    arrays as raw void bytes; restore must reinterpret via the template) —
    this is what --ckpt-dir resume of the bf16 archs depends on."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    tree = {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": jnp.linspace(0, 1, 4, dtype=jnp.float32)}
    save_checkpoint(str(tmp_path), 3, tree, {"loss": 1.0})
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 3
    for k in tree:
        got = jnp.asarray(restored[k])
        assert got.dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(tree[k], np.float32))


def test_lm_pipeline_roundtrip():
    from repro.data.lm import SyntheticLM, lm_batches_for_dfl

    corpus = SyntheticLM(vocab_size=97, num_nodes=3, noniid_alpha=0.7)
    b = lm_batches_for_dfl(corpus, tau1=2, num_nodes=3, batch_per_node=4,
                           seq_len=16, round_idx=0)
    assert b["tokens"].shape == (2, 3, 4, 16)
    assert int(b["tokens"].max()) < 97
    # labels are next-token shifted views of the same stream.
    b2 = lm_batches_for_dfl(corpus, tau1=2, num_nodes=3, batch_per_node=4,
                            seq_len=16, round_idx=0)
    np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                  np.asarray(b2["tokens"]))  # deterministic
