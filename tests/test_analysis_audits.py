"""Compiled-artifact audits (repro.analysis.audits).

Each audit must (a) pass on a correct artifact and (b) FAIL when seeded
with its deliberate violation — an un-donated carry, a baked tau
constant, a wrong permute pair — otherwise the audit is decoration:

* donation: jit WITHOUT donate_argnums vs WITH, on a real carry-shaped
  function (donation aliasing works on single-device CPU).
* recompile: static_argnums bakes the tau into the executable (texts
  differ) vs a traced tau (byte-identical lowerings).
* collective-matching: synthetic optimized HLO with correct vs
  wrong-shift ``source_target_pairs`` against ring(8).
* telemetry-neutrality: a host-side (trace-time print/counter) hook
  leaves the lowering byte-identical; a hook that inserts a traced op
  (``jax.debug.print`` on a traced value — the violation class) moves
  the fingerprint and must FAIL.

The production artifact itself (8-node sparse superstep via
``RoundExecutor.lower_superstep``) runs in a subprocess with 8 forced
host devices through the real CLI: ``python -m repro.analysis audit``.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.audits import (
    AuditResult, audit_collective_matching, audit_donation, audit_recompile,
    audit_telemetry_neutrality, expected_shift_pairs, hlo_fingerprint,
    parse_input_output_aliases)
from repro.core.topology import fully_connected, ring

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# parsing helpers
# ---------------------------------------------------------------------------


def test_parse_input_output_aliases_synthetic_header():
    text = ("HloModule jit_step, input_output_alias={ {0}: (0, {}, "
            "may-alias), {1}: (2, {}, must-alias) }, "
            "entry_computation_layout={...}\n")
    assert parse_input_output_aliases(text) == {(0,): 0, (1,): 2}


def test_parse_input_output_aliases_absent_means_empty():
    assert parse_input_output_aliases("HloModule jit_step\nROOT x = ...") == {}


def test_expected_shift_pairs_ring8():
    pairs = expected_shift_pairs(ring(8))
    assert set(pairs) == {1, 7}
    assert pairs[1] == frozenset((s, (s + 1) % 8) for s in range(8))
    assert pairs[7] == frozenset((s, (s + 7) % 8) for s in range(8))


# ---------------------------------------------------------------------------
# donation audit: deliberate violation = drop donate_argnums
# ---------------------------------------------------------------------------


def _carry_fn(state):
    return jax.tree_util.tree_map(lambda x: x * 2.0, state)


def _carry():
    return {"params": jnp.ones((64,)), "opt": jnp.zeros((64,))}


def test_audit_donation_passes_with_donate_argnums():
    text = jax.jit(_carry_fn, donate_argnums=(0,)).lower(
        _carry()).compile().as_text()
    res = audit_donation(text, ["params", "opt"])
    assert res.ok, res.detail


def test_audit_donation_fails_without_donate_argnums():
    text = jax.jit(_carry_fn).lower(_carry()).compile().as_text()
    res = audit_donation(text, ["params", "opt"])
    assert not res.ok
    assert "params" in res.detail and "donate_argnums" in res.detail


def test_audit_donation_catches_partial_donation():
    # donating only arg 0 of (state_leaf0, state_leaf1) as separate args:
    # leaf 1 must be reported missing.
    def f(a, b):
        return a * 2, b * 2

    text = jax.jit(f, donate_argnums=(0,)).lower(
        jnp.ones((8,)), jnp.ones((8,))).compile().as_text()
    res = audit_donation(text, ["a", "b"])
    assert not res.ok and "param 1 (b)" in str(res.data["missing"])


# ---------------------------------------------------------------------------
# recompile audit: deliberate violation = static_argnums-baked tau
# ---------------------------------------------------------------------------


def _loop(x, tau):
    return jax.lax.fori_loop(0, tau, lambda _, v: v * 1.5, x)


def test_audit_recompile_passes_for_traced_taus():
    fn = jax.jit(_loop)
    x = jnp.ones((16,))
    texts = [fn.lower(x, jnp.int32(t)).as_text() for t in (1, 3)]
    res = audit_recompile(texts, labels=["tau=1", "tau=3"])
    assert res.ok, res.detail
    assert len(set(res.data["fingerprints"].values())) == 1


def test_audit_recompile_fails_for_baked_tau():
    fn = jax.jit(_loop, static_argnums=(1,))
    x = jnp.ones((16,))
    texts = [fn.lower(x, t).as_text() for t in (1, 3)]
    res = audit_recompile(texts, labels=["tau=1", "tau=3"])
    assert not res.ok
    assert "baked" in res.detail


def test_hlo_fingerprint_is_content_hash():
    assert hlo_fingerprint("abc") == hlo_fingerprint("abc")
    assert hlo_fingerprint("abc") != hlo_fingerprint("abd")


# ---------------------------------------------------------------------------
# collective-matching audit: deliberate violation = wrong shift pairs
# ---------------------------------------------------------------------------


def _permute_hlo(pair_strs):
    perms = "\n".join(
        f"  %p{i} = f32[8]{{0}} collective-permute(%x), "
        f"source_target_pairs={{{pairs}}}"
        for i, pairs in enumerate(pair_strs))
    return (
        "HloModule jit_round\n\n"
        "ENTRY %main (x: f32[8]) -> f32[8] {\n"
        "  %x = f32[8]{0} parameter(0)\n"
        f"{perms}\n"
        "  ROOT %out = f32[8]{0} add(%p0, %p0)\n"
        "}\n")


def _pairs_str(shift, n=8):
    return ",".join(f"{{{s},{(s + shift) % n}}}" for s in range(n))


def test_audit_collective_matching_passes_on_ring8_pairs():
    text = _permute_hlo([_pairs_str(1), _pairs_str(7)])
    res = audit_collective_matching(text, ring(8))
    assert res.ok, res.detail
    assert res.data["num_permutes"] == 2


def test_audit_collective_matching_fails_on_wrong_shift():
    # shift 2 instead of 7: one expected set missing, one unexpected.
    text = _permute_hlo([_pairs_str(1), _pairs_str(2)])
    res = audit_collective_matching(text, ring(8))
    assert not res.ok
    assert "missing" in res.detail


def test_audit_collective_matching_fails_on_dropped_shift():
    text = _permute_hlo([_pairs_str(1)])
    res = audit_collective_matching(text, ring(8))
    assert not res.ok


def test_audit_collective_matching_requires_permutes_when_shifted():
    text = ("HloModule jit_round\n\n"
            "ENTRY %main (x: f32[8]) -> f32[8] {\n"
            "  ROOT %x = f32[8]{0} parameter(0)\n}\n")
    res = audit_collective_matching(text, ring(8))
    assert not res.ok


def test_audit_collective_matching_fully_connected_single_shift_set():
    # fully_connected(4) has shifts 1,2,3 — all three pair sets required.
    topo = fully_connected(4)
    strs = [_pairs_str(s, 4) for s, _ in topo.shifts()]
    good = audit_collective_matching(
        _permute_hlo(strs).replace("f32[8]", "f32[4]"), topo)
    assert good.ok, good.detail


# ---------------------------------------------------------------------------
# telemetry-neutrality audit: deliberate violation = a hook that traces
# ---------------------------------------------------------------------------


def test_audit_telemetry_neutrality_passes_for_host_side_hooks():
    """A trace-time HOST hook (the Telemetry emit pattern: counter +
    event append, no jax calls) leaves the lowering byte-identical."""
    from repro.obs import Telemetry

    tel = Telemetry()

    def make_step(sink):
        # same __name__ either way: the HLO module is named after the
        # function, and the audit compares like-for-like builds.
        def step(x, tau):
            if sink is not None:
                # host-side instrumentation, runs at trace time (the
                # Telemetry emit pattern: counter + append, no jax calls)
                sink.emit("compile", track="dispatch", count=1)
            return jax.lax.fori_loop(0, tau, lambda _, v: v * 1.5, x)
        return step

    x = jnp.ones((16,))
    bare = jax.jit(make_step(None)).lower(x, jnp.int32(2)).as_text()
    inst = jax.jit(make_step(tel)).lower(x, jnp.int32(2)).as_text()
    assert any(e["type"] == "compile" for e in tel.events)  # hook ran
    res = audit_telemetry_neutrality(bare, inst)
    assert res.ok, res.detail
    fps = res.data["fingerprints"]
    assert fps["bare"] == fps["instrumented"]


def test_audit_telemetry_neutrality_fails_when_hook_traces():
    """The violation class: instrumentation that inserts an op into the
    traced graph (debug.print on a traced value) moves the HLO."""

    def make_step(leaky):
        # same __name__ either way, so the ONLY difference is the op.
        def step(x, tau):
            if leaky:
                jax.debug.print("tau1={t}", t=tau)  # traced: in the HLO
            return jax.lax.fori_loop(0, tau, lambda _, v: v * 1.5, x)
        return step

    x = jnp.ones((16,))
    bare = jax.jit(make_step(False)).lower(x, jnp.int32(2)).as_text()
    leaky = jax.jit(make_step(True)).lower(x, jnp.int32(2)).as_text()
    res = audit_telemetry_neutrality(bare, leaky)
    assert not res.ok
    assert "CHANGED" in res.detail


def test_audit_telemetry_neutrality_on_dense_executor_lowerings():
    """The real surface, in-process on the dense engine: a RoundExecutor
    with a live Telemetry sink lowers the SAME superstep HLO as one
    without (the sparse production version runs via the CLI test)."""
    from repro.core import DFLConfig, init_state
    from repro.core.executor import RoundExecutor, stack_round_batches
    from repro.obs import Telemetry
    from repro.optim import sgd

    def build(telemetry):
        cfg = DFLConfig(tau1=2, tau2=1, topology=ring(4))
        opt = sgd(0.1)

        def loss_fn(p, b, k=None):
            return jnp.mean((p["w"][None] - b) ** 2)

        ex = RoundExecutor(cfg, loss_fn, opt, engine="dense",
                           telemetry=telemetry)
        state = init_state({"w": jnp.zeros((5,))}, 4, opt, jax.random.key(0))
        batches = stack_round_batches(
            [jax.random.normal(jax.random.key(1), (2, 4, 3, 5))] * 2, 2)
        return ex.lower_superstep(state, batches, [[1, 1], [2, 0]])

    tel = Telemetry()
    bare = build(None).as_text()
    inst = build(tel).as_text()
    assert any(e["type"] == "compile" for e in tel.events)
    res = audit_telemetry_neutrality(bare, inst)
    assert res.ok, res.detail


def test_audit_result_to_dict_roundtrips():
    r = AuditResult("x", True, "fine", {"k": 1})
    assert r.to_dict() == {"name": "x", "ok": True, "detail": "fine",
                           "data": {"k": 1}}


# ---------------------------------------------------------------------------
# the production artifact, through the real CLI (8 fake devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_production_audits_pass_via_cli(tmp_path):
    out_json = tmp_path / "audit.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)   # the CLI must inject the device flag itself
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "audit",
         "--json", str(out_json)],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr[-3000:]
    results = json.loads(out_json.read_text())
    assert {r["name"] for r in results} == {
        "donation", "recompile", "collective-matching",
        "telemetry-neutrality", "participation-recompile",
        "participation-collectives", "overlap-recompile",
        "overlap-collectives", "cohort-recompile"}
    assert all(r["ok"] for r in results), results
    donation = next(r for r in results if r["name"] == "donation")
    # the whole DFLState carry: params, opt_state, rng, round_idx.
    assert donation["data"]["expected_params"] == 4
    neutrality = next(r for r in results
                      if r["name"] == "telemetry-neutrality")
    fps = neutrality["data"]["fingerprints"]
    assert fps["bare"] == fps["instrumented"]
