"""Per-architecture smoke tests: REDUCED variant of each assigned config
runs one forward/train step on CPU, asserting output shapes + no NaNs;
decode-capable archs also run a prefill + decode step."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY
from repro.models import (
    decode_step, init_params, prefill, train_loss,
)

ARCH_IDS = sorted(REGISTRY)


def _smoke_batch(cfg, b=2, s=32, key=jax.random.key(0)):
    from repro.models.common import pad_vocab

    ks = jax.random.split(key, 3)
    v = min(cfg.vocab_size, pad_vocab(cfg.vocab_size))
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
    }
    if cfg.has_memory_input:
        m = cfg.memory_tokens or 16
        batch["memory"] = jax.random.normal(
            ks[2], (b, m, cfg.memory_dim or cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_train_step(arch_id):
    arch = REGISTRY[arch_id]
    cfg = arch.reduced
    params, axes = init_params(cfg, jax.random.key(0))
    is_axes = lambda x: isinstance(x, tuple)
    n_params = len(jax.tree_util.tree_leaves(params))
    n_axes = len(jax.tree_util.tree_leaves(axes, is_leaf=is_axes))
    assert n_params == n_axes
    batch = _smoke_batch(cfg)

    loss, grads = jax.value_and_grad(
        lambda p: train_loss(p, batch, cfg))(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch_id}: non-finite loss"
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.isfinite(g).all()), f"{arch_id}: NaN grad at {path}"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_decode_roundtrip(arch_id):
    arch = REGISTRY[arch_id]
    cfg = arch.reduced
    params, _ = init_params(cfg, jax.random.key(0))
    batch = _smoke_batch(cfg, b=2, s=16)
    logits, state = prefill(params, batch, cfg, max_len=24)
    from repro.models.common import pad_vocab

    assert logits.shape == (2, pad_vocab(cfg.vocab_size))
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32) % cfg.vocab_size
    for _ in range(3):
        logits, state = decode_step(params, state, tok, cfg)
        assert bool(jnp.isfinite(logits).all()), f"{arch_id}: NaN decode"
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32) % cfg.vocab_size
    assert int(state.position) == 19


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """The FULL configs carry the exact assigned hyper-parameters."""
    m = REGISTRY[arch_id].model
    expected = {
        "granite-moe-1b-a400m": dict(num_layers=24, d_model=1024, num_heads=16,
                                     num_kv_heads=8, d_ff=512, vocab_size=49155,
                                     num_experts=32, experts_per_token=8),
        "llama-3.2-vision-90b": dict(num_layers=100, d_model=8192, num_heads=64,
                                     num_kv_heads=8, d_ff=28672,
                                     vocab_size=128256),
        "qwen3-1.7b": dict(num_layers=28, d_model=2048, num_heads=16,
                           num_kv_heads=8, d_ff=6144, vocab_size=151936,
                           qk_norm=True),
        "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12288, vocab_size=151936,
                         qk_norm=True),
        "gemma3-4b": dict(num_layers=34, d_model=2560, num_heads=8,
                          num_kv_heads=4, d_ff=10240, vocab_size=262144),
        "seamless-m4t-medium": dict(num_layers=12, encoder_layers=12,
                                    d_model=1024, num_heads=16,
                                    num_kv_heads=16, d_ff=4096,
                                    vocab_size=256206),
        "falcon-mamba-7b": dict(num_layers=64, d_model=4096, d_ff=0,
                                vocab_size=65024, ssm_state=16),
        "jamba-1.5-large-398b": dict(num_layers=72, d_model=8192, num_heads=64,
                                     num_kv_heads=8, d_ff=24576,
                                     vocab_size=65536, num_experts=16,
                                     experts_per_token=2, ssm_state=16),
        "deepseek-coder-33b": dict(num_layers=62, d_model=7168, num_heads=56,
                                   num_kv_heads=8, d_ff=19200,
                                   vocab_size=32256),
        "phi3.5-moe-42b-a6.6b": dict(num_layers=32, d_model=4096, num_heads=32,
                                     num_kv_heads=8, d_ff=6400,
                                     vocab_size=32064, num_experts=16,
                                     experts_per_token=2),
    }[arch_id]
    for k, v in expected.items():
        assert getattr(m, k) == v, f"{arch_id}.{k}: {getattr(m, k)} != {v}"
    assert m.citation, f"{arch_id} missing source citation"


def test_gemma3_pattern_is_5_local_1_global():
    m = REGISTRY["gemma3-4b"].model
    globals_ = [i for i, s in enumerate(m.layer_specs()) if s.window == 0]
    assert globals_ == [5, 11, 17, 23, 29]


def test_jamba_pattern_interleave():
    m = REGISTRY["jamba-1.5-large-398b"].model
    specs = m.layer_specs()
    attn = [i for i, s in enumerate(specs) if s.mixer == "attn"]
    assert attn == list(range(4, 72, 8))            # 1:7 interleave, offset 4
    moe = [i for i, s in enumerate(specs) if s.ffn == "moe"]
    assert moe == list(range(1, 72, 2))             # MoE every 2, offset 1


def test_llama_vision_cross_every_5th():
    m = REGISTRY["llama-3.2-vision-90b"].model
    cross = [i for i, s in enumerate(m.layer_specs()) if s.cross_attn]
    assert cross == list(range(4, 100, 5))
