"""Unit tests of the dense/sparse engine unification layer.

Covers the pieces the multi-device subprocess test can't check cheaply:
the wire-cost accounting helper, the engine-eligibility predicate (and its
agreement with ``Topology.shifts()``), the JAX version-compat shims, and
the substrate metric/key parity on the dense side.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (DFLConfig, DenseSubstrate, consensus_distance,
                        disconnected, fully_connected, make_compressor,
                        ring, round_wire_bits, sparse_engine_eligible, star,
                        torus)
from repro.core import mixing as M
from repro.core import substrate as sub_lib
from repro.core.compression import Identity, tree_wire_bits


class FakeMesh:
    """Just enough of a Mesh for the eligibility predicate."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


# ---------------------------------------------------------------------------
# Wire-cost accounting (one helper, both engines)
# ---------------------------------------------------------------------------


def test_gossip_copies_dense_vs_sparse():
    topo = ring(8)
    assert M.gossip_copies_per_step(topo, "sparse") == 2      # deg
    assert M.gossip_copies_per_step(topo, "dense") == 7       # N-1 all-gather
    assert M.gossip_copies_per_step(topo, "auto") == 2        # ring -> sparse
    hub = star(8)
    assert M.gossip_copies_per_step(hub, "sparse") == 7       # hub degree
    assert M.gossip_copies_per_step(hub, "auto") == 7         # not circulant
    with pytest.raises(ValueError):
        M.gossip_copies_per_step(topo, "einsum")


def test_mixing_bytes_per_step_uses_helper():
    topo = torus(2, 4)
    pb = 1000
    assert (M.mixing_bytes_per_step(topo, pb, sparse=True)
            == M.gossip_copies_per_step(topo, "sparse") * pb)
    assert (M.mixing_bytes_per_step(topo, pb, sparse=False)
            == (topo.num_nodes - 1) * pb)


def test_round_wire_bits_engine_parameterized():
    params = {"w": jnp.zeros((100,)), "b": jnp.zeros((10,))}
    cfg = DFLConfig(tau1=2, tau2=3, topology=ring(8))
    full = tree_wire_bits(Identity(), params)
    assert round_wire_bits(cfg, params, engine="sparse") == full * 2 * 3
    assert round_wire_bits(cfg, params, engine="dense") == full * 7 * 3
    # compressed accounting still scales by the engine's copy count.
    ccfg = DFLConfig(tau1=2, tau2=3, topology=ring(8),
                     compression=make_compressor("qsgd"))
    assert (round_wire_bits(ccfg, params, engine="dense")
            > round_wire_bits(ccfg, params, engine="sparse"))


# ---------------------------------------------------------------------------
# Engine eligibility: predicate and shifts() agree
# ---------------------------------------------------------------------------


def test_shift_structured_agrees_with_shifts():
    """is_shift_structured() is THE eligibility predicate: wherever it says
    True, the sparse engine must accept (non-empty shifts, or C = I)."""
    for topo in (ring(6), torus(2, 3), fully_connected(5), disconnected(6),
                 star(6)):
        structured = topo.is_shift_structured()
        if structured and topo.max_degree > 0:
            assert topo.shifts(), topo.name
        if not structured:
            assert not topo.shifts() or topo.max_degree == 0, topo.name
    assert disconnected(6).is_shift_structured()      # C = I: zero shifts OK
    assert disconnected(6).shifts() == []
    assert not star(6).is_shift_structured()          # hub: not circulant


def test_sharded_substrate_accepts_degenerate_no_edge_topology():
    # The predicate and the engine must agree on C = I: constructing the
    # substrate (which asserts eligibility) must succeed, with no shifts.
    s = sub_lib.ShardedSubstrate(disconnected(4), ("data",))
    assert s.shifts == [] and s.self_weight == 1.0
    with pytest.raises(AssertionError):
        sub_lib.ShardedSubstrate(star(4), ("data",))


def test_sharded_round_fn_rejects_mismatched_mesh():
    """Forcing engine='sparse' bypasses auto-eligibility, so the engine
    itself must reject a mesh whose node axes don't enumerate all nodes
    (it would silently drop every node beyond the axis size)."""
    from repro.core import init_state, make_round_fn
    from repro.optim import sgd

    mesh = jax.make_mesh((1,), ("data",))
    cfg = DFLConfig(tau1=1, tau2=1, topology=ring(4))
    with pytest.raises(AssertionError, match="4 nodes"):
        make_round_fn(cfg, lambda p, b, k: 0.0, sgd(0.1),
                      engine="sparse", mesh=mesh, node_axes=("data",))


def test_sparse_engine_eligibility_rules():
    cfg = DFLConfig(tau1=1, tau2=1, topology=ring(4))
    assert sparse_engine_eligible(cfg, FakeMesh({"data": 4}), ("data",))
    # node axes must enumerate all N nodes
    assert not sparse_engine_eligible(cfg, FakeMesh({"data": 2}), ("data",))
    assert not sparse_engine_eligible(cfg, None, ("data",))
    # non-circulant topology -> dense
    scfg = DFLConfig(tau1=1, tau2=1, topology=star(4))
    assert not sparse_engine_eligible(scfg, FakeMesh({"data": 4}), ("data",))
    # dense-only features -> dense
    pcfg = DFLConfig(tau1=1, tau2=2, topology=ring(4),
                     mixing_impl="dense_power")
    assert not sparse_engine_eligible(pcfg, FakeMesh({"data": 4}), ("data",))
    # single node -> dense
    ocfg = DFLConfig(tau1=1, tau2=1, topology=fully_connected(1))
    assert not sparse_engine_eligible(ocfg, FakeMesh({"data": 1}), ("data",))
    # >1-sized auto axes need a JAX whose partial-manual shard_map works
    mesh_tp = FakeMesh({"data": 4, "model": 2})
    assert (sparse_engine_eligible(cfg, mesh_tp, ("data",))
            == sub_lib.supports_partial_auto())


# ---------------------------------------------------------------------------
# Version-compat shims (must work on the pinned 0.4.37 AND newer JAX)
# ---------------------------------------------------------------------------


def test_compat_shard_map_and_axis_size_single_device():
    mesh = jax.make_mesh((1,), ("data",))

    def body(x):
        return x * sub_lib.axis_size("data") + jax.lax.axis_index("data")

    out = sub_lib.shard_map(body, mesh, (P("data"),), P("data"))(
        jnp.ones((1, 3)))
    np.testing.assert_allclose(np.asarray(out), np.ones((1, 3)))


def test_mix_ppermute_empty_shifts_is_identity():
    mesh = jax.make_mesh((1,), ("data",))
    x = {"w": jnp.arange(4.0)[None]}
    out = sub_lib.shard_map(
        lambda p: M.mix_ppermute_shifts(p, [], 1.0, "data"),
        mesh, (P("data"),), P("data"))(x)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(x["w"]))


# ---------------------------------------------------------------------------
# Dense substrate: shared formulas match the historical reference ones
# ---------------------------------------------------------------------------


def test_dense_substrate_consensus_matches_reference():
    params = {"w": jax.random.normal(jax.random.key(0), (6, 11)),
              "b": jax.random.normal(jax.random.key(1), (6, 3, 2))}
    sub = DenseSubstrate(ring(6))
    got = float(sub.consensus_sq(params))
    want = float(consensus_distance(params))
    assert abs(got - want) < 1e-4 * max(1.0, abs(want))


def test_dense_substrate_node_keys_fold_discipline():
    sub = DenseSubstrate(ring(4))
    key = jax.random.key(3)
    keys = sub.node_keys(key)
    for i in range(4):
        np.testing.assert_array_equal(
            jax.random.key_data(keys[i]),
            jax.random.key_data(jax.random.fold_in(key, i)))
