"""Loop-aware HLO analysis: trip-count correction validated against XLA."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hloanalysis import analyze_text, parse_module


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_match_unrolled():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def scanned(a):
        def body(c, _):
            return jnp.tanh(c @ c), None
        return jax.lax.scan(body, a, None, length=9)[0]

    def unrolled(a):
        for _ in range(9):
            a = jnp.tanh(a @ a)
        return a

    expected = 9 * 2 * 64**3
    f_scan = analyze_text(_compile(scanned, x).as_text())["flops"]
    f_unr = analyze_text(_compile(unrolled, x).as_text())["flops"]
    assert abs(f_scan - expected) / expected < 0.02
    assert abs(f_unr - expected) / expected < 0.02


def test_unrolled_matches_xla_cost_analysis():
    x = jax.ShapeDtypeStruct((96, 96), jnp.float32)

    def f(a):
        return (a @ a) @ a

    compiled = _compile(f, x)
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    mine = analyze_text(compiled.as_text())
    assert abs(mine["flops"] - float(ca["flops"])) / float(ca["flops"]) < 0.02


def test_nested_scans_multiply():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(a):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, a, None, length=5)[0]

    expected = 15 * 2 * 32**3
    got = analyze_text(_compile(f, x).as_text())["flops"]
    assert abs(got - expected) / expected < 0.05


def test_parse_module_finds_entry():
    hlo = _compile(lambda a: a + 1.0,
                   jax.ShapeDtypeStruct((8,), jnp.float32)).as_text()
    comps, entry = parse_module(hlo)
    assert entry is not None and entry in comps


def test_gqa_einsum_flops():
    """dot_general with batch dims counts 2*M*N*K*B."""
    q = jax.ShapeDtypeStruct((4, 16, 8, 32), jnp.float32)
    k = jax.ShapeDtypeStruct((4, 64, 8, 32), jnp.float32)

    def f(q, k):
        return jnp.einsum("bsnd,btnd->bnst", q, k)

    expected = 2 * 4 * 8 * 16 * 64 * 32
    got = analyze_text(_compile(f, q, k).as_text())["flops"]
    assert abs(got - expected) / expected < 0.02
