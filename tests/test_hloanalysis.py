"""Loop-aware HLO analysis: trip-count correction validated against XLA,
plus parser-hardening regressions: collectives nested in fusion bodies and
while loops missing known_trip_count must be reported (warn + count once),
never silently dropped."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hloanalysis import (HloParseWarning, analyze_text,
                                      collective_sites, parse_module)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_match_unrolled():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def scanned(a):
        def body(c, _):
            return jnp.tanh(c @ c), None
        return jax.lax.scan(body, a, None, length=9)[0]

    def unrolled(a):
        for _ in range(9):
            a = jnp.tanh(a @ a)
        return a

    expected = 9 * 2 * 64**3
    f_scan = analyze_text(_compile(scanned, x).as_text())["flops"]
    f_unr = analyze_text(_compile(unrolled, x).as_text())["flops"]
    assert abs(f_scan - expected) / expected < 0.02
    assert abs(f_unr - expected) / expected < 0.02


def test_unrolled_matches_xla_cost_analysis():
    x = jax.ShapeDtypeStruct((96, 96), jnp.float32)

    def f(a):
        return (a @ a) @ a

    compiled = _compile(f, x)
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    mine = analyze_text(compiled.as_text())
    assert abs(mine["flops"] - float(ca["flops"])) / float(ca["flops"]) < 0.02


def test_nested_scans_multiply():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(a):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, a, None, length=5)[0]

    expected = 15 * 2 * 32**3
    got = analyze_text(_compile(f, x).as_text())["flops"]
    assert abs(got - expected) / expected < 0.05


def test_parse_module_finds_entry():
    hlo = _compile(lambda a: a + 1.0,
                   jax.ShapeDtypeStruct((8,), jnp.float32)).as_text()
    comps, entry = parse_module(hlo)
    assert entry is not None and entry in comps


# ---------------------------------------------------------------------------
# hardening regressions (synthetic HLO): no silent drops
# ---------------------------------------------------------------------------

_FUSED_PERMUTE_HLO = """\
HloModule synth_fused

%fbody (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  ROOT %cp = f32[8]{0} collective-permute(%p), source_target_pairs={{0,1},{1,0}}
}

ENTRY %main (x: f32[8]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  ROOT %f = f32[8]{0} fusion(%x), kind=kLoop, calls=%fbody
}
"""


def _while_hlo(trip_annotation):
    return f"""\
HloModule synth_while

%wbody (t: (s32[], f32[8])) -> (s32[], f32[8]) {{
  %t = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[8]{{0}} get-tuple-element(%t), index=1
  %cp = f32[8]{{0}} collective-permute(%x), source_target_pairs={{{{0,1}},{{1,0}}}}
  ROOT %out = (s32[], f32[8]) tuple(%i, %cp)
}}

%wcond (t: (s32[], f32[8])) -> pred[] {{
  %t = (s32[], f32[8]) parameter(0)
  ROOT %lt = pred[] constant(true)
}}

ENTRY %main (init: (s32[], f32[8])) -> (s32[], f32[8]) {{
  %init = (s32[], f32[8]) parameter(0)
  ROOT %w = (s32[], f32[8]) while(%init), condition=%wcond, body=%wbody{trip_annotation}
}}
"""


def test_fusion_nested_collective_is_counted_and_sited():
    # regression: a permute hidden inside a fusion body must show up in
    # both the cost accounting and the site walker (flagged in_fusion).
    res = analyze_text(_FUSED_PERMUTE_HLO)
    assert res["collective_bytes_per_kind"]["collective-permute"] == 8 * 4
    sites = collective_sites(_FUSED_PERMUTE_HLO)
    assert len(sites) == 1
    s = sites[0]
    assert s.opcode == "collective-permute" and s.in_fusion
    assert s.pairs == ((0, 1), (1, 0))
    assert s.trip_product == 1 and s.known_trips


def test_known_trip_while_multiplies_collective_sites():
    hlo = _while_hlo(
        ', backend_config={"known_trip_count":{"n":"5"}}')
    res = analyze_text(hlo)
    assert res["unknown_trip_loops"] == 0
    assert res["collective_bytes_per_kind"]["collective-permute"] == 5 * 8 * 4
    (s,) = collective_sites(hlo)
    assert s.trip_product == 5 and s.known_trips and not s.in_fusion


def test_unknown_trip_while_warns_and_counts_once():
    # regression: a while with no known_trip_count used to be a silent
    # lower bound — now it warns, reports unknown_trip_loops, and the
    # body's collective is still counted (once).
    hlo = _while_hlo("")
    with pytest.warns(HloParseWarning, match="known_trip_count"):
        res = analyze_text(hlo)
    assert res["unknown_trip_loops"] == 1
    assert res["collective_bytes_per_kind"]["collective-permute"] == 8 * 4
    with pytest.warns(HloParseWarning, match="known_trip_count"):
        (s,) = collective_sites(hlo)
    assert s.trip_product == 1 and not s.known_trips
    # warn=False: same sites, no noise (the auditor's pair-matching path).
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        (s2,) = collective_sites(hlo, warn=False)
    assert s2 == s


def test_gqa_einsum_flops():
    """dot_general with batch dims counts 2*M*N*K*B."""
    q = jax.ShapeDtypeStruct((4, 16, 8, 32), jnp.float32)
    k = jax.ShapeDtypeStruct((4, 64, 8, 32), jnp.float32)

    def f(q, k):
        return jnp.einsum("bsnd,btnd->bnst", q, k)

    expected = 2 * 4 * 8 * 16 * 64 * 32
    got = analyze_text(_compile(f, q, k).as_text())["flops"]
    assert abs(got - expected) / expected < 0.02
