"""Determinism regression suite for the mega-scale (batched) path.

Three contracts, each of which a seeded re-run must reproduce EXACTLY:

  * **train twice == same history**: an end-to-end ``train.py`` session
    (dense and batched engines) run twice with identical seeds, faults
    and cohorts writes an identical ``--history-out`` JSON, excluding
    only the monotonic-clock fields (``round_s``) — losses, schedules,
    cohort columns and compile counters are all bit-stable.
  * **shard-order pinning**: the lazy ``SyntheticLM`` keys every
    per-node chain by ``SeedSequence([seed, node])``, so shard content
    is independent of construction order, access order, and prefetcher
    THREADING — and ``lm_batches_for_cohort`` streams by GLOBAL node
    id, so a node's data never depends on which cohort slot it lands in.
  * **checkpoint restart under sampling**: resuming mid-run from an
    atomic checkpoint with a sampled cohort continues bitwise — the
    cohort draw is a pure function of (sampler seed, round), and every
    RNG the round consumes lives in ``DFLState`` (rng, round_idx), so
    nothing outside the checkpoint can shift the continuation.
"""
import json
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import DFLConfig, RoundExecutor, init_state, ring
from repro.data.lm import (SyntheticLM, lm_batches_for_cohort,
                           lm_batches_for_dfl)
from repro.faults import CohortSampler
from repro.optim import sgd

# ---------------------------------------------------------------------------
# train twice -> identical history JSON
# ---------------------------------------------------------------------------

# per-round wall-clock stamps are the ONLY fields a deterministic re-run
# may legitimately change.
_CLOCK_FIELDS = ("round_s",)


def _train_history(tmp_path, tag, argv):
    from repro.launch import train as train_cli

    out = tmp_path / f"hist_{tag}.json"
    train_cli.main(list(argv) + ["--history-out", str(out)])
    h = json.loads(out.read_text())
    for f in _CLOCK_FIELDS:
        h.pop(f, None)
    return h


def test_train_twice_identical_history_dense(tmp_path):
    argv = ["--arch", "qwen3-1.7b", "--nodes", "2", "--rounds", "3",
            "--batch", "1", "--seq", "16", "--log-every", "10"]
    a = _train_history(tmp_path, "dense_a", argv)
    b = _train_history(tmp_path, "dense_b", argv)
    assert a == b
    assert len(a["loss"]) == 3


def test_train_twice_identical_history_batched(tmp_path):
    """Batched engine with a sampled cohort + injected faults: the lazy
    corpus, the prefetcher thread, the cohort draws and the fault masks
    must all be pinned."""
    argv = ["--arch", "qwen3-1.7b", "--nodes", "4", "--topology", "ring",
            "--rounds", "4", "--batch", "1", "--seq", "16",
            "--virtual-nodes", "16", "--cohort", "4", "--cohort-seed", "3",
            "--faults",
            '{"faults": [{"kind": "sporadic", "p_node": 0.8, '
            '"p_edge": 0.9, "r_start": 0, "r_stop": 100}], "seed": 7}',
            "--log-every", "2"]
    a = _train_history(tmp_path, "batched_a", argv)
    b = _train_history(tmp_path, "batched_b", argv)
    assert a == b
    # schema-4 cohort columns are stamped on every sampled round.
    assert a["cohort_size"] == [4] * 4
    assert a["population"] == [16] * 4
    # cohort draws are schedule data on ONE executable: no post-warmup
    # compiles anywhere in the session.
    assert a["compile_count"] == a["compile_count_warmup"]


# ---------------------------------------------------------------------------
# shard-order pinning (lazy corpus + cohort streaming)
# ---------------------------------------------------------------------------


def _batch_leaves(b):
    return {k: np.asarray(v) for k, v in b.items()}


def test_lazy_shards_independent_of_access_order():
    v = 64
    fwd = SyntheticLM(vocab_size=32, num_nodes=v, seed=5, lazy=True)
    rev = SyntheticLM(vocab_size=32, num_nodes=v, seed=5, lazy=True)
    # warm the caches in OPPOSITE orders (the eager builder was
    # order-dependent: chains drawn sequentially from one rng stream).
    for n in range(v):
        fwd.batch(n, 1, 8, step=0)
    for n in reversed(range(v)):
        rev.batch(n, 1, 8, step=0)
    for n in (0, 7, 31, 63):
        a = _batch_leaves(fwd.batch(n, 2, 12, step=3))
        b = _batch_leaves(rev.batch(n, 2, 12, step=3))
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_lazy_shards_threadsafe_by_idempotence():
    """Prefetcher threading: concurrent first-touch of the same shards
    from many threads yields the same bytes as serial access."""
    corpus = SyntheticLM(vocab_size=32, num_nodes=128, seed=9, lazy=True)
    serial = SyntheticLM(vocab_size=32, num_nodes=128, seed=9, lazy=True)
    nodes = list(range(128)) * 2
    with ThreadPoolExecutor(max_workers=8) as pool:
        got = list(pool.map(
            lambda n: _batch_leaves(corpus.batch(n, 1, 8, step=1)), nodes))
    for n, b in zip(nodes, got):
        want = _batch_leaves(serial.batch(n, 1, 8, step=1))
        for k in want:
            np.testing.assert_array_equal(b[k], want[k])


def test_cohort_batches_stream_by_global_id():
    """Slot j streams GLOBAL node ids[j]: an identity cohort reproduces
    the legacy loader bitwise, and a node's shard is the same whatever
    slot (or draw order) it arrives in."""
    corpus = SyntheticLM(vocab_size=32, num_nodes=16, seed=2, lazy=True)
    ids = np.arange(4, dtype=np.int32)
    a = lm_batches_for_cohort(corpus, 2, ids, 1, 8, round_idx=5)
    b = lm_batches_for_dfl(corpus, 2, 4, 1, 8, round_idx=5)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    # permuted cohort: slot contents follow the ids, not the slots.
    perm = np.array([14, 3, 9, 6], np.int32)
    c = lm_batches_for_cohort(corpus, 2, perm, 1, 8, round_idx=5)
    sorted_ids = np.sort(perm)
    d = lm_batches_for_cohort(corpus, 2, sorted_ids, 1, 8, round_idx=5)
    order = np.argsort(perm)
    for k in c:
        np.testing.assert_array_equal(np.asarray(c[k])[:, order],
                                      np.asarray(d[k]))
    with pytest.raises(ValueError, match="1-D"):
        lm_batches_for_cohort(corpus, 2, perm[None], 1, 8, round_idx=0)


def test_eager_corpus_unchanged_by_lazy_refactor():
    """The eager default must keep its historical sequential-rng chains
    (lazy is opt-in; the two modes intentionally differ)."""
    eager = SyntheticLM(vocab_size=32, num_nodes=4, seed=5)
    lazy = SyntheticLM(vocab_size=32, num_nodes=4, seed=5, lazy=True)
    rng = np.random.default_rng(5)
    want_shared_nxt = rng.integers(0, 32, size=(32, 16))
    np.testing.assert_array_equal(eager._shared[0], want_shared_nxt)
    assert not np.array_equal(eager._shared[0], lazy._shared[0])


# ---------------------------------------------------------------------------
# checkpoint restart under sampling
# ---------------------------------------------------------------------------

DIM = 7
TAU1, TAU2 = 2, 1


def noisy_loss(p, b, k=None):
    jitter = 0.05 * jax.random.normal(k, p["w"].shape)
    return jnp.mean((p["w"] + jitter - b) ** 2)


def _ckpt_tree(state):
    """Everything a bitwise resume needs, as npz-serializable leaves.

    The cohort draw itself needs NO entry: it is a pure function of the
    sampler's (seed, round), and the round index rides DFLState."""
    return {
        "params": state.params,
        "opt_state": state.opt_state,
        "hat_params": state.hat_params,
        "rng": jax.random.key_data(state.rng),
        "round_idx": np.asarray(state.round_idx),
    }


def _state_from_tree(template_state, tree):
    return template_state._replace(
        params=tree["params"],
        opt_state=tree["opt_state"],
        hat_params=tree["hat_params"],
        rng=jax.random.wrap_key_data(jnp.asarray(tree["rng"])),
        round_idx=jnp.asarray(tree["round_idx"]))


def assert_model_state_bitwise(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a.params),
                    jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree_util.tree_leaves(a.opt_state),
                    jax.tree_util.tree_leaves(b.opt_state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert int(a.round_idx) == int(b.round_idx)


def test_checkpoint_restart_under_sampling_bitwise(tmp_path):
    """Atomic-checkpoint resume mid-run with a SAMPLED cohort continues
    bitwise (the batched analogue of PR 9's drain-at-boundary restart):
    rounds 2..3 dispatched by a fresh executor from the restored state
    equal the uninterrupted run, because round r's cohort redraws from
    (seed, r) and all consumed RNG lives in DFLState."""
    pop, k_total = 16, 4
    topo = ring(4)
    opt = sgd(0.1)
    cfg = DFLConfig(tau1=TAU1, tau2=TAU2, topology=topo)
    sampler = CohortSampler(population=pop, cohort=4, seed=11)
    rows = sampler.cohort_trajectory(
        np.tile(np.array([[TAU1, TAU2]], np.int32), (k_total, 1)),
        round0=0, num_edges=topo.num_edges)
    batches = jax.random.normal(jax.random.key(7),
                                (k_total, TAU1, 4, DIM))

    def fresh():
        return init_state({"w": jnp.zeros((DIM,))}, pop, opt,
                          jax.random.key(1))

    # uninterrupted 4-round reference.
    ex = RoundExecutor(cfg, noisy_loss, opt, engine="batched",
                       population=pop, donate=False)
    ref, _ = ex.dispatch_trajectory(fresh(), batches, rows)

    # run rounds 0..1, checkpoint through DISK, resume in a fresh
    # executor, run rounds 2..3.
    ex_a = RoundExecutor(cfg, noisy_loss, opt, engine="batched",
                         population=pop, donate=False)
    mid, _ = ex_a.dispatch_trajectory(
        fresh(), jax.tree_util.tree_map(lambda x: x[:2], batches),
        rows[:2])
    save_checkpoint(str(tmp_path), 2, _ckpt_tree(mid), {"loss": 0.0})
    del ex_a, mid

    restored_tree, step = restore_checkpoint(str(tmp_path),
                                             _ckpt_tree(fresh()))
    assert step == 2
    resumed = _state_from_tree(fresh(), restored_tree)
    assert int(resumed.round_idx) == 2
    ex_b = RoundExecutor(cfg, noisy_loss, opt, engine="batched",
                         population=pop, donate=False)
    # the resumed half replays the SAME absolute rounds: the sampler
    # re-derives rounds 2..3's cohorts from (seed, round) alone.
    rows_tail = sampler.cohort_trajectory(
        np.tile(np.array([[TAU1, TAU2]], np.int32), (2, 1)),
        round0=2, num_edges=topo.num_edges)
    np.testing.assert_array_equal(rows_tail, rows[2:])
    end, _ = ex_b.dispatch_trajectory(
        resumed, jax.tree_util.tree_map(lambda x: x[2:], batches),
        rows_tail)
    assert_model_state_bitwise(end, ref)


def test_checkpoint_restart_with_choco_hat(tmp_path):
    """Same restart, CHOCO compression: hat_params is part of the
    checkpointed state and the resumed error-feedback chain is bitwise."""
    from repro.core import make_compressor

    pop = 12
    topo = ring(4)
    opt = sgd(0.1)
    comp = make_compressor("qsgd", levels=4)
    cfg = DFLConfig(tau1=TAU1, tau2=TAU2, topology=topo, compression=comp,
                    gamma=0.5)
    sampler = CohortSampler(population=pop, cohort=4, seed=21)
    rows = sampler.cohort_trajectory(
        np.tile(np.array([[TAU1, TAU2]], np.int32), (4, 1)),
        round0=0, num_edges=topo.num_edges)
    batches = jax.random.normal(jax.random.key(3), (4, TAU1, 4, DIM))

    def fresh():
        return init_state({"w": jnp.zeros((DIM,))}, pop, opt,
                          jax.random.key(2), compressed=True)

    ex = RoundExecutor(cfg, noisy_loss, opt, engine="batched",
                       population=pop, donate=False)
    ref, _ = ex.dispatch_trajectory(fresh(), batches, rows)

    mid, _ = ex.dispatch_trajectory(
        fresh(), jax.tree_util.tree_map(lambda x: x[:2], batches),
        rows[:2])
    save_checkpoint(str(tmp_path), 2, _ckpt_tree(mid), {})
    restored_tree, _ = restore_checkpoint(str(tmp_path),
                                          _ckpt_tree(fresh()))
    resumed = _state_from_tree(fresh(), restored_tree)
    end, _ = ex.dispatch_trajectory(
        resumed, jax.tree_util.tree_map(lambda x: x[2:], batches),
        rows[2:])
    assert_model_state_bitwise(end, ref)
    for x, y in zip(jax.tree_util.tree_leaves(end.hat_params),
                    jax.tree_util.tree_leaves(ref.hat_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
