"""The resource-constrained planner: cost models, Proposition-1 bounds as a
library, grid search, and the adaptive controller.

The headline acceptance tests reproduce the paper's qualitative result
end-to-end on the analytic quadratic testbed (benchmarks/theory_check):
as t_comm/t_compute rises the planned tau1/tau2 ratio is non-decreasing,
and the planned schedule's MEASURED loss at budget beats every other grid
point's (bench_balance-style simulation, not just the bound).
"""
import warnings

import numpy as np
import pytest

from benchmarks.theory_check import run_dfl_quadratic
from repro.core.compression import QSGD, TopK
from repro.core.topology import fully_connected, ring, star
from repro.planner import (AdaptiveController, Budget, ComputeModel,
                           CostModel, CostProcess, Episode, LinkModel,
                           WirelessLinks, bounds, edge_outage,
                           evaluate_grid, faded_links, plan,
                           plan_trajectory, rounds_within, select_plan,
                           straggler_links, unit_cost_model, wireless_link)

# -- the quadratic testbed shared by the acceptance tests -------------------

TOPO = ring(8)
SIGMA = 0.5            # sampling-noise sigma of the testbed
TSCALE = 0.8           # target (heterogeneity) scale
REF_ROUNDS = 60        # budget = this many rounds of the (2, 2) schedule
GRID = [(1, 4), (1, 2), (2, 2), (2, 1), (4, 1), (8, 1)]
SEEDS = 4
DIM = 16


def _testbed_constants():
    """f_gap and the Assumption-1.5 sigma (sampling + heterogeneity)."""
    rng = np.random.default_rng(0)
    targets = rng.normal(size=(TOPO.num_nodes, DIM)) * TSCALE
    tbar = targets.mean(0)
    f_gap = 0.5 * float(np.sum(tbar**2))
    sig_eff = np.sqrt(SIGMA**2
                      + float(np.max(np.sum((targets - tbar) ** 2, axis=1))))
    return f_gap, sig_eff


def _measured(eta, tau1, tau2, rounds):
    """Mean measured avg ||grad F(u_t)||^2 — the quantity bound (20)
    bounds — over the testbed seeds."""
    return float(np.mean([
        run_dfl_quadratic(eta, tau1, tau2, TOPO, rounds, d=DIM, sigma=SIGMA,
                          seed=s, target_scale=TSCALE)[0]
        for s in range(SEEDS)]))


def _plan_at(ratio):
    f_gap, sig_eff = _testbed_constants()
    cm = unit_cost_model(TOPO, ratio)
    budget = Budget(wall_clock_s=cm.round_cost(2, 2).time_s * REF_ROUNDS)
    cands = evaluate_grid(budget, cm, sigma=sig_eff, f_gap=f_gap, grid=GRID)
    return select_plan(cands), cands


# -- acceptance: the paper's qualitative result end-to-end ------------------


def test_planned_ratio_monotone_in_comm_cost():
    """As t_comm/t_compute rises, planned tau1/tau2 is non-decreasing and
    strictly rises across the sweep (paper Sec. V: slower links shift the
    balance toward local computation)."""
    ratios = [_plan_at(r)[0] for r in (0.2, 1.0, 5.0, 25.0)]
    tau_ratio = [p.tau1 / p.tau2 for p in ratios]
    assert all(a <= b for a, b in zip(tau_ratio, tau_ratio[1:])), tau_ratio
    assert tau_ratio[-1] > tau_ratio[0], tau_ratio


@pytest.mark.parametrize("ratio", [0.2, 25.0])
def test_planned_schedule_wins_empirically(ratio):
    """The planned schedule's measured loss at budget is <= every other
    grid point's, on actual Algorithm-1 runs (not the bound)."""
    p, cands = _plan_at(ratio)
    measured = {(c.tau1, c.tau2): _measured(c.eta, c.tau1, c.tau2, c.rounds)
                for c in cands}
    mine = measured[(p.tau1, p.tau2)]
    assert mine <= min(measured.values()) + 1e-12, (p.tau1, p.tau2, measured)


# -- cost models ------------------------------------------------------------


def test_unit_cost_model_prices_the_ratio():
    cm = unit_cost_model(TOPO, 5.0)
    rc = cm.round_cost(4, 2)
    assert rc.t_compute_step == pytest.approx(1.0)
    assert rc.t_gossip_step == pytest.approx(5.0)
    assert rc.time_s == pytest.approx(4 + 2 * 5.0)
    assert rc.comm_fraction == pytest.approx(10.0 / 14.0)


def test_engine_accounting_dense_vs_sparse():
    """Dense all-gather lowering ships N-1 copies; sparse ships degree."""
    base = dict(compute=ComputeModel(1e9, 1e12),
                link=LinkModel(1e9), topology=ring(10), model_bits=32e6)
    sparse = CostModel(engine="sparse", **base)
    dense = CostModel(engine="dense", **base)
    assert sparse.copies_per_step() == 2
    assert dense.copies_per_step() == 9
    assert (dense.round_cost(1, 1).wire_bits
            == pytest.approx(sparse.round_cost(1, 1).wire_bits * 9 / 2))


def test_compression_reduces_cost():
    cm = unit_cost_model(TOPO, 1.0)
    full = cm.round_cost(2, 4)
    topk = cm.round_cost(2, 4, TopK(frac=0.25))
    qsgd = cm.round_cost(2, 4, QSGD(levels=16))
    assert topk.wire_bits < full.wire_bits
    assert qsgd.wire_bits < full.wire_bits
    assert topk.time_s < full.time_s
    # compute side is untouched by compression
    assert topk.t_compute_step == full.t_compute_step


def test_wireless_links_snr_and_slowest_edge():
    """Lower SNR -> slower link; the slowest edge gates the gossip step."""
    fast = wireless_link(20e6, 30.0)
    slow = wireless_link(20e6, 0.0)
    assert slow.bytes_per_s < fast.bytes_per_s
    topo = ring(6)
    uniform = CostModel(
        compute=ComputeModel(1e9, 1e12),
        link=WirelessLinks(default=fast), topology=topo, model_bits=8e6)
    degraded = CostModel(
        compute=ComputeModel(1e9, 1e12),
        link=WirelessLinks(default=fast, per_edge={(0, 1): slow}),
        topology=topo, model_bits=8e6)
    assert (degraded.t_gossip_step()
            > uniform.t_gossip_step())
    # serial (half-duplex) radios sum per-node transfers
    serial = CostModel(
        compute=ComputeModel(1e9, 1e12),
        link=WirelessLinks(default=fast, concurrency="serial"),
        topology=topo, model_bits=8e6)
    assert serial.t_gossip_step() == pytest.approx(
        2 * uniform.t_gossip_step())


def test_budget_currencies():
    cm = unit_cost_model(TOPO, 1.0)
    rc = cm.round_cost(4, 4)
    assert rounds_within(Budget(wall_clock_s=80.0), rc) == 10
    assert rounds_within(Budget(wire_bits=rc.wire_bits * 3.5), rc) == 3
    # the tightest currency binds
    assert rounds_within(Budget(wall_clock_s=80.0,
                                wire_bits=rc.wire_bits * 3.5), rc) == 3
    with pytest.raises(ValueError):
        Budget()


def test_plan_infeasible_budget_raises():
    cm = unit_cost_model(TOPO, 1.0)
    with pytest.raises(ValueError):
        plan(Budget(wall_clock_s=0.5), cm, sigma=1.0, f_gap=1.0,
             grid=[(4, 4)])


# -- time-varying processes & per-round trajectories ------------------------


def _wireless_unit(t_gossip: float):
    """WirelessLinks pricing one gossip step at ``t_gossip`` units."""
    copy_bytes = 32.0 * DIM / 8.0
    return WirelessLinks(default=LinkModel(bytes_per_s=copy_bytes / t_gossip))


def _process(episodes=()):
    base = CostModel(compute=ComputeModel(1.0, 1.0),
                     link=_wireless_unit(1.0), topology=TOPO,
                     model_bits=32.0 * DIM)
    return CostProcess(base=base, episodes=tuple(episodes))


def test_link_helpers_price_per_edge():
    """straggler slows ONLY the touched edges (each exactly once), fading
    slows everything, outage drops named edges to a residual rate."""
    wl = _wireless_unit(1.0)
    strag = straggler_links(wl, TOPO, 0, 10.0)
    assert strag.link(0, 1).bytes_per_s == pytest.approx(
        wl.default.bytes_per_s / 10.0)   # scaled ONCE, not once per side
    assert strag.link(0, 7).bytes_per_s == pytest.approx(
        wl.default.bytes_per_s / 10.0)
    assert strag.link(2, 3).bytes_per_s == wl.default.bytes_per_s
    fade = faded_links(wl, 10.0)
    assert fade.link(2, 3).bytes_per_s == pytest.approx(
        wl.default.bytes_per_s / 10.0)
    out = edge_outage(wl, [(3, 2)], residual=1e-3)
    assert out.link(2, 3).bytes_per_s == pytest.approx(
        wl.default.bytes_per_s * 1e-3)
    assert out.link(0, 1).bytes_per_s == wl.default.bytes_per_s
    # one slow edge gates the whole synchronous gossip step
    cm = CostModel(compute=ComputeModel(1.0, 1.0), link=strag,
                   topology=TOPO, model_bits=32.0 * DIM)
    assert cm.t_gossip_step() == pytest.approx(10.0)


def test_cost_process_episode_windows_and_compute_scale():
    proc = _process([Episode(10.0, 20.0, link=faded_links(
        _wireless_unit(1.0), 50.0), compute_scale=2.0, label="ep")])
    assert not proc.is_static and proc.horizon() == 20.0
    assert proc.at(5.0).t_gossip_step() == pytest.approx(1.0)
    assert proc.at(15.0).t_gossip_step() == pytest.approx(50.0)
    assert proc.at(15.0).compute.t_step == pytest.approx(2.0)
    assert proc.at(20.0).t_gossip_step() == pytest.approx(1.0)  # half-open
    assert _process().is_static


def test_plan_trajectory_degenerates_to_plan_when_time_invariant():
    """The satellite acceptance: a static process yields EXACTLY the fixed
    plan's schedule, repeated."""
    f_gap, sig_eff = _testbed_constants()
    proc = _process()
    budget = Budget(wall_clock_s=proc.base.round_cost(2, 2).time_s
                    * REF_ROUNDS)
    p = plan(budget, proc.base, sigma=sig_eff, f_gap=f_gap, grid=GRID)
    tp = plan_trajectory(budget, proc, rounds=40, sigma=sig_eff,
                         f_gap=f_gap, grid=GRID)
    assert tp.rounds == min(p.rounds, 40)
    assert all((t1, t2) == (p.tau1, p.tau2) for (t1, t2) in tp.taus)
    assert tp.steps[0].eta == p.eta
    assert tp.total_time_s == pytest.approx(
        p.round_cost.time_s * tp.rounds)
    assert tp.tau_maxima == (p.tau1, p.tau2)


def test_plan_trajectory_shifts_through_episodes():
    """During an outage-severity episode the per-round schedule drops
    gossip (tau2-light / compute-only rounds); off-episode it keeps the
    base plan's balance — and the whole trajectory respects the budget on
    the process clock."""
    f_gap, sig_eff = _testbed_constants()
    grid = GRID + [(1, 0), (8, 0)]
    ep_link = straggler_links(_wireless_unit(1.0), TOPO, 0, 1000.0)
    proc = _process([Episode(30.0, 90.0, link=ep_link)])
    budget = Budget(wall_clock_s=150.0)
    tp = plan_trajectory(budget, proc, rounds=500, sigma=sig_eff,
                         f_gap=f_gap, grid=grid)
    assert tp.total_time_s <= 150.0 + 1e-9
    # walk the clock: split rounds into off-episode and in-episode
    clock, in_ep, off_ep = 0.0, [], []
    for p in tp.steps:
        (in_ep if 30.0 <= clock < 90.0 else off_ep).append((p.tau1, p.tau2))
        clock += p.round_cost.time_s
    assert in_ep and off_ep
    # every in-episode round avoids the ruinous gossip entirely
    assert all(t2 == 0 for _, t2 in in_ep), in_ep
    # off-episode rounds gossip (the base tariff makes it worthwhile)
    assert any(t2 >= 1 for _, t2 in off_ep), off_ep


def test_plan_trajectory_infeasible_budget_raises():
    with pytest.raises(ValueError):
        plan_trajectory(Budget(wall_clock_s=0.5), _process(), rounds=10,
                        sigma=1.0, f_gap=1.0, grid=[(4, 4)])


def test_bounds_reject_standing_tau2_zero():
    """tau2 = 0 on a non-complete graph is a never-gossip POLICY: no
    finite bound, no admissible eta — it stays a last-resort trajectory
    grid point via select_plan's tie-break."""
    assert not bounds.lr_condition_19(0.01, 4, 0, TOPO)
    assert bounds.bound_20(0.01, 4, 0, TOPO, 100, 1.0, 1.0, 8) == float("inf")
    ev = bounds.predicted_loss_decrement(4, 0, TOPO, 1.0, T=100, f_gap=1.0)
    assert ev.bound == float("inf")
    # the complete graph is no exception: tau2 = 0 means no communication
    # STEPS at all, however fast the graph would mix — including
    # fully_connected(2), whose zeta computes to EXACTLY 0.0 (the guard
    # is num_nodes > 1, not float-noise zeta > 0)
    for full in (fully_connected(8), fully_connected(2)):
        assert bounds.predicted_loss_decrement(
            4, 0, full, 1.0, T=100, f_gap=1.0).bound == float("inf")
        assert not bounds.lr_condition_19(0.01, 4, 0, full)
        assert bounds.max_eta_19(4, 0, full) == 0.0
        assert bounds.bound_20(0.01, 4, 0, full, 100, 1.0, 1.0,
                               full.num_nodes) == float("inf")
    # a single node has no consensus to lose: tau2 = 0 stays finite
    assert np.isfinite(bounds.predicted_loss_decrement(
        4, 0, fully_connected(1), 1.0, T=100, f_gap=1.0).bound)


# -- deprecation shim -------------------------------------------------------


def test_metrics_shim_matches_planner_on_docstring_example():
    from repro.core.metrics import comm_compute_cost as old
    from repro.planner.cost import comm_compute_cost as new

    kw = dict(step_flops=1e9, model_bytes=4e6, degree=2, flops_per_s=1e12,
              link_bytes_per_s=1e9)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got = old(4, 2, 10, **kw)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    want = new(4, 2, 10, **kw)
    assert got == want
    assert got["t_compute"] == pytest.approx(1e-3)
    assert got["t_comm"] == pytest.approx(8e-3)


# -- bounds library ---------------------------------------------------------


def test_bounds_moved_and_reexported():
    import benchmarks.theory_check as tc

    assert tc.lr_condition_19 is bounds.lr_condition_19
    assert tc.bound_20 is bounds.bound_20
    assert tc.max_eta_19 is bounds.max_eta_19


def test_predicted_loss_decrement_improves_with_iterations():
    a = bounds.predicted_loss_decrement(4, 2, TOPO, 1.0, T=600, f_gap=1.0)
    b = bounds.predicted_loss_decrement(4, 2, TOPO, 1.0, T=60, f_gap=1.0)
    assert np.isfinite(a.bound) and a.bound < b.bound
    assert bounds.lr_condition_19(a.eta, 4, 2, TOPO)
    assert a.bound == pytest.approx(a.opt_term + a.stat_term + a.drift_term)


def test_cdfl_constants():
    topo = ring(8)
    g = bounds.choco_gamma_star(topo, 0.5)
    assert 0.0 < g < 1.0
    c_full = bounds.cdfl_contraction(topo, 0.5)
    c_half = bounds.cdfl_contraction(topo, 0.5, gamma=g / 2)
    assert 0.0 < c_full < 1.0
    assert c_full < c_half < 1.0          # less gamma -> slower consensus
    # uncompressed mixing keeps the exact spectral zeta
    assert bounds.effective_zeta(topo) == pytest.approx(topo.zeta)
    # compression can never mix FASTER than uncompressed
    z_comp = bounds.effective_zeta(topo, delta=0.25)
    assert topo.zeta <= z_comp < 1.0
    # perfect averaging degrades gracefully too
    z_full = bounds.effective_zeta(fully_connected(8), delta=0.25)
    assert 0.0 <= z_full < 1.0


def test_plan_with_compression_candidates():
    """With an expensive link, a compressed candidate can buy more rounds;
    the chosen plan must at least not be worse in predicted bound than the
    best uncompressed candidate."""
    f_gap, sig_eff = _testbed_constants()
    cm = unit_cost_model(TOPO, 25.0)
    budget = Budget(wall_clock_s=cm.round_cost(2, 2).time_s * REF_ROUNDS)
    p_plain = plan(budget, cm, sigma=sig_eff, f_gap=f_gap, grid=GRID)
    p_comp = plan(budget, cm, sigma=sig_eff, f_gap=f_gap, grid=GRID,
                  compressors=(None, QSGD(levels=16)))
    assert p_comp.predicted_bound <= p_plain.predicted_bound
    assert p_comp.compressor_name in ("none", "qsgd")


def test_non_circulant_topology_priced():
    """Cost model works for any topology (star has degree N-1 hub)."""
    cm = CostModel(compute=ComputeModel(1e9, 1e12), link=LinkModel(1e9),
                   topology=star(8), model_bits=32e6, engine="sparse")
    assert cm.copies_per_step() == 7  # the hub's degree gates accounting


# -- adaptive controller ----------------------------------------------------


def _controller(ratio_prior, budget_s, replan_every=5):
    cm = unit_cost_model(TOPO, ratio_prior)
    f_gap, sig_eff = _testbed_constants()
    return AdaptiveController(
        Budget(wall_clock_s=budget_s), cm, sigma=sig_eff, f_gap=f_gap,
        replan_every=replan_every, grid=GRID)


def test_adaptive_refits_and_replans_to_true_costs():
    """Prior says comm is cheap; measurements reveal comm 25x compute.
    After replanning the controller must shift to a tau1-heavier schedule
    and its fitted per-step times must match the true ones."""
    t_step, t_gossip = 1.0, 25.0
    ctrl = _controller(ratio_prior=0.2, budget_s=(2 + 2 * 25.0) * REF_ROUNDS)
    p0 = ctrl.initial_plan()
    rng = np.random.default_rng(0)
    tau1, tau2 = p0.tau1, p0.tau2
    for r in range(1, 16):
        seconds = (tau1 * t_step + tau2 * t_gossip
                   * (1 + 0.01 * rng.standard_normal()))
        ctrl.observe(tau1, tau2, seconds)
        new = ctrl.maybe_replan(r)
        if new is not None:
            tau1, tau2 = new.tau1, new.tau2
    assert not ctrl.exhausted
    last = ctrl.current
    assert (last.tau1 / last.tau2) > (p0.tau1 / p0.tau2)
    fitted = ctrl.fitted_cost_model()
    assert fitted.compute.t_step == pytest.approx(t_step, rel=0.2)
    assert fitted.t_gossip_step(None) == pytest.approx(t_gossip, rel=0.2)
    # every (re)plan event is in the history with the schedule it chose
    assert ctrl.history[0]["cause"] == "initial"
    assert any(h["cause"] == "replan" for h in ctrl.history)
    assert all({"round", "tau1", "tau2", "predicted_bound"} <= set(h)
               for h in ctrl.history)


def test_observe_fits_every_round_and_deprecates_fit_kwarg():
    """With the recompile-free executor no round is compile-contaminated:
    observe() enters EVERY measured round into the cost fit, and the old
    ``fit=`` escape hatch is a deprecation shim that is ignored."""
    ctrl = _controller(ratio_prior=1.0, budget_s=1e6)
    ctrl.initial_plan()
    t1, t2 = ctrl.current.tau1, ctrl.current.tau2
    ctrl.observe(t1, t2, 1.0)
    assert len(ctrl.observations) == 1
    with pytest.warns(DeprecationWarning, match="fit"):
        ctrl.observe(t1, t2, 1.0, fit=False)   # ignored: still fitted
    with pytest.warns(DeprecationWarning, match="fit"):
        ctrl.observe(t1, t2, 1.0, fit=True)
    assert len(ctrl.observations) == 3
    # budget is spent for every observed round regardless.
    assert ctrl.spent_s == pytest.approx(3.0)


def test_adaptive_rank_deficient_fallback_scales_prior():
    """With all observations at one schedule the 2-unknown fit is rank-1:
    the controller scales the prior uniformly instead of diverging."""
    ctrl = _controller(ratio_prior=1.0, budget_s=1e6)
    ctrl.initial_plan()
    t1, t2 = ctrl.current.tau1, ctrl.current.tau2
    prior_round = t1 * 1.0 + t2 * 1.0
    for _ in range(6):
        ctrl.observe(t1, t2, 10.0 * prior_round)   # 10x slower than prior
    fitted = ctrl.fitted_cost_model()
    assert fitted.compute.t_step == pytest.approx(10.0, rel=1e-6)
    assert fitted.t_gossip_step(None) == pytest.approx(10.0, rel=1e-6)


def test_adaptive_probes_rank_deficient_fit_then_replans():
    """All history at one schedule -> the boundary emits a PROBE (a
    rank-raising grid schedule, cause "probe") instead of re-planning off
    the unidentifiable scaled fit; once the probe's rounds are measured
    the next boundary is a real re-plan off a rank-2 fit."""
    ctrl = _controller(ratio_prior=0.2, budget_s=1e5, replan_every=3)
    p = ctrl.initial_plan()
    t_step, t_gossip = 1.0, 25.0
    rows = np.array([[p.tau1, p.tau2]], dtype=float)
    for r in range(1, 4):
        ctrl.observe(p.tau1, p.tau2, p.tau1 * t_step + p.tau2 * t_gossip)
    probe = ctrl.maybe_replan(3)
    assert probe is not None
    assert ctrl.history[-1]["cause"] == "probe"
    # the probe row makes the fit full-rank BY CONSTRUCTION
    rows = np.vstack([rows, [probe.tau1, probe.tau2]])
    assert np.linalg.matrix_rank(rows) == 2
    for r in range(4, 7):
        ctrl.observe(probe.tau1, probe.tau2,
                     probe.tau1 * t_step + probe.tau2 * t_gossip)
    assert ctrl.fit_rank() == 2
    ctrl.maybe_replan(6)
    assert ctrl.history[-1]["cause"] == "replan"
    fitted = ctrl.fitted_cost_model()
    assert fitted.compute.t_step == pytest.approx(t_step, rel=1e-3)
    assert fitted.t_gossip_step(None) == pytest.approx(t_gossip, rel=1e-3)


def test_next_trajectory_uniform_chunk_and_probe_ride():
    """Without a process the emitted chunk is the fitted plan's schedule
    uniformly — except a probe riding the LAST round when the fit is
    rank-deficient; the trajectory event lands in the history."""
    ctrl = _controller(ratio_prior=1.0, budget_s=1e5)
    p = ctrl.initial_plan()
    taus = ctrl.next_trajectory(4)
    # no observations yet: no probe, uniform current plan
    assert taus.shape == (4, 2)
    assert all((t1, t2) == (p.tau1, p.tau2) for (t1, t2) in taus)
    for (t1, t2) in taus:
        ctrl.observe(int(t1), int(t2), 5.0)
    taus2 = ctrl.next_trajectory(4, round_idx=4)
    assert taus2 is not None and ctrl.fit_rank() < 2
    head, probe = taus2[:-1], taus2[-1]
    assert np.linalg.matrix_rank(
        np.vstack([ctrl._obs_rows(), probe[None].astype(float)])) == 2
    ev = ctrl.history[-1]
    assert ev["cause"] == "trajectory"
    assert ev["probe"] == [int(probe[0]), int(probe[1])]
    assert len(ev["schedule"]) == 4


def test_next_trajectory_with_known_process_routes_around_episode():
    """A controller given a KNOWN episode process emits heterogeneous
    chunks: the episode rounds drop gossip while off-episode rounds keep
    it (re-planning INSIDE the superstep)."""
    f_gap, sig_eff = _testbed_constants()
    grid = GRID + [(1, 0), (8, 0)]
    copy_bytes = 32.0 * DIM / 8.0
    wl = WirelessLinks(default=LinkModel(bytes_per_s=copy_bytes))
    base = CostModel(compute=ComputeModel(1.0, 1.0), link=wl,
                     topology=TOPO, model_bits=32.0 * DIM)
    proc = CostProcess(base=base, episodes=(
        Episode(6.0, 200.0, link=straggler_links(wl, TOPO, 0, 1000.0)),))
    ctrl = AdaptiveController(Budget(wall_clock_s=300.0), base,
                              sigma=sig_eff, f_gap=f_gap, grid=grid,
                              process=proc)
    ctrl.initial_plan()
    taus = ctrl.next_trajectory(12)
    assert taus is not None
    # the chunk starts at clock 0 (off-episode, gossip worthwhile) and
    # crosses into the episode (compute-only rounds)
    assert taus[0][1] >= 1, taus
    assert any(t2 == 0 for _, t2 in taus), taus


def test_observe_chunk_aggregates_heterogeneous_supersteps():
    """A fused heterogeneous superstep is only host-timed as a WHOLE:
    observe_chunk enters ONE (sum tau1, sum tau2) fit row, so mixed-
    schedule chunks (probe included) identify the true per-step times
    exactly — per-round amortized times would corrupt the fit."""
    t_step, t_gossip = 1.0, 25.0
    ctrl = _controller(ratio_prior=1.0, budget_s=1e6)
    ctrl.initial_plan()

    def chunk_seconds(taus):
        return sum(t1 * t_step + t2 * t_gossip for (t1, t2) in taus)

    uniform = [(4, 1)] * 5
    with_probe = [(4, 1)] * 4 + [(1, 4)]
    ctrl.observe_chunk(uniform, chunk_seconds(uniform))
    assert ctrl.fit_rank() == 1 and len(ctrl.observations) == 1
    assert ctrl.observations[0].tau1 == 20 and ctrl.observations[0].tau2 == 5
    ctrl.observe_chunk(with_probe, chunk_seconds(with_probe))
    assert ctrl.fit_rank() == 2
    fitted = ctrl.fitted_cost_model()
    assert fitted.compute.t_step == pytest.approx(t_step, rel=1e-6)
    assert fitted.t_gossip_step(None) == pytest.approx(t_gossip, rel=1e-6)
    # budget spend matches the measured chunk totals
    assert ctrl.spent_s == pytest.approx(chunk_seconds(uniform)
                                         + chunk_seconds(with_probe))


def test_next_trajectory_probe_skipped_when_unaffordable():
    """A rank-raising probe that would blow the remaining budget is
    dropped (the chunk keeps its planned schedule) rather than dispatched
    past the envelope."""
    cm = unit_cost_model(TOPO, 100.0)   # gossip brutally expensive
    f_gap, sig_eff = _testbed_constants()
    ctrl = AdaptiveController(Budget(wall_clock_s=250.0), cm,
                              sigma=sig_eff, f_gap=f_gap,
                              grid=[(1, 0), (2, 0), (8, 1)])
    ctrl.initial_plan()
    p = ctrl.current
    for _ in range(3):
        ctrl.observe(p.tau1, p.tau2, 1.0)
    taus = ctrl.next_trajectory(4, round_idx=3)
    assert taus is not None
    ev = ctrl.history[-1]
    if ev["probe"] is not None:   # probe only rides when it fits
        t1, t2 = ev["probe"]
        rc = ctrl.cost_model.round_cost(t1, t2)
        assert rc.time_s <= 250.0 - ctrl.spent_s


def test_next_trajectory_exhaustion():
    ctrl = _controller(ratio_prior=1.0, budget_s=10.0)
    p = ctrl.initial_plan()
    ctrl.observe(p.tau1, p.tau2, 50.0)   # blow the whole budget
    assert ctrl.next_trajectory(4, round_idx=1) is None
    assert ctrl.exhausted


def test_adaptive_energy_budget_spend_down():
    """An energy-only budget is spent down analytically per round and
    triggers exhaustion; the fitted model keeps the energy prices."""
    f_gap, sig_eff = _testbed_constants()
    cm = CostModel(
        compute=ComputeModel(step_flops=1.0, flops_per_s=1.0,
                             joules_per_flop=2.0),
        link=LinkModel(bytes_per_s=1.0, joules_per_byte=0.5),
        topology=TOPO, model_bits=80.0)
    per_round = {(t1, t2): cm.round_cost(t1, t2).energy_j for t1, t2 in GRID}
    budget_j = 40.0 * min(per_round.values())
    ctrl = AdaptiveController(Budget(energy_j=budget_j), cm, sigma=sig_eff,
                              f_gap=f_gap, grid=GRID, replan_every=1)
    p = ctrl.initial_plan()
    r = 0
    while not ctrl.exhausted and r < 500:
        r += 1
        ctrl.observe(p.tau1, p.tau2, 1.0)
        new = ctrl.maybe_replan(r)
        p = new or p
    assert ctrl.exhausted and r < 500
    assert ctrl.spent_j <= budget_j + max(per_round.values())
    assert ctrl.spent_j >= budget_j - max(per_round.values())
    # the measured-time refit must not drop the energy pricing
    assert ctrl.fitted_cost_model().round_cost(2, 2).energy_j > 0.0


def test_adaptive_budget_exhaustion():
    ctrl = _controller(ratio_prior=1.0, budget_s=100.0, replan_every=1)
    p = ctrl.initial_plan()
    spent, r = 0.0, 0
    while not ctrl.exhausted and r < 1000:
        r += 1
        ctrl.observe(p.tau1, p.tau2, 30.0)
        spent += 30.0
        ctrl.maybe_replan(r)
    assert ctrl.exhausted
    assert r < 1000
    # stops once the remainder can't fund another planned round: within
    # one round's cost of the envelope, never grossly over it.
    assert 100.0 - 30.0 <= spent <= 100.0 + 30.0


# -- launcher integration ---------------------------------------------------


def test_train_cli_adaptive_session(tmp_path):
    """`train.py --plan-budget` end-to-end: the controller plans, measures,
    re-plans, and the (tau1, tau2) trajectory lands in the history JSON."""
    from repro.launch import train as train_cli

    out = tmp_path / "hist.json"
    train_cli.main([
        "--arch", "qwen3-1.7b", "--nodes", "2", "--rounds", "3",
        "--batch", "1", "--seq", "16", "--plan-budget", "3600",
        "--replan-every", "1", "--log-every", "10",
        "--history-out", str(out)])
    import json

    h = json.loads(out.read_text())
    assert len(h["round"]) == 3
    assert len(h["tau1"]) == 3 and len(h["tau2"]) == 3
    assert all(t >= 1 for t in h["tau1"])
    events = h["plan_events"]
    assert events[0]["cause"] == "initial"
    assert any(e["cause"] == "replan" for e in events)
    # re-planned schedules are the ones the rounds actually ran
    assert (events[0]["tau1"], events[0]["tau2"]) == (h["tau1"][0],
                                                     h["tau2"][0])


def test_train_cli_trajectory_session(tmp_path):
    """`train.py --schedule trajectory` end-to-end: per-round [K, 2]
    schedules dispatched inside supersteps, the realized schedule in the
    history JSON's ``schedule`` field, and ZERO recompiles after warmup."""
    from repro.launch import train as train_cli

    out = tmp_path / "hist.json"
    train_cli.main([
        "--arch", "qwen3-1.7b", "--nodes", "2", "--rounds", "6",
        "--batch", "1", "--seq", "16", "--plan-budget", "3600",
        "--schedule", "trajectory", "--superstep", "3",
        "--log-every", "10", "--history-out", str(out)])
    import json

    h = json.loads(out.read_text())
    assert h["schedule_mode"] == "trajectory"
    assert len(h["round"]) == 6
    # the realized per-round schedule field mirrors the tau columns
    assert h["schedule"] == [[t1, t2] for t1, t2 in
                             zip(h["tau1"], h["tau2"])]
    assert all(t1 >= 1 for t1, _ in h["schedule"])
    # trajectory re-plans are schedule DATA: zero recompiles after warmup
    assert h["compile_count"] == h["compile_count_warmup"]
    causes = {e["cause"] for e in h["plan_events"]}
    assert "initial" in causes and "trajectory" in causes


def test_build_planned_round_smoke():
    from repro.configs import REGISTRY
    from repro.launch import steps as S
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    arch = REGISTRY["qwen3-1.7b"]
    built = S.build_planned_round(arch, "train_4k", mesh, budget_s=3600.0,
                                  reduced=True)
    meta = built.meta["plan"]
    assert meta["tau1"] >= 1 and meta["tau2"] >= 1
    assert built.meta["tau1"] == meta["tau1"]
    assert np.isfinite(meta["predicted_bound"])
    assert built.lower() is not None
