"""Recompile-free executor tests (repro.core.executor + dynamic taus).

Pins the three tentpole properties:
  * a dynamic-(tau1, tau2) round is BITWISE equal to the static round in
    model state (params / opt_state / hat_params) and consensus metric on
    both engines, all paths (plain, CHOCO/C-DFL, kernels, schedules) —
    the scalar loss METRIC is allowed ~1 ulp (XLA associates the
    tau1-length vs tau1_max-length loss reduction differently);
  * a K-round superstep equals K sequential round_fn calls, including the
    fold_in RNG discipline and round_idx advance;
  * a forced (tau1, tau2) re-plan triggers ZERO new XLA compilations
    (trace-counter instrumentation), while K-shape changes and the static
    fallback cache compile exactly once per key.

Sparse-engine parity (shard_map + ppermute, kernels) needs 8 fake devices,
so it runs in a subprocess like tests/test_multidevice.py.
"""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DFLConfig, HostPrefetcher, MetricsBuffer,
                        RoundExecutor, init_state, make_compressor,
                        make_round_fn, ring, stack_round_batches)
from repro.core.topology import from_adjacency
from repro.optim import momentum_sgd, sgd

N = 8
DIM = 5


def noisy_loss(p, b, k=None):
    jitter = 0.02 * jax.random.normal(k, p["w"].shape)
    return jnp.mean((p["w"] + jitter - b) ** 2)


def batches_for(tau1, seed=2):
    return jax.random.normal(jax.random.key(seed), (tau1, N, DIM))


def fresh_state(opt, compressed=False, seed=1):
    return init_state({"w": jnp.zeros((DIM,))}, N, opt, jax.random.key(seed),
                      compressed=compressed)


def assert_state_bitwise(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Dynamic taus == static taus (dense engine; sparse in the subprocess test)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comp,opt_name", [
    (None, "sgd"), ("qsgd", "sgd"), ("top_k", "momentum"),
])
def test_dynamic_round_equals_static_round(comp, opt_name):
    opt = sgd(0.1) if opt_name == "sgd" else momentum_sgd(0.1)
    compressor = make_compressor(comp) if comp else None
    cfg_static = DFLConfig(tau1=3, tau2=2, topology=ring(N),
                           compression=compressor, gamma=0.5)
    cfg_max = DFLConfig(tau1=5, tau2=4, topology=ring(N),
                        compression=compressor, gamma=0.5)
    st = fresh_state(opt, compressed=compressor is not None)
    full = batches_for(5)
    ref, m_ref = jax.jit(make_round_fn(cfg_static, noisy_loss, opt))(
        st, full[:3])
    dyn = jax.jit(make_round_fn(cfg_max, noisy_loss, opt, dynamic_taus=True))
    out, m_dyn = dyn(st, full, jnp.int32(3), jnp.int32(2))
    assert_state_bitwise(ref.params, out.params)
    assert_state_bitwise(ref.opt_state, out.opt_state)
    if compressor is not None:
        assert_state_bitwise(ref.hat_params, out.hat_params)
    assert int(out.round_idx) == 1
    np.testing.assert_array_equal(np.asarray(m_ref["consensus_sq"]),
                                  np.asarray(m_dyn["consensus_sq"]))
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_dyn["loss"]),
                               rtol=1e-6)


def test_dynamic_round_at_maxima_and_tau2_zero():
    """The bounds themselves and the no-gossip edge both dispatch against
    the same executable."""
    opt = sgd(0.1)
    cfg_max = DFLConfig(tau1=4, tau2=3, topology=ring(N))
    dyn = jax.jit(make_round_fn(cfg_max, noisy_loss, opt, dynamic_taus=True))
    full = batches_for(4)
    st = fresh_state(opt)
    for (t1, t2) in [(4, 3), (1, 0), (2, 3)]:
        cfg_s = DFLConfig(tau1=t1, tau2=t2, topology=ring(N))
        ref, _ = jax.jit(make_round_fn(cfg_s, noisy_loss, opt))(st, full[:t1])
        out, _ = dyn(st, full, jnp.int32(t1), jnp.int32(t2))
        assert_state_bitwise(ref.params, out.params)
    assert dyn._cache_size() == 1   # one executable served all three


def test_dynamic_round_topology_schedule_parity():
    """Round-varying topologies keep working under dynamic taus (the
    lax.switch branches take the dynamic trip count)."""
    adj = np.zeros((N, N), np.int64)
    for i in range(0, N, 2):
        j = (i + 1) % N
        adj[i, j] = adj[j, i] = 1
    m0 = from_adjacency("m0", adj)
    sched = (m0, ring(N))
    opt = sgd(0.1)
    cfg_s = DFLConfig(tau1=2, tau2=2, topology=m0, topology_schedule=sched)
    cfg_max = DFLConfig(tau1=3, tau2=3, topology=m0, topology_schedule=sched)
    st = fresh_state(opt)
    full = batches_for(3)
    rf_s = jax.jit(make_round_fn(cfg_s, noisy_loss, opt))
    rf_d = jax.jit(make_round_fn(cfg_max, noisy_loss, opt, dynamic_taus=True))
    ref = out = st
    for _ in range(2):   # two rounds: both schedule branches execute
        ref, _ = rf_s(ref, full[:2])
        out, _ = rf_d(out, full, jnp.int32(2), jnp.int32(2))
    assert_state_bitwise(ref.params, out.params)


def test_dense_power_rejects_dynamic_taus():
    cfg = DFLConfig(tau1=2, tau2=2, topology=ring(N),
                    mixing_impl="dense_power")
    with pytest.raises(ValueError, match="dense_power"):
        make_round_fn(cfg, noisy_loss, sgd(0.1), dynamic_taus=True)


# ---------------------------------------------------------------------------
# Fused supersteps
# ---------------------------------------------------------------------------


def test_superstep_equals_sequential_rounds():
    """K fused rounds == K sequential round_fn calls: params bitwise,
    per-round stacked metrics, round_idx advanced K, rng unchanged — the
    fold_in discipline derives every key from (rng, round_idx), so equality
    across MULTIPLE rounds is exactly the RNG-discipline check."""
    opt = sgd(0.1)
    cfg_s = DFLConfig(tau1=2, tau2=1, topology=ring(N))
    rf = jax.jit(make_round_fn(cfg_s, noisy_loss, opt))
    per_round = [batches_for(2, seed=10 + i) for i in range(4)]
    ref = fresh_state(opt)
    ref_metrics = []
    for b in per_round:
        ref, m = rf(ref, b)
        ref_metrics.append(m)

    ex = RoundExecutor(DFLConfig(tau1=3, tau2=2, topology=ring(N)),
                       noisy_loss, opt)
    stacked = stack_round_batches(per_round, tau1_max=3)
    out, m = ex.dispatch(fresh_state(opt), stacked, 2, 1)
    assert_state_bitwise(ref.params, out.params)
    assert int(out.round_idx) == 4
    np.testing.assert_array_equal(jax.random.key_data(out.rng),
                                  jax.random.key_data(fresh_state(opt).rng))
    assert m["loss"].shape == (4,)
    for i, mr in enumerate(ref_metrics):
        np.testing.assert_array_equal(np.asarray(mr["consensus_sq"]),
                                      np.asarray(m["consensus_sq"])[i])
        np.testing.assert_allclose(float(mr["loss"]),
                                   float(m["loss"][i]), rtol=1e-6)


def test_trajectory_superstep_equals_sequential_rounds():
    """A heterogeneous [K, 2] trajectory fused into one superstep equals
    the same schedule run as K sequential STATIC rounds (each jitted at
    its own (tau1, tau2)): params bitwise, metrics tagged with the
    realized schedule, RNG fold_in discipline intact across the mixed
    rounds. Plain and C-DFL (stochastic QSGD exercises the per-round key
    folding)."""
    from repro.core import make_compressor

    schedule = [(2, 1), (3, 0), (1, 2), (3, 2)]
    for comp_name in (None, "qsgd"):
        comp = make_compressor(comp_name) if comp_name else None
        opt = sgd(0.1)
        per_round = [batches_for(3, seed=20 + i) for i in range(len(schedule))]
        ref = fresh_state(opt, compressed=comp is not None)
        for b, (t1, t2) in zip(per_round, schedule):
            cfg_s = DFLConfig(tau1=t1, tau2=t2, topology=ring(N),
                              compression=comp, gamma=0.5)
            ref, _ = jax.jit(make_round_fn(cfg_s, noisy_loss, opt))(
                ref, b[:t1])
        ex = RoundExecutor(DFLConfig(tau1=3, tau2=2, topology=ring(N),
                                     compression=comp, gamma=0.5),
                           noisy_loss, opt)
        stacked = stack_round_batches(per_round, tau1_max=3)
        out, m = ex.dispatch_trajectory(
            fresh_state(opt, compressed=comp is not None), stacked,
            np.array(schedule, np.int32))
        assert_state_bitwise(ref.params, out.params)
        if comp is not None:
            assert_state_bitwise(ref.hat_params, out.hat_params)
        assert int(out.round_idx) == len(schedule)
        # metrics carry the REALIZED per-round schedule
        np.testing.assert_array_equal(np.asarray(m["tau1"]),
                                      [t1 for t1, _ in schedule])
        np.testing.assert_array_equal(np.asarray(m["tau2"]),
                                      [t2 for _, t2 in schedule])


def test_trajectory_shares_executable_with_uniform_dispatch():
    """Heterogeneous trajectories ride the SAME compiled executable as
    uniform dispatches — schedule heterogeneity never compiles."""
    opt = sgd(0.1)
    ex = RoundExecutor(DFLConfig(tau1=4, tau2=3, topology=ring(N)),
                       noisy_loss, opt)
    stacked = stack_round_batches([batches_for(4, seed=i) for i in range(3)],
                                  tau1_max=4)
    st, _ = ex.dispatch(fresh_state(opt), stacked, 2, 2)
    assert ex.compile_count == 1
    st, _ = ex.dispatch_trajectory(
        st, stacked, np.array([(4, 3), (1, 0), (2, 1)], np.int32))
    st, _ = ex.dispatch_trajectory(
        st, stacked, np.array([(1, 1), (4, 0), (3, 3)], np.int32))
    assert ex.compile_count == 1


def test_trajectory_static_fallback_segments():
    """dynamic=False plays a trajectory as contiguous uniform segments
    through the keyed cache: one compile per distinct (tau1, tau2), model
    state identical to the dynamic path."""
    opt = sgd(0.1)
    schedule = np.array([(2, 1), (2, 1), (3, 2)], np.int32)
    stacked = stack_round_batches([batches_for(3, seed=i) for i in range(3)],
                                  tau1_max=3)
    dyn = RoundExecutor(DFLConfig(tau1=3, tau2=2, topology=ring(N)),
                        noisy_loss, opt)
    want, m_dyn = dyn.dispatch_trajectory(fresh_state(opt), stacked, schedule)
    ex = RoundExecutor(DFLConfig(tau1=3, tau2=2, topology=ring(N)),
                       noisy_loss, opt, dynamic=False)
    out, m = ex.dispatch_trajectory(fresh_state(opt), stacked, schedule)
    assert ex.compile_count == 2          # two distinct (tau1, tau2) keys
    assert_state_bitwise(want.params, out.params)
    np.testing.assert_array_equal(np.asarray(m["tau1"]), [2, 2, 3])
    np.testing.assert_array_equal(np.asarray(m["tau2"]), [1, 1, 2])
    assert m["loss"].shape == (3,)


def test_trajectory_validation():
    opt = sgd(0.1)
    ex = RoundExecutor(DFLConfig(tau1=3, tau2=2, topology=ring(N)),
                       noisy_loss, opt)
    stacked = stack_round_batches([batches_for(3)] * 2, tau1_max=3)
    st = fresh_state(opt)
    with pytest.raises(ValueError, match=r"\[K, 2\]"):
        ex.dispatch_trajectory(st, stacked, np.array([2, 1], np.int32))
    with pytest.raises(ValueError, match="K=2"):
        ex.dispatch_trajectory(st, stacked,
                               np.array([(2, 1)] * 3, np.int32))
    with pytest.raises(ValueError, match="tau1=4"):
        ex.dispatch_trajectory(st, stacked,
                               np.array([(2, 1), (4, 1)], np.int32))
    with pytest.raises(ValueError, match="tau2=3"):
        ex.dispatch_trajectory(st, stacked,
                               np.array([(2, 1), (2, 3)], np.int32))


def test_superstep_round_idx_continues_across_dispatches():
    opt = sgd(0.1)
    ex = RoundExecutor(DFLConfig(tau1=2, tau2=1, topology=ring(N)),
                       noisy_loss, opt)
    stacked = stack_round_batches([batches_for(2), batches_for(2, 3)], 2)
    st, _ = ex.dispatch(fresh_state(opt), stacked, 2, 1)
    st, _ = ex.dispatch(st, stacked, 2, 1)
    assert int(st.round_idx) == 4
    assert ex.rounds_dispatched == 4 and ex.dispatch_count == 2


# ---------------------------------------------------------------------------
# Zero-recompile property (trace-counter instrumentation)
# ---------------------------------------------------------------------------


def test_replan_triggers_zero_recompiles():
    """THE acceptance property: re-planning (tau1, tau2) mid-run dispatches
    against the already-compiled executable — compile_count stays put."""
    opt = sgd(0.1)
    ex = RoundExecutor(DFLConfig(tau1=5, tau2=4, topology=ring(N)),
                       noisy_loss, opt)
    stacked = stack_round_batches([batches_for(5)], tau1_max=5)
    st, _ = ex.dispatch(fresh_state(opt), stacked, 3, 2)
    assert ex.compile_count == 1
    for (t1, t2) in [(5, 4), (1, 0), (2, 3), (3, 2)]:   # forced re-plans
        st, _ = ex.dispatch(st, stacked, t1, t2)
    assert ex.compile_count == 1
    # a new K (batch leading dim) is a new shape: exactly one more compile.
    st, _ = ex.dispatch(
        st, stack_round_batches([batches_for(5), batches_for(5)], 5), 2, 2)
    assert ex.compile_count == 2


def test_static_fallback_compile_cache():
    """dynamic=False: one compile per distinct (tau1, tau2), cached."""
    opt = sgd(0.1)
    ex = RoundExecutor(DFLConfig(tau1=5, tau2=4, topology=ring(N)),
                       noisy_loss, opt, dynamic=False)
    stacked = stack_round_batches([batches_for(5)], tau1_max=5)
    st, _ = ex.dispatch(fresh_state(opt), stacked, 3, 2)
    st, _ = ex.dispatch(st, stacked, 3, 2)
    assert ex.compile_count == 1
    st, _ = ex.dispatch(st, stacked, 2, 2)      # new key -> one compile
    assert ex.compile_count == 2
    st, _ = ex.dispatch(st, stacked, 3, 2)      # cached
    assert ex.compile_count == 2
    # static slices off the padding, so it matches the static reference.
    cfg_s = DFLConfig(tau1=3, tau2=2, topology=ring(N))
    ref, _ = jax.jit(make_round_fn(cfg_s, noisy_loss, opt))(
        fresh_state(opt), batches_for(5)[:3])
    ex2 = RoundExecutor(DFLConfig(tau1=5, tau2=4, topology=ring(N)),
                        noisy_loss, opt, dynamic=False)
    out, _ = ex2.dispatch(fresh_state(opt), stacked, 3, 2)
    assert_state_bitwise(ref.params, out.params)


def test_dispatch_rejects_out_of_bounds_taus():
    opt = sgd(0.1)
    ex = RoundExecutor(DFLConfig(tau1=3, tau2=2, topology=ring(N)),
                       noisy_loss, opt)
    stacked = stack_round_batches([batches_for(3)], tau1_max=3)
    st = fresh_state(opt)
    with pytest.raises(ValueError, match="tau1=4"):
        ex.dispatch(st, stacked, 4, 1)
    with pytest.raises(ValueError, match="tau2=3"):
        ex.dispatch(st, stacked, 1, 3)
    with pytest.raises(ValueError, match="tau1=0"):
        ex.dispatch(st, stacked, 0, 1)


# ---------------------------------------------------------------------------
# Host-side pieces: batch stacking, prefetch, deferred metrics
# ---------------------------------------------------------------------------


def test_stack_round_batches_pads_and_checks():
    a = {"x": np.ones((2, 4)), "y": np.ones((2, 3, 2))}
    b = {"x": 2 * np.ones((2, 4)), "y": 2 * np.ones((2, 3, 2))}
    out = stack_round_batches([a, b], tau1_max=4)
    assert out["x"].shape == (2, 4, 4) and out["y"].shape == (2, 4, 3, 2)
    np.testing.assert_array_equal(np.asarray(out["x"][1, :2]), 2 * np.ones((2, 4)))
    np.testing.assert_array_equal(np.asarray(out["x"][:, 2:]), 0.0)
    with pytest.raises(AssertionError, match="tau1_max"):
        stack_round_batches([{"x": np.ones((5, 4))}], tau1_max=4)


def test_host_prefetcher_overlap_and_staleness():
    pf = HostPrefetcher()

    def build(r, k):
        time.sleep(0.01)
        return ("batches", r, k)

    pf.schedule(build, 3, 2, meta=(3, 2))
    assert pf.pending_meta == (3, 2)
    out, meta = pf.take()
    assert out == ("batches", 3, 2) and meta == (3, 2)
    assert pf.pending_meta is None
    # worker exceptions surface on take(), not in the background thread.
    pf.schedule(lambda: 1 / 0, meta="boom")
    with pytest.raises(ZeroDivisionError):
        pf.take()
    pf.schedule(build, 0, 1, meta="stale")
    pf.cancel()
    assert pf.pending_meta is None


def test_host_prefetcher_failure_paths():
    """Misuse is loud: double-schedule and take-without-schedule raise,
    a worker exception surfaces on take() (and counts as an error), and
    cancel()/mark_stale() keep the stats ledger honest."""
    from repro.obs import Telemetry

    tel = Telemetry()
    pf = HostPrefetcher(telemetry=tel)

    with pytest.raises(RuntimeError, match="nothing scheduled"):
        pf.take()

    pf.schedule(lambda: "ok", meta="a")
    with pytest.raises(RuntimeError, match="previous prefetch not taken"):
        pf.schedule(lambda: "ok2", meta="b")
    assert pf.take() == ("ok", "a")

    # worker exception: raised on take(), prefetcher stays usable after.
    pf.schedule(lambda: 1 / 0, meta="boom")
    with pytest.raises(ZeroDivisionError):
        pf.take()
    pf.schedule(lambda: "alive", meta="c")
    assert pf.take() == ("alive", "c")

    # cancel() after schedule joins the worker without surfacing results.
    pf.schedule(lambda: "discarded", meta="d")
    pf.cancel()
    assert pf.pending_meta is None
    pf.cancel()          # idempotent when nothing is pending
    pf.mark_stale()

    assert pf.stats == {"scheduled": 4, "taken": 2, "cancelled": 1,
                        "stale": 1, "errors": 1, "retries": 0}
    types = [e["type"] for e in tel.events]
    assert types.count("prefetch") >= 5   # 4 builds + cancel/stale instants


def test_metrics_buffer_defers_and_amortizes():
    buf = MetricsBuffer()
    assert buf.flush() == []
    m1 = {"loss": jnp.asarray([1.0, 2.0]), "consensus_sq": jnp.asarray([0.1, 0.2])}
    m2 = {"loss": jnp.asarray([3.0]), "consensus_sq": jnp.asarray([0.3])}
    # the window opens at the FIRST chunk's pre-dispatch stamp: on the
    # pinned jaxlib the CPU client executes inside dispatch, so a
    # push-time origin would measure ~zero wall-clock per round.
    buf.push(10, 2, 4, 1, m1, dispatched_at=time.perf_counter() - 0.3)
    buf.push(12, 1, 2, 2, m2)
    assert buf.pending_rounds == 3
    rows = buf.flush()
    assert [r["round"] for r in rows] == [10, 11, 12]
    assert [r["loss"] for r in rows] == [1.0, 2.0, 3.0]
    assert [r["tau1"] for r in rows] == [4, 4, 2]
    assert rows[0]["round_s"] == rows[2]["round_s"] >= 0.1  # 0.3s / 3
    assert buf.pending_rounds == 0 and buf.flush() == []


def test_metrics_buffer_uses_metric_carried_taus():
    """Executor metrics tag each round with its realized (tau1, tau2);
    the buffer's rows must report THOSE (heterogeneous trajectories), with
    the push-args scalars as the legacy fallback."""
    buf = MetricsBuffer()
    m = {"loss": jnp.asarray([1.0, 2.0, 3.0]),
         "tau1": jnp.asarray([2, 3, 1]), "tau2": jnp.asarray([1, 0, 2])}
    buf.push(5, 3, None, None, m, dispatched_at=time.perf_counter())
    rows = buf.flush()
    assert [(r["tau1"], r["tau2"]) for r in rows] == [(2, 1), (3, 0), (1, 2)]
    assert all(isinstance(r["tau1"], int) for r in rows)
    assert [r["loss"] for r in rows] == [1.0, 2.0, 3.0]


def test_executor_warmup_precompiles_without_stats():
    """warmup() pays the compile for a batch shape on a throwaway state
    copy: the first real dispatch at that shape then adds no compile, and
    warmup leaves dispatch statistics and the caller's state untouched."""
    opt = sgd(0.1)
    ex = RoundExecutor(DFLConfig(tau1=3, tau2=2, topology=ring(N)),
                       noisy_loss, opt)
    st = fresh_state(opt)
    stacked = stack_round_batches([batches_for(3)] * 2, tau1_max=3)
    ex.warmup(st, stacked)
    assert ex.compile_count == 1
    assert ex.dispatch_count == 0 and ex.rounds_dispatched == 0
    out, _ = ex.dispatch(st, stacked, 3, 2)   # st still alive post-warmup
    assert ex.compile_count == 1
    assert int(out.round_idx) == 2


# ---------------------------------------------------------------------------
# Sparse engine (shard_map + ppermute): 8 fake devices -> subprocess
# ---------------------------------------------------------------------------

SPARSE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.core import (DFLConfig, RoundExecutor, init_state, make_compressor,
                        make_round_fn, ring, stack_round_batches)
from repro.optim import sgd

mesh = jax.make_mesh((8,), ("data",))
N = 8
topo = ring(N)
opt = sgd(0.1)

def noisy_loss(p, b, k=None):
    jitter = 0.05 * jax.random.normal(k, p["w"].shape)
    return jnp.mean((p["w"][None] + jitter[None] - b) ** 2)

targets = jnp.linspace(-1, 1, N)[:, None] * jnp.ones((N, 17))
full = jnp.broadcast_to(targets[None], (4, N, 17))
full = full[:, :, None, :] * jnp.ones((4, N, 2, 17))
st0 = init_state({"w": jnp.zeros((17,))}, N, opt, jax.random.key(5))

# dynamic sparse round == static DENSE reference (the numerical oracle),
# plain and C-DFL (stochastic QSGD), kernels hot path included.
for comp, kernels, tag in [(None, False, "PLAIN"),
                           ("qsgd", False, "CDFL"),
                           ("qsgd", True, "KERNELS")]:
    compressor = make_compressor(comp) if comp else None
    cfg_s = DFLConfig(tau1=2, tau2=2, topology=topo, compression=compressor,
                      gamma=0.5)
    cfg_max = DFLConfig(tau1=4, tau2=3, topology=topo, compression=compressor,
                        gamma=0.5)
    st = init_state({"w": jnp.zeros((17,))}, N, opt, jax.random.key(7),
                    compressed=comp is not None)
    ref, m_ref = jax.jit(make_round_fn(cfg_s, noisy_loss, opt))(st, full[:2])
    dyn = jax.jit(make_round_fn(cfg_max, noisy_loss, opt, engine="sparse",
                                mesh=mesh, node_axes=("data",),
                                use_kernels=kernels, dynamic_taus=True))
    out, m_dyn = dyn(st, full, jnp.int32(2), jnp.int32(2))
    err = float(jnp.max(jnp.abs(ref.params["w"] - out.params["w"])))
    assert err < 1e-5, f"{tag} sparse dynamic mismatch: {err}"
    assert abs(float(m_ref["loss"]) - float(m_dyn["loss"])) < 1e-5
    print(f"SPARSE_DYN_{tag}_OK", err)

# K-round sparse superstep == sequential static sparse rounds, and a forced
# re-plan triggers zero recompiles on the sparse engine too.
cfg_s = DFLConfig(tau1=2, tau2=2, topology=topo)
rf = jax.jit(make_round_fn(cfg_s, noisy_loss, opt, engine="sparse",
                           mesh=mesh, node_axes=("data",)))
ref = st0
for _ in range(3):
    ref, _ = rf(ref, full[:2])
ex = RoundExecutor(DFLConfig(tau1=4, tau2=3, topology=topo), noisy_loss,
                   opt, engine="sparse", mesh=mesh, node_axes=("data",))
stacked = stack_round_batches([full] * 3, tau1_max=4)
out, m = ex.dispatch(st0, stacked, 2, 2)
err2 = float(jnp.max(jnp.abs(ref.params["w"] - out.params["w"])))
assert err2 < 1e-5, f"sparse superstep mismatch: {err2}"
assert int(out.round_idx) == 3 and m["loss"].shape == (3,)
assert ex.compile_count == 1
out, _ = ex.dispatch(out, stacked, 4, 1)   # re-plan: tau1-heavy
out, _ = ex.dispatch(out, stacked, 1, 3)   # re-plan: tau2-heavy
assert ex.compile_count == 1, ex.compile_count
print("SPARSE_SUPERSTEP_OK", err2)
print("SPARSE_ZERO_RECOMPILE_OK")

# heterogeneous [K, 2] trajectory on the sparse engine == the same
# schedule as sequential static DENSE rounds (the numerical oracle), and
# it rides the SAME executable as the uniform dispatches above. (st0 was
# DONATED by the dispatches above — fresh same-key states here.)
fresh = lambda: init_state({"w": jnp.zeros((17,))}, N, opt, jax.random.key(5))
schedule = [(2, 2), (3, 0), (1, 1)]
ref = fresh()
for (t1, t2) in schedule:
    cfg_s = DFLConfig(tau1=t1, tau2=t2, topology=topo)
    ref, _ = jax.jit(make_round_fn(cfg_s, noisy_loss, opt))(ref, full[:t1])
out, m = ex.dispatch_trajectory(fresh(), stacked, np.array(schedule, np.int32))
err3 = float(jnp.max(jnp.abs(ref.params["w"] - out.params["w"])))
assert err3 < 1e-5, f"sparse trajectory mismatch: {err3}"
assert list(np.asarray(m["tau1"])) == [2, 3, 1]
assert ex.compile_count == 1, ex.compile_count
print("SPARSE_TRAJECTORY_OK", err3)

# constrain guard: a >1-sized auto axis + constrain must raise loudly
# (the re-assertion would be silently dropped); a node-only mesh accepts
# and ignores it.
mesh42 = jax.make_mesh((4, 2), ("data", "model"))
cfg4 = DFLConfig(tau1=2, tau2=1, topology=ring(4))
try:
    make_round_fn(cfg4, noisy_loss, sgd(0.1), constrain=lambda t: t,
                  engine="sparse", mesh=mesh42, node_axes=("data",))
except NotImplementedError as e:
    assert "constrain" in str(e)
    print("SPARSE_CONSTRAIN_GUARD_OK")
make_round_fn(DFLConfig(tau1=2, tau2=1, topology=topo), noisy_loss,
              sgd(0.1), constrain=lambda t: t, engine="sparse", mesh=mesh,
              node_axes=("data",))
print("SPARSE_CONSTRAIN_IGNORED_OK")
"""


@pytest.mark.slow
def test_sparse_executor_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SPARSE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ["SPARSE_DYN_PLAIN_OK", "SPARSE_DYN_CDFL_OK",
                "SPARSE_DYN_KERNELS_OK", "SPARSE_SUPERSTEP_OK",
                "SPARSE_ZERO_RECOMPILE_OK", "SPARSE_TRAJECTORY_OK",
                "SPARSE_CONSTRAIN_GUARD_OK", "SPARSE_CONSTRAIN_IGNORED_OK"]:
        assert tag in out.stdout, (tag, out.stdout, out.stderr[-2000:])
