"""Overlapped-superstep benchmark: pipelined vs additive round time.

The pipeline's win is a DEPLOYMENT property: the tau2 ppermute exchange
rides under the next round's tau1 local steps, so the round costs
``tau1*T_step + max(0, tau2*T_gossip - tau1*T_step)`` instead of the
paper's additive sum. A CI host has neither a real interconnect nor
spare cores, so — exactly like ``bench_faults`` — the headline numbers
are priced on the deployment clock from MEASURED inputs:

  * ``T_step``   — fitted from wall-clock: median per-round time of the
                   ``overlap="none"`` executor at tau2=0 for two tau1
                   values; the slope isolates the per-step cost from the
                   dispatch floor.
  * ``T_gossip`` — measured per-collective wire bytes, parsed off the
                   compiled superstep's optimized HLO
                   (``roofline.collective_bytes_from_hlo``: the ring's
                   two ppermutes, result bytes per device == one node's
                   per-step wire traffic), over the modeled deployment
                   link bandwidth (``--link-bw``; default 2 GB/s, a
                   modest interconnect that leaves the default (2, 4)
                   schedule gossip-dominated while the hidden window
                   stays a visible fraction of the round).

``roofline.predict_overlap`` turns those two numbers into the predicted
additive/pipelined round times BEFORE a single pipelined round runs, and
``--check`` asserts (a) the config is gossip-dominated (the max binds),
(b) pipelined < additive, and (c) the planner's ``CostModel(overlap=
"pipeline")`` round time agrees with the roofline prediction within
``PLANNER_TOL_PCT`` — two independent implementations of the max-form
model fed the same measured inputs.

Wall-clock sections (both paired dispatch-for-dispatch with the cyclic
GC disabled, order flipped per pass, median-of-diffs — the
``bench_round_overhead`` telemetry methodology):

  * ``none_overhead``   — ``overlap="none"`` vs the legacy executor:
                          ``--check`` holds the knob's cost < 2% (the
                          bitwise contract's wall-clock half).
  * ``pipeline_wall``   — ``overlap="pipeline"`` vs ``"none"``: recorded,
                          NOT asserted — one CPU core cannot overlap
                          anything; the delta documents the scheduling
                          overhead the deployment win must beat.

Zero recompiles are asserted on every executor. Writes
``BENCH_overlap.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.bench_overlap --smoke --check
"""
from __future__ import annotations

import os

# The sparse engine (the executable whose ppermute bytes we measure) needs
# one device per ring node — force host devices BEFORE jax initializes,
# like `python -m repro.analysis audit` does.
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import gc
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DFLConfig, RoundExecutor, init_state, ring, \
    stack_round_batches
from repro.launch.roofline import Roofline, collective_bytes_from_hlo, \
    predict_overlap
from repro.optim import sgd
from repro.planner import CostModel
from repro.planner.cost import ComputeModel, LinkModel

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_overlap.json")

N = 8
PLANNER_TOL_PCT = 1.0      # planner-vs-roofline max-form agreement bar


def quad_loss(p, b, k=None):
    return jnp.mean((p["w"] - b) ** 2)


def make_executor(dim: int, tau_max: int, overlap: str = None):
    cfg = DFLConfig(tau1=tau_max, tau2=tau_max, topology=ring(N))
    mesh = jax.make_mesh((N,), ("data",))
    kw = {} if overlap is None else {"overlap": overlap}
    return RoundExecutor(cfg, quad_loss, sgd(3e-2), engine="sparse",
                         mesh=mesh, node_axes=("data",), donate=False, **kw)


def fit_t_step(ex, state, batches, k: int, reps: int) -> Dict[str, float]:
    """T_step from the tau2=0 wall-clock slope between tau1=1 and tau1=4.

    The two trajectories alternate dispatch-for-dispatch (order flipped
    per pass) so throughput drift cancels in the per-pair difference —
    a block-sequential slope reads negative under the drift of a busy
    1-core host. The dispatch floor cancels in the difference too,
    leaving 3*K local steps' worth of wall clock per pair.
    """
    lo = np.array([[1, 0]] * k, np.int32)
    hi = np.array([[4, 0]] * k, np.int32)
    states = {"lo": state, "hi": state}
    taus = {"lo": lo, "hi": hi}
    # settle: the first dispatches after warmup/lowering pay one-offs
    for mode in ("lo", "hi"):
        states[mode], _ = ex.dispatch_trajectory(states[mode], batches,
                                                 taus[mode])
    diffs: List[float] = []
    per_round = {"lo": [], "hi": []}
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for p in range(reps):
            order = ("lo", "hi") if p % 2 == 0 else ("hi", "lo")
            pair = {}
            for mode in order:
                t0 = time.perf_counter()
                states[mode], m = ex.dispatch_trajectory(
                    states[mode], batches, taus[mode])
                float(np.asarray(m["loss"])[-1])
                pair[mode] = time.perf_counter() - t0
            diffs.append(pair["hi"] - pair["lo"])
            for mode in pair:
                per_round[mode].append(pair[mode] / k)
    finally:
        if gc_was_enabled:
            gc.enable()
    t_step = max(float(np.median(diffs)) / (3.0 * k), 1e-9)
    return {"round_s_tau1_1": float(np.median(per_round["lo"])),
            "round_s_tau1_4": float(np.median(per_round["hi"])),
            "t_step_s": t_step}


def paired_delta(ex_a, ex_b, state, batches, taus, passes: int) -> Dict:
    """Median per-pair wall difference (b - a) over median a, dispatch
    for dispatch, order flipped per pass, GC disabled."""
    states = {"a": state, "b": state}
    exes = {"a": ex_a, "b": ex_b}
    diffs: List[float] = []
    base: List[float] = []
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for p in range(passes):
            order = ("a", "b") if p % 2 == 0 else ("b", "a")
            pair = {}
            for mode in order:
                t0 = time.perf_counter()
                states[mode], m = exes[mode].dispatch_trajectory(
                    states[mode], batches, taus)
                float(np.asarray(m["loss"])[-1])
                pair[mode] = time.perf_counter() - t0
            diffs.append(pair["b"] - pair["a"])
            base.append(pair["a"])
    finally:
        if gc_was_enabled:
            gc.enable()
    base_s = float(np.median(base))
    diff_s = float(np.median(diffs))
    return {"base_dispatch_s": base_s, "delta_s": diff_s,
            "delta_pct": 100.0 * diff_s / base_s, "pairs": len(diffs)}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=16384,
                    help="model dim; big enough that 3*K local steps beat "
                         "timer noise in the paired T_step fit")
    ap.add_argument("--rounds", type=int, default=8,
                    help="rounds per fused superstep (K)")
    ap.add_argument("--passes", type=int, default=24)
    ap.add_argument("--tau1", type=int, default=2)
    ap.add_argument("--tau2", type=int, default=4,
                    help="gossip-heavy by default: the max must bind")
    ap.add_argument("--link-bw", type=float, default=2e9,
                    help="deployment link bytes/s pricing T_gossip")
    ap.add_argument("--smoke", action="store_true",
                    help="small dim + few passes (the CI config)")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    if args.smoke:
        # dim is NOT shrunk: below ~16k the quadratic local step costs
        # sub-microseconds and the T_step slope drowns in timer noise;
        # passes/K carry the shrink instead.
        args.passes = min(args.passes, 10)
        args.rounds = min(args.rounds, 4)

    tau_max = 4
    assert max(args.tau1, args.tau2) <= tau_max
    k = args.rounds
    rng = np.random.default_rng(0)
    batches = stack_round_batches(
        [jnp.asarray(rng.normal(size=(tau_max, N, args.dim)), jnp.float32)
         for _ in range(k)], tau_max)
    opt_state = init_state({"w": jnp.zeros((args.dim,))}, N, sgd(3e-2),
                           jax.random.key(1))
    taus = np.array([[args.tau1, args.tau2]] * k, np.int32)
    print(f"bench_overlap: dim={args.dim} K={k} taus=({args.tau1},"
          f"{args.tau2}) link_bw={args.link_bw:.0e} B/s")

    exes = {
        "legacy": make_executor(args.dim, tau_max),
        "none": make_executor(args.dim, tau_max, overlap="none"),
        "pipeline": make_executor(args.dim, tau_max, overlap="pipeline"),
    }
    for ex in exes.values():
        ex.warmup(opt_state, batches)
    warm = {name: ex.compile_count for name, ex in exes.items()}

    # -- measured wire bytes: the compiled artifact, not an estimate ------
    low = exes["none"].lower_superstep(opt_state, batches,
                                      [[args.tau1, args.tau2]] * k)
    wire = collective_bytes_from_hlo(low.compile().as_text())
    step_bytes = wire["bytes_per_kind"]["collective-permute"]
    n_permutes = wire["counts"]["collective-permute"]
    assert n_permutes == 2, (
        f"ring gossip step should ship 2 ppermutes, HLO has {n_permutes}")
    t_gossip = step_bytes / args.link_bw
    print(f"  measured wire: {step_bytes:.0f} B/step/node over "
          f"{n_permutes} ppermutes -> T_gossip {1e6 * t_gossip:.1f} us")

    # -- measured T_step: wall-clock slope at tau2=0 ----------------------
    fit = fit_t_step(exes["none"], opt_state, batches, k,
                     max(args.passes // 2, 5))
    t_step = fit["t_step_s"]
    print(f"  fitted T_step {1e6 * t_step:.1f} us "
          f"(round {1e6 * fit['round_s_tau1_1']:.0f} -> "
          f"{1e6 * fit['round_s_tau1_4']:.0f} us over tau1 1 -> 4)")

    # -- the deployment-clock prediction (before any pipelined round) -----
    gossip_rl = Roofline(flops=0.0, hbm_bytes=0.0,
                         collective_bytes=step_bytes, chips=N,
                         link_bw=args.link_bw)
    local_rl = Roofline(flops=0.0, hbm_bytes=0.0, collective_bytes=0.0,
                        chips=N)
    pred = predict_overlap(local_rl, gossip_rl, args.tau1, args.tau2,
                           t_local_step_s=t_step)
    gossip_dominated = (args.tau2 * pred.t_gossip_step_s
                        > args.tau1 * pred.t_local_step_s)
    print(f"  deployment round: additive {1e6 * pred.additive_s:.1f} us, "
          f"pipelined {1e6 * pred.pipelined_s:.1f} us "
          f"({pred.speedup:.2f}x, {1e6 * pred.hidden_s:.1f} us hidden, "
          f"gossip_dominated={gossip_dominated})")

    # -- planner agreement: CostModel's max-form == roofline's ------------
    model_bits = step_bytes / 2 * 8.0       # one copy, from measured bytes
    def cm(overlap):
        return CostModel(
            compute=ComputeModel(step_flops=t_step, flops_per_s=1.0),
            link=LinkModel(bytes_per_s=args.link_bw), topology=ring(N),
            model_bits=model_bits, engine="sparse", overlap=overlap)
    plan_none = cm("none").round_cost(args.tau1, args.tau2).time_s
    plan_pipe = cm("pipeline").round_cost(args.tau1, args.tau2).time_s
    err_none = 100.0 * abs(plan_none - pred.additive_s) / pred.additive_s
    err_pipe = 100.0 * abs(plan_pipe - pred.pipelined_s) / pred.pipelined_s
    print(f"  planner round times: additive {1e6 * plan_none:.1f} us "
          f"({err_none:.3f}% off roofline), pipelined "
          f"{1e6 * plan_pipe:.1f} us ({err_pipe:.3f}% off)")

    # -- wall clock: the none knob is free, the pipeline delta recorded ---
    none_overhead = paired_delta(exes["legacy"], exes["none"], opt_state,
                                 batches, taus, args.passes)
    print(f"  overlap='none' wall overhead {none_overhead['delta_pct']:+.2f}%"
          f" over legacy ({none_overhead['pairs']} pairs)")
    pipeline_wall = paired_delta(exes["none"], exes["pipeline"], opt_state,
                                 batches, taus, args.passes)
    print(f"  pipeline wall delta {pipeline_wall['delta_pct']:+.2f}% vs none"
          " (1-core host: recorded, not asserted)")

    for name, ex in exes.items():
        assert ex.compile_count == warm[name], (
            f"{name} executor recompiled mid-bench "
            f"({warm[name]} -> {ex.compile_count})")

    payload = {
        "config": {
            "nodes": N, "dim": args.dim, "rounds_per_superstep": k,
            "tau1": args.tau1, "tau2": args.tau2,
            "link_bytes_per_s": args.link_bw, "smoke": args.smoke,
            "backend": jax.default_backend(),
            "planner_tolerance_pct": PLANNER_TOL_PCT,
        },
        "measured": {
            "wire_bytes_per_gossip_step": step_bytes,
            "collective_permutes": n_permutes,
            **fit,
            "t_gossip_step_s": t_gossip,
            "gossip_dominated": bool(gossip_dominated),
        },
        "deployment": pred.as_dict(),
        "planner": {
            "additive_round_s": plan_none,
            "pipelined_round_s": plan_pipe,
            "err_vs_roofline_pct": {"additive": err_none,
                                    "pipelined": err_pipe},
        },
        "none_overhead": none_overhead,
        "pipeline_wall": pipeline_wall,
        "zero_recompiles": True,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")

    if args.check:
        assert t_step > 1e-6, (
            f"T_step fit collapsed to the floor ({t_step:.2e}s): the "
            "slope was not measurable — raise --dim")
        assert gossip_dominated, (
            f"config not gossip-dominated: tau2*T_gossip "
            f"{args.tau2 * t_gossip:.2e} <= tau1*T_step "
            f"{args.tau1 * t_step:.2e} — the max never binds")
        assert pred.pipelined_s < pred.additive_s, (
            f"pipelined {pred.pipelined_s:.2e} !< additive "
            f"{pred.additive_s:.2e}")
        assert plan_pipe < plan_none, "planner sees no pipelined win"
        assert max(err_none, err_pipe) < PLANNER_TOL_PCT, (
            f"planner round time {max(err_none, err_pipe):.2f}% off the "
            f"roofline prediction (bar {PLANNER_TOL_PCT}%)")
        ov = none_overhead["delta_pct"]
        assert ov < 2.0, (
            f"overlap='none' costs {ov:.2f}% of dispatch throughput "
            "(>= 2% bar)")
        print(f"check OK: pipelined {pred.speedup:.2f}x additive on the "
              f"deployment clock, planner within {PLANNER_TOL_PCT}%, "
              f"none-knob overhead {ov:+.2f}% < 2%")


if __name__ == "__main__":
    main()
