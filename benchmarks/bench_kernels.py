"""Compression-kernel benchmark: parity, throughput, buffer passes.

Three measurements over the ``repro.kernels`` subsystem, written to
``BENCH_kernels.json`` at the repo root (tier-2 CI artifact):

  * ``parity``        — the registry's reference-parity harness
                        (``repro.kernels.registry.parity_suite``): every
                        registered op, interpret mode vs its jnp oracle,
                        over a shape/dtype sweep. ASSERTED on every run
                        (not only under ``--check``): bitwise ops
                        (TopK select/mask) must match EXACTLY, the rest
                        to f32/bf16 tolerance.
  * ``throughput``    — wall-clock of the kernel dispatch path vs the
                        plain lax/jnp reference on flat parameter
                        buffers. Off-TPU the kernels run in INTERPRET
                        mode (a correctness vehicle, not a fast path) —
                        the numbers are recorded honestly under
                        ``mode: interpret`` and make no speed claim; on
                        a TPU the same entry points Mosaic-compile and
                        this section becomes the real kernel-vs-XLA
                        comparison. The XLA-fallback TopK threshold
                        (what a TPU host runs for the candidate pass) is
                        timed as its own row.
  * ``buffer_passes`` — the fused CHOCO claim, counted not vibed:
                        ``ops.op_stats()`` ticks one ``pad_roundtrips``
                        per flatten/pad/unpad cycle and one
                        ``pallas_calls`` per kernel launch while the
                        un-jitted wrapper bodies execute. The fused
                        compress-and-move must touch the buffer STRICTLY
                        fewer times than the unfused
                        move -> compress -> add chain for both QSGD and
                        TopK (asserted on every run).

    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke --check
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import TopK
from repro.kernels import ops, ref, registry

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_kernels.json")


def _time(fn, *args, reps: int) -> float:
    """Median seconds per call (jit-warmed, synced)."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run_parity(smoke: bool) -> Dict[str, Any]:
    shapes = [(64,), (1000,), (300, 70)] if smoke else list(
        registry.PARITY_SHAPES)
    records = registry.parity_suite(shapes=shapes)
    failures = [r for r in records if not r["ok"]]
    assert not failures, f"kernel parity failures: {failures}"
    bitwise = [r for r in records if r["bitwise"]]
    assert bitwise and all(r["max_err"] == 0.0 for r in bitwise), (
        "bitwise ops drifted", [r for r in bitwise if r["max_err"] != 0.0])
    print(f"[parity] {len(records)} records over {len(shapes)} shapes: "
          f"all ok ({len(bitwise)} bitwise-exact)")
    return {"records": len(records), "shapes": [list(s) for s in shapes],
            "failures": 0,
            "max_err_by_op": {
                op.name: max(r["max_err"] for r in records
                             if r["op"] == op.name)
                for op in registry.list_ops()}}


def run_throughput(smoke: bool, reps: int) -> List[Dict[str, Any]]:
    n = 2 ** 16 if smoke else 2 ** 20
    mode = registry.resolve_mode("qsgd_quantize", None)
    key = jax.random.key(0)
    x = jax.random.normal(jax.random.fold_in(key, 0), (n,))
    noise = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    y = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    my = jax.random.normal(jax.random.fold_in(key, 3), (n,))
    k = n // 16
    d = float(n)
    s = 16.0
    c = 1.0 + min(d / (s * s), d ** 0.5 / s)

    ref_qsgd = jax.jit(lambda a, b: ref.qsgd_ref(a, b, levels=16, c=c))
    ref_topk = jax.jit(lambda a: ref.top_k_ref(a, k))
    ref_choco = jax.jit(
        lambda a, b, m, nz: ref.choco_qsgd_ref(a, b, m, 0.5, nz, levels=16,
                                               c=c))
    fallback_thresh = jax.jit(
        lambda a: jax.lax.top_k(jnp.abs(a), k)[0][k - 1])

    rows = []

    def row(name, kernel_s, ref_s, note=""):
        rows.append({
            "op": name, "elements": n, "mode": mode,
            "kernel_s": kernel_s, "reference_s": ref_s,
            "kernel_elems_per_s": n / kernel_s,
            "reference_elems_per_s": n / ref_s,
            "speedup_vs_reference": ref_s / kernel_s,
            "note": note,
        })
        print(f"[throughput] {name:18s} kernel {kernel_s * 1e3:8.2f} ms  "
              f"ref {ref_s * 1e3:8.2f} ms  ({mode})")

    row("qsgd_quantize",
        _time(lambda: ops.qsgd_quantize(x, noise, levels=16), reps=reps),
        _time(ref_qsgd, x, noise, reps=reps))
    row("top_k_compress",
        _time(lambda: ops.top_k_compress(x, k), reps=reps),
        _time(ref_topk, x, reps=reps),
        note=f"k={k}; two-pass candidate select + mask")
    deg = 2
    nbrs = jnp.stack([y, my])
    w = jnp.concatenate([jnp.asarray([0.5]), jnp.full((deg,), 0.25)])
    ref_mix = jax.jit(lambda a, b, ww: ref.gossip_mix_ref(a, b, ww))
    row("gossip_mix",
        _time(lambda: ops.gossip_mix(x, nbrs, w), reps=reps),
        _time(ref_mix, x, nbrs, w, reps=reps),
        note=f"deg={deg} weighted neighbor accumulate")
    row("topk_threshold_fallback",
        _time(lambda: ops._topk_threshold(x, k=k, mode="fallback"),
              reps=reps),
        _time(fallback_thresh, x, reps=reps),
        note="the plain-XLA candidate-pass fallback a TPU host runs for "
             "the select (mosaic=False op); both sides are XLA")
    row("choco_qsgd_move",
        _time(lambda: ops.choco_qsgd_move(x, y, my, 0.5, noise, levels=16),
              reps=reps),
        _time(ref_choco, x, y, my, noise, reps=reps),
        note="fused compress-and-move vs unfused oracle chain")
    return rows


def count_passes(fn_fused, fn_unfused) -> Dict[str, Any]:
    with ops.op_stats_delta() as df:
        fn_fused()
    with ops.op_stats_delta() as du:
        fn_unfused()
    fused, unfused = df.as_dict(), du.as_dict()
    assert fused["pallas_calls"] < unfused["pallas_calls"], (fused, unfused)
    assert fused["pad_roundtrips"] < unfused["pad_roundtrips"], (fused,
                                                                 unfused)
    return {"fused": fused, "unfused": unfused}


def run_buffer_passes() -> Dict[str, Any]:
    shape = (3, 5, 7)
    key = jax.random.key(7)
    x, y, my = (jax.random.normal(jax.random.fold_in(key, i), shape)
                for i in range(3))
    noise = jax.random.uniform(jax.random.fold_in(key, 9), shape)
    k = 26

    def fused_qsgd():
        ops.eager_impl("choco_qsgd_move")(x, y, my, 0.5, noise, levels=16,
                                          interpret=True)

    def unfused_qsgd():
        _, d = ops.eager_impl("choco_move")(x, y, my, 0.5, interpret=True)
        ops.eager_impl("qsgd_quantize")(d, noise, levels=16, interpret=True)

    def fused_topk():
        ops.eager_impl("choco_topk_move")(x, y, my, 0.5, k=k,
                                          tmode="interpret", interpret=True)

    def unfused_topk():
        _, d = ops.eager_impl("choco_move")(x, y, my, 0.5, interpret=True)
        ops.eager_impl("top_k_compress")(d, k=k, tmode="interpret",
                                         imask=True)

    out = {
        "choco_qsgd": count_passes(fused_qsgd, unfused_qsgd),
        "choco_topk": count_passes(fused_topk, unfused_topk),
    }
    for name, rec in out.items():
        print(f"[buffer_passes] {name}: fused {rec['fused']} < "
              f"unfused {rec['unfused']}")
    return out


def run_kernel_topk_is_reference(smoke: bool) -> Dict[str, Any]:
    """The headline acceptance bit, spelled out in the artifact: the
    kernel-backed TopK compressor is the SAME operator as the library
    reference, bitwise, flag on or off."""
    n = 2 ** 14 if smoke else 2 ** 18
    x = jax.random.normal(jax.random.key(11), (n,))
    matches = {}
    for frac in (0.01, 0.1, 0.5, 1.0):
        a = TopK(frac=frac)(x, None)
        b = TopK(frac=frac, use_kernels=True)(x, None)
        matches[str(frac)] = bool(jnp.array_equal(a, b))
    assert all(matches.values()), matches
    print(f"[topk] kernel-vs-reference bitwise over fracs: {matches}")
    return {"elements": n, "bitwise_by_frac": matches}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few reps (the CI config)")
    ap.add_argument("--check", action="store_true",
                    help="extra acceptance asserts (parity and buffer "
                         "passes are asserted regardless)")
    ap.add_argument("--reps", type=int, default=0,
                    help="timing repetitions (default: 5 smoke / 20 full)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    reps = args.reps or (5 if args.smoke else 20)

    result = {
        "meta": {
            "backend": registry.backend(),
            "jax": jax.__version__,
            "smoke": bool(args.smoke),
            "reps": reps,
            "dispatch_mode": registry.resolve_mode("qsgd_quantize", None),
            "ops": [op.name for op in registry.list_ops()],
        },
        "parity": run_parity(args.smoke),
        "topk_vs_reference": run_kernel_topk_is_reference(args.smoke),
        "buffer_passes": run_buffer_passes(),
        "throughput": run_throughput(args.smoke, reps),
    }

    if args.check:
        # the fused path must beat the unfused chain on BOTH counters for
        # BOTH compressors (already asserted in run_buffer_passes), and
        # parity must have zero failures (asserted in run_parity); here we
        # additionally pin the structural claims the README makes.
        bp = result["buffer_passes"]
        assert bp["choco_qsgd"]["fused"]["pallas_calls"] == 1
        assert bp["choco_topk"]["fused"]["pallas_calls"] == 2
        assert result["topk_vs_reference"]["bitwise_by_frac"]
        print("[check] structural acceptance asserts passed")

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
