"""Benchmark entrypoint: one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run              # reduced scale
    PYTHONPATH=src python -m benchmarks.run --full       # paper scale-ish
    PYTHONPATH=src python -m benchmarks.run --only fig7

Emits CSV rows (bench,label,...) per bench plus the roofline summary table
if dry-run results exist.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer runs (closer to paper scale)")
    ap.add_argument("--only", default="",
                    help="comma list: fig7,fig8,fig9,fig10,table1,theory,"
                         "balance,roofline")
    args = ap.parse_args()
    rounds = 150 if args.full else 40
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    t0 = time.time()
    from benchmarks import (bench_balance, fig7_tau2, fig8_tau1, fig9_zeta,
                            fig10_cdfl, roofline_report, table1_methods)

    if want("fig7"):
        print("# Fig 7 — effect of tau2 (DFL vs C-SGD), ring")
        fig7_tau2.run(rounds=rounds)
        if args.full:
            print("# Fig 7 — quasi-ring")
            fig7_tau2.run(rounds=rounds, topology="quasi")
            print("# Fig 7 — cifar-shaped")
            fig7_tau2.run(rounds=rounds, flavor="cifar")
    if want("fig8"):
        print("# Fig 8 — effect of tau1")
        fig8_tau1.run(rounds=rounds)
    if want("fig9"):
        print("# Fig 9 — effect of zeta")
        fig9_zeta.run(rounds=rounds)
    if want("fig10"):
        print("# Fig 10 — C-DFL compression")
        fig10_cdfl.run(rounds=rounds)
    if want("table1"):
        print("# Table I — method comparison")
        table1_methods.run(budget_iters=480 if not args.full else 1200)
    if want("theory"):
        print("# Theory — Proposition 1 bound verification")
        from benchmarks import theory_check
        theory_check.main()
    if want("balance"):
        print("# Balance — communication vs computing cost optimum")
        bench_balance.run(rounds=max(30, rounds // 2))
    if want("roofline"):
        print("# Roofline (from dry-run artifacts, if present)")
        try:
            roofline_report.summarize("1pod")
        except Exception as e:
            print(f"(no dry-run artifacts: {e})")
    print(f"\n# total bench wall-clock: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
