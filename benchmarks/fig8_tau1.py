"""Fig. 8: effect of tau1 (computation frequency).

Paper claim (Remark 1): more local updates per round intensify local
drift. Protocol: EQUAL SGD-STEP budget (rounds = budget / tau1) so every
variant performs the same number of gradient steps and differs ONLY in how
much drift accumulates between averagings; synchronous SGD (tau1 = 1,
C = J) is the drift-free benchmark (Corollary 1).
"""
from __future__ import annotations

from benchmarks.common import RunSpec, print_csv, run_dfl_cnn, save_result

TAU1S = (2, 4, 10)


def run(rounds: int = 60, flavor: str = "mnist"):
    rows = []
    results = {}
    sgd_budget = rounds * 2  # total local update steps for every variant
    # benchmark: synchronous SGD (tau1=1, C=J)  [Corollary 1]
    sync = RunSpec(name="fig8-sync", tau1=1, tau2=1, topology="full",
                   flavor=flavor, rounds=sgd_budget,
                   partition="label_shard")
    out = run_dfl_cnn(sync)
    results[sync.name] = out
    rows.append({"bench": "fig8", "label": "sync-SGD", "tau1": 1,
                 "loss_at_iter_budget": round(out["history"]["global_loss"][-1], 4),
                 "final_acc": round(out["history"]["test_acc"][-1], 4)})
    for tau1 in TAU1S:
        r = max(4, sgd_budget // tau1)
        spec = RunSpec(name=f"fig8-tau1{tau1}", tau1=tau1, tau2=4,
                       topology="ring", flavor=flavor, rounds=r,
                       partition="label_shard")
        out = run_dfl_cnn(spec)
        results[spec.name] = out
        h = out["history"]
        rows.append({"bench": "fig8", "label": f"DFL tau1={tau1}",
                     "tau1": tau1,
                     "loss_at_iter_budget": round(h["global_loss"][-1], 4),
                     "final_acc": round(h["test_acc"][-1], 4)})
    save_result(f"fig8_{flavor}", results)
    print_csv(rows, ["bench", "label", "tau1", "loss_at_iter_budget",
                     "final_acc"])
    return rows


if __name__ == "__main__":
    run()
