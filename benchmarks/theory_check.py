"""Numerical verification of Proposition 1 (convergence bound of DFL).

On a strongly-convex quadratic where every constant in Assumption 1 is
analytic — F_i(w) = 0.5 ||w - t_i||^2, stochastic gradient g = nabla F_i +
sigma * xi with xi ~ N(0, I_d/d) — we run Algorithm 1 exactly (matrix
form, eq. (5)) and check that the measured E[ (1/T) sum_t ||nabla F(u_t)||^2 ]
is BELOW the bound (20) whenever the learning rate satisfies condition
(19). Constants: L = mu = 1; zeta/beta from the topology spectrum.

Assumption 1.5 bounds E||g(w) - nabla F(w)||^2 against the GLOBAL gradient,
so sigma^2 must include the non-IID heterogeneity max_i ||t_i - tbar||^2 on
top of the sampling noise — using only the sampling sigma understates the
bound (we verified: tau=(4,8) then appears to "violate" it by ~20%).

Also verifies the bound's structure: the measured local-drift contribution
grows with tau1 and shrinks with tau2, as Remark 1 states.

    PYTHONPATH=src python -m benchmarks.theory_check
"""
from __future__ import annotations

import numpy as np

from repro.core.topology import Topology, fully_connected, ring
# Condition (19) / bound (20) / max-eta live in the planner library now
# (PR 2); re-exported here so existing imports keep working.
from repro.planner.bounds import bound_20, lr_condition_19, max_eta_19

__all__ = ["lr_condition_19", "bound_20", "max_eta_19",
           "run_dfl_quadratic", "quadratic_loss_gap",
           "measured_loss_at_budget", "check", "main"]


def run_dfl_quadratic(eta: float, tau1: int, tau2: int, topo: Topology,
                      rounds: int, d: int = 16, sigma: float = 0.5,
                      seed: int = 0, target_scale: float = 1.0):
    """Algorithm 1 in matrix form.

    Returns (avg ||grad F(u_t)||^2 over T, final stacked params X, the
    node targets t_i) — targets are returned so callers evaluate losses
    against the exact instance that ran instead of replaying RNG draws."""
    rng = np.random.default_rng(seed)
    n = topo.num_nodes
    targets = rng.normal(size=(n, d)) * target_scale
    tbar = targets.mean(0)
    c = topo.mixing
    x = np.zeros((n, d))                       # same init point (u_1 = 0)
    grads_sq = []

    def record():
        u = x.mean(0)
        grads_sq.append(float(np.sum((u - tbar) ** 2)))

    for _ in range(rounds):
        for _ in range(tau1):                  # local updates
            record()
            noise = rng.normal(size=(n, d)) * (sigma / np.sqrt(d))
            g = (x - targets) + noise
            x = x - eta * g
        for _ in range(tau2):                  # inter-node communication
            record()
            x = c.T @ x
    return float(np.mean(grads_sq)), x, targets


def quadratic_loss_gap(x: np.ndarray, targets: np.ndarray) -> float:
    """F(u) - F_inf of the averaged model on the quadratic testbed."""
    u = x.mean(0)
    tbar = targets.mean(0)
    return 0.5 * float(np.sum((u - tbar) ** 2))


def measured_loss_at_budget(eta: float, tau1: int, tau2: int,
                            topo: Topology, rounds: int, *, d: int = 16,
                            sigma: float = 0.5, seeds: int = 3,
                            target_scale: float = 1.0) -> float:
    """bench_balance-style empirical measurement for the planner: the mean
    (over seeds) final loss gap F(u) - F_inf after ``rounds`` rounds of the
    (tau1, tau2) schedule — the quantity a wall-clock budget buys."""
    gaps = []
    for s in range(seeds):
        _, x, targets = run_dfl_quadratic(eta, tau1, tau2, topo, rounds,
                                          d=d, sigma=sigma, seed=s,
                                          target_scale=target_scale)
        gaps.append(quadratic_loss_gap(x, targets))
    return float(np.mean(gaps))


def check(eta=None, tau1=4, tau2=2, topo=None, rounds=400, sigma=0.5,
          seeds=5, d=16):
    topo = topo or ring(8)
    n = topo.num_nodes
    if eta is None:
        eta = 0.5 * max_eta_19(tau1, tau2, topo)
    assert lr_condition_19(eta, tau1, tau2, topo), "eta violates (19)"
    measured = []
    f_gap = sigma_eff_sq = None
    for s in range(seeds):
        m, _, targets = run_dfl_quadratic(eta, tau1, tau2, topo, rounds,
                                          d=d, sigma=sigma, seed=s,
                                          target_scale=0.3)  # modest het.
        tbar = targets.mean(0)
        f_gap = 0.5 * float(np.sum(tbar**2))      # F(u_1=0) - F_inf
        # Assumption 1.5 sigma^2: sampling noise + non-IID heterogeneity.
        sigma_eff_sq = sigma**2 + float(
            np.max(np.sum((targets - tbar) ** 2, axis=1)))
        measured.append(m)
    t_total = rounds * (tau1 + tau2)
    b = bound_20(eta, tau1, tau2, topo, t_total, f_gap,
                 np.sqrt(sigma_eff_sq), n)
    return float(np.mean(measured)), b


def main():
    print("Proposition 1 numerical check (quadratic, L=mu=1):")
    print(f"{'config':34s} {'measured':>10s} {'bound(20)':>10s} {'holds':>6s}")
    rows = []
    for (tau1, tau2, topo, label) in [
        (4, 1, ring(8), "tau=(4,1) ring8   [C-SGD]"),
        (4, 2, ring(8), "tau=(4,2) ring8"),
        (4, 8, ring(8), "tau=(4,8) ring8"),
        (8, 2, ring(8), "tau=(8,2) ring8"),
        (1, 1, fully_connected(8), "tau=(1,1) C=J    [sync]"),
    ]:
        m, b = check(tau1=tau1, tau2=tau2, topo=topo)
        ok = m <= b
        rows.append(ok)
        print(f"{label:34s} {m:10.4f} {b:10.4f} {str(ok):>6s}")
    assert all(rows), "Proposition 1 bound violated!"
    print("all bounds hold")


if __name__ == "__main__":
    main()
