"""Table I: comparison of distributed SGD methods.

Runs FL-style sync SGD, D-SGD, C-SGD and DFL under an equal ITERATION
budget and reports loss/accuracy/consensus + per-round wire bytes — the
empirical counterpart of the paper's qualitative Table I.
"""
from __future__ import annotations

from benchmarks.common import RunSpec, print_csv, run_dfl_cnn, save_result

# (label, tau1, tau2, topology)  — iteration budget tau*rounds ~ 480.
METHODS = [
    ("sync-SGD (FL)", 1, 1, "full", 240),
    ("D-SGD", 1, 1, "ring", 240),
    ("C-SGD", 4, 1, "ring", 96),
    ("DFL", 4, 4, "ring", 60),
]


def run(flavor: str = "mnist", budget_iters: int = 480):
    rows = []
    results = {}
    for label, t1, t2, topo, rounds in METHODS:
        rounds = max(8, min(rounds, budget_iters // (t1 + t2)))
        spec = RunSpec(name=f"table1-{label}", tau1=t1, tau2=t2,
                       topology=topo, flavor=flavor, rounds=rounds)
        out = run_dfl_cnn(spec)
        results[label] = out
        h = out["history"]
        rows.append({
            "bench": "table1", "method": label, "tau1": t1, "tau2": t2,
            "iterations": h["iteration"][-1],
            "final_loss": round(h["global_loss"][-1], 4),
            "final_acc": round(h["test_acc"][-1], 4),
            "consensus": f'{h["consensus"][-1]:.2e}',
            "gbits": round(h["gbits"][-1], 3),
        })
    save_result(f"table1_{flavor}", results)
    print_csv(rows, ["bench", "method", "tau1", "tau2", "iterations",
                     "final_loss", "final_acc", "consensus", "gbits"])
    return rows


if __name__ == "__main__":
    run()
