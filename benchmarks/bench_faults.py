"""Sporadic participation vs. synchronous blocking under injected
faults, at equal deployment-clock budget — the fault-masking payoff.

The deployment is the 8-node ring quadratic testbed with a
deterministic ``repro.faults.FaultPlan``: a node crash window and a
link-outage window (plus their composition). Two policies ride the SAME
fault timeline and the SAME wall-clock budget:

  * ``blocking`` — the classic synchronous round: every node, every
    edge, every round. During a fault window the round still waits on
    the dead peer/link, so gossip is priced through the
    ``edge_outage`` residual tariff (~1/residual slower) — the clock
    burns while the model barely moves.
  * ``sporadic`` — the participation engine: faulted nodes skip local
    SGD, faulted edges fold their mixing weight onto the diagonal
    (``FaultPlan.masks`` -> widened ``[K, 2+N+E]`` schedule rows), and
    the round is priced by ``CostModel.masked_round_cost`` over the
    surviving sets only — degraded rounds stay cheap and keep learning.

Both policies execute FOR REAL on ONE participation-enabled
``RoundExecutor`` (the blocking run is the all-ones mask trajectory),
so the whole bench shares one compiled executable per superstep shape:
``recompiles_after_warmup == 0`` is asserted. The headline (asserted
under ``--check``, the CI config): at equal budget the sporadic run's
measured loss beats the blocking run's.

The measured loss is the mean per-node global loss gap
mean_i F(x_i) - F* = 0.5 mean_i ||x_i - tbar||^2 (charges both
average-model error and residual consensus drift). One shared learning
rate and one shared (tau1, tau2) keep the comparison purely about the
participation policy.

Writes ``BENCH_faults.json`` at the repo root. ``--smoke`` drops to
2 seeds (the CI config).

    PYTHONPATH=src python -m benchmarks.bench_faults --smoke --check
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DFLConfig, RoundExecutor, init_state, ring
from repro.faults import FaultPlan, LinkOutage, NodeCrash
from repro.optim import sgd
from repro.planner import (ComputeModel, CostModel, LinkModel,
                           WirelessLinks, edge_outage)

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_faults.json")

N = 8
DIM = 16
SIGMA = 0.5            # sampling-noise sigma (gradient = w - t_i - noise)
TSCALE = 0.8           # non-IID target spread
ETA = 0.008            # one shared lr: the comparison is about the policy
TAU1, TAU2 = 2, 1      # one shared schedule, likewise
T_GOSSIP = 1.0         # base gossip step cost (compute step = 1 unit)
RESIDUAL = 1e-2        # dead-link tariff: blocking gossip ~100x slower
BUDGET = 300.0
SUPERSTEP = 10
MAX_ROUNDS = 2000

# the fault timeline, in rounds (1 nominal round = TAU1 + TAU2*T_GOSSIP
# = 3 deployment-clock units): a mid-run crash, then a link outage.
CRASH = NodeCrash(node=3, r_start=5, r_stop=25)
OUTAGE = LinkOutage(edges=((0, 1), (4, 5)), r_start=40, r_stop=70)
SEC_PER_ROUND = float(TAU1 + TAU2 * T_GOSSIP)


def build_testbed() -> Tuple[CostModel, FaultPlan]:
    topo = ring(N)
    model_bits = 32.0 * DIM
    copy_bytes = model_bits / 8.0
    base_link = WirelessLinks(
        default=LinkModel(bytes_per_s=copy_bytes / T_GOSSIP))
    base = CostModel(compute=ComputeModel(step_flops=1.0, flops_per_s=1.0),
                     link=base_link, topology=topo, model_bits=model_bits)
    plan = FaultPlan(topo, (CRASH, OUTAGE), seed=0)
    return base, plan


def active_sets(topo, node_mask: np.ndarray, edge_mask: np.ndarray):
    nodes = [i for i in range(topo.num_nodes) if node_mask[i]]
    edges = [e for e, m in zip(topo.edges(), edge_mask) if m]
    return nodes, edges


def blocking_schedule(base: CostModel, plan: FaultPlan,
                      budget: float) -> Tuple[int, float]:
    """Rounds the synchronous policy affords: any masked edge at the
    round's nominal fault index drags the WHOLE round through the
    outage tariff (the synchronous gossip blocks on its slowest link)."""
    topo = base.topology
    clock, rounds = 0.0, 0
    while rounds < MAX_ROUNDS:
        # fault windows are defined on the nominal (non-blocked) round
        # clock — a wall-clock outage does not end early just because
        # the blocked run made no progress through it.
        r_nominal = int(clock // SEC_PER_ROUND)
        _, em = plan.masks(r_nominal)
        down = [e for e, m in zip(topo.edges(), em) if not m]
        if down:
            link = edge_outage(base.link, down, residual=RESIDUAL)
            cm = CostModel(compute=base.compute, link=link,
                           topology=topo, model_bits=base.model_bits,
                           engine=base.engine)
            rc = cm.round_cost(TAU1, TAU2)
        else:
            rc = base.round_cost(TAU1, TAU2)
        if clock + rc.time_s > budget:
            break
        clock += rc.time_s
        rounds += 1
    return rounds, clock


def sporadic_schedule(base: CostModel, plan: FaultPlan, budget: float
                      ) -> Tuple[np.ndarray, float]:
    """Masked rounds the sporadic policy affords: each round priced by
    ``masked_round_cost`` over the surviving node/edge sets only.
    Returns the realized ``[K, 2+N+E]`` trajectory and the clock."""
    topo = base.topology
    clock, rows = 0.0, []
    while len(rows) < MAX_ROUNDS:
        r_nominal = int(clock // SEC_PER_ROUND)
        nm, em = plan.masks(r_nominal)
        nodes, edges = active_sets(topo, nm, em)
        rc = base.masked_round_cost(TAU1, TAU2, active_nodes=nodes,
                                    active_edges=edges)
        if clock + rc.time_s > budget:
            break
        clock += rc.time_s
        rows.append(np.concatenate(
            [np.array([TAU1, TAU2], np.int32), nm, em]))
    return np.asarray(rows, np.int32), clock


def run_trajectory(executor: RoundExecutor, rows: np.ndarray,
                   targets: np.ndarray, seed: int) -> float:
    """Execute the (possibly masked) trajectory and return the final
    mean per-node global loss gap."""
    rng = np.random.default_rng(seed)
    state = init_state({"w": jnp.zeros((DIM,))}, N, sgd(ETA),
                       jax.random.key(seed))
    r = 0
    while r < len(rows):
        k = min(SUPERSTEP, len(rows) - r)
        noise = rng.normal(size=(k, TAU1, N, DIM)) * (SIGMA / np.sqrt(DIM))
        batches = jnp.asarray(targets[None, None] + noise, jnp.float32)
        state, _ = executor.dispatch_trajectory(state, batches,
                                                rows[r:r + k])
        r += k
    x = np.asarray(state.params["w"])
    tbar = targets.mean(0)
    return 0.5 * float(np.mean(np.sum((x - tbar) ** 2, axis=1)))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=6)
    ap.add_argument("--smoke", action="store_true",
                    help="2 seeds (the CI config)")
    ap.add_argument("--check", action="store_true",
                    help="assert sporadic beats blocking at equal budget")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    seeds = 2 if args.smoke else args.seeds

    base, plan = build_testbed()
    topo = base.topology
    targets = np.random.default_rng(0).normal(size=(N, DIM)) * TSCALE
    opt = sgd(ETA)

    def quad_loss(p, b, k=None):
        return 0.5 * jnp.sum((p["w"] - b) ** 2)

    executor = RoundExecutor(
        DFLConfig(tau1=TAU1, tau2=TAU2, topology=topo),
        quad_loss, opt, participation=True)

    # -- price both policies on the same clock ------------------------------
    blk_rounds, blk_clock = blocking_schedule(base, plan, BUDGET)
    spo_rows, spo_clock = sporadic_schedule(base, plan, BUDGET)
    blk_rows = np.concatenate(
        [np.tile(np.array([[TAU1, TAU2]], np.int32), (blk_rounds, 1)),
         np.ones((blk_rounds, N + topo.num_edges), np.int32)], axis=1)
    degraded = int(sum(
        1 for row in spo_rows
        if row[2:2 + N].sum() < N or row[2 + N:].sum() < topo.num_edges))
    print(f"blocking: rounds={blk_rounds} priced_time={blk_clock:.1f}")
    print(f"sporadic: rounds={len(spo_rows)} priced_time={spo_clock:.1f} "
          f"degraded={degraded}")

    # -- warm every superstep shape, then measure ---------------------------
    lengths = {blk_rounds, len(spo_rows)}
    shapes = {min(SUPERSTEP, n) for n in lengths if n} | \
             {n % SUPERSTEP for n in lengths if n % SUPERSTEP}
    dummy_state = init_state({"w": jnp.zeros((DIM,))}, N, opt,
                             jax.random.key(0))
    for k in sorted(shapes, reverse=True):
        executor.warmup(dummy_state, jnp.zeros((k, TAU1, N, DIM)))
    warm_compiles = executor.compile_count

    results: Dict[str, dict] = {}
    for name, rows, clock in (("blocking", blk_rows, blk_clock),
                              ("sporadic", spo_rows, spo_clock)):
        losses = [run_trajectory(executor, rows, targets, s)
                  for s in range(seeds)]
        results[name] = {
            "rounds": len(rows), "priced_time": clock,
            "loss": float(np.mean(losses)),
            "loss_per_seed": [float(v) for v in losses],
        }
        print(f"{name}: loss={np.mean(losses):.4f}")

    blk_loss = results["blocking"]["loss"]
    spo_loss = results["sporadic"]["loss"]
    recompiles = executor.compile_count - warm_compiles
    verdict = ("WINS %.2fx" % (blk_loss / spo_loss)
               if spo_loss < blk_loss else "LOSES")
    print(f"sporadic {verdict} vs blocking at budget={BUDGET} | "
          f"recompiles after warmup: {recompiles}")

    # THE zero-recompile property: the all-ones blocking run and every
    # masked sporadic round reused the warmed executables.
    assert recompiles == 0, (
        f"{recompiles} recompiles after warmup across the bench")

    payload = {
        "config": {
            "nodes": N, "dim": DIM, "sigma": SIGMA, "target_scale": TSCALE,
            "eta": ETA, "tau1": TAU1, "tau2": TAU2, "t_gossip": T_GOSSIP,
            "residual": RESIDUAL, "budget": BUDGET,
            "superstep": SUPERSTEP, "seeds": seeds, "smoke": args.smoke,
            "faults": plan.to_spec(),
            "backend": jax.default_backend(),
        },
        "blocking": results["blocking"],
        "sporadic": {**results["sporadic"], "degraded_rounds": degraded},
        "sporadic_beats_blocking": spo_loss < blk_loss,
        "margin_x": blk_loss / spo_loss if spo_loss > 0 else float("inf"),
        "recompiles_after_warmup": recompiles,
        "compile_count_warmup": warm_compiles,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")
    if args.check:
        assert spo_loss < blk_loss, (
            f"sporadic loss {spo_loss:.4f} does not beat blocking "
            f"{blk_loss:.4f} at equal budget")
        print("check OK: sporadic participation beats synchronous "
              "blocking at equal deployment-clock budget, zero recompiles")


if __name__ == "__main__":
    main()
