"""The paper's headline trade-off: balancing communication and computing
costs under a WALL-CLOCK budget (abstract / Sec. I).

For a grid of (tau1, tau2) we measure convergence per ROUND empirically and
price round wall-clock with the planner's cost model (one local step = 1
compute unit, one gossip step = the comm/comp ratio being swept); the best
(tau1, tau2) shifts toward more local computation as links get slower — the
balance DFL exposes and C-SGD/D-SGD cannot tune.

The planner (``repro.planner``) picks its schedule from Proposition 1
*before* seeing any measurement; this benchmark is its empirical
validation: the JSON records both the measured winner per ratio and the
planner's a-priori pick.
"""
from __future__ import annotations

from benchmarks.common import RunSpec, print_csv, run_dfl_cnn, save_result
from repro.core.topology import ring
from repro.planner import Budget, plan, rounds_within, unit_cost_model

GRID = [(1, 1), (2, 2), (4, 1), (4, 4), (8, 2), (1, 4)]
# compute:comm cost ratios to evaluate (t_comm / t_compute per step).
RATIOS = (0.2, 1.0, 5.0)
# Wall-clock budget = this many rounds of the reference (4, 4) schedule
# (the old inline "40 * (1 + ratio) * 4" constant, now derived).
BUDGET_REF_ROUNDS = 40
NODES = 10


def budget_for(ratio: float) -> Budget:
    cm = unit_cost_model(ring(NODES), ratio)
    return Budget(wall_clock_s=cm.round_cost(4, 4).time_s * BUDGET_REF_ROUNDS)


def run(flavor: str = "mnist", rounds: int = 50):
    runs = {}
    for (t1, t2) in GRID:
        spec = RunSpec(name=f"bal-{t1}-{t2}", tau1=t1, tau2=t2,
                       topology="ring", flavor=flavor, rounds=rounds)
        runs[(t1, t2)] = run_dfl_cnn(spec)
    rows = []
    results = {"runs": {f"{k}": v for k, v in runs.items()}, "winners": {},
               "planned": {}}
    for ratio in RATIOS:
        cost_model = unit_cost_model(ring(NODES), ratio)
        budget = budget_for(ratio)
        best = None
        for (t1, t2), out in runs.items():
            h = out["history"]
            n_rounds = rounds_within(budget, cost_model.round_cost(t1, t2))
            idx = min(range(len(h["round"])),
                      key=lambda i: abs(h["round"][i] - n_rounds))
            loss = h["global_loss"][idx]
            rows.append({"bench": "balance", "comm/comp": ratio,
                         "tau1": t1, "tau2": t2,
                         "rounds_in_budget": n_rounds,
                         "loss_at_budget": round(loss, 4)})
            if best is None or loss < best[0]:
                best = (loss, t1, t2)
        results["winners"][str(ratio)] = best
        # the planner's a-priori pick over the SAME grid and budget (CNN
        # constants are unknown; generic sigma/f_gap rank the grid).
        p = plan(budget, cost_model, sigma=1.0, f_gap=1.0, grid=GRID)
        results["planned"][str(ratio)] = {
            "tau1": p.tau1, "tau2": p.tau2, "eta": p.eta,
            "rounds": p.rounds, "predicted_bound": p.predicted_bound,
        }
        rows.append({"bench": "balance", "comm/comp": ratio,
                     "tau1": f"BEST={best[1]}", "tau2": best[2],
                     "rounds_in_budget": "",
                     "loss_at_budget": round(best[0], 4)})
    save_result(f"balance_{flavor}", results)
    print_csv(rows, ["bench", "comm/comp", "tau1", "tau2",
                     "rounds_in_budget", "loss_at_budget"])
    return rows


if __name__ == "__main__":
    run()
