"""The paper's headline trade-off: balancing communication and computing
costs under a WALL-CLOCK budget (abstract / Sec. I).

For a grid of (tau1, tau2) we measure convergence per ROUND empirically and
model round wall-clock as tau1 * t_compute + tau2 * t_comm for a given
compute/comm speed ratio (metrics.comm_compute_cost); the best (tau1, tau2)
shifts toward more local computation as links get slower — the balance DFL
exposes and C-SGD/D-SGD cannot tune.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import RunSpec, print_csv, run_dfl_cnn, save_result
from repro.core.metrics import comm_compute_cost

GRID = [(1, 1), (2, 2), (4, 1), (4, 4), (8, 2), (1, 4)]
# compute:comm cost ratios to evaluate (t_comm / t_compute per step).
RATIOS = (0.2, 1.0, 5.0)


def run(flavor: str = "mnist", rounds: int = 50):
    runs = {}
    for (t1, t2) in GRID:
        spec = RunSpec(name=f"bal-{t1}-{t2}", tau1=t1, tau2=t2,
                       topology="ring", flavor=flavor, rounds=rounds)
        runs[(t1, t2)] = run_dfl_cnn(spec)
    rows = []
    results = {"runs": {f"{k}": v for k, v in runs.items()}, "winners": {}}
    for ratio in RATIOS:
        best = None
        for (t1, t2), out in runs.items():
            h = out["history"]
            per_round = t1 * 1.0 + t2 * ratio  # arbitrary compute unit
            budget = 40 * (1 + ratio) * 4      # fixed wall-clock budget
            n_rounds = int(budget / per_round)
            idx = min(range(len(h["round"])),
                      key=lambda i: abs(h["round"][i] - n_rounds))
            loss = h["global_loss"][idx]
            rows.append({"bench": "balance", "comm/comp": ratio,
                         "tau1": t1, "tau2": t2,
                         "rounds_in_budget": n_rounds,
                         "loss_at_budget": round(loss, 4)})
            if best is None or loss < best[0]:
                best = (loss, t1, t2)
        results["winners"][str(ratio)] = best
        rows.append({"bench": "balance", "comm/comp": ratio,
                     "tau1": f"BEST={best[1]}", "tau2": best[2],
                     "rounds_in_budget": "",
                     "loss_at_budget": round(best[0], 4)})
    save_result(f"balance_{flavor}", results)
    print_csv(rows, ["bench", "comm/comp", "tau1", "tau2",
                     "rounds_in_budget", "loss_at_budget"])
    return rows


if __name__ == "__main__":
    run()
