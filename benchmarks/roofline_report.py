"""Render the dry-run JSONs (results/dryrun/*.json) into the EXPERIMENTS.md
roofline tables: per (arch x shape x mesh) the three roofline terms, the
dominant bottleneck, MODEL_FLOPS ratio, and per-device memory."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import REGISTRY
from repro.configs.base import SHAPES

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_all(dir_: str = DRYRUN_DIR) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            blob = json.load(f)
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        arch, shape, pod = parts[0], parts[1], parts[2]
        tag = parts[3] if len(parts) > 3 else ""
        for kind, rec in blob.items():
            rec = dict(rec)
            rec.setdefault("arch", arch)
            rec.setdefault("shape", shape)
            rec["pod"] = pod
            rec["tag"] = tag
            rec["file_kind"] = kind
            out.append(rec)
    return out


def model_flops_for(arch_id: str, shape_name: str, kind: str,
                    chips: int) -> Optional[float]:
    """Per-device MODEL_FLOPS (6*N_active*D train / 2*N_active*B decode)."""
    arch = REGISTRY[arch_id]
    shape = SHAPES[shape_name]
    na = arch.model.active_param_count()
    if kind in ("round", "local"):
        tokens = shape.global_batch * shape.seq_len
        mult = 4.0 if kind == "round" else 1.0   # default round has tau1=4
        return 6.0 * na * tokens * mult / chips
    if kind == "prefill":
        return 2.0 * na * shape.global_batch * shape.seq_len / chips
    if kind == "decode":
        return 2.0 * na * shape.global_batch / chips
    return None


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def render_table(records: List[Dict], pod: str = "1pod",
                 kinds=("local", "gossip", "prefill", "decode"),
                 tag: str = "") -> str:
    lines = [
        "| arch | shape | kind | compute | memory | collective | dominant "
        "| MODEL/HLO flops | HBM/dev (args) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        if not rec.get("ok") or rec["pod"] != pod or rec.get("tag", "") != tag:
            continue
        if rec.get("kind") not in kinds:
            continue
        roof = rec["roofline"]
        if rec["kind"] == "gossip":
            mf = None
        else:
            mf = model_flops_for(rec["arch"], rec["shape"], rec["kind"],
                                 rec.get("chips", 256))
        ratio = f"{mf / roof['flops']:.2f}" if mf and roof["flops"] else "-"
        mem = rec.get("memory", {})
        args_gib = mem.get("argument_size_in_bytes", 0) / 2**30
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['kind']} "
            f"| {fmt_s(roof['compute_s'])} | {fmt_s(roof['memory_s'])} "
            f"| {fmt_s(roof['collective_s'])} | **{roof['dominant']}** "
            f"| {ratio} | {args_gib:.2f} GiB |")
    return "\n".join(lines)


def summarize(pod: str = "1pod") -> None:
    recs = load_all()
    print(render_table(recs, pod=pod))


if __name__ == "__main__":
    import sys

    summarize(sys.argv[1] if len(sys.argv) > 1 else "1pod")
