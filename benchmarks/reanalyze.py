"""Re-run the loop-aware HLO analysis over dumped .hlo artifacts and patch
the corresponding results/dryrun JSONs in place (analysis iterations don't
need recompiles).

    PYTHONPATH=src python -m benchmarks.reanalyze
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

DRY = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def main() -> None:
    from repro.launch.hloanalysis import analyze_text
    from repro.launch.roofline import Roofline

    n = 0
    for hpath in sorted(glob.glob(os.path.join(DRY, "hlo", "*.hlo"))):
        base = os.path.basename(hpath)[:-4]
        parts = base.split("__")
        arch, shape, pod, kind = parts[0], parts[1], parts[2], parts[3]
        tag = parts[4] if len(parts) > 4 else ""
        jname = f"{arch}__{shape}__{pod}" + (f"__{tag}" if tag else "")
        jpath = os.path.join(DRY, jname + ".json")
        if not os.path.exists(jpath):
            continue
        with open(jpath) as f:
            blob = json.load(f)
        # map hlo kind -> json key (headline json key is 'headline')
        jkey = None
        for k, rec in blob.items():
            if rec.get("kind") == kind or (k == "headline" and kind in (
                    "headline", rec.get("kind", ""))):
                jkey = k
                break
        if jkey is None:
            continue
        with open(hpath) as f:
            corr = analyze_text(f.read())
        rec = blob[jkey]
        roof = Roofline(flops=corr["flops"], hbm_bytes=corr["bytes"],
                        collective_bytes=corr["collective_bytes"],
                        chips=rec.get("chips", 256))
        rec["corrected"] = corr
        rec["roofline"] = roof.as_dict()
        blob[jkey] = rec
        with open(jpath, "w") as f:
            json.dump(blob, f, indent=1, default=str)
        n += 1
    print(f"re-analyzed {n} artifacts")


if __name__ == "__main__":
    main()
