"""Fig. 10: C-DFL communication efficiency under compression.

Paper claims (tau1 = tau2 = 4, gamma = 1, 10-node ring):
 (a) against COMMUNICATION VOLUME (the paper measures wall-clock on a real
     NIC; offline we account exact wire bits and derive time over a fixed
     link bandwidth): moderate compression (top_k delta~0.89/0.67,
     rand-gossip p=0.8) converges FASTER than uncompressed DFL per byte;
 (b) against ITERATIONS: compression is slightly worse, and worse for
     smaller delta.
"""
from __future__ import annotations

from benchmarks.common import RunSpec, print_csv, run_dfl_cnn, save_result

VARIANTS = [
    ("DFL", "", {}),
    ("top_k d=0.89", "top_k", {"frac": 0.89}),
    ("top_k d=0.67", "top_k", {"frac": 0.67}),
    ("rand_gossip p=0.8", "rand_gossip", {"p": 0.8}),
    ("rand_gossip p=0.6", "rand_gossip", {"p": 0.6}),
]


def loss_at_gbits(history, budget_gbits):
    """First logged loss once cumulative traffic exceeds the budget."""
    for gb, loss in zip(history["gbits"], history["global_loss"]):
        if gb >= budget_gbits:
            return loss
    return history["global_loss"][-1]


def run(rounds: int = 60, flavor: str = "mnist"):
    rows = []
    results = {}
    runs = {}
    for label, comp, kw in VARIANTS:
        spec = RunSpec(name=f"fig10-{comp or 'dfl'}-{kw}",
                       tau1=4, tau2=4, topology="ring", compression=comp,
                       comp_kwargs=kw, gamma=1.0 if not comp else 0.6,
                       flavor=flavor, rounds=rounds)
        out = run_dfl_cnn(spec)
        runs[label] = out
        results[label] = out
    # common byte budget = half of what uncompressed DFL used.
    budget = runs["DFL"]["history"]["gbits"][-1] * 0.5
    for label, out in runs.items():
        h = out["history"]
        rows.append({
            "bench": "fig10", "label": label,
            "bits_per_round_rel": round(
                out["bits_per_round"] / runs["DFL"]["bits_per_round"], 3),
            "loss_at_byte_budget": round(loss_at_gbits(h, budget), 4),
            "final_loss_per_iter": round(h["global_loss"][-1], 4),
            "final_acc": round(h["test_acc"][-1], 4),
        })
    save_result(f"fig10_{flavor}", results)
    print_csv(rows, ["bench", "label", "bits_per_round_rel",
                     "loss_at_byte_budget", "final_loss_per_iter",
                     "final_acc"])
    return rows


if __name__ == "__main__":
    run()
