"""Fig. 7: accelerated convergence of DFL vs C-SGD as tau2 grows.

Paper claim: with tau1 = 4 fixed, training loss and test accuracy improve
monotonically with tau2 (tau2 = 1 is C-SGD, the worst; tau2 = 15 the best)
on ring and quasi-ring topologies.
"""
from __future__ import annotations

from benchmarks.common import RunSpec, print_csv, run_dfl_cnn, save_result

TAU2S = (1, 2, 4, 15)


def run(rounds: int = 60, flavor: str = "mnist", topology: str = "ring"):
    rows = []
    results = {}
    for tau2 in TAU2S:
        label = "C-SGD" if tau2 == 1 else f"DFL tau2={tau2}"
        spec = RunSpec(name=f"fig7-{flavor}-{topology}-tau2{tau2}",
                       tau1=4, tau2=tau2, topology=topology,
                       flavor=flavor, rounds=rounds)
        out = run_dfl_cnn(spec)
        results[spec.name] = out
        h = out["history"]
        rows.append({
            "bench": "fig7", "label": label, "tau2": tau2,
            "final_loss": round(h["global_loss"][-1], 4),
            "final_acc": round(h["test_acc"][-1], 4),
            "consensus": f'{h["consensus"][-1]:.2e}',
        })
    save_result(f"fig7_{flavor}_{topology}", results)
    print_csv(rows, ["bench", "label", "tau2", "final_loss", "final_acc",
                     "consensus"])
    return rows


if __name__ == "__main__":
    run()
