"""§Perf hillclimb driver (runs as its own process: fake 512 devices).

    PYTHONPATH=src python -m benchmarks.perf_hillclimb --pair qwen-gossip
    PYTHONPATH=src python -m benchmarks.perf_hillclimb --pair deepseek-decode
    PYTHONPATH=src python -m benchmarks.perf_hillclimb --pair jamba-train

Each pair runs the paper-faithful baseline and the beyond-paper variants,
extracting loop-corrected roofline terms per variant; results go to
results/perf/<pair>.json and a printed before/after table.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _measure(built, chips=256):
    from repro.launch import roofline as R

    t0 = time.time()
    compiled = built.lower().compile()
    rec = R.analyze_compiled(compiled, chips)
    rec["compile_s"] = round(time.time() - t0, 1)
    rec.update(built.meta)
    return rec


def _row(name, rec):
    r = rec["roofline"]
    return {
        "variant": name,
        "compute_s": r["compute_s"],
        "memory_s": r["memory_s"],
        "collective_s": r["collective_s"],
        "dominant": r["dominant"],
        "coll_bytes": r["collective_bytes"],
        "coll_kinds": rec.get("corrected", {}).get(
            "collective_bytes_per_kind", {}),
    }


def pair_qwen_gossip():
    """qwen3-8b x train_4k: the paper's communication stage itself."""
    import jax
    from repro.configs import get_arch
    from repro.core.compression import make_compressor
    from repro.launch import perf, steps
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    arch = get_arch("qwen3-8b")
    rows = []
    rows.append(_row("baseline dense f32 (paper-faithful XC)",
                     _measure(steps.build_gossip_step(arch, mesh))))
    rows.append(_row("dense C^4 power (1 contraction per round)",
                     _measure(perf.build_gossip_step_power(arch, mesh, 4))))
    rows.append(_row("sparse ppermute ring (2 neighbors)",
                     _measure(perf.build_gossip_step_sparse(arch, mesh))))
    rows.append(_row("C-DFL qsgd-compressed gossip (CHOCO)",
                     _measure(steps.build_gossip_step(
                         arch, mesh, compression=make_compressor("qsgd")))))
    return "qwen-gossip", rows


def pair_deepseek_decode():
    """deepseek-coder-33b x decode_32k: serving reshard churn."""
    import dataclasses as dc

    from repro.configs import get_arch
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    arch = get_arch("deepseek-coder-33b")
    rows = []
    rows.append(_row("baseline chunked decode (kv scan over sharded seq)",
                     _measure(steps.build_decode(arch, "decode_32k", mesh))))
    arch_opt = dc.replace(
        arch, model=dc.replace(arch.model, decode_unchunked=True))
    rows.append(_row("unchunked decode (single-block masked softmax)",
                     _measure(steps.build_decode(arch_opt, "decode_32k",
                                                 mesh))))
    # variant: batch over data AND seq replicated (cache replicated over
    # model is infeasible at 33B; keep seq=model) vs seq over data+model
    # variant: pad 56 -> 64 query heads (zero o-weights => identical
    # function) so attention shards on heads instead of head_dim; head_dim
    # sharding all-reduces full score tiles (the dominant collective).
    arch_pad = dc.replace(
        arch_opt, model=dc.replace(arch_opt.model, num_heads=64,
                                   attn_shard="heads"))
    rows.append(_row("unchunked + heads padded 56->64 (shard heads)",
                     _measure(steps.build_decode(arch_pad, "decode_32k",
                                                 mesh))))
    # variant: serve with model-only weight sharding (no FSDP): 33B bf16 /16
    # = 4.1 GiB weights + 4.1 GiB cache per device fits v5e HBM, and the
    # per-token FSDP weight re-gather (the dominant memory+collective
    # traffic) disappears entirely.
    arch_dp = dc.replace(arch_opt, sharding_mode="gossip-dp")
    rows.append(_row("unchunked + model-only weights (no serve FSDP)",
                     _measure(steps.build_decode(arch_dp, "decode_32k",
                                                 mesh))))
    return "deepseek-decode", rows


def pair_jamba_train():
    """jamba-1.5-large x train_4k: most collective-bound local step."""
    import dataclasses as dc

    from repro.configs import get_arch
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    arch = get_arch("jamba-1.5-large-398b")
    rows = []
    rows.append(_row("baseline local step (fsdp2, remat)",
                     _measure(steps.build_local_step(arch, "train_4k",
                                                     mesh))))
    # variant: no-remat (saves the re-gather of FSDP weights in backward at
    # the cost of saved activations)
    arch_nr = dc.replace(arch, model=dc.replace(arch.model, remat=False))
    rows.append(_row("no-remat local step (no bwd re-gather)",
                     _measure(steps.build_local_step(arch_nr, "train_4k",
                                                     mesh))))
    # variant: 4 replicated nodes instead of 2 (more copies, fewer FSDP
    # shards per copy -> same gather volume? measure)
    arch_n4 = dc.replace(arch, fsdp_nodes=4)
    rows.append(_row("fsdp_nodes=4",
                     _measure(steps.build_local_step(arch_n4, "train_4k",
                                                     mesh))))
    return "jamba-train", rows


PAIRS = {
    "qwen-gossip": pair_qwen_gossip,
    "deepseek-decode": pair_deepseek_decode,
    "jamba-train": pair_jamba_train,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=sorted(PAIRS))
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    name, rows = PAIRS[args.pair]()
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, name + ".json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)
    print(f"\n=== {name} ===")
    print(f"{'variant':52s} {'compute':>9s} {'memory':>9s} {'collect':>9s} "
          f"{'dominant':>10s}")
    for r in rows:
        print(f"{r['variant']:52s} {r['compute_s']*1e3:8.1f}m "
              f"{r['memory_s']*1e3:8.1f}m {r['collective_s']*1e3:8.1f}m "
              f"{r['dominant']:>10s}")


if __name__ == "__main__":
    main()
