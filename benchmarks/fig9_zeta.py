"""Fig. 9: effect of the topology parameter zeta on convergence.

Paper claim: with tau1 = 2, tau2 = 4, smaller zeta converges better;
zeta = 0 (C = J) is the best benchmark (Remark 2 / Corollary 2).
"""
from __future__ import annotations

from benchmarks.common import RunSpec, print_csv, run_dfl_cnn, save_result

TOPOLOGIES = (("full", 0.0), ("quasi", 0.85), ("ring", 0.8727))


def run(rounds: int = 60, flavor: str = "mnist"):
    rows = []
    results = {}
    for topo, zeta in TOPOLOGIES:
        # pathological non-IID + a single gossip step per round makes the
        # topology (zeta) the binding constraint, as in the paper's Fig. 9.
        spec = RunSpec(name=f"fig9-{topo}", tau1=2, tau2=1, topology=topo,
                       flavor=flavor, rounds=rounds * 2,
                       partition="label_shard")
        out = run_dfl_cnn(spec)
        results[spec.name] = out
        h = out["history"]
        rows.append({"bench": "fig9", "topology": topo,
                     "zeta": round(out["zeta"], 4),
                     "final_loss": round(h["global_loss"][-1], 4),
                     "final_acc": round(h["test_acc"][-1], 4),
                     "consensus": f'{h["consensus"][-1]:.2e}'})
    save_result(f"fig9_{flavor}", results)
    print_csv(rows, ["bench", "topology", "zeta", "final_loss", "final_acc",
                     "consensus"])
    return rows


if __name__ == "__main__":
    run()
