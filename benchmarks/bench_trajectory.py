"""Per-round (tau1, tau2) trajectories vs. every fixed schedule, at equal
budget, under straggler/fading episodes — the schedule-as-data payoff.

The deployment is the 8-node ring quadratic testbed with a TIME-VARYING
cost process (``planner.cost.CostProcess``): link episodes priced via
``WirelessLinks.per_edge`` make gossip ~1000x more expensive during two
windows (one straggling node gating the synchronous gossip step, one
network-wide deep fade). Every run is charged on the same simulated
deployment clock and stopped at the same wall-clock budget:

  * ``fixed``      — each (tau1, tau2) grid point run unchanged through
                     the episodes (a fixed schedule keeps paying the
                     episode tariff: that is the cost of schedule-as-
                     control-flow).
  * ``trajectory`` — ``planner.optimize.plan_trajectory`` walks the same
                     clock and re-plans EVERY ROUND from the remaining
                     budget and the tariff in force: gossip rounds while
                     links are good, compute-only (tau2 = 0) rounds
                     through the outages, gossip again after.

All runs execute for real on ``RoundExecutor`` — the fixed grid as uniform
dispatches, the trajectory as heterogeneous [K, 2] ``dispatch_trajectory``
supersteps — through ONE executor, so the whole sweep (every schedule,
every seed) shares one compiled executable per superstep shape:
``recompiles_after_warmup == 0`` is asserted on every run. The headline
(asserted under ``--check``, the CI config): the trajectory's measured
loss at budget beats EVERY fixed grid point's.

The measured loss is the mean per-node global loss gap
mean_i F(x_i) - F* = 0.5 mean_i ||x_i - tbar||^2 (what each node actually
deploys — it charges both average-model error and residual consensus
drift, so under- and over-gossiping both lose). One shared learning rate
for every run keeps the comparison purely about the schedule.

Writes ``BENCH_trajectory.json`` at the repo root. ``--smoke`` drops to
2 seeds (the CI config).

    PYTHONPATH=src python -m benchmarks.bench_trajectory --smoke --check
"""
from __future__ import annotations

import argparse
import json
import os
from collections import Counter
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DFLConfig, RoundExecutor, init_state, ring, \
    stack_round_batches
from repro.optim import sgd
from repro.planner import (Budget, ComputeModel, CostModel, CostProcess,
                           Episode, LinkModel, WirelessLinks, faded_links,
                           plan_trajectory, straggler_links)

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_trajectory.json")

N = 8
DIM = 16
SIGMA = 0.5            # sampling-noise sigma (gradient = w - t_i - noise)
TSCALE = 0.8           # non-IID target spread
ETA = 0.008            # one shared lr: the comparison is about schedules
GRID = [(1, 2), (2, 2), (2, 1), (4, 1), (8, 1), (16, 1),
        (1, 0), (4, 0), (16, 0)]   # tau2=0: the outage escape hatches
T_GOSSIP = 1.0         # base gossip step cost (compute step = 1 unit)
SLOWDOWN = 1000.0      # episode link degradation (outage-severity)
EPISODES = ((100.0, 220.0, "straggler"), (300.0, 420.0, "fade"))
BUDGET = 500.0
SUPERSTEP = 10
MAX_ROUNDS = 3000


def build_process() -> CostProcess:
    """The straggler/fading scenario priced via WirelessLinks.per_edge."""
    topo = ring(N)
    model_bits = 32.0 * DIM
    copy_bytes = model_bits / 8.0
    base_link = WirelessLinks(
        default=LinkModel(bytes_per_s=copy_bytes / T_GOSSIP))
    episodes = []
    for (t0, t1, kind) in EPISODES:
        if kind == "straggler":
            link = straggler_links(base_link, topo, 0, SLOWDOWN)
        else:
            link = faded_links(base_link, SLOWDOWN)
        episodes.append(Episode(t0, t1, link=link, label=kind))
    base = CostModel(compute=ComputeModel(step_flops=1.0, flops_per_s=1.0),
                     link=base_link, topology=topo, model_bits=model_bits)
    return CostProcess(base=base, episodes=tuple(episodes))


def testbed_constants(targets: np.ndarray) -> Tuple[float, float]:
    """(f_gap, effective sigma) — Assumption 1.5 sigma includes the
    non-IID heterogeneity (see benchmarks/theory_check)."""
    tbar = targets.mean(0)
    f_gap = 0.5 * float(np.sum(tbar ** 2))
    sig_eff = float(np.sqrt(
        SIGMA ** 2 + np.max(np.sum((targets - tbar) ** 2, axis=1))))
    return f_gap, sig_eff


def fixed_schedule(process: CostProcess, budget: float, t1: int,
                   t2: int) -> Tuple[List[Tuple[int, int]], float]:
    """The rounds a fixed (t1, t2) affords: walk the deployment clock,
    each round priced at the tariff in force when it starts."""
    clock = 0.0
    taus: List[Tuple[int, int]] = []
    while len(taus) < MAX_ROUNDS:
        rc = process.at(clock).round_cost(t1, t2)
        if clock + rc.time_s > budget:
            break
        clock += rc.time_s
        taus.append((t1, t2))
    return taus, clock


def run_schedule(executor: RoundExecutor, taus: List[Tuple[int, int]],
                 targets: np.ndarray, seed: int, tau1_max: int,
                 opt) -> float:
    """Execute the schedule on the executor (heterogeneous [K, 2] chunks)
    and return the final mean per-node global loss gap."""
    rng = np.random.default_rng(seed)
    state = init_state({"w": jnp.zeros((DIM,))}, N, opt, jax.random.key(seed))
    r = 0
    while r < len(taus):
        k = min(SUPERSTEP, len(taus) - r)
        chunk = np.asarray(taus[r:r + k], np.int32)
        # batches row t of round k: target + noise (the stochastic
        # gradient's noise lives in the data; rows >= tau1 never read).
        noise = rng.normal(size=(k, tau1_max, N, DIM)) * (SIGMA / np.sqrt(DIM))
        batches = jnp.asarray(targets[None, None] + noise, jnp.float32)
        state, _ = executor.dispatch_trajectory(state, batches, chunk)
        r += k
    x = np.asarray(state.params["w"])
    tbar = targets.mean(0)
    return 0.5 * float(np.mean(np.sum((x - tbar) ** 2, axis=1)))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=6)
    ap.add_argument("--smoke", action="store_true",
                    help="2 seeds (the CI config)")
    ap.add_argument("--check", action="store_true",
                    help="assert the trajectory beats every fixed grid "
                         "point's measured loss at budget")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    seeds = 2 if args.smoke else args.seeds

    topo = ring(N)
    process = build_process()
    targets = np.random.default_rng(0).normal(size=(N, DIM)) * TSCALE
    f_gap, sig_eff = testbed_constants(targets)
    opt = sgd(ETA)

    def quad_loss(p, b, k=None):
        return 0.5 * jnp.sum((p["w"] - b) ** 2)

    # every schedule the sweep dispatches fits one executor compiled
    # against the grid maxima: the WHOLE bench is one executable per
    # superstep shape.
    tau1_max = max(t1 for t1, _ in GRID)
    tau2_max = max(t2 for _, t2 in GRID)
    executor = RoundExecutor(
        DFLConfig(tau1=tau1_max, tau2=tau2_max, topology=topo),
        quad_loss, opt)

    # -- plan ---------------------------------------------------------------
    tp = plan_trajectory(Budget(wall_clock_s=BUDGET), process,
                         rounds=MAX_ROUNDS, sigma=sig_eff, f_gap=f_gap,
                         grid=GRID, eta=ETA)
    traj = [tuple(map(int, row)) for row in tp.taus]
    fixed = {(t1, t2): fixed_schedule(process, BUDGET, t1, t2)
             for (t1, t2) in GRID}

    # -- warm every superstep shape, then measure ---------------------------
    lengths = {len(traj)} | {len(taus) for taus, _ in fixed.values()}
    shapes = {min(SUPERSTEP, n) for n in lengths if n} | \
             {n % SUPERSTEP for n in lengths if n % SUPERSTEP}
    dummy_state = init_state({"w": jnp.zeros((DIM,))}, N, opt,
                             jax.random.key(0))
    for k in sorted(shapes, reverse=True):
        executor.warmup(dummy_state, jnp.zeros((k, tau1_max, N, DIM)))
    warm_compiles = executor.compile_count

    results: Dict[str, dict] = {}
    for (t1, t2), (taus, clock) in fixed.items():
        losses = [run_schedule(executor, taus, targets, s, tau1_max, opt)
                  for s in range(seeds)]
        results[f"{t1},{t2}"] = {
            "tau1": t1, "tau2": t2, "rounds": len(taus),
            "priced_time": clock, "loss": float(np.mean(losses)),
            "loss_per_seed": [float(v) for v in losses],
        }
        print(f"fixed ({t1:2d},{t2}): rounds={len(taus):4d} "
              f"time={clock:6.1f} loss={np.mean(losses):.4f}")

    traj_losses = [run_schedule(executor, traj, targets, s, tau1_max, opt)
                   for s in range(seeds)]
    traj_loss = float(np.mean(traj_losses))
    counts = Counter(traj)
    print(f"trajectory: rounds={len(traj)} time={tp.total_time_s:6.1f} "
          f"loss={traj_loss:.4f} schedule={dict(counts)}")

    best_key = min(results, key=lambda k: results[k]["loss"])
    best_loss = results[best_key]["loss"]
    recompiles = executor.compile_count - warm_compiles
    print(f"best fixed: ({best_key}) loss={best_loss:.4f} -> trajectory "
          f"{'WINS %.2fx' % (best_loss / traj_loss) if traj_loss < best_loss else 'LOSES'}"
          f" | recompiles after warmup: {recompiles}")

    # THE zero-recompile property: the whole sweep — every fixed schedule,
    # every seed, and the heterogeneous trajectory — reused the warmed
    # executables (hard failure otherwise).
    assert recompiles == 0, (
        f"{recompiles} recompiles after warmup across the sweep")

    payload = {
        "config": {
            "nodes": N, "dim": DIM, "sigma": SIGMA, "target_scale": TSCALE,
            "eta": ETA, "grid": [list(g) for g in GRID],
            "t_gossip": T_GOSSIP, "slowdown": SLOWDOWN,
            "episodes": [list(e) for e in EPISODES], "budget": BUDGET,
            "superstep": SUPERSTEP, "seeds": seeds, "smoke": args.smoke,
            "backend": jax.default_backend(),
        },
        "fixed": results,
        "trajectory": {
            "rounds": len(traj), "priced_time": tp.total_time_s,
            "loss": traj_loss,
            "loss_per_seed": [float(v) for v in traj_losses],
            "schedule_counts": {f"{a},{b}": c for (a, b), c in
                                counts.items()},
            "schedule": [list(t) for t in traj],
        },
        "best_fixed": {"key": best_key, "loss": best_loss},
        "trajectory_beats_best_fixed": traj_loss < best_loss,
        "margin_x": best_loss / traj_loss if traj_loss > 0 else float("inf"),
        "recompiles_after_warmup": recompiles,
        "compile_count_warmup": warm_compiles,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")
    if args.check:
        assert traj_loss < best_loss, (
            f"trajectory loss {traj_loss:.4f} does not beat best fixed "
            f"({best_key}) {best_loss:.4f}")
        print("check OK: trajectory beats every fixed grid point at "
              "budget, zero recompiles across the sweep")


if __name__ == "__main__":
    main()
