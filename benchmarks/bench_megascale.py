"""Mega-scale node-batched engine: rounds/s and host-memory footprint
vs. virtual-node count, with the correctness gates asserted.

One host simulates V virtual nodes by stacking model state ``[V, ...]``
and activating a sampled C-node cohort per round
(``RoundExecutor(engine="batched", population=V)`` over a ring(C)
cohort topology, cohort ids drawn by ``repro.faults.CohortSampler``).
The bench measures, per population scale:

  * **rounds/s** — steady-state sampled-cohort rounds through the fused
    superstep (warmup excluded), with a fresh cohort draw every round;
  * **host memory** — the stacked state's exact byte count (params +
    opt state) plus the process peak RSS after the scale ran;
  * **zero recompiles** — ``compile_count`` must not move across cohort
    draws after warmup (the schedule-as-data property at mega scale;
    asserted at EVERY scale, and recorded per scale in the JSON).

Before any scale runs, a differential gate proves the engine honest at
small N where the dense engine can run the same rounds: batched ==
dense BITWISE on model state for {plain, CHOCO-QSGD} x {full cohort,
sampled cohort-as-masks}, with a noisy loss so the per-node RNG
fold_in discipline is load-bearing (asserted under ``--check``; the
deeper matrix lives in tests/test_batched_parity.py).

Writes ``BENCH_megascale.json`` at the repo root. ``--smoke`` runs the
10k-node scale only (the CI config); the default also runs 100k — the
ROADMAP's mega-scale smoke, asserted trained + recompile-free in the
JSON payload.

    PYTHONPATH=src python -m benchmarks.bench_megascale --smoke --check
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DFLConfig, RoundExecutor, init_state, make_compressor, ring
from repro.faults import CohortSampler
from repro.optim import sgd

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_megascale.json")

C = 8                  # cohort size == cohort topology nodes
DIM = 16
ETA = 0.05
TAU1, TAU2 = 2, 1
SUPERSTEP = 10
ROUNDS = 30            # sampled rounds measured per scale
SCALES = (10_000, 100_000)
SMOKE_SCALES = (10_000,)


def noisy_loss(p, b, k=None):
    # the key makes the per-node fold_in discipline load-bearing: a
    # batched engine that folded cohort SLOTS instead of global ids
    # would diverge bitwise here.
    jitter = 0.02 * jax.random.normal(k, p["w"].shape)
    return jnp.mean((p["w"] + jitter - b) ** 2)


def tree_bytes(tree) -> int:
    return int(sum(x.nbytes for x in jax.tree_util.tree_leaves(tree)
                   if hasattr(x, "nbytes")))


# ---------------------------------------------------------------------------
# differential gate: batched == dense bitwise at small N
# ---------------------------------------------------------------------------


def _run_small(engine: str, taus: np.ndarray, compression=None):
    opt = sgd(ETA)
    cfg = DFLConfig(tau1=TAU1, tau2=TAU2, topology=ring(C),
                    compression=compression)
    state = init_state({"w": jnp.zeros((DIM,))}, C, opt, jax.random.key(1),
                       compressed=compression is not None)
    kw = dict(population=C) if engine == "batched" else {}
    ex = RoundExecutor(cfg, noisy_loss, opt, engine=engine,
                       participation=engine == "dense", **kw)
    k = taus.shape[0]
    batches = jax.random.normal(jax.random.key(7), (k, TAU1, C, DIM))
    state, metrics = ex.dispatch_trajectory(state, batches, taus)
    return state, metrics


def parity_gate() -> Dict[str, bool]:
    """batched == dense BITWISE on model state (and metrics), full and
    sampled-as-masks cohorts, plain and CHOCO."""
    k = 3
    plain = np.tile(np.array([[TAU1, TAU2]], np.int32), (k, 1))
    e = ring(C).num_edges
    rng = np.random.default_rng(0)
    nm = rng.integers(0, 2, (k, C)).astype(np.int32)
    nm[:, 0] = 1
    masked_dense = np.concatenate([plain, nm, np.ones((k, e), np.int32)], 1)
    ids = np.tile(np.arange(C, dtype=np.int32), (k, 1))
    masked_batch = np.concatenate(
        [plain, ids, nm, np.ones((k, e), np.int32)], 1)
    qsgd = make_compressor("qsgd", levels=4)

    out: Dict[str, bool] = {}
    cases = [
        ("plain_full", plain, plain, None),
        ("plain_sampled_masks", masked_dense, masked_batch, None),
        ("choco_full", plain, plain, qsgd),
        ("choco_sampled_masks", masked_dense, masked_batch, qsgd),
    ]
    for name, t_dense, t_batch, comp in cases:
        sd, md = _run_small("dense", t_dense, comp)
        sb, mb = _run_small("batched", t_batch, comp)
        ok = True
        cmp_d = (sd.params, sd.opt_state, sd.hat_params, md)
        cmp_b = (sb.params, sb.opt_state, sb.hat_params, mb)
        for x, y in zip(jax.tree_util.tree_leaves(cmp_d),
                        jax.tree_util.tree_leaves(cmp_b)):
            ok &= bool(np.array_equal(np.asarray(x), np.asarray(y)))
        out[name] = ok
        print(f"parity[{name}]: {'BITWISE' if ok else 'DIVERGED'}")
    return out


# ---------------------------------------------------------------------------
# the scale sweep
# ---------------------------------------------------------------------------


def measure_scale(population: int, rounds: int) -> dict:
    opt = sgd(ETA)
    topo = ring(C)
    cfg = DFLConfig(tau1=TAU1, tau2=TAU2, topology=topo)
    ex = RoundExecutor(cfg, noisy_loss, opt, engine="batched",
                       population=population)
    state = init_state({"w": jnp.zeros((DIM,))}, population, opt,
                       jax.random.key(1))
    state_bytes = tree_bytes(state.params) + tree_bytes(state.opt_state)
    sampler = CohortSampler(population=population, cohort=C, seed=0)

    def chunk(r0: int, k: int):
        taus = np.tile(np.array([[TAU1, TAU2]], np.int32), (k, 1))
        rows = sampler.cohort_trajectory(taus, r0, num_edges=topo.num_edges)
        b = jax.random.normal(jax.random.fold_in(jax.random.key(3), r0),
                              (k, TAU1, C, DIM))
        return b, rows

    # warm both superstep shapes the sweep dispatches, then count.
    shapes = sorted({min(SUPERSTEP, rounds), rounds % SUPERSTEP} - {0},
                    reverse=True)
    for k in shapes:
        ex.warmup(state, jnp.zeros((k, TAU1, C, DIM)))
    warm_compiles = ex.compile_count

    # build every chunk's batches + cohort rows BEFORE the timer: the
    # batch builder's own jit compile is cached process-wide, so leaving
    # it inside the loop taxes only the FIRST scale measured and skews
    # the rounds/s-vs-V curve. The timed region is dispatch only.
    chunks = []
    r = 0
    while r < rounds:
        k = min(SUPERSTEP, rounds - r)
        chunks.append(chunk(r, k))
        r += k
    jax.block_until_ready([b for b, _ in chunks])

    losses: List[float] = []
    t0 = time.perf_counter()
    for b, rows in chunks:
        state, metrics = ex.dispatch_trajectory(state, b, rows)
        losses.append(float(np.asarray(metrics["loss"])[-1]))
    jax.block_until_ready(state.params)
    elapsed = time.perf_counter() - t0
    recompiles = ex.compile_count - warm_compiles
    # every chunk drew a DIFFERENT cohort: recompiles across draws must
    # be zero or the mega-scale property is fiction.
    assert recompiles == 0, (
        f"{recompiles} recompiles across cohort draws at V={population}")
    # trained: the cohort rounds actually moved the model off init.
    moved = float(np.abs(np.asarray(
        state.params["w"][sampler.draw(0)])).max())
    peak_rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    res = {
        "virtual_nodes": population, "cohort": C, "rounds": rounds,
        "rounds_per_s": rounds / elapsed, "elapsed_s": elapsed,
        "state_bytes": state_bytes,
        "state_mb": state_bytes / 1e6,
        "peak_rss_mb": peak_rss_mb,
        "final_loss": losses[-1],
        "trained": moved > 0.0,
        "recompiles_after_warmup": recompiles,
        "compile_count_warmup": warm_compiles,
    }
    print(f"V={population:>9,}: {res['rounds_per_s']:.1f} rounds/s  "
          f"state={res['state_mb']:.1f} MB  peak_rss={peak_rss_mb:.0f} MB  "
          f"recompiles={recompiles}")
    return res


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="10k-node scale only (the CI config)")
    ap.add_argument("--check", action="store_true",
                    help="assert bitwise parity + zero recompiles")
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    parity = parity_gate()
    scales = SMOKE_SCALES if args.smoke else SCALES
    results = [measure_scale(v, args.rounds) for v in scales]

    ok_100k = any(r["virtual_nodes"] >= 100_000 and r["trained"]
                  and r["recompiles_after_warmup"] == 0 for r in results)
    payload = {
        "config": {
            "cohort": C, "dim": DIM, "eta": ETA, "tau1": TAU1,
            "tau2": TAU2, "superstep": SUPERSTEP, "rounds": args.rounds,
            "scales": list(scales), "smoke": args.smoke,
            "backend": jax.default_backend(),
        },
        "parity": parity,
        "scales": results,
        # the acceptance assertion: 100k virtual nodes trained on one
        # host with zero recompiles across cohort draws (full runs; the
        # smoke config stops at 10k and records the same per-scale
        # zero-recompile facts).
        "megascale_100k_zero_recompiles": ok_100k,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")
    if args.check:
        assert all(parity.values()), f"parity gate failed: {parity}"
        assert all(r["recompiles_after_warmup"] == 0 for r in results)
        assert all(r["trained"] for r in results)
        if not args.smoke:
            assert ok_100k, "100k-node scale missing or not recompile-free"
        print("check OK: batched bitwise == dense, sampled cohorts ride "
              "one executable at every scale")


if __name__ == "__main__":
    main()
