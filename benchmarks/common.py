"""Shared harness for the paper-reproduction benchmarks (Figs. 7-10, Table I).

Trains the paper's CNN (Appendix C) with DFL/C-DFL on the synthetic
MNIST-/CIFAR-shaped datasets (offline container — DESIGN.md section 7) over
the paper's 10-node topologies, and reports training-loss / test-accuracy
trajectories plus exact wire-byte accounting.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DFLConfig, average_model, fully_connected, init_state, make_compressor,
    make_round_fn, paper_quasi_ring, ring, round_wire_bits,
)
from repro.data.images import SyntheticImages, image_batches_for_dfl
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn
from repro.optim import sgd

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "repro")

_DATA_CACHE: Dict = {}


def get_data(flavor: str) -> SyntheticImages:
    if flavor not in _DATA_CACHE:
        # sized for the single-CPU container: large enough for stable
        # non-IID statistics across 10 nodes, small enough that a bench
        # config finishes in ~2 minutes.
        _DATA_CACHE[flavor] = SyntheticImages(
            flavor=flavor, train_size=3000, test_size=600, seed=7)
    return _DATA_CACHE[flavor]


@dataclasses.dataclass
class RunSpec:
    name: str
    tau1: int = 4
    tau2: int = 4
    topology: str = "ring"          # ring | quasi | full | disconnected-ish
    compression: str = ""
    comp_kwargs: Optional[dict] = None
    gamma: float = 1.0
    lr: float = 0.05                # synthetic data needs a livelier lr than
    flavor: str = "mnist"           # the paper's 0.002 on real MNIST
    nodes: int = 10
    rounds: int = 40
    batch: int = 16
    partition: str = "dirichlet"
    seed: int = 0

    def topology_obj(self):
        if self.topology == "ring":
            return ring(self.nodes)
        if self.topology == "quasi":
            return paper_quasi_ring()
        if self.topology == "full":
            return fully_connected(self.nodes)
        raise ValueError(self.topology)


def run_dfl_cnn(spec: RunSpec, log_every: int = 5) -> Dict:
    data = get_data(spec.flavor)
    parts = data.partition(spec.nodes, scheme=spec.partition, seed=spec.seed)
    comp = (make_compressor(spec.compression, **(spec.comp_kwargs or {}))
            if spec.compression else None)
    cfg = DFLConfig(tau1=spec.tau1, tau2=spec.tau2,
                    topology=spec.topology_obj(),
                    compression=comp, gamma=spec.gamma)
    opt = sgd(spec.lr)

    def loss_fn(params, batch, key=None):
        return cnn_loss(params, batch, flavor=spec.flavor)

    params0 = init_cnn(jax.random.key(spec.seed), spec.flavor)
    state = init_state(params0, spec.nodes, opt, jax.random.key(spec.seed + 1),
                       compressed=cfg.is_compressed)
    round_fn = jax.jit(make_round_fn(cfg, loss_fn, opt))
    eval_fn = jax.jit(lambda p, x, y: cnn_accuracy(p, x, y, spec.flavor))
    # global train loss F(u) of the averaged model — the quantity the
    # paper's training-loss curves (and Prop. 1) track.
    gloss_fn = jax.jit(lambda p, x, y: cnn_loss(p, (x, y), spec.flavor))
    # engine="sparse": the paper's per-neighbor deployment accounting (deg
    # copies/step), regardless of the single-host dense simulation engine.
    bits_per_round = round_wire_bits(cfg, params0, engine="sparse")

    test_x = jnp.asarray(data.test_x)
    test_y = jnp.asarray(data.test_y)
    gtrain_x = jnp.asarray(data.train_x[:1000])
    gtrain_y = jnp.asarray(data.train_y[:1000])
    hist: Dict[str, List[float]] = {
        "round": [], "iteration": [], "loss": [], "global_loss": [],
        "consensus": [], "test_acc": [], "gbits": [],
    }
    t0 = time.time()
    for r in range(spec.rounds):
        xs, ys = image_batches_for_dfl(
            data, parts, spec.tau1, spec.batch, r, seed=spec.seed)
        state, m = round_fn(state, (jnp.asarray(xs), jnp.asarray(ys)))
        if (r + 1) % log_every == 0 or r == spec.rounds - 1:
            avg = average_model(state.params)
            acc = float(eval_fn(avg, test_x, test_y))
            hist["round"].append(r + 1)
            hist["iteration"].append((r + 1) * (spec.tau1 + spec.tau2))
            hist["loss"].append(float(m["loss"]))
            hist["global_loss"].append(float(gloss_fn(avg, gtrain_x,
                                                      gtrain_y)))
            hist["consensus"].append(float(m["consensus_sq"]))
            hist["test_acc"].append(acc)
            hist["gbits"].append((r + 1) * bits_per_round / 1e9)
    return {
        "spec": dataclasses.asdict(spec),
        "bits_per_round": bits_per_round,
        "zeta": spec.topology_obj().zeta,
        "wall_s": round(time.time() - t0, 1),
        "history": hist,
    }


def save_result(name: str, payload: Dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def print_csv(rows: List[Dict], cols: List[str]) -> None:
    print(",".join(cols))
    for row in rows:
        print(",".join(str(row.get(c, "")) for c in cols))
