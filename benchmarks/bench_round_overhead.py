"""Per-round dispatch-overhead benchmark: legacy vs. the fused executor.

Two measurements over the SAME pre-generated batches on the 8-node ring
(host data generation excluded, so the numbers isolate dispatch + sync +
compile overhead — the quantities the executor exists to remove):

  * ``dispatch``     — the paper-testbed quadratic model (the same model
                       family as ``theory_check``/``bench_balance``): round
                       compute is near-zero, so per-round Python dispatch,
                       host-device sync, and recompiles dominate. THE
                       acceptance numbers live here: superstep >= 2x legacy
                       rounds/sec, and a forced mid-run (tau1, tau2)
                       re-plan with ZERO new XLA compilations.
  * ``telemetry``    — the quad superstep path with a live ``Telemetry``
                       sink vs ``telemetry=None`` (best of repeats):
                       instrumentation is host-side appends only, so
                       ``--check`` holds the throughput regression < 2%.
  * ``reduced_arch`` — the reduced transformer arch end-to-end: device
                       compute dominates steady-state (XLA-CPU op overhead
                       floors a round at a few ms regardless of model
                       width), so the headline here is the re-plan stall —
                       legacy pays a multi-second re-jit, the executor two
                       device scalars.

Three dispatch strategies per measurement:

  * ``legacy``             — the pre-executor train loop: one static
                             ``make_round_fn`` jit per (tau1, tau2), one
                             Python dispatch + blocking loss fetch per
                             round; a re-plan REBUILDS the jit.
  * ``executor_round``     — ``RoundExecutor`` K=1: dynamic-tau,
                             compile-once (re-plan = two device scalars).
  * ``executor_superstep`` — K-round fused ``lax.scan`` supersteps
                             (donated state, one host sync per K rounds).

Writes ``BENCH_round_executor.json`` at the repo root (the perf-trajectory
seed). ``--smoke`` shrinks the transformer so the run finishes in ~a
minute — the config CI tracks. The zero-recompile property is asserted on
every run; ``--check`` additionally asserts the >= 2x dispatch speedup.

    PYTHONPATH=src python -m benchmarks.bench_round_overhead --smoke --check
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import reduced_from
from repro.core import (DFLConfig, RoundExecutor, init_state, make_round_fn,
                        ring, stack_round_batches)
from repro.models import init_params, train_loss
from repro.optim import sgd

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_round_executor.json")


def run_legacy(cfg_fn, loss_fn, opt, state, per_round, schedule, sync=True):
    """The pre-executor loop: static jit per (tau1, tau2), per-round
    blocking sync; a schedule change re-jits (the recompile the executor
    removes)."""
    compiles = 0
    current: Tuple[int, int] = None
    rf = None
    compile_rounds = set()
    times: List[float] = []
    replan_stall = 0.0
    for r, (t1, t2) in enumerate(schedule):
        tr0 = time.perf_counter()
        if (t1, t2) != current:
            rf = jax.jit(make_round_fn(cfg_fn(t1, t2), loss_fn, opt))
            current = (t1, t2)
            compiles += 1
            compile_rounds.add(r)
        state, m = rf(state, per_round[r][t1])
        if sync:
            float(m["loss"])           # the per-round host sync
        dt = time.perf_counter() - tr0
        times.append(dt)
        if r > 0 and r in compile_rounds:
            replan_stall += dt
    steady = [t for r, t in enumerate(times) if r not in compile_rounds]
    return {
        "rounds_per_s": len(steady) / sum(steady),
        "steady_round_ms": 1e3 * sum(steady) / len(steady),
        "recompiles": compiles,
        "replan_stall_s": replan_stall,
    }


def run_executor(executor: RoundExecutor, state, stacked_chunks, superstep):
    """Dispatch pre-stacked (chunk, tau1, tau2) supersteps; one blocking
    metric fetch per chunk. EVERY distinct chunk shape (incl. the shorter
    tail when rounds % superstep != 0) is warmed up front — the dynamic
    executor compiles once per K — so ``recompiles_after_warmup`` isolates
    the schedule property: the forced re-plan inside ``stacked_chunks``
    must leave it at zero."""
    seen = set()
    for chunk, _, _ in stacked_chunks:
        k = jax.tree_util.tree_leaves(chunk)[0].shape[0]
        if k not in seen:
            executor.warmup(state, chunk)
            seen.add(k)
    warm_compiles = executor.compile_count
    times: List[float] = []
    rounds = 0
    replan_stall = 0.0
    prev = stacked_chunks[0][1:]
    for stacked, t1, t2 in stacked_chunks:
        k = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        tr0 = time.perf_counter()
        state, m = executor.dispatch(state, stacked, t1, t2)
        float(np.asarray(m["loss"])[-1])   # one sync per superstep
        dt = time.perf_counter() - tr0
        times.append(dt)
        rounds += k
        if (t1, t2) != prev:
            # extra wall-clock of the first chunk at the new schedule over
            # the typical chunk: the (absence of a) re-plan stall.
            replan_stall += max(dt - float(np.median(times[:-1])), 0.0)
            prev = (t1, t2)
    total = sum(times)
    return {
        "rounds_per_s": rounds / total,
        "steady_round_ms": 1e3 * total / rounds,
        "recompiles_after_warmup": executor.compile_count - warm_compiles,
        "replan_stall_s": replan_stall,
        "superstep": superstep,
        "dispatches": len(times),
    }


def schedule_chunks(per_round, schedule, k, tau1_max):
    """Pre-stacked (chunk, tau1, tau2) supersteps covering ``schedule``
    in runs of (at most) ``k`` same-tau rounds."""
    out = []
    r = 0
    while r < len(schedule):
        kk = min(k, len(schedule) - r)
        t1, t2 = schedule[r]
        assert all(s == (t1, t2) for s in schedule[r:r + kk])
        stacked = stack_round_batches(
            [per_round[i][t1] for i in range(r, r + kk)], tau1_max)
        out.append((stacked, t1, t2))
        r += kk
    return out


def bench_modes(name, cfg_fn, loss_fn, opt, fresh, per_round, schedule,
                tau1_max, tau2_max, superstep) -> Dict:
    """All three dispatch strategies over one (model, schedule) setup.

    ``per_round``: per round r a dict tau1 -> batch tree [tau1, N, ...]
    (legacy needs exact-length leaves, the executor the padded maxima).
    """
    legacy = run_legacy(cfg_fn, loss_fn, opt, fresh(),
                        per_round, schedule)

    def chunks(k):
        return schedule_chunks(per_round, schedule, k, tau1_max)

    ex1 = RoundExecutor(cfg_fn(tau1_max, tau2_max), loss_fn, opt)
    exec_round = run_executor(ex1, fresh(), chunks(1), 1)
    exk = RoundExecutor(cfg_fn(tau1_max, tau2_max), loss_fn, opt)
    exec_super = run_executor(exk, fresh(), chunks(superstep), superstep)

    speedup = exec_super["rounds_per_s"] / legacy["rounds_per_s"]
    print(f"[{name}] legacy {legacy['rounds_per_s']:9.1f} r/s "
          f"(replan stall {legacy['replan_stall_s']*1e3:7.1f} ms, "
          f"{legacy['recompiles']} compiles) | K=1 "
          f"{exec_round['rounds_per_s']:9.1f} r/s | K={superstep} "
          f"{exec_super['rounds_per_s']:9.1f} r/s -> {speedup:.2f}x")
    # THE recompile-free property: the forced re-plan triggered zero new
    # XLA compilations on either executor mode (hard failure otherwise).
    assert exec_round["recompiles_after_warmup"] == 0, exec_round
    assert exec_super["recompiles_after_warmup"] == 0, exec_super
    return {
        "legacy": legacy,
        "executor_round": exec_round,
        "executor_superstep": exec_super,
        "speedup_superstep_vs_legacy": speedup,
    }


def bench_telemetry_overhead(cfg_fn, loss_fn, opt, fresh, chunks,
                             tau1_max, tau2_max, superstep,
                             passes=24) -> Dict:
    """Superstep dispatch throughput with a live Telemetry sink vs none.

    Telemetry hooks are host-side dict appends on the dispatch path (one
    ``superstep`` event per K rounds, zero device syncs, zero recompiles
    — the neutrality audit proves the HLO is untouched): ~2us against a
    dispatch quantum of hundreds. Resolving that under real machine
    noise needs care, so the measurement is PAIRED — one instrumented
    and one bare executor alternate dispatch-for-dispatch inside the
    same loop (order flipping every pass), and the statistic is the
    median of per-pair time differences, which throughput drift cannot
    bias toward either mode (block-sequential best-of-N reads >10%
    phantom deltas on a busy box). The cyclic GC is disabled inside the
    timed loop, exactly as ``timeit`` does: retained event dicts
    otherwise make allocation-triggered gen scans land preferentially
    inside the instrumented windows and charge the collector's cost to
    telemetry. ``--check`` holds the regression under 2%.
    """
    import gc

    from repro.obs import Telemetry

    tel = Telemetry()
    exes = {
        "off": RoundExecutor(cfg_fn(tau1_max, tau2_max), loss_fn, opt),
        "on": RoundExecutor(cfg_fn(tau1_max, tau2_max), loss_fn, opt,
                            telemetry=tel),
    }
    states = {mode: fresh() for mode in exes}
    for mode, ex in exes.items():
        seen = set()
        for chunk, _, _ in chunks:
            k = jax.tree_util.tree_leaves(chunk)[0].shape[0]
            if k not in seen:
                ex.warmup(states[mode], chunk)
                seen.add(k)
    warm = {mode: ex.compile_count for mode, ex in exes.items()}

    diffs: List[float] = []
    base: List[float] = []
    rounds_per_dispatch: List[int] = []
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for p in range(passes):
            order = ("off", "on") if p % 2 == 0 else ("on", "off")
            for stacked, t1, t2 in chunks:
                k = jax.tree_util.tree_leaves(stacked)[0].shape[0]
                rounds_per_dispatch.append(k)
                pair = {}
                for mode in order:
                    t0 = time.perf_counter()
                    states[mode], m = exes[mode].dispatch(
                        states[mode], stacked, t1, t2)
                    float(np.asarray(m["loss"])[-1])
                    pair[mode] = time.perf_counter() - t0
                diffs.append(pair["on"] - pair["off"])
                base.append(pair["off"])
    finally:
        if gc_was_enabled:
            gc.enable()
    for mode, ex in exes.items():
        assert ex.compile_count == warm[mode], (
            f"telemetry bench recompiled in mode {mode!r}")

    k_mean = sum(rounds_per_dispatch) / len(rounds_per_dispatch)
    off_s = float(np.median(base))
    diff_s = float(np.median(diffs))
    rps_off = k_mean / off_s
    rps_on = k_mean / (off_s + diff_s)
    overhead_pct = 100.0 * diff_s / off_s
    print(f"[telemetry/quad] off {rps_off:9.1f} r/s | on "
          f"{rps_on:9.1f} r/s -> {overhead_pct:+.2f}% overhead "
          f"({len(tel.events)} events, paired diffs over "
          f"{len(diffs)} dispatch pairs)")
    return {
        "rounds_per_s_off": rps_off,
        "rounds_per_s_on": rps_on,
        "overhead_pct": overhead_pct,
        "events_per_run": len(tel.events),
        "dispatch_pairs": len(diffs),
        "superstep": superstep,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--tau1", type=int, default=2)
    ap.add_argument("--tau2", type=int, default=2)
    ap.add_argument("--replan-tau1", type=int, default=4)
    ap.add_argument("--replan-tau2", type=int, default=1)
    ap.add_argument("--superstep", type=int, default=10)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--smoke", action="store_true",
                    help="micro transformer + short seq (the CI config)")
    ap.add_argument("--check", action="store_true",
                    help="assert superstep >= 2x legacy rounds/sec on the "
                         "dispatch measurement")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    n = args.nodes
    topo = ring(n)
    opt = sgd(3e-2)
    tau1_max = max(args.tau1, args.replan_tau1)
    tau2_max = max(args.tau2, args.replan_tau2)
    taus_used = sorted({args.tau1, args.replan_tau1})
    # forced mid-run re-plan at the halfway superstep boundary.
    half = max((args.rounds // 2 // args.superstep) * args.superstep,
               args.superstep)
    half = min(half, args.rounds)
    schedule = ([(args.tau1, args.tau2)] * half
                + [(args.replan_tau1, args.replan_tau2)]
                * (args.rounds - half))
    cfg_fn = lambda t1, t2: DFLConfig(tau1=t1, tau2=t2, topology=topo)
    print(f"bench: nodes={n} rounds={args.rounds} "
          f"schedule=({args.tau1},{args.tau2})->"
          f"({args.replan_tau1},{args.replan_tau2})@{half} "
          f"superstep={args.superstep}")

    # -- 1. telemetry overhead on the quad superstep path -----------------
    # Runs FIRST, on a quiet process: the later benches leave hundreds of
    # MB and dozens of executables resident, which inflates paired noise
    # past the bar being tested. Dedicated wider testbed (dim 4096,
    # ~1.4ms per K=10 dispatch): the hook cost is a constant couple of
    # us of host work per dispatch, so the 2% bar needs a dispatch
    # quantum big enough to resolve it above paired measurement noise
    # (~6us) — on the dim-64 quad the bar itself sits inside the noise
    # floor. Chunks are cycled for ~480 measured pairs (batches are jit
    # INPUTS, not donated, so reuse is safe).
    rng = np.random.default_rng(0)

    def quad_loss(p, b, k=None):
        return jnp.mean((p["w"] - b) ** 2)

    dim_tel = 4096
    tel_params = {"w": jnp.zeros((dim_tel,))}
    tel_batches = [
        {args.tau1: jnp.asarray(rng.normal(size=(args.tau1, n, dim_tel)),
                                jnp.float32)}
        for _ in range(args.rounds)]
    tel_fresh = lambda: init_state(tel_params, n, opt, jax.random.key(2))
    tel_chunks = schedule_chunks(
        tel_batches, [(args.tau1, args.tau2)] * args.rounds,
        args.superstep, args.tau1)
    telemetry_overhead = bench_telemetry_overhead(
        cfg_fn, quad_loss, opt, tel_fresh,
        tel_chunks * max(1, 20 // len(tel_chunks)),
        args.tau1, args.tau2, args.superstep)
    del tel_batches, tel_chunks

    # -- 2. dispatch microbench: quadratic testbed model ------------------
    dim = 64
    quad_params = {"w": jnp.zeros((dim,))}
    quad_batches = [
        {t1: jnp.asarray(rng.normal(size=(t1, n, dim)), jnp.float32)
         for t1 in taus_used}
        for _ in range(args.rounds)
    ]
    # legacy slices per tau1 from the same noise draw: keep both tau views
    # of a round consistent.
    for row in quad_batches:
        full = row[max(taus_used)]
        for t1 in taus_used:
            row[t1] = full[:t1]
    quad_fresh = lambda: init_state(quad_params, n, opt, jax.random.key(1))
    dispatch = bench_modes("dispatch/quad", cfg_fn, quad_loss, opt,
                           quad_fresh, quad_batches, schedule,
                           tau1_max, tau2_max, args.superstep)

    # -- 3. reduced transformer arch end-to-end ---------------------------
    arch = get_arch(args.arch)
    cfg = arch.reduced
    if args.smoke:
        cfg = reduced_from(arch.model, d_model=32, d_ff=64, num_layers=2,
                           num_heads=2, num_kv_heads=1, head_dim=16,
                           vocab_size=64, attn_q_chunk=8, attn_kv_chunk=8,
                           loss_seq_chunk=8)
        args.seq = min(args.seq, 8)

    def lm_loss(p, b, k):
        return train_loss(p, b, cfg, k)

    toks = rng.integers(0, cfg.vocab_size,
                        (args.rounds, tau1_max, n, args.batch, args.seq + 1))
    lm_batches = []
    for r in range(args.rounds):
        full = {"tokens": jnp.asarray(toks[r, ..., :-1], jnp.int32),
                "labels": jnp.asarray(toks[r, ..., 1:], jnp.int32)}
        lm_batches.append({
            t1: jax.tree_util.tree_map(lambda x, t=t1: x[:t], full)
            for t1 in taus_used})
    lm_params, _ = init_params(cfg, jax.random.key(0))
    lm_fresh = lambda: init_state(lm_params, n, opt, jax.random.key(1))
    reduced_arch = bench_modes(f"reduced/{cfg.name}", cfg_fn, lm_loss, opt,
                               lm_fresh, lm_batches, schedule,
                               tau1_max, tau2_max, args.superstep)

    payload = {
        "config": {
            "nodes": n, "rounds": args.rounds,
            "schedule": [[args.tau1, args.tau2],
                         [args.replan_tau1, args.replan_tau2]],
            "replan_round": half, "superstep": args.superstep,
            "tau1_max": tau1_max, "tau2_max": tau2_max,
            "quad_dim": dim, "arch": cfg.name, "batch": args.batch,
            "seq": args.seq, "smoke": args.smoke,
            "backend": jax.default_backend(),
        },
        "dispatch": dispatch,
        "telemetry_overhead": telemetry_overhead,
        "reduced_arch": reduced_arch,
        "zero_recompile_replan": True,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")
    if args.check:
        sp = dispatch["speedup_superstep_vs_legacy"]
        assert sp >= 2.0, (
            f"superstep dispatch only {sp:.2f}x legacy (< 2x bar)")
        tov = telemetry_overhead["overhead_pct"]
        assert tov < 2.0, (
            f"telemetry costs {tov:.2f}% of superstep throughput "
            "(>= 2% bar)")
        print("check OK: superstep >= 2x legacy, zero recompiles on "
              f"re-plan, telemetry overhead {tov:+.2f}% < 2%")


if __name__ == "__main__":
    main()
