"""Named lint rules: the repo's written-down invariants as AST checks.

Each rule guards a contract that regressed once before it was written
down (docs/ARCHITECTURE.md "Invariants & enforcement" maps rule ->
contract -> the PR that first broke it). A rule is a pure function over
one parsed source file; the engine in ``repro.analysis.lint`` handles
file iteration, ``# repro-lint: disable=<rule> (<reason>)`` pragmas and
the baseline. Rules are *individually* suppressible and every
suppression must state a reason — a reasonless pragma is itself a
violation (``bad-pragma``).

Path scoping uses posix suffixes (e.g. ``core/substrate.py``) so the
rules behave identically whether the engine was pointed at the repo
root, ``src/``, or the package directory.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Tuple

__all__ = ["Rule", "RULES", "TAU_NAMES", "ROUND_PATH_FILES"]


@dataclasses.dataclass(frozen=True)
class Rule:
    """One named invariant. ``check(ctx)`` yields (lineno, message);
    ``checker=None`` marks engine-level rules (emitted by the lint
    engine itself, e.g. ``bad-pragma``) that still need docs/pragma
    handling."""

    name: str
    description: str
    check: Optional[Callable[["FileContext"], Iterator[Tuple[int, str]]]]


@dataclasses.dataclass
class FileContext:
    """One parsed source file as the rules see it."""

    path: str            # posix path, e.g. "src/repro/core/dfl.py"
    tree: ast.Module
    lines: List[str]

    def matches(self, *suffixes: str) -> bool:
        return any(self.path.endswith(s) for s in suffixes)

    def in_dir(self, fragment: str) -> bool:
        return fragment in self.path


def _dotted(node: ast.AST) -> Optional[str]:
    """Resolve an Attribute/Name chain to 'a.b.c' (None for computed)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ---------------------------------------------------------------------------
# compat-boundary
# ---------------------------------------------------------------------------

# Version-sensitive JAX APIs: each spelling below changed (or appeared)
# across the supported 0.4.37 -> current range. core/substrate.py is the
# ONE module allowed to touch them; everything else uses its wrappers.
_COMPAT_ATTRS = {
    "jax.lax.axis_size",    # absent on 0.4.37
    "lax.axis_size",
    "jax.shard_map",        # top-level alias is >= 0.6 only
}
_COMPAT_IMPORT_MODULES = ("jax.experimental.shard_map",)
_COMPAT_KWARGS = {"check_rep", "check_vma"}  # renamed across versions
_COMPAT_HASATTR_PROBES = {"shard_map", "axis_size", "check_vma", "check_rep"}


def _check_compat_boundary(ctx: FileContext):
    if ctx.matches("core/substrate.py"):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom) and node.module and (
                node.module.startswith(_COMPAT_IMPORT_MODULES)):
            yield node.lineno, (
                f"import from {node.module!r}: version-sensitive shard_map "
                "entry point — use repro.core.substrate.shard_map")
        elif isinstance(node, ast.Attribute):
            name = _dotted(node)
            if name in _COMPAT_ATTRS:
                yield node.lineno, (
                    f"{name}: version-sensitive JAX API — use the "
                    "repro.core.substrate wrapper")
        elif isinstance(node, ast.Call):
            fname = _dotted(node.func) or ""
            for kw in node.keywords:
                if kw.arg in _COMPAT_KWARGS:
                    yield node.lineno, (
                        f"keyword {kw.arg!r}: renamed across JAX versions "
                        "(check_rep <-> check_vma) — route through "
                        "substrate.shard_map(check=...)")
            if (fname.split(".")[-1] == "psum" and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and node.args[0].value == 1):
                yield node.lineno, (
                    "psum(1, axis): the axis-size compat shim — call "
                    "substrate.axis_size(axis) instead")
            if (fname == "hasattr" and len(node.args) == 2
                    and isinstance(node.args[1], ast.Constant)
                    and node.args[1].value in _COMPAT_HASATTR_PROBES):
                yield node.lineno, (
                    f"hasattr(..., {node.args[1].value!r}): JAX "
                    "feature-probing belongs in core/substrate.py")


# ---------------------------------------------------------------------------
# no-import-time-backend-probe
# ---------------------------------------------------------------------------

_BACKEND_PROBES = {
    "jax.devices", "jax.local_devices", "jax.device_count",
    "jax.local_device_count", "jax.default_backend", "jax.process_count",
    "jax.lib.xla_bridge.get_backend", "jax.extend.backend.get_backend",
}


def _check_import_time_probe(ctx: FileContext):
    # Module scope = executed at import. Class bodies execute at import
    # too, so they stay "module scope"; only function/lambda bodies are
    # deferred. (Decorators and default-arg expressions also run at
    # import but probing there is unheard of — not modeled.)
    def visit(node: ast.AST, in_func: bool):
        for child in ast.iter_child_nodes(node):
            child_in_func = in_func or isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda))
            if not in_func and isinstance(child, ast.Call):
                name = _dotted(child.func)
                if name in _BACKEND_PROBES:
                    yield child.lineno, (
                        f"{name}() at module scope: import-time backend "
                        "probe (the ops.ON_TPU regression class) — detect "
                        "lazily inside a function "
                        "(see kernels/registry.backend())")
            yield from visit(child, child_in_func)

    yield from visit(ctx.tree, False)


# ---------------------------------------------------------------------------
# no-host-coercion-of-device-scalars
# ---------------------------------------------------------------------------

TAU_NAMES = {"tau", "tau1", "tau2", "taus", "t1", "t2", "round_idx",
             "tau_1", "tau_2"}

# Modules on the round/superstep hot path: every int()/float()/.item()
# there runs under trace, where a host coercion is a
# ConcretizationTypeError at best and a silent recompile/sync at worst.
ROUND_PATH_FILES = ("core/dfl.py", "core/sharded.py", "core/substrate.py",
                    "core/mixing.py", "core/compression.py")
_HOST_COERCIONS = {"int", "float"}
_NP_COERCIONS = {"np.asarray", "numpy.asarray", "np.array", "numpy.array"}


def _mentions_tau(node: ast.AST) -> Optional[str]:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id in TAU_NAMES:
            return n.id
        if isinstance(n, ast.Attribute) and n.attr in TAU_NAMES:
            return n.attr
    return None


def _check_host_coercion(ctx: FileContext):
    on_round_path = ctx.matches(*ROUND_PATH_FILES)
    is_executor = ctx.matches("core/executor.py")
    if not (on_round_path or is_executor):
        return

    # executor.py's methods legitimately coerce on the host (dispatch
    # bounds checks, metric rows); only its NESTED functions (the
    # closures jit actually traces: superstep/body) are round code.
    def visit(node: ast.AST, depth: int):
        for child in ast.iter_child_nodes(node):
            d = depth + isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda))
            if isinstance(child, ast.Call) and (on_round_path or d >= 2):
                yield from check_call(child)
            yield from visit(child, d)

    def check_call(call: ast.Call):
        fname = _dotted(call.func) or ""
        target = None
        if fname in _HOST_COERCIONS and call.args:
            target = call.args[0]
        elif fname in _NP_COERCIONS and call.args:
            target = call.args[0]
        elif (isinstance(call.func, ast.Attribute)
              and call.func.attr == "item"):
            target = call.func.value
        if target is None:
            return
        tau = _mentions_tau(target)
        if tau:
            yield call.lineno, (
                f"host coercion {fname or '.item()'} of {tau!r} in round "
                "code: (tau1, tau2)/round_idx are DEVICE scalars here — a "
                "host read is a recompile or sync point (keep them traced; "
                "see core/executor.py)")

    yield from visit(ctx.tree, 0)


# ---------------------------------------------------------------------------
# rng-discipline
# ---------------------------------------------------------------------------

_RAW_KEY_CALLS = {"jax.random.PRNGKey", "jax.random.key", "random.PRNGKey",
                  "jrandom.PRNGKey", "jrandom.key", "jr.PRNGKey", "jr.key"}


def _check_rng_discipline(ctx: FileContext):
    if not ctx.matches(*ROUND_PATH_FILES):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            name = _dotted(node.func)
            if name in _RAW_KEY_CALLS:
                yield node.lineno, (
                    f"{name}(...) inside a round_body-reachable module: "
                    "keys must arrive via the fold_in chain "
                    "(core.dfl.round_keys) — a raw key here silently "
                    "breaks dense<->sparse bitwise parity")


# ---------------------------------------------------------------------------
# no-disable-jit
# ---------------------------------------------------------------------------


def _check_no_disable_jit(ctx: FileContext):
    if not ctx.in_dir("repro/kernels/"):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and (
                _dotted(node) in ("jax.disable_jit", "jax.config.disable_jit")):
            yield node.lineno, (
                "jax.disable_jit in kernels/: pallas interpret-mode kernels "
                "RECURSE under disable_jit on the pinned jaxlib "
                "(tests/test_kernels.py pins it) — use ops.eager_impl() for "
                "un-jitted instrumentation instead")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

RULES: Dict[str, Rule] = {
    r.name: r
    for r in [
        Rule(
            "compat-boundary",
            "Version-sensitive JAX APIs (shard_map, axis_size, "
            "check_rep/check_vma, psum(1, axis), hasattr probes) only in "
            "core/substrate.py; everything else goes through its wrappers.",
            _check_compat_boundary,
        ),
        Rule(
            "no-import-time-backend-probe",
            "No jax.devices()/default_backend()/platform checks at module "
            "scope — backend detection must be lazy (first call).",
            _check_import_time_probe,
        ),
        Rule(
            "no-host-coercion-of-device-scalars",
            "No int()/float()/.item()/np.asarray on tau/round-idx scalars "
            "in round/superstep code paths — each is a silent recompile or "
            "host sync.",
            _check_host_coercion,
        ),
        Rule(
            "rng-discipline",
            "No raw PRNGKey construction in round_body-reachable modules; "
            "keys arrive via the round_keys fold_in chain.",
            _check_rng_discipline,
        ),
        Rule(
            "no-disable-jit",
            "jax.disable_jit is forbidden in src/repro/kernels/ (pallas "
            "interpret kernels recurse under it on the pinned jaxlib).",
            _check_no_disable_jit,
        ),
        Rule(
            "bad-pragma",
            "Every `# repro-lint: disable=<rule>` pragma must name a known "
            "rule and carry a (reason) — no silent allowlisting.",
            None,  # emitted by the engine while applying pragmas
        ),
    ]
}
