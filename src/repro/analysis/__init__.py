"""Invariant auditor: compat-boundary lint + compiled-artifact audits.

The repo's correctness rests on contracts that are easy to re-break by
accident (each already regressed once — see docs/ARCHITECTURE.md
"Invariants & enforcement"):

  * JAX version drift lives ONLY in ``core/substrate.py``;
  * backend probes never run at import time (the ``ops.ON_TPU`` class);
  * (tau1, tau2) are device data in round code — a host ``int()`` is a
    silent recompile or sync point;
  * round-reachable code derives PRNG keys by ``fold_in``, never by raw
    construction (dense<->sparse bitwise parity depends on it);
  * the superstep carry is donated, its executable has no baked tau
    constants, and the sparse engine's collective-permutes match
    ``Topology.shifts()``.

Two layers machine-check these on every PR:

  * ``repro.analysis.lint``  — AST lint over ``src/repro`` with named,
    individually-suppressible rules (``repro.analysis.rules``); inline
    pragmas REQUIRE a reason: ``# repro-lint: disable=<rule> (<why>)``.
  * ``repro.analysis.audits`` — compiled-artifact audits reading the
    lowered/optimized HLO of the production superstep: donation
    (input-output aliasing of every DFLState leaf), recompile hazard
    (identical fingerprints across schedule values), and collective
    matching (ppermute source-target pairs == ``Topology.shifts()``).

Run ``python -m repro.analysis lint`` / ``... audit`` (tier-1 CI), or
let pytest collect the same checks via ``tests/test_analysis_*.py``.
"""
from repro.analysis.lint import (LintReport, Violation, lint_paths,
                                 lint_tree, load_baseline)
from repro.analysis.rules import RULES

__all__ = [
    "RULES",
    "LintReport",
    "Violation",
    "lint_paths",
    "lint_tree",
    "load_baseline",
]
