"""The lint engine: file iteration, pragmas, baseline, reporting.

Rules live in ``repro.analysis.rules``; this module applies them to a
tree of Python sources and handles the two escape hatches:

* **Inline pragma** — ``# repro-lint: disable=<rule>[,<rule>] (<reason>)``
  on the violating line or the immediately preceding comment-only line.
  The reason is MANDATORY: a pragma without one (or naming an unknown
  rule) does not suppress and raises a ``bad-pragma`` violation of its
  own, so the tree can never accumulate silent allowlisting.
* **Baseline** — ``lint_baseline.json`` holds fingerprints
  (``rule::path::line``) of violations that predate a rule. It ships
  EMPTY: pre-existing violations were fixed or pragma'd in the PR that
  introduced their rule; the file exists so a future rule can land with
  a visible, reviewable debt list instead of a weakened rule.

CLI: ``python -m repro.analysis lint`` (exit 1 on any non-baselined
violation). Pytest: ``tests/test_analysis_lint.py`` runs the same check
tier-1.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.rules import RULES, FileContext

__all__ = [
    "Violation",
    "Suppression",
    "LintReport",
    "lint_source",
    "lint_paths",
    "lint_tree",
    "load_baseline",
    "default_baseline_path",
    "source_root",
]

_PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([\w\-,]+)\s*(\(([^)]*)\))?")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    message: str

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}::{self.path}::{self.line}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    rule: str
    path: str
    line: int
    reason: str


@dataclasses.dataclass
class LintReport:
    new: List[Violation]
    baselined: List[Violation]
    suppressed: List[Suppression]
    files_scanned: int

    @property
    def ok(self) -> bool:
        return not self.new

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "files_scanned": self.files_scanned,
            "new": [dataclasses.asdict(v) for v in self.new],
            "baselined": [dataclasses.asdict(v) for v in self.baselined],
            "suppressed": [dataclasses.asdict(s) for s in self.suppressed],
            "rules": sorted(RULES),
        }


def _parse_pragmas(lines: Sequence[str], path: str):
    """Pragma table {line -> (rules, reason)} plus bad-pragma violations."""
    pragmas: Dict[int, Tuple[set, str]] = {}
    bad: List[Violation] = []
    for i, text in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(text)
        if not m:
            if "repro-lint" in text and "disable" in text and (
                    text.lstrip().startswith("#")):
                bad.append(Violation(
                    "bad-pragma", path, i,
                    "unparseable repro-lint pragma (expected "
                    "`# repro-lint: disable=<rule> (<reason>)`)"))
            continue
        names = {n for n in m.group(1).split(",") if n}
        reason = (m.group(3) or "").strip()
        unknown = sorted(n for n in names if n not in RULES and n != "all")
        if unknown:
            bad.append(Violation(
                "bad-pragma", path, i,
                f"pragma names unknown rule(s) {unknown} "
                f"(known: {sorted(RULES)})"))
        if not reason:
            bad.append(Violation(
                "bad-pragma", path, i,
                "pragma has no (reason) — every suppression must say why"))
            continue  # a reasonless pragma never suppresses
        pragmas[i] = (names, reason)
    return pragmas, bad


def lint_source(source: str, path: str
                ) -> Tuple[List[Violation], List[Suppression]]:
    """Lint one file's text. ``path`` is the posix path the rules (and
    fingerprints) see. Returns (violations, suppressions) — violations
    include ``bad-pragma`` findings; pragma-suppressed ones are moved to
    the suppression list."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Violation("bad-pragma", path, e.lineno or 0,
                          f"file does not parse: {e.msg}")], []
    lines = source.splitlines()
    ctx = FileContext(path=path, tree=tree, lines=lines)
    pragmas, violations = _parse_pragmas(lines, path)

    def pragma_for(line: int, rule: str) -> Optional[str]:
        for cand in (line, line - 1):
            if cand in pragmas:
                names, reason = pragmas[cand]
                if cand == line - 1:
                    prev = lines[cand - 1].lstrip()
                    if not prev.startswith("#"):
                        continue  # only comment-only lines reach forward
                if rule in names or "all" in names:
                    return reason
        return None

    suppressed: List[Suppression] = []
    for rule in RULES.values():
        if rule.check is None:
            continue
        for line, message in rule.check(ctx):
            reason = pragma_for(line, rule.name)
            if reason is not None:
                suppressed.append(Suppression(rule.name, path, line, reason))
            else:
                violations.append(Violation(rule.name, path, line, message))
    violations.sort(key=lambda v: (v.path, v.line, v.rule))
    return violations, suppressed


def source_root() -> str:
    """The ``src/`` directory this package was imported from — linting
    anchors paths there so fingerprints are stable across checkouts."""
    here = os.path.dirname(os.path.abspath(__file__))   # .../src/repro/analysis
    return os.path.dirname(os.path.dirname(here))       # .../src


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "lint_baseline.json")


def load_baseline(path: Optional[str] = None) -> set:
    path = path or default_baseline_path()
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        data = json.load(f)
    return set(data.get("fingerprints", []))


def iter_python_files(root: str) -> Iterable[str]:
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in sorted(dirnames)
                       if d not in ("__pycache__", ".git")]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def lint_paths(paths: Sequence[str], *, rel_to: Optional[str] = None,
               baseline: Optional[set] = None) -> LintReport:
    rel_to = rel_to or source_root()
    baseline = baseline if baseline is not None else load_baseline()
    all_v: List[Violation] = []
    all_s: List[Suppression] = []
    count = 0
    for p in paths:
        files = iter_python_files(p) if os.path.isdir(p) else [p]
        for f in files:
            count += 1
            rel = os.path.relpath(os.path.abspath(f), rel_to)
            rel = rel.replace(os.sep, "/")
            with open(f, encoding="utf-8") as fh:
                v, s = lint_source(fh.read(), rel)
            all_v.extend(v)
            all_s.extend(s)
    new = [v for v in all_v if v.fingerprint not in baseline]
    old = [v for v in all_v if v.fingerprint in baseline]
    return LintReport(new=new, baselined=old, suppressed=all_s,
                      files_scanned=count)


def lint_tree(root: Optional[str] = None, *,
              baseline: Optional[set] = None) -> LintReport:
    """Lint the whole ``src/repro`` package (or ``root``)."""
    src = source_root()
    root = root or os.path.join(src, "repro")
    return lint_paths([root], rel_to=src, baseline=baseline)
