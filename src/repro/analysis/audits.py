"""Compiled-artifact audits: what the lint cannot see, read off the HLO.

Four invariants live only in the compiled executable, so no source
check can protect them; each is asserted directly against the lowered /
optimized module of the production superstep
(``RoundExecutor.lower_superstep``):

* **donation** — every ``DFLState`` leaf of the superstep carry must be
  input-output aliased (``input_output_alias`` on the ``HloModule``
  header). A dropped ``donate_argnums`` (the PR-3 regression class)
  silently doubles peak state memory; XLA only warns in logs.
* **recompile** — lowering the superstep at two different trajectory
  values must produce byte-identical HLO. A baked tau constant (someone
  adding ``static_argnums`` or a host ``int()``) shows up as a
  fingerprint mismatch — the PR-3/PR-4 zero-recompile guarantee,
  checked without timing anything.
* **collective-matching** — the sparse engine's ``collective-permute``
  ``source_target_pairs`` in the OPTIMIZED module must equal the pair
  sets implied by ``Topology.shifts()`` — wireless/wire-cost accounting
  (``round_wire_bits``) prices shifts; if XLA ships different pairs the
  accounting is fiction. Parsed via ``launch.hloanalysis
  .collective_sites`` (fusion- and loop-aware, never silently drops).
* **telemetry-neutrality** — the ``repro.obs``-instrumented superstep
  must lower to HLO byte-identical to the uninstrumented one. Telemetry
  hooks are host-side Python at trace/dispatch time; if one ever touches
  a traced value (a ``jax.debug.print``, a host coercion, an inserted
  callback) the instrumented graph diverges and this audit catches it —
  the zero-syncs / zero-recompiles-on-the-round-path contract, enforced
  rather than hoped.

``run_production_audits()`` builds a real 8-node ring sparse superstep
(needs 8 devices — ``python -m repro.analysis audit`` forces 8 host
devices; tests do the same in a subprocess) and runs all four, plus two
participation variants on the widened ``[K, 2+N+E]`` executor:
**participation-recompile** (all-ones vs crash vs sporadic mask
trajectories share one fingerprint — masks are schedule data, never
trace constants) and **participation-collectives** (the masked
executable still ships the full shift pair set — masks gate mixing
weights, not collectives); plus two overlap variants on the pipelined
(``overlap="pipeline"``) executor: **overlap-recompile** (the
double-buffered superstep keeps one fingerprint across trajectories —
the in-flight carry must not bake a tau into the trace) and
**overlap-collectives** (the pipelined executable, drain included,
still ships exactly ``Topology.shifts()`` — overlap moves the exchange
one round later, never onto different wires); plus one batched-engine
variant: **cohort-recompile** (lowering the ``[K, 2+2C+E]`` cohort rows
at the identity cohort and at two distinct ``CohortSampler`` draws
shares one fingerprint — sampled cohort ids are schedule data, so a
mega-scale run never recompiles across draws). The individual
``audit_*`` functions are pure text analysis, testable on synthetic
HLO and deliberately-broken fixtures.
"""
from __future__ import annotations

import dataclasses
import hashlib
import re
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "AuditResult",
    "parse_input_output_aliases",
    "audit_donation",
    "hlo_fingerprint",
    "audit_recompile",
    "expected_shift_pairs",
    "audit_collective_matching",
    "audit_telemetry_neutrality",
    "build_audit_executor",
    "build_cohort_audit_executor",
    "run_production_audits",
]


@dataclasses.dataclass
class AuditResult:
    name: str
    ok: bool
    detail: str
    data: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"name": self.name, "ok": self.ok, "detail": self.detail,
                "data": self.data}


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------

_ALIAS_ENTRY_RE = re.compile(r"\{\s*([0-9,\s]*)\}\s*:\s*\((\d+)")


def _balanced_block(text: str, start: int) -> str:
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "{":
            depth += 1
        elif text[i] == "}":
            depth -= 1
            if depth == 0:
                return text[start:i + 1]
    return text[start:]


def parse_input_output_aliases(hlo_text: str) -> Dict[Tuple[int, ...], int]:
    """``{output_tuple_index: parameter_number}`` from the module header's
    ``input_output_alias={ {0}: (0, {}, may-alias), ... }`` annotation.
    Empty dict when the module declares no aliasing (= nothing donated)."""
    key = "input_output_alias="
    pos = hlo_text.find(key)
    if pos < 0:
        return {}
    block = _balanced_block(hlo_text, pos + len(key))
    out: Dict[Tuple[int, ...], int] = {}
    for m in _ALIAS_ENTRY_RE.finditer(block):
        idx = tuple(int(x) for x in m.group(1).replace(" ", "").split(",")
                    if x != "")
        out[idx] = int(m.group(2))
    return out


def audit_donation(compiled_text: str, leaf_names: Sequence[str],
                   name: str = "donation") -> AuditResult:
    """Every one of the first ``len(leaf_names)`` parameters (the
    flattened donated carry, in tree-flatten order) must appear as an
    aliased input in the compiled module."""
    aliases = parse_input_output_aliases(compiled_text)
    donated = set(aliases.values())
    missing = [f"param {i} ({n})" for i, n in enumerate(leaf_names)
               if i not in donated]
    data = {"aliases": {str(k): v for k, v in aliases.items()},
            "expected_params": len(leaf_names), "missing": missing}
    if missing:
        return AuditResult(name, False,
                           f"carry leaves NOT donated: {missing} — check "
                           "donate_argnums on the superstep jit", data)
    return AuditResult(
        name, True,
        f"all {len(leaf_names)} state leaves input-output aliased", data)


# ---------------------------------------------------------------------------
# recompile fingerprints
# ---------------------------------------------------------------------------


def hlo_fingerprint(hlo_text: str) -> str:
    return hashlib.sha256(hlo_text.encode()).hexdigest()[:16]


def audit_recompile(lowered_texts: Sequence[str],
                    labels: Optional[Sequence[str]] = None,
                    name: str = "recompile") -> AuditResult:
    """All lowerings (same shapes, different schedule VALUES) must be
    byte-identical: a difference means a tau reached the trace as a
    constant (static_argnums / host int()) and every re-plan recompiles."""
    labels = list(labels or range(len(lowered_texts)))
    fps = [hlo_fingerprint(t) for t in lowered_texts]
    data = {"fingerprints": dict(zip(map(str, labels), fps))}
    if len(set(fps)) != 1:
        return AuditResult(
            name, False,
            f"HLO fingerprints differ across schedule values {data} — a "
            "(tau1, tau2) constant is baked into the executable", data)
    return AuditResult(
        name, True,
        f"{len(fps)} lowerings share one fingerprint {fps[0]}", data)


# ---------------------------------------------------------------------------
# collective matching
# ---------------------------------------------------------------------------


def expected_shift_pairs(topology) -> Dict[int, frozenset]:
    """shift s -> the ppermute pair set {(src, (src+s) % N)} it lowers to
    (see mixing.mix_ppermute_shifts / ShardedSubstrate.mix)."""
    n = topology.num_nodes
    return {
        int(s): frozenset((src, (src + int(s)) % n) for src in range(n))
        for s, _ in topology.shifts()
    }


def audit_collective_matching(optimized_text: str, topology,
                              name: str = "collective-matching"
                              ) -> AuditResult:
    """The optimized module's collective-permute pair sets must be
    exactly the topology's shift pair sets — no missing shift (a node
    silently not gossiping) and no extra/wrong permute (traffic the wire
    accounting never priced)."""
    from repro.launch.hloanalysis import collective_sites

    # warn=False: trip counts are irrelevant to pair matching, and
    # optimized modules routinely carry unannotated control-flow loops.
    sites = [s for s in collective_sites(optimized_text, warn=False)
             if s.opcode == "collective-permute"]
    observed = {frozenset(s.pairs) for s in sites if s.pairs}
    expected = set(expected_shift_pairs(topology).values())
    data = {
        "num_permutes": len(sites),
        "observed": sorted(sorted(p) for p in observed),
        "expected": sorted(sorted(p) for p in expected),
    }
    if not expected:
        return AuditResult(name, not observed,
                           "topology has no shifts; module must have no "
                           "permutes", data)
    missing = expected - observed
    extra = observed - expected
    if missing or extra:
        return AuditResult(
            name, False,
            f"permute pairs != Topology.shifts(): missing shifts "
            f"{sorted(sorted(p) for p in missing)}, unexpected "
            f"{sorted(sorted(p) for p in extra)}", data)
    return AuditResult(
        name, True,
        f"{len(sites)} collective-permutes, pair sets == shifts("
        f"{topology.name})", data)


# ---------------------------------------------------------------------------
# telemetry neutrality
# ---------------------------------------------------------------------------


def audit_telemetry_neutrality(bare_text: str, instrumented_text: str,
                               name: str = "telemetry-neutrality"
                               ) -> AuditResult:
    """The telemetry-instrumented superstep must lower to HLO
    byte-identical to the bare one: observability may never add a host
    sync, a traced op, or a recompile to the round path. The caller
    lowers the SAME function with and without a live ``repro.obs``
    sink (the instrumented trace really runs its hooks — see
    ``RoundExecutor.lower_superstep``); any graph divergence lands here
    as a fingerprint mismatch."""
    fp_bare = hlo_fingerprint(bare_text)
    fp_inst = hlo_fingerprint(instrumented_text)
    data = {"fingerprints": {"bare": fp_bare, "instrumented": fp_inst}}
    if fp_bare != fp_inst:
        return AuditResult(
            name, False,
            "telemetry instrumentation CHANGED the superstep HLO "
            f"({fp_bare} != {fp_inst}) — a hook leaked a traced op or "
            "host sync into the round path", data)
    return AuditResult(
        name, True,
        f"instrumented lowering fingerprint-identical to bare ({fp_bare})",
        data)


# ---------------------------------------------------------------------------
# the production artifact
# ---------------------------------------------------------------------------


def build_audit_executor(num_nodes: int = 8, *, tau1_max: int = 3,
                         tau2_max: int = 2, rounds: int = 2, dim: int = 33,
                         telemetry=None, participation: bool = False,
                         overlap: str = "none"):
    """A small but REAL sparse-engine superstep: ring(N) topology, node
    axis manual over an N-device mesh, dynamic taus, donated carry — the
    exact executable class ``launch.train`` dispatches. Returns
    ``(executor, state, batches, topology)`` ready for
    ``executor.lower_superstep``. Needs ``num_nodes`` devices."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import DFLConfig, init_state, make_round_fn  # noqa: F401
    from repro.core.executor import RoundExecutor, stack_round_batches
    from repro.core.topology import ring
    from repro.optim import sgd

    if len(jax.devices()) < num_nodes:
        raise RuntimeError(
            f"audit superstep needs {num_nodes} devices, have "
            f"{len(jax.devices())} — run via `python -m repro.analysis "
            "audit` (it forces host devices) or set XLA_FLAGS")
    mesh = jax.make_mesh((num_nodes,), ("data",))
    topo = ring(num_nodes)
    cfg = DFLConfig(tau1=tau1_max, tau2=tau2_max, topology=topo)
    opt = sgd(0.1)

    def loss_fn(p, b, k=None):
        return jnp.mean((p["w"][None] - b) ** 2)

    ex = RoundExecutor(cfg, loss_fn, opt, engine="sparse", mesh=mesh,
                       node_axes=("data",), dynamic=True, donate=True,
                       telemetry=telemetry, participation=participation,
                       overlap=overlap)
    state = init_state({"w": jnp.zeros((dim,))}, num_nodes, opt,
                       jax.random.key(0))
    sh = NamedSharding(mesh, P("data"))
    state = state._replace(
        params=jax.tree_util.tree_map(
            lambda x: jax.device_put(x, sh), state.params))
    key = jax.random.key(1)
    per_round = [jax.random.normal(jax.random.fold_in(key, r),
                                   (tau1_max, num_nodes, 4, dim))
                 for r in range(rounds)]
    batches = stack_round_batches(per_round, tau1_max)
    return ex, state, batches, topo


def build_cohort_audit_executor(population: int = 32, cohort: int = 8, *,
                                tau1_max: int = 3, tau2_max: int = 2,
                                rounds: int = 2, dim: int = 33):
    """A small but REAL batched-engine superstep: ring(C) cohort topology
    over a ``population``-node virtual state stack, dynamic taus, cohort
    ids as schedule data — the executable class ``launch.train
    --virtual-nodes`` dispatches. Single-device (the whole point of the
    batched engine). Returns ``(executor, state, batches, topology)``."""
    import jax
    import jax.numpy as jnp

    from repro.core import DFLConfig, init_state
    from repro.core.executor import RoundExecutor, stack_round_batches
    from repro.core.topology import ring
    from repro.optim import sgd

    topo = ring(cohort)
    cfg = DFLConfig(tau1=tau1_max, tau2=tau2_max, topology=topo)
    opt = sgd(0.1)

    def loss_fn(p, b, k=None):
        return jnp.mean((p["w"][None] - b) ** 2)

    ex = RoundExecutor(cfg, loss_fn, opt, engine="batched",
                       population=population, dynamic=True, donate=True)
    state = init_state({"w": jnp.zeros((dim,))}, population, opt,
                       jax.random.key(0))
    key = jax.random.key(1)
    per_round = [jax.random.normal(jax.random.fold_in(key, r),
                                   (tau1_max, cohort, 4, dim))
                 for r in range(rounds)]
    batches = stack_round_batches(per_round, tau1_max)
    return ex, state, batches, topo


def run_production_audits(num_nodes: int = 8) -> List[AuditResult]:
    """Build the production sparse superstep (plus its participation and
    pipelined-overlap variants) and run the full audit suite."""
    import jax

    from repro.obs import Telemetry

    ex, state, batches, topo = build_audit_executor(num_nodes)
    leaf_names = [str(p) for p, _ in
                  jax.tree_util.tree_flatten_with_path(state)[0]]
    taus_a = [[1, 1]] * 2
    taus_b = [[3, 0], [2, 2]]
    low_a = ex.lower_superstep(state, batches, taus_a)
    low_b = ex.lower_superstep(state, batches, taus_b)
    compiled_text = low_a.compile().as_text()
    # identical build with a LIVE telemetry sink: its trace-time hooks
    # run during this lowering (same example args as low_a), and the
    # neutrality audit asserts the graph didn't move.
    tel = Telemetry()
    ex_inst, state_i, batches_i, _ = build_audit_executor(
        num_nodes, telemetry=tel)
    low_inst = ex_inst.lower_superstep(state_i, batches_i, taus_a)
    assert any(e["type"] == "compile" for e in tel.events), (
        "instrumented audit lowering never ran its telemetry hooks — "
        "the neutrality comparison would be vacuous")

    # Participation: masked trajectories are schedule DATA on the widened
    # [K, 2+N+E] rows — lowering an all-ones trajectory and two distinct
    # fault patterns must produce one fingerprint (masks never reach the
    # trace as constants), and the masked executable must still ship the
    # full shift pair set (masks gate mixing WEIGHTS, not collectives —
    # dropping a ppermute per masked edge would recompile per pattern).
    import numpy as np

    from repro.faults import FaultPlan, NodeCrash, SporadicParticipation

    ex_p, state_p, batches_p, _ = build_audit_executor(
        num_nodes, participation=True)
    taus = np.array([[1, 1], [2, 1]], np.int32)
    all_on = np.concatenate(
        [taus, np.ones((2, ex_p.row_width - 2), np.int32)], axis=1)
    crash = FaultPlan(topo, (NodeCrash(3, 0, 8),), seed=0)
    sporadic = FaultPlan(
        topo, (SporadicParticipation(0.6, 0.5, 0, 8),), seed=7)
    low_on = ex_p.lower_superstep(state_p, batches_p, all_on)
    low_crash = ex_p.lower_superstep(state_p, batches_p,
                                     crash.mask_trajectory(taus))
    low_spor = ex_p.lower_superstep(state_p, batches_p,
                                    sporadic.mask_trajectory(taus))

    # Overlap: the pipelined superstep is still schedule-as-data — one
    # fingerprint across trajectories (the double-buffer carry must not
    # smuggle a tau into the trace as a constant) — and its executable
    # still ships exactly the topology's shift pairs (pipelining moves
    # the exchange one round LATER, it must not move it onto different
    # wires or drop the drain's final exchange).
    ex_o, state_o, batches_o, _ = build_audit_executor(
        num_nodes, overlap="pipeline")
    low_oa = ex_o.lower_superstep(state_o, batches_o, taus_a)
    low_ob = ex_o.lower_superstep(state_o, batches_o, taus_b)

    # Cohort sampling: on the batched engine the [K, 2+2C+E] rows carry
    # the sampled cohort IDS as schedule data — lowering the identity
    # cohort and two distinct CohortSampler draws must share one
    # fingerprint (a baked id constant would recompile on every draw,
    # destroying the mega-scale zero-recompile property).
    from repro.faults import CohortSampler

    ex_c, state_c, batches_c, topo_c = build_cohort_audit_executor()
    pop = ex_c.population
    sampler_a = CohortSampler(population=pop, cohort=topo_c.num_nodes,
                              seed=3)
    sampler_b = CohortSampler(population=pop, cohort=topo_c.num_nodes,
                              seed=11)
    identity = np.array([[1, 1], [2, 1]], np.int32)
    low_ca = ex_c.lower_superstep(
        state_c, batches_c, ex_c._check_trajectory(identity, 2))
    low_cb = ex_c.lower_superstep(
        state_c, batches_c,
        sampler_a.cohort_trajectory(identity, num_edges=topo_c.num_edges))
    low_cc = ex_c.lower_superstep(
        state_c, batches_c,
        sampler_b.cohort_trajectory(identity, round0=5,
                                    num_edges=topo_c.num_edges))

    return [
        audit_donation(compiled_text, leaf_names),
        audit_recompile([low_a.as_text(), low_b.as_text()],
                        labels=["taus=[[1,1],[1,1]]", "taus=[[3,0],[2,2]]"]),
        audit_collective_matching(compiled_text, topo),
        audit_telemetry_neutrality(low_a.as_text(), low_inst.as_text()),
        audit_recompile(
            [low_on.as_text(), low_crash.as_text(), low_spor.as_text()],
            labels=["all-ones", "crash(node=3)", "sporadic(p=0.6/0.5)"],
            name="participation-recompile"),
        audit_collective_matching(low_crash.compile().as_text(), topo,
                                  name="participation-collectives"),
        audit_recompile([low_oa.as_text(), low_ob.as_text()],
                        labels=["taus=[[1,1],[1,1]]", "taus=[[3,0],[2,2]]"],
                        name="overlap-recompile"),
        audit_collective_matching(low_oa.compile().as_text(), topo,
                                  name="overlap-collectives"),
        audit_recompile(
            [low_ca.as_text(), low_cb.as_text(), low_cc.as_text()],
            labels=["identity-cohort", "sampler(seed=3)@r0",
                    "sampler(seed=11)@r5"],
            name="cohort-recompile"),
    ]
