"""``python -m repro.analysis`` — the invariant auditor CLI.

Subcommands:

* ``lint``  — AST lint over ``src/repro`` (no jax import, runs anywhere):
  exit 1 on violations not covered by a pragma or the shipped baseline.
* ``audit`` — compiled-artifact audits (donation / recompile /
  collective-matching) on the production sparse superstep; forces 8 host
  devices via XLA_FLAGS **before** importing jax, so it works on any
  single-CPU CI box. Exit 1 on any failed audit.

Both accept ``--json OUT`` to write a machine-readable report (the CI
``tier1-analysis`` job uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import json
import sys


def _cmd_lint(args) -> int:
    # deliberately jax-free: the lint must run on boxes (and canary jax
    # versions) where the library itself may not even import.
    from repro.analysis.lint import (default_baseline_path, lint_tree,
                                     load_baseline)

    baseline_path = args.baseline or str(default_baseline_path())
    report = lint_tree(baseline=load_baseline(baseline_path))
    for v in report.new:
        print(v.render())
    for v in report.baselined:
        print(f"[baselined] {v.render()}")
    print(f"repro-lint: files: {report.files_scanned}  "
          f"new: {len(report.new)}  baselined: {len(report.baselined)}  "
          f"suppressed: {len(report.suppressed)}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report.to_dict(), f, indent=2)
        print(f"report written to {args.json}")
    # --error-on-new is the (default) contract made explicit for CI logs;
    # --no-error-on-new exists for local exploration only.
    return 1 if (report.new and args.error_on_new) else 0


def _cmd_audit(args) -> int:
    import os

    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count="
            f"{args.devices}").strip()
    # import AFTER the flag: jax snapshots XLA_FLAGS at first import.
    from repro.analysis.audits import run_production_audits

    results = run_production_audits(num_nodes=args.devices)
    for r in results:
        print(f"[{'PASS' if r.ok else 'FAIL'}] {r.name}: {r.detail}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([r.to_dict() for r in results], f, indent=2)
        print(f"report written to {args.json}")
    return 0 if all(r.ok for r in results) else 1


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="invariant auditor: source lint + compiled-artifact "
                    "audits")
    sub = p.add_subparsers(dest="cmd", required=True)

    pl = sub.add_parser("lint", help="AST lint over src/repro")
    pl.add_argument("--baseline", default=None,
                    help="baseline JSON (default: shipped lint_baseline.json)")
    pl.add_argument("--json", default=None, metavar="OUT",
                    help="write JSON report to OUT")
    pl.add_argument("--error-on-new", dest="error_on_new",
                    action="store_true", default=True,
                    help="exit 1 on new violations (default)")
    pl.add_argument("--no-error-on-new", dest="error_on_new",
                    action="store_false")
    pl.set_defaults(fn=_cmd_lint)

    pa = sub.add_parser("audit",
                        help="compiled-artifact audits (needs jax)")
    pa.add_argument("--devices", type=int, default=8,
                    help="forced host device count / ring size (default 8)")
    pa.add_argument("--json", default=None, metavar="OUT",
                    help="write JSON report to OUT")
    pa.set_defaults(fn=_cmd_audit)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
