"""Runtime (tau1, tau2) control from *measured* round timings.

The static planner prices schedules from a priori FLOPs/bandwidth numbers;
real deployments drift (thermal throttling, contended links, interpret-mode
kernels). ``AdaptiveController`` closes the loop: every round it records
the measured wall-clock of the (tau1, tau2) schedule that actually ran,
every ``replan_every`` rounds it re-fits the per-step compute/gossip times
by least squares over the observed (tau1, tau2, seconds) history and
re-plans the remainder of the budget with ``planner.optimize.plan``.

Identifiability: with observations at a single (tau1, tau2) the 2-unknown
fit is rank-1. Rather than re-planning off an unidentifiable fit, the
controller then INJECTS A PROBE ROUND — the grid schedule closest in
predicted round time to the current one whose (tau1, tau2) row is linearly
independent of everything observed — so one round of measurement buys full
identification; until a probe lands, ``fitted_cost_model`` scales the
prior uniformly to match the measured round time (preserving the prior
compute/comm split).

Two control surfaces, both recompile-free under the fused executor:
``maybe_replan`` (superstep-boundary re-plan, ``train.py --plan-budget``)
and ``next_trajectory`` (a per-round [k, 2] schedule emitted for the NEXT
superstep — re-planning INSIDE the superstep via
``RoundExecutor.dispatch_trajectory``, optionally against a known
time-varying ``CostProcess``; ``train.py --schedule trajectory``). Every
(re)plan/probe/trajectory event is appended to ``controller.history`` so
the emitted metrics show the schedule trajectory.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.compression import Compressor
from repro.planner.bounds import Availability
from repro.planner.cost import (ComputeModel, CostModel, CostProcess,
                               LinkModel, WirelessLinks)
from repro.planner.optimize import (Budget, DEFAULT_GRID, Plan,
                                    plan as plan_fn,
                                    plan_trajectory as plan_trajectory_fn)

__all__ = ["AdaptiveController"]

_T_FLOOR = 1e-9  # seconds; keeps fitted per-step times strictly positive


@dataclasses.dataclass(frozen=True)
class _Observation:
    tau1: int
    tau2: int
    seconds: float
    compression_ratio: float  # wire-bits ratio active during this round


class AdaptiveController:
    """Re-plans (tau1, tau2, compressor) from measured timings.

    Args:
      budget: total resource envelope for the WHOLE session (the
        controller spends it down as rounds complete).
      cost_model: the prior — engine/topology/model_bits are trusted, the
        compute/link speeds are re-fitted from measurements.
      sigma, f_gap, L, gamma, grid, compressors: forwarded to
        ``planner.optimize.plan``.
      replan_every: rounds between re-plans (K).
      process: optional KNOWN time-varying deviation (straggler/fading/
        outage episodes on the deployment clock). ``next_trajectory``
        re-bases it on the measured-fit cost model each superstep, so the
        emitted per-round schedule routes around announced episodes while
        the base speeds stay measurement-driven.
    """

    def __init__(
        self,
        budget: Budget,
        cost_model: CostModel,
        *,
        sigma: float,
        f_gap: float,
        replan_every: int = 10,
        grid: Optional[Sequence[Tuple[int, int]]] = None,
        compressors: Sequence[Optional[Compressor]] = (None,),
        gamma: float = 1.0,
        L: float = 1.0,
        process: Optional[CostProcess] = None,
        telemetry=None,
    ):
        assert replan_every >= 1
        self.budget = budget
        self.cost_model = cost_model
        self.process = process
        self.sigma = sigma
        self.f_gap = f_gap
        self.replan_every = replan_every
        self.grid = grid
        self.compressors = tuple(compressors)
        self.gamma = gamma
        self.L = L
        self.observations: List[_Observation] = []
        self.spent_s = 0.0
        self.spent_bits = 0.0
        self.spent_j = 0.0
        # sporadic-participation tallies (observe_participation)
        self.resume_tau2 = 1.0
        self._node_up = 0
        self._node_total = 0
        self._edge_up = 0
        self._edge_total = 0
        self.history: List[dict] = []   # one dict per (re)plan event
        self._telemetry = telemetry     # optional repro.obs.Telemetry sink
        self.current: Optional[Plan] = None
        self.exhausted = False

    # -- planning ----------------------------------------------------------

    def _plan_kwargs(self):
        kw = dict(sigma=self.sigma, f_gap=self.f_gap,
                  compressors=self.compressors, gamma=self.gamma, L=self.L)
        if self.grid is not None:
            kw["grid"] = self.grid
        avail = self.availability()
        if avail is not None:
            kw["availability"] = avail
        return kw

    def _remaining_budget(self) -> Optional[Budget]:
        wall = (self.budget.wall_clock_s - self.spent_s
                if self.budget.wall_clock_s is not None else None)
        bits = (self.budget.wire_bits - self.spent_bits
                if self.budget.wire_bits is not None else None)
        joules = (self.budget.energy_j - self.spent_j
                  if self.budget.energy_j is not None else None)
        if any(rem is not None and rem <= 0.0
               for rem in (wall, bits, joules)):
            return None
        return Budget(wall_clock_s=wall, wire_bits=bits, energy_j=joules)

    # telemetry event type per plan cause ("trajectory" chunks are plan
    # decisions too; probes get their own type so timelines can mark the
    # identifiability injections).
    _EVENT_TYPE = {"initial": "plan", "replan": "replan", "probe": "probe"}

    def _emit(self, round_idx: int, cause: str, **extra) -> None:
        p = self.current
        assert p is not None
        rec = {
            "round": round_idx,
            "cause": cause,
            "tau1": p.tau1,
            "tau2": p.tau2,
            "compressor": p.compressor_name,
            "eta": p.eta,
            "rounds_planned": p.rounds,
            "predicted_bound": p.predicted_bound,
            "t_compute_step": p.round_cost.t_compute_step,
            "t_gossip_step": p.round_cost.t_gossip_step,
            "spent_s": self.spent_s,
            **extra,
        }
        self.history.append(rec)
        if self._telemetry is not None:
            # mirror the exact record into the event stream: the
            # --history-out plan_events view reconstructs from these.
            self._telemetry.emit(self._EVENT_TYPE.get(cause, "plan"),
                                 track="planner", name=cause, **rec)

    def initial_plan(self) -> Plan:
        """Plan round 0 from the prior cost model and the full budget."""
        self.current = plan_fn(self.budget, self.cost_model,
                               **self._plan_kwargs())
        self._emit(0, "initial")
        return self.current

    # -- measurement -------------------------------------------------------

    def observe(self, tau1: int, tau2: int, seconds: float, *,
                fit: Optional[bool] = None) -> None:
        """Record one completed round's measured wall-clock.

        EVERY measured round enters the least-squares cost fit: since the
        recompile-free executor (``repro.core.executor``) a schedule change
        is two device scalars, so no round's wall-clock is ever
        contaminated by a jit re-trace/compile and the old ``fit=False``
        escape hatch (used to drop freshly-(re)built rounds) is obsolete.
        The parameter is kept as a deprecation shim and IGNORED.
        """
        if fit is not None:
            import warnings

            warnings.warn(
                "AdaptiveController.observe(fit=...) is deprecated and "
                "ignored: dynamic-tau dispatch never compile-contaminates "
                "a round, so every measured round enters the cost fit",
                DeprecationWarning, stacklevel=2)
        comp = self.current.compressor if self.current is not None else None
        ratio = self.cost_model.compression_ratio(comp)
        self.observations.append(
            _Observation(tau1, tau2, float(seconds), ratio))
        self.spent_s += float(seconds)
        # wire/energy accounting is analytic (exact), not measured:
        self.spent_bits += (
            tau2 * self.cost_model.gossip_bits_per_step(comp))
        self.spent_j += self.cost_model.round_cost(tau1, tau2, comp).energy_j

    def observe_chunk(self, taus, seconds: float) -> None:
        """Record one dispatched SUPERSTEP's measured wall-clock as a
        single aggregated observation: the fit row is
        (sum tau1_k, sum tau2_k) over the chunk's [k, 2] schedule.

        This is how heterogeneous-trajectory supersteps must be observed:
        the host can only time the fused dispatch as a whole, and
        amortizing elapsed/K uniformly over rounds of DIFFERENT schedules
        (``MetricsBuffer``'s per-round rows) would corrupt a per-round
        least-squares fit — e.g. a probe round inherits the chunk mean and
        the 'identified' fit is garbage. The per-step model is linear, so
        the chunk total  seconds ~= (sum tau1) t_step + (sum tau2) ratio
        t_gossip  is an exact aggregation, and a chunk carrying a probe
        still raises the fit rank (its tau1:tau2 ratio differs from the
        uniform chunks').
        """
        arr = np.asarray(taus, dtype=np.int64).reshape(-1, 2)
        assert len(arr) >= 1
        comp = self.current.compressor if self.current is not None else None
        ratio = self.cost_model.compression_ratio(comp)
        t1_sum, t2_sum = int(arr[:, 0].sum()), int(arr[:, 1].sum())
        self.observations.append(
            _Observation(t1_sum, t2_sum, float(seconds), ratio))
        self.spent_s += float(seconds)
        self.spent_bits += (
            t2_sum * self.cost_model.gossip_bits_per_step(comp))
        # per-round energy is linear in (tau1, tau2): pricing the sums
        # equals summing the rounds.
        self.spent_j += self.cost_model.round_cost(
            t1_sum, t2_sum, comp).energy_j

    def observe_participation(self, node_mask, edge_mask) -> None:
        """Tally one round's realized participation (the [N]/[E] masks of
        a sporadic round, or the ``active_nodes``/``masked_edges`` counts
        already reduced by the executor — any 0/1 array-likes work). The
        running rates feed ``availability()``, which every subsequent
        (re)plan prices schedules with."""
        nm = np.asarray(node_mask).ravel()
        em = np.asarray(edge_mask).ravel()
        self._node_up += int(nm.sum())
        self._node_total += int(nm.size)
        self._edge_up += int(em.sum())
        self._edge_total += int(em.size)

    def availability(self) -> Optional[Availability]:
        """The estimated sporadic-participation rates, or None while no
        participation has been observed (or it has been full — the exact
        Prop-1 formulas then apply unmodified)."""
        if self._node_total == 0 and self._edge_total == 0:
            return None
        node_rate = (self._node_up / self._node_total
                     if self._node_total else 1.0)
        edge_rate = (self._edge_up / self._edge_total
                     if self._edge_total else 1.0)
        avail = Availability(node_rate=min(node_rate, 1.0),
                             edge_rate=min(edge_rate, 1.0),
                             resume_tau2=self.resume_tau2)
        return None if avail.is_full else avail

    def spend_overhead(self, seconds: float) -> None:
        """Charge one-off wall-clock (executor warmup compiles, stalls) to
        the budget WITHOUT entering the per-round cost fit — overhead is
        real budget spend but is not a (tau1, tau2) round sample."""
        self.spent_s += float(seconds)

    def _obs_rows(self) -> np.ndarray:
        """The least-squares design matrix rows of every observation."""
        return np.array([[o.tau1, o.tau2 * o.compression_ratio]
                         for o in self.observations], dtype=np.float64)

    def fit_rank(self) -> int:
        """Rank of the step/gossip-time fit (0 no data, 1 unidentifiable —
        all history proportional to one (tau1, tau2) direction, 2 full)."""
        if not self.observations:
            return 0
        return int(np.linalg.matrix_rank(self._obs_rows()))

    def fitted_cost_model(self) -> CostModel:
        """The prior cost model with compute/link speeds re-fitted.

        Least squares over rows  seconds ~= tau1 * t_step + (tau2 * ratio)
        * t_gossip  (ratio = the observation's compression factor, so the
        fitted t_gossip is the UNCOMPRESSED per-step gossip time and
        compressed candidates are priced consistently). Rank-deficient
        histories fall back to scaling the prior uniformly.
        """
        if not self.observations:
            return self.cost_model
        a = self._obs_rows()
        b = np.array([o.seconds for o in self.observations], dtype=np.float64)
        prior_t_step = self.cost_model.compute.t_step
        prior_t_gossip = self.cost_model.t_gossip_step(None)
        if np.linalg.matrix_rank(a) >= 2:
            (t_step, t_gossip), *_ = np.linalg.lstsq(a, b, rcond=None)
            t_step = max(float(t_step), _T_FLOOR)
            t_gossip = max(float(t_gossip), _T_FLOOR)
        else:
            # all history at one schedule: scale the prior split to match
            # the measured mean round time.
            predicted = a @ np.array([prior_t_step, prior_t_gossip])
            scale = float(np.sum(predicted * b) /
                          max(np.sum(predicted * predicted), _T_FLOOR))
            scale = max(scale, _T_FLOOR)
            t_step = max(prior_t_step * scale, _T_FLOOR)
            t_gossip = max(prior_t_gossip * scale, _T_FLOOR)
        bytes_per_step = max(
            self.cost_model.copies_per_step(), 1
        ) * self.cost_model.model_bits / 8.0
        # fitted model carries step_flops = t_step at unit throughput; keep
        # the prior's per-step ENERGY prices invariant under that reparam
        # (timing refits speed, not joules).
        e_step = self.cost_model.compute.energy_step
        prior_link = self.cost_model.link
        jpb = (prior_link.default.joules_per_byte
               if isinstance(prior_link, WirelessLinks)
               else prior_link.joules_per_byte)
        return dataclasses.replace(
            self.cost_model,
            compute=ComputeModel(step_flops=t_step, flops_per_s=1.0,
                                 joules_per_flop=e_step / t_step),
            link=LinkModel(bytes_per_s=bytes_per_step / t_gossip,
                           joules_per_byte=jpb))

    # -- identifiability probes -------------------------------------------

    def _probe_candidate(self) -> Optional[Tuple[int, int]]:
        """A grid (tau1, tau2) whose observation row is linearly
        independent of everything measured so far (i.e. it RAISES the fit
        rank), closest in predicted round time to the current schedule so
        the probe disturbs the budget as little as possible. None when the
        grid has no rank-raising point."""
        if self.current is None or not self.observations:
            return None
        grid = tuple(self.grid) if self.grid is not None else DEFAULT_GRID
        rows = self._obs_rows()
        rank = np.linalg.matrix_rank(rows)
        cm = self.fitted_cost_model()
        comp = self.current.compressor
        ratio = self.cost_model.compression_ratio(comp)
        cur_t = cm.round_cost(self.current.tau1, self.current.tau2,
                              comp).time_s
        best = None
        for (t1, t2) in grid:
            row = np.array([[t1, t2 * ratio]], dtype=np.float64)
            if np.linalg.matrix_rank(np.vstack([rows, row])) <= rank:
                continue
            dt = abs(cm.round_cost(t1, t2, comp).time_s - cur_t)
            if best is None or dt < best[0]:
                best = (dt, (t1, t2))
        return best[1] if best is not None else None

    def _probe_plan(self, remaining: Budget) -> Optional[Plan]:
        """The probe candidate priced as a full Plan under the
        (scaled-prior) fitted model, so callers get eta/rounds/bound for
        the probe schedule too."""
        cand = self._probe_candidate()
        if cand is None:
            return None
        kw = self._plan_kwargs()
        kw["grid"] = [cand]
        try:
            return plan_fn(remaining, self.fitted_cost_model(), **kw)
        except ValueError:
            return None

    # -- the control loop hooks -------------------------------------------

    def maybe_replan(self, round_idx: int) -> Optional[Plan]:
        """Call once per completed round (after ``observe``).

        Returns a NEW Plan when the schedule changed at this boundary,
        else None. Sets ``exhausted`` when the remaining budget affords no
        further rounds. With a rank-deficient timing fit (all history at
        one schedule direction) the boundary emits a PROBE plan — a
        rank-raising grid schedule — instead of re-planning off the
        unidentifiable scaled fit; the probe's own measurements make the
        next boundary fully identified.
        """
        if self.exhausted or self.current is None:
            return None
        remaining = self._remaining_budget()
        if remaining is None:
            self.exhausted = True
            return None
        if round_idx % self.replan_every != 0:
            return None
        if self.observations and self.fit_rank() < 2:
            probe = self._probe_plan(remaining)
            if probe is not None:
                self.current = probe
                self._emit(round_idx, "probe")
                return probe
        self.cost_model = self.fitted_cost_model()
        try:
            new = plan_fn(remaining, self.cost_model, **self._plan_kwargs())
        except ValueError:
            self.exhausted = True
            return None
        changed = (new.tau1, new.tau2, new.compressor_name) != (
            self.current.tau1, self.current.tau2,
            self.current.compressor_name)
        self.current = new
        self._emit(round_idx, "replan")
        return new if changed else None

    def _trajectory_candidate(self, k: int):
        """Compute the next k-round trajectory WITHOUT mutating any
        controller state: (fitted_cost_model, trajectory_plan, taus,
        probe) or None when the remaining budget affords no round. Both
        ``next_trajectory`` (which commits the result) and
        ``predict_trajectory`` (which only peeks) run exactly this, so a
        prediction taken between ``observe_chunk`` and the next
        ``next_trajectory`` call is deterministic-identical to what the
        controller will emit — the contract the prefetch-ahead path in
        ``train.py --schedule trajectory`` relies on."""
        remaining = self._remaining_budget()
        if remaining is None:
            return None
        probe = (self._probe_candidate()
                 if self.observations and self.fit_rank() < 2 else None)
        cm = self.fitted_cost_model()
        process = (CostProcess(base=cm)
                   if self.process is None
                   else dataclasses.replace(self.process, base=cm))
        try:
            tp = plan_trajectory_fn(remaining, process, rounds=k,
                                    t0=self.spent_s, **self._plan_kwargs())
        except ValueError:
            return None
        if tp.rounds == 0:
            return None
        taus = tp.taus
        if probe is not None:
            # the probe replaces the chunk's LAST planned round — only if
            # the swapped chunk still fits the remaining budget (the
            # probe is chosen nearest in round time, but a tight budget
            # end could not absorb an expensive rank-raiser).
            comp = tp.steps[0].compressor
            rc_probe = cm.round_cost(int(probe[0]), int(probe[1]), comp)
            rc_last = tp.steps[-1].round_cost
            fits = (
                (remaining.wall_clock_s is None
                 or tp.total_time_s - rc_last.time_s + rc_probe.time_s
                 <= remaining.wall_clock_s)
                and (remaining.wire_bits is None
                     or tp.total_wire_bits - rc_last.wire_bits
                     + rc_probe.wire_bits <= remaining.wire_bits)
                and (remaining.energy_j is None
                     or tp.total_energy_j - rc_last.energy_j
                     + rc_probe.energy_j <= remaining.energy_j))
            if fits:
                taus[-1] = probe
            else:
                probe = None
        return cm, tp, taus, probe

    def predict_trajectory(self, k: int) -> Optional[np.ndarray]:
        """PREDICT the next k-round [k, 2] schedule without committing it.

        Pure read: no observation, no spend, no history event, no
        ``current``/``cost_model``/``exhausted`` update — calling it any
        number of times leaves the controller bit-identical. Called with
        the same observation/spend state the next ``next_trajectory`` will
        see (i.e. after the chunk's ``observe_chunk`` and before any new
        spend), the returned rows equal what ``next_trajectory`` will
        emit — which is what lets trajectory mode prefetch host batches
        against the prediction and rebuild only on a genuine mismatch
        (``HostPrefetcher.mark_stale``). Returns None when the controller
        is exhausted or the remaining budget affords no round (prediction
        never *sets* ``exhausted`` — the committing call does)."""
        assert k >= 1
        if self.exhausted or self.current is None:
            return None
        cand = self._trajectory_candidate(k)
        return None if cand is None else cand[2]

    def next_trajectory(self, k: int,
                        round_idx: int = 0) -> Optional[np.ndarray]:
        """The next k rounds' [k, 2] (tau1, tau2) schedule — the
        per-round control surface for ``RoundExecutor.dispatch_trajectory``
        (``train.py --schedule trajectory``).

        Re-fits the cost model from every observation, then plans a
        per-round trajectory over the remaining budget: against the known
        ``process`` episodes (re-based on the fitted speeds) when one was
        given, else the fitted model held constant (a uniform chunk). A
        rank-deficient fit rides a probe round on the LAST round of the
        chunk — re-planning INSIDE the superstep, not just at its
        boundary — so identifiability costs one round and zero recompiles.
        Returns None (and sets ``exhausted``) when the budget affords no
        further round; the returned trajectory may be SHORTER than k when
        the budget runs out mid-chunk.
        """
        assert k >= 1
        if self.exhausted or self.current is None:
            return None
        cand = self._trajectory_candidate(k)
        if cand is None:
            self.exhausted = True
            return None
        cm, tp, taus, probe = cand
        self.cost_model = cm
        self.current = tp.steps[0]
        self._emit(round_idx, "trajectory",
                   schedule=[[int(a), int(b)] for a, b in taus],
                   probe=([int(probe[0]), int(probe[1])]
                          if probe is not None else None))
        return taus
