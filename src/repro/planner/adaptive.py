"""Runtime (tau1, tau2) control from *measured* round timings.

The static planner prices schedules from a priori FLOPs/bandwidth numbers;
real deployments drift (thermal throttling, contended links, interpret-mode
kernels). ``AdaptiveController`` closes the loop: every round it records
the measured wall-clock of the (tau1, tau2) schedule that actually ran,
every ``replan_every`` rounds it re-fits the per-step compute/gossip times
by least squares over the observed (tau1, tau2, seconds) history and
re-plans the remainder of the budget with ``planner.optimize.plan``.

Identifiability: with observations at a single (tau1, tau2) the 2-unknown
fit is rank-1; the controller then scales the prior cost model uniformly to
match the measured round time (preserving the prior compute/comm split)
and full identification kicks in as soon as a re-plan changes the schedule.

Wired into ``repro.launch.train`` via ``--plan-budget`` /
``--replan-every``; every re-plan is appended to ``controller.history`` so
the emitted metrics show the schedule trajectory.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.compression import Compressor
from repro.planner.cost import (ComputeModel, CostModel, LinkModel,
                               WirelessLinks)
from repro.planner.optimize import Budget, Plan, plan as plan_fn

__all__ = ["AdaptiveController"]

_T_FLOOR = 1e-9  # seconds; keeps fitted per-step times strictly positive


@dataclasses.dataclass(frozen=True)
class _Observation:
    tau1: int
    tau2: int
    seconds: float
    compression_ratio: float  # wire-bits ratio active during this round


class AdaptiveController:
    """Re-plans (tau1, tau2, compressor) from measured timings.

    Args:
      budget: total resource envelope for the WHOLE session (the
        controller spends it down as rounds complete).
      cost_model: the prior — engine/topology/model_bits are trusted, the
        compute/link speeds are re-fitted from measurements.
      sigma, f_gap, L, gamma, grid, compressors: forwarded to
        ``planner.optimize.plan``.
      replan_every: rounds between re-plans (K).
    """

    def __init__(
        self,
        budget: Budget,
        cost_model: CostModel,
        *,
        sigma: float,
        f_gap: float,
        replan_every: int = 10,
        grid: Optional[Sequence[Tuple[int, int]]] = None,
        compressors: Sequence[Optional[Compressor]] = (None,),
        gamma: float = 1.0,
        L: float = 1.0,
    ):
        assert replan_every >= 1
        self.budget = budget
        self.cost_model = cost_model
        self.sigma = sigma
        self.f_gap = f_gap
        self.replan_every = replan_every
        self.grid = grid
        self.compressors = tuple(compressors)
        self.gamma = gamma
        self.L = L
        self.observations: List[_Observation] = []
        self.spent_s = 0.0
        self.spent_bits = 0.0
        self.spent_j = 0.0
        self.history: List[dict] = []   # one dict per (re)plan event
        self.current: Optional[Plan] = None
        self.exhausted = False

    # -- planning ----------------------------------------------------------

    def _plan_kwargs(self):
        kw = dict(sigma=self.sigma, f_gap=self.f_gap,
                  compressors=self.compressors, gamma=self.gamma, L=self.L)
        if self.grid is not None:
            kw["grid"] = self.grid
        return kw

    def _remaining_budget(self) -> Optional[Budget]:
        wall = (self.budget.wall_clock_s - self.spent_s
                if self.budget.wall_clock_s is not None else None)
        bits = (self.budget.wire_bits - self.spent_bits
                if self.budget.wire_bits is not None else None)
        joules = (self.budget.energy_j - self.spent_j
                  if self.budget.energy_j is not None else None)
        if any(rem is not None and rem <= 0.0
               for rem in (wall, bits, joules)):
            return None
        return Budget(wall_clock_s=wall, wire_bits=bits, energy_j=joules)

    def _emit(self, round_idx: int, cause: str) -> None:
        p = self.current
        assert p is not None
        self.history.append({
            "round": round_idx,
            "cause": cause,
            "tau1": p.tau1,
            "tau2": p.tau2,
            "compressor": p.compressor_name,
            "eta": p.eta,
            "rounds_planned": p.rounds,
            "predicted_bound": p.predicted_bound,
            "t_compute_step": p.round_cost.t_compute_step,
            "t_gossip_step": p.round_cost.t_gossip_step,
            "spent_s": self.spent_s,
        })

    def initial_plan(self) -> Plan:
        """Plan round 0 from the prior cost model and the full budget."""
        self.current = plan_fn(self.budget, self.cost_model,
                               **self._plan_kwargs())
        self._emit(0, "initial")
        return self.current

    # -- measurement -------------------------------------------------------

    def observe(self, tau1: int, tau2: int, seconds: float, *,
                fit: Optional[bool] = None) -> None:
        """Record one completed round's measured wall-clock.

        EVERY measured round enters the least-squares cost fit: since the
        recompile-free executor (``repro.core.executor``) a schedule change
        is two device scalars, so no round's wall-clock is ever
        contaminated by a jit re-trace/compile and the old ``fit=False``
        escape hatch (used to drop freshly-(re)built rounds) is obsolete.
        The parameter is kept as a deprecation shim and IGNORED.
        """
        if fit is not None:
            import warnings

            warnings.warn(
                "AdaptiveController.observe(fit=...) is deprecated and "
                "ignored: dynamic-tau dispatch never compile-contaminates "
                "a round, so every measured round enters the cost fit",
                DeprecationWarning, stacklevel=2)
        comp = self.current.compressor if self.current is not None else None
        ratio = self.cost_model.compression_ratio(comp)
        self.observations.append(
            _Observation(tau1, tau2, float(seconds), ratio))
        self.spent_s += float(seconds)
        # wire/energy accounting is analytic (exact), not measured:
        self.spent_bits += (
            tau2 * self.cost_model.gossip_bits_per_step(comp))
        self.spent_j += self.cost_model.round_cost(tau1, tau2, comp).energy_j

    def spend_overhead(self, seconds: float) -> None:
        """Charge one-off wall-clock (executor warmup compiles, stalls) to
        the budget WITHOUT entering the per-round cost fit — overhead is
        real budget spend but is not a (tau1, tau2) round sample."""
        self.spent_s += float(seconds)

    def fitted_cost_model(self) -> CostModel:
        """The prior cost model with compute/link speeds re-fitted.

        Least squares over rows  seconds ~= tau1 * t_step + (tau2 * ratio)
        * t_gossip  (ratio = the observation's compression factor, so the
        fitted t_gossip is the UNCOMPRESSED per-step gossip time and
        compressed candidates are priced consistently). Rank-deficient
        histories fall back to scaling the prior uniformly.
        """
        if not self.observations:
            return self.cost_model
        a = np.array([[o.tau1, o.tau2 * o.compression_ratio]
                      for o in self.observations], dtype=np.float64)
        b = np.array([o.seconds for o in self.observations], dtype=np.float64)
        prior_t_step = self.cost_model.compute.t_step
        prior_t_gossip = self.cost_model.t_gossip_step(None)
        if np.linalg.matrix_rank(a) >= 2:
            (t_step, t_gossip), *_ = np.linalg.lstsq(a, b, rcond=None)
            t_step = max(float(t_step), _T_FLOOR)
            t_gossip = max(float(t_gossip), _T_FLOOR)
        else:
            # all history at one schedule: scale the prior split to match
            # the measured mean round time.
            predicted = a @ np.array([prior_t_step, prior_t_gossip])
            scale = float(np.sum(predicted * b) /
                          max(np.sum(predicted * predicted), _T_FLOOR))
            scale = max(scale, _T_FLOOR)
            t_step = max(prior_t_step * scale, _T_FLOOR)
            t_gossip = max(prior_t_gossip * scale, _T_FLOOR)
        bytes_per_step = max(
            self.cost_model.copies_per_step(), 1
        ) * self.cost_model.model_bits / 8.0
        # fitted model carries step_flops = t_step at unit throughput; keep
        # the prior's per-step ENERGY prices invariant under that reparam
        # (timing refits speed, not joules).
        e_step = self.cost_model.compute.energy_step
        prior_link = self.cost_model.link
        jpb = (prior_link.default.joules_per_byte
               if isinstance(prior_link, WirelessLinks)
               else prior_link.joules_per_byte)
        return dataclasses.replace(
            self.cost_model,
            compute=ComputeModel(step_flops=t_step, flops_per_s=1.0,
                                 joules_per_flop=e_step / t_step),
            link=LinkModel(bytes_per_s=bytes_per_step / t_gossip,
                           joules_per_byte=jpb))

    # -- the control loop hook --------------------------------------------

    def maybe_replan(self, round_idx: int) -> Optional[Plan]:
        """Call once per completed round (after ``observe``).

        Returns a NEW Plan when the schedule changed at this boundary,
        else None. Sets ``exhausted`` when the remaining budget affords no
        further rounds.
        """
        if self.exhausted or self.current is None:
            return None
        remaining = self._remaining_budget()
        if remaining is None:
            self.exhausted = True
            return None
        if round_idx % self.replan_every != 0:
            return None
        self.cost_model = self.fitted_cost_model()
        try:
            new = plan_fn(remaining, self.cost_model, **self._plan_kwargs())
        except ValueError:
            self.exhausted = True
            return None
        changed = (new.tau1, new.tau2, new.compressor_name) != (
            self.current.tau1, self.current.tau2,
            self.current.compressor_name)
        self.current = new
        self._emit(round_idx, "replan")
        return new if changed else None
