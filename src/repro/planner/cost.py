"""Composable per-round cost models for DFL schedules.

A DFL round is ``tau1`` local-update steps plus ``tau2`` gossip steps; its
resource cost decomposes as

    time   = tau1 * t_compute_step + tau2 * t_gossip_step
    bits   = tau2 * copies * model_bits * compression_ratio      (per node)
    energy = tau1 * e_compute_step + tau2 * e_gossip_step

(under the pipelined executor, ``overlap="pipeline"``, the time term is
``tau1 * t_compute_step + max(0, tau2 * t_gossip_step - overlap_window)``
with the window equal to the local-phase time — gossip rides under the
next round's compute and only the overhang is paid; bits and energy are
unchanged)

where ``copies`` — the model copies each node receives per gossip step —
comes from ``mixing.gossip_copies_per_step(topology, engine)`` so the dense
all-gather lowering (N-1 copies) and the sparse per-neighbor engine
(max_degree copies) are priced correctly, and the compression ratio comes
from the C-DFL compressor's ``bits_per_value``. Link time is either a
single shared ``LinkModel`` or a ``WirelessLinks`` table with per-edge
bandwidth/SNR (Shannon capacity, in the spirit of arXiv:2308.06496's
resource-constrained DFL over wireless networks).

``CostModel.round_cost(tau1, tau2, compressor)`` is the one entry point;
``planner.optimize.plan`` minimizes a convergence bound subject to a budget
expressed in any of these currencies.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import mixing as mixing_lib
from repro.core.compression import Compressor
from repro.core.topology import Topology

__all__ = [
    "ComputeModel",
    "LinkModel",
    "WirelessLinks",
    "wireless_link",
    "RoundCost",
    "CostModel",
    "Episode",
    "CostProcess",
    "straggler_links",
    "faded_links",
    "edge_outage",
    "unit_cost_model",
    "comm_compute_cost",
]


@dataclasses.dataclass(frozen=True)
class ComputeModel:
    """One local SGD step priced from its FLOPs.

    step_flops: FLOPs of one local update on one node (fwd+bwd+opt).
    flops_per_s: sustained device throughput.
    joules_per_flop: optional energy price (0 disables energy accounting).
    """

    step_flops: float
    flops_per_s: float
    joules_per_flop: float = 0.0

    @property
    def t_step(self) -> float:
        return self.step_flops / self.flops_per_s

    @property
    def energy_step(self) -> float:
        return self.step_flops * self.joules_per_flop


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """A point-to-point link: fixed latency + bandwidth + energy price."""

    bytes_per_s: float
    latency_s: float = 0.0
    joules_per_byte: float = 0.0

    def t_transfer(self, nbytes: float) -> float:
        return self.latency_s + nbytes / self.bytes_per_s

    def energy_transfer(self, nbytes: float) -> float:
        return nbytes * self.joules_per_byte


def wireless_link(
    bandwidth_hz: float,
    snr_db: float,
    *,
    efficiency: float = 1.0,
    latency_s: float = 0.0,
    joules_per_byte: float = 0.0,
) -> LinkModel:
    """Shannon-capacity link: rate = eff * B * log2(1 + SNR) bits/s.

    The standard physical-layer model for DFL over wireless networks
    (arXiv:2308.06496 Sec. II): per-edge bandwidth and SNR determine the
    achievable rate; ``efficiency`` < 1 derates for coding/protocol
    overhead.
    """
    snr = 10.0 ** (snr_db / 10.0)
    bits_per_s = efficiency * bandwidth_hz * math.log2(1.0 + snr)
    return LinkModel(bytes_per_s=bits_per_s / 8.0, latency_s=latency_s,
                     joules_per_byte=joules_per_byte)


@dataclasses.dataclass(frozen=True)
class WirelessLinks:
    """A per-edge link table over a topology's undirected edges.

    ``per_edge[(i, j)]`` (i < j) overrides ``default`` for that edge —
    heterogeneous bandwidth/SNR per link, the defining feature of the
    wireless DFL setting. Synchronous gossip waits for the slowest
    transfer, so the step time is a max over the active links:

      concurrency="parallel": all edges transfer simultaneously (wired
        full-duplex ICI); t_step = max over edges of the edge time.
      concurrency="serial": each node's radio serves its neighbors one at
        a time (half-duplex wireless); t_step = max over nodes of the SUM
        of that node's incoming-edge times.
    """

    default: LinkModel
    per_edge: Mapping[Tuple[int, int], LinkModel] = dataclasses.field(
        default_factory=dict)
    concurrency: str = "parallel"

    def link(self, i: int, j: int) -> LinkModel:
        key = (min(i, j), max(i, j))
        return self.per_edge.get(key, self.default)

    def gossip_time(self, topology: Topology, copy_bytes: float,
                    active_edges: Optional[Sequence[Tuple[int, int]]] = None,
                    ) -> float:
        """Time of one gossip step shipping ``copy_bytes`` per neighbor.

        ``active_edges``: optional undirected edge subset actually carrying
        traffic this step (a sporadic round's unmasked edges) — masked
        edges ship nothing and so never gate the step, which is exactly
        why a sporadic round is cheaper than a blocking round waiting on
        an outage tariff.
        """
        if self.concurrency not in ("parallel", "serial"):
            raise ValueError(f"unknown concurrency {self.concurrency!r}")
        act = (None if active_edges is None else
               {(min(i, j), max(i, j)) for (i, j) in active_edges})
        per_node = []
        for i, nbrs in enumerate(topology.neighbors):
            times = [self.link(i, j).t_transfer(copy_bytes)
                     for (j, _w) in nbrs
                     if act is None or (min(i, j), max(i, j)) in act]
            if not times:
                per_node.append(0.0)
            elif self.concurrency == "serial":
                per_node.append(sum(times))
            else:
                per_node.append(max(times))
        return max(per_node, default=0.0)

    def gossip_energy(self, topology: Topology, copy_bytes: float,
                      active_edges: Optional[Sequence[Tuple[int, int]]] = None,
                      ) -> float:
        """Per-node mean energy of one gossip step (receive side)."""
        n = max(topology.num_nodes, 1)
        act = (None if active_edges is None else
               {(min(i, j), max(i, j)) for (i, j) in active_edges})
        total = sum(
            self.link(i, j).energy_transfer(copy_bytes)
            for i, nbrs in enumerate(topology.neighbors) for (j, _w) in nbrs
            if act is None or (min(i, j), max(i, j)) in act)
        return total / n


@dataclasses.dataclass(frozen=True)
class RoundCost:
    """The priced resources of ONE DFL round (per node)."""

    time_s: float
    wire_bits: float
    energy_j: float
    t_compute_step: float
    t_gossip_step: float
    _comm_time: float = 0.0

    @property
    def comm_fraction(self) -> float:
        return self._comm_time / self.time_s if self.time_s > 0.0 else 0.0


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Prices (tau1, tau2, compressor) schedules on one deployment.

    compute:    the local-step model.
    link:       a shared LinkModel or a per-edge WirelessLinks table.
    topology:   gossip graph (copies per step + edge set).
    model_bits: uncompressed wire bits of one model copy (fp32 tree).
    engine:     wire-accounting engine — "sparse" per-neighbor (deployment
                truth & the ppermute engine), "dense" all-gather lowering,
                "auto" whichever the launcher would pick (see
                ``mixing.gossip_copies_per_step``).
    overlap:    executor overlap mode being priced. "none" is the paper's
                additive round time; "pipeline" hides the wire under the
                NEXT round's local steps (``RoundExecutor(overlap=
                "pipeline")``), so the round time becomes

                    tau1 * t_c + max(0, tau2 * t_g - overlap_window)

                with overlap_window = tau1 * t_c — i.e. only the gossip
                time that does not fit under compute is paid. Degenerates
                EXACTLY to the additive model at "none" (window 0). Wire
                bits and energy are unchanged: overlap hides time, it does
                not remove traffic.
    """

    compute: ComputeModel
    link: Union[LinkModel, WirelessLinks]
    topology: Topology
    model_bits: float
    engine: str = "sparse"
    overlap: str = "none"

    def __post_init__(self):
        if self.overlap not in ("none", "pipeline"):
            raise ValueError(
                f"overlap must be 'none' or 'pipeline', got {self.overlap!r}")

    def overlap_window(self, tau1: int) -> float:
        """Seconds of gossip hidden under the next round's local phase."""
        if self.overlap == "pipeline":
            return tau1 * self.compute.t_step
        return 0.0

    def compression_ratio(self, compressor: Optional[Compressor]) -> float:
        """Wire-bits ratio vs fp32 for one model copy (1.0 uncompressed)."""
        if compressor is None:
            return 1.0
        d = max(int(round(self.model_bits / 32.0)), 1)
        return float(compressor.bits_per_value(d)) / 32.0

    def copies_per_step(self) -> int:
        return mixing_lib.gossip_copies_per_step(self.topology, self.engine)

    def gossip_bits_per_step(
        self, compressor: Optional[Compressor] = None
    ) -> float:
        """Wire bits each node receives per gossip step."""
        return (self.copies_per_step() * self.model_bits
                * self.compression_ratio(compressor))

    def t_gossip_step(self, compressor: Optional[Compressor] = None) -> float:
        copy_bytes = (self.model_bits * self.compression_ratio(compressor)
                      / 8.0)
        if isinstance(self.link, WirelessLinks):
            return self.link.gossip_time(self.topology, copy_bytes)
        return self.link.t_transfer(self.copies_per_step() * copy_bytes)

    def round_cost(self, tau1: int, tau2: int,
                   compressor: Optional[Compressor] = None) -> RoundCost:
        t_c = self.compute.t_step
        t_g = self.t_gossip_step(compressor)
        copy_bytes = (self.model_bits * self.compression_ratio(compressor)
                      / 8.0)
        if isinstance(self.link, WirelessLinks):
            e_g = self.link.gossip_energy(self.topology, copy_bytes)
        else:
            e_g = self.link.energy_transfer(
                self.copies_per_step() * copy_bytes)
        comm_time = max(0.0, tau2 * t_g - self.overlap_window(tau1))
        return RoundCost(
            time_s=tau1 * t_c + comm_time,
            wire_bits=tau2 * self.gossip_bits_per_step(compressor),
            energy_j=tau1 * self.compute.energy_step + tau2 * e_g,
            t_compute_step=t_c,
            t_gossip_step=t_g,
            _comm_time=comm_time,
        )

    def masked_round_cost(
        self, tau1: int, tau2: int,
        compressor: Optional[Compressor] = None,
        *,
        active_nodes: Optional[Sequence[int]] = None,
        active_edges: Optional[Sequence[Tuple[int, int]]] = None,
    ) -> RoundCost:
        """Price a SPORADIC round over its realized participation.

        A masked node skips its local steps; a masked edge ships nothing
        (its ppermute still runs, but the accumulation weight is zero —
        nothing crosses the wire). Deployment truth for the round is
        therefore: compute time 0 when every node is masked, gossip time
        gated only by the ACTIVE edges, wire/energy counted only on
        active traffic. This is why the sporadic engine beats a blocking
        baseline at equal deployment-clock budget: the blocking round
        pays the outage tariff (``edge_outage`` residual-rate links) on
        the very edges the sporadic round simply drops.
        """
        n_active = (self.topology.num_nodes if active_nodes is None
                    else len(set(active_nodes)))
        act = (None if active_edges is None else
               [(min(i, j), max(i, j)) for (i, j) in active_edges])
        t_c = self.compute.t_step if n_active > 0 else 0.0
        copy_bytes = (self.model_bits * self.compression_ratio(compressor)
                      / 8.0)
        wl = _as_wireless(self.link)
        t_g = wl.gossip_time(self.topology, copy_bytes, active_edges=act)
        e_g = wl.gossip_energy(self.topology, copy_bytes, active_edges=act)
        if act is None:
            bits_step = self.gossip_bits_per_step(compressor)
        else:
            # each active undirected edge delivers one copy per direction;
            # per-node mean received copies = 2|E_active| / N
            n = max(self.topology.num_nodes, 1)
            bits_step = (2.0 * len(set(act)) / n
                         * self.model_bits
                         * self.compression_ratio(compressor))
        # the window only spans compute that actually runs: a fully masked
        # round (t_c = 0) hides nothing.
        window = (tau1 * t_c if self.overlap == "pipeline" else 0.0)
        comm_time = max(0.0, tau2 * t_g - window)
        frac = n_active / max(self.topology.num_nodes, 1)
        return RoundCost(
            time_s=tau1 * t_c + comm_time,
            wire_bits=tau2 * bits_step,
            energy_j=(tau1 * self.compute.energy_step * frac + tau2 * e_g),
            t_compute_step=t_c,
            t_gossip_step=t_g,
            _comm_time=comm_time,
        )


# ---------------------------------------------------------------------------
# Time-varying deployments: straggler episodes, fading links, outages
# ---------------------------------------------------------------------------


def _as_wireless(link: Union[LinkModel, WirelessLinks]) -> WirelessLinks:
    return link if isinstance(link, WirelessLinks) else WirelessLinks(
        default=link)


def _scale_link(link: LinkModel, slowdown: float) -> LinkModel:
    return dataclasses.replace(link, bytes_per_s=link.bytes_per_s / slowdown)


def straggler_links(
    link: Union[LinkModel, WirelessLinks],
    topology: Topology,
    node: int,
    slowdown: float,
) -> WirelessLinks:
    """Every edge touching ``node`` runs ``slowdown``x slower.

    Synchronous gossip waits for the slowest transfer
    (``WirelessLinks.gossip_time`` is a max over active links), so one
    straggling node gates every gossip step of the whole network — the
    canonical heterogeneous-node episode the per-round trajectory planner
    exists to route around.
    """
    wl = _as_wireless(link)
    # undirected edge set (neighbors lists both directions — dedupe first
    # so each edge is slowed exactly once).
    touched = {(min(i, j), max(i, j))
               for i, nbrs in enumerate(topology.neighbors)
               for j, _w in nbrs if node in (i, j)}
    per = dict(wl.per_edge)
    for key in sorted(touched):
        per[key] = _scale_link(per.get(key, wl.default), slowdown)
    return dataclasses.replace(wl, per_edge=per)


def faded_links(
    link: Union[LinkModel, WirelessLinks], slowdown: float
) -> WirelessLinks:
    """Uniform fading: every link's rate (default and per-edge overrides)
    divides by ``slowdown`` — a network-wide deep-fade / congestion
    episode."""
    wl = _as_wireless(link)
    per = {k: _scale_link(v, slowdown) for k, v in wl.per_edge.items()}
    return dataclasses.replace(wl, default=_scale_link(wl.default, slowdown),
                               per_edge=per)


def edge_outage(
    link: Union[LinkModel, WirelessLinks],
    edges: Sequence[Tuple[int, int]],
    residual: float = 1e-3,
) -> WirelessLinks:
    """Per-edge outage: the named undirected edges drop to ``residual`` of
    their rate (a hard 0 would make the synchronous gossip step infinite;
    DFL over a severed edge in practice degrades to retransmission at some
    residual throughput)."""
    wl = _as_wireless(link)
    per = dict(wl.per_edge)
    for (i, j) in edges:
        key = (min(i, j), max(i, j))
        per[key] = _scale_link(per.get(key, wl.default), 1.0 / residual)
    return dataclasses.replace(wl, per_edge=per)


@dataclasses.dataclass(frozen=True)
class Episode:
    """A wall-clock window during which the deployment deviates from base.

    t_start/t_stop: the window [t_start, t_stop) on the deployment clock
      (seconds, same clock ``CostProcess.at`` is queried with).
    link: optional LinkModel/WirelessLinks replacing the base link table
      for the window (build with ``straggler_links``/``faded_links``/
      ``edge_outage`` for the standard scenarios).
    compute_scale: >1 slows every local step by that factor for the window
      (synchronous local epochs wait for the slowest node, so a compute
      straggler scales the whole step time).
    """

    t_start: float
    t_stop: float
    link: Optional[Union[LinkModel, WirelessLinks]] = None
    compute_scale: float = 1.0
    label: str = ""

    def __post_init__(self):
        assert self.t_stop > self.t_start, "empty episode window"
        assert self.compute_scale > 0.0

    def active(self, t: float) -> bool:
        return self.t_start <= t < self.t_stop


@dataclasses.dataclass(frozen=True)
class CostProcess:
    """A time-varying deployment: base costs plus episodic deviations.

    ``at(t)`` is the cost model in force at deployment-clock ``t``;
    overlapping episodes compose in declaration order (a later episode's
    link override wins, compute scales multiply). The trajectory planner
    (``planner.optimize.plan_trajectory``) walks this clock to price each
    round of a length-K schedule; ``is_static`` processes degenerate to
    the fixed-schedule ``plan``.
    """

    base: CostModel
    episodes: Tuple[Episode, ...] = ()

    @property
    def is_static(self) -> bool:
        return not self.episodes

    def at(self, t: float) -> CostModel:
        cm = self.base
        for ep in self.episodes:
            if not ep.active(t):
                continue
            if ep.link is not None:
                cm = dataclasses.replace(cm, link=ep.link)
            if ep.compute_scale != 1.0:
                comp = cm.compute
                cm = dataclasses.replace(
                    cm, compute=dataclasses.replace(
                        comp,
                        flops_per_s=comp.flops_per_s / ep.compute_scale))
        return cm

    def horizon(self) -> float:
        """The last episode boundary (0.0 when static) — after this the
        process is its base forever."""
        return max((ep.t_stop for ep in self.episodes), default=0.0)


def unit_cost_model(topology: Topology, comm_compute_ratio: float, *,
                    engine: str = "sparse",
                    rep_dim: int = 1024,
                    overlap: str = "none") -> CostModel:
    """The benchmarks' abstract cost unit: t_compute_step = 1, and one
    gossip step costs ``comm_compute_ratio`` — the "comm/comp" knob that
    ``bench_balance`` sweeps. ``rep_dim`` is the representative parameter
    count used to price compressors (their ``bits_per_value`` depends on
    the vector dimension)."""
    model_bits = 32.0 * rep_dim
    copies = mixing_lib.gossip_copies_per_step(topology, engine)
    bytes_per_step = max(copies, 1) * model_bits / 8.0
    link = LinkModel(bytes_per_s=bytes_per_step / comm_compute_ratio)
    return CostModel(
        compute=ComputeModel(step_flops=1.0, flops_per_s=1.0),
        link=link, topology=topology, model_bits=model_bits, engine=engine,
        overlap=overlap)


def comm_compute_cost(
    tau1: int,
    tau2: int,
    rounds: int,
    *,
    step_flops: float,
    model_bytes: float,
    degree: int,
    flops_per_s: float,
    link_bytes_per_s: float,
    bits_per_value_ratio: float = 1.0,
) -> Dict[str, float]:
    """Analytic time model for the paper's 'balancing' trade-off.

    Total time = rounds * (tau1 * t_compute + tau2 * t_comm) with
    t_comm = degree * model_bytes * bits_ratio / link_bw. Kept as the
    degree-explicit flat API (the old ``core.metrics.comm_compute_cost``,
    now a deprecation shim over this); ``CostModel`` is the composable
    topology-aware replacement.

    Example: step_flops=1e9, model_bytes=4e6, degree=2, flops_per_s=1e12,
    link_bytes_per_s=1e9 gives t_compute=1e-3 s, t_comm=8e-3 s.
    """
    compute = ComputeModel(step_flops=step_flops, flops_per_s=flops_per_s)
    link = LinkModel(bytes_per_s=link_bytes_per_s)
    t_compute = compute.t_step
    t_comm = link.t_transfer(degree * model_bytes * bits_per_value_ratio)
    per_round = tau1 * t_compute + tau2 * t_comm
    return {
        "t_compute": t_compute,
        "t_comm": t_comm,
        "per_round": per_round,
        "total": per_round * rounds,
        "comm_fraction": (tau2 * t_comm) / per_round if per_round else 0.0,
    }
