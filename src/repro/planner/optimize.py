"""Schedule search: minimize the predicted bound under a resource budget.

``plan(budget, cost_model, sigma=..., f_gap=...)`` walks the
(tau1, tau2, compressor) grid; each candidate's per-round cost (from the
``CostModel``) converts the budget into an affordable round count, the
round count into a total iteration count T, and Proposition 1
(``bounds.predicted_loss_decrement``) into a predicted average gradient
norm — the candidate minimizing it wins. This is the paper's "convergence
rate ... optimized to achieve the balance of communication and computing
costs under constrained resources" (abstract / Sec. V) as an executable
object; ``benchmarks/bench_balance.py`` validates the picks empirically.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.compression import Compressor
from repro.planner.bounds import BoundEval, predicted_loss_decrement
from repro.planner.cost import CostModel, RoundCost

__all__ = [
    "DEFAULT_GRID",
    "Budget",
    "Plan",
    "rounds_within",
    "evaluate_grid",
    "select_plan",
    "plan",
]

DEFAULT_GRID: Tuple[Tuple[int, int], ...] = tuple(
    (t1, t2) for t1 in (1, 2, 4, 8, 16) for t2 in (1, 2, 4, 8))


@dataclasses.dataclass(frozen=True)
class Budget:
    """A resource envelope; any subset of currencies may be constrained.

    wall_clock_s: total seconds available.
    wire_bits: total wire bits per node available.
    energy_j: total joules per node available.
    """

    wall_clock_s: Optional[float] = None
    wire_bits: Optional[float] = None
    energy_j: Optional[float] = None

    def __post_init__(self):
        if (self.wall_clock_s is None and self.wire_bits is None
                and self.energy_j is None):
            raise ValueError("Budget needs at least one constrained resource")


@dataclasses.dataclass(frozen=True)
class Plan:
    """A planned schedule: the knobs plus the prediction that chose them."""

    tau1: int
    tau2: int
    compressor: Optional[Compressor]
    eta: float
    rounds: int
    total_iters: int
    predicted_bound: float
    round_cost: RoundCost
    bound_eval: BoundEval

    @property
    def compressor_name(self) -> str:
        return self.compressor.name if self.compressor is not None else "none"


def rounds_within(budget: Budget, rc: RoundCost) -> int:
    """Rounds affordable under every constrained currency (floor)."""
    limits: List[float] = []
    if budget.wall_clock_s is not None:
        limits.append(budget.wall_clock_s / rc.time_s if rc.time_s > 0
                      else float("inf"))
    if budget.wire_bits is not None:
        limits.append(budget.wire_bits / rc.wire_bits if rc.wire_bits > 0
                      else float("inf"))
    if budget.energy_j is not None:
        limits.append(budget.energy_j / rc.energy_j if rc.energy_j > 0
                      else float("inf"))
    lim = min(limits)
    return int(lim) if lim != float("inf") else 10**9


def evaluate_grid(
    budget: Budget,
    cost_model: CostModel,
    *,
    sigma: float,
    f_gap: float,
    grid: Sequence[Tuple[int, int]] = DEFAULT_GRID,
    compressors: Sequence[Optional[Compressor]] = (None,),
    gamma: float = 1.0,
    L: float = 1.0,
    eta: Optional[float] = None,
) -> List[Plan]:
    """Every feasible candidate as a Plan, in grid order (for tables)."""
    topo = cost_model.topology
    model_dim = max(int(round(cost_model.model_bits / 32.0)), 1)
    out: List[Plan] = []
    for comp in compressors:
        for (t1, t2) in grid:
            rc = cost_model.round_cost(t1, t2, comp)
            r = rounds_within(budget, rc)
            if r < 1:
                continue
            T = r * (t1 + t2)
            ev = predicted_loss_decrement(
                t1, t2, topo, sigma, T=T, f_gap=f_gap, L=L, eta=eta,
                compressor=comp, gamma=gamma,
                model_dim=model_dim)
            out.append(Plan(tau1=t1, tau2=t2, compressor=comp, eta=ev.eta,
                            rounds=r, total_iters=T,
                            predicted_bound=ev.bound, round_cost=rc,
                            bound_eval=ev))
    return out


def select_plan(cands: Sequence[Plan]) -> Plan:
    """The winner among evaluated candidates — THE selection rule.

    Deterministic tie-breaking: lower predicted bound, then cheaper round
    time, then smaller (tau1, tau2) — so equal-bound candidates resolve
    stably across platforms. Callers that already hold an
    ``evaluate_grid`` result (for tables/reports) should select with this
    instead of re-running ``plan``.
    """
    if not cands:
        raise ValueError("no feasible schedule candidates to select from")
    return min(cands, key=lambda p: (p.predicted_bound, p.round_cost.time_s,
                                     p.tau1, p.tau2))


def plan(
    budget: Budget,
    cost_model: CostModel,
    *,
    sigma: float,
    f_gap: float,
    grid: Sequence[Tuple[int, int]] = DEFAULT_GRID,
    compressors: Sequence[Optional[Compressor]] = (None,),
    gamma: float = 1.0,
    L: float = 1.0,
    eta: Optional[float] = None,
) -> Plan:
    """The best feasible schedule under ``budget`` by predicted bound
    (``evaluate_grid`` then ``select_plan``)."""
    cands = evaluate_grid(
        budget, cost_model, sigma=sigma, f_gap=f_gap, grid=grid,
        compressors=compressors, gamma=gamma, L=L, eta=eta)
    if not cands:
        raise ValueError(
            f"no (tau1, tau2) grid point affords even one round in {budget}")
    return select_plan(cands)
