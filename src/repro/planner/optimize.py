"""Schedule search: minimize the predicted bound under a resource budget.

``plan(budget, cost_model, sigma=..., f_gap=...)`` walks the
(tau1, tau2, compressor) grid; each candidate's per-round cost (from the
``CostModel``) converts the budget into an affordable round count, the
round count into a total iteration count T, and Proposition 1
(``bounds.predicted_loss_decrement``) into a predicted average gradient
norm — the candidate minimizing it wins. This is the paper's "convergence
rate ... optimized to achieve the balance of communication and computing
costs under constrained resources" (abstract / Sec. V) as an executable
object; ``benchmarks/bench_balance.py`` validates the picks empirically.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.compression import Compressor
from repro.planner.bounds import (Availability, BoundEval,
                                  predicted_loss_decrement)
from repro.planner.cost import CostModel, CostProcess, RoundCost

__all__ = [
    "DEFAULT_GRID",
    "Budget",
    "Plan",
    "TrajectoryPlan",
    "rounds_within",
    "evaluate_grid",
    "select_plan",
    "plan",
    "plan_trajectory",
]

DEFAULT_GRID: Tuple[Tuple[int, int], ...] = tuple(
    (t1, t2) for t1 in (1, 2, 4, 8, 16) for t2 in (1, 2, 4, 8))


@dataclasses.dataclass(frozen=True)
class Budget:
    """A resource envelope; any subset of currencies may be constrained.

    wall_clock_s: total seconds available.
    wire_bits: total wire bits per node available.
    energy_j: total joules per node available.
    """

    wall_clock_s: Optional[float] = None
    wire_bits: Optional[float] = None
    energy_j: Optional[float] = None

    def __post_init__(self):
        if (self.wall_clock_s is None and self.wire_bits is None
                and self.energy_j is None):
            raise ValueError("Budget needs at least one constrained resource")


@dataclasses.dataclass(frozen=True)
class Plan:
    """A planned schedule: the knobs plus the prediction that chose them."""

    tau1: int
    tau2: int
    compressor: Optional[Compressor]
    eta: float
    rounds: int
    total_iters: int
    predicted_bound: float
    round_cost: RoundCost
    bound_eval: BoundEval

    @property
    def compressor_name(self) -> str:
        return self.compressor.name if self.compressor is not None else "none"


def rounds_within(budget: Budget, rc: RoundCost) -> int:
    """Rounds affordable under every constrained currency (floor)."""
    limits: List[float] = []
    if budget.wall_clock_s is not None:
        limits.append(budget.wall_clock_s / rc.time_s if rc.time_s > 0
                      else float("inf"))
    if budget.wire_bits is not None:
        limits.append(budget.wire_bits / rc.wire_bits if rc.wire_bits > 0
                      else float("inf"))
    if budget.energy_j is not None:
        limits.append(budget.energy_j / rc.energy_j if rc.energy_j > 0
                      else float("inf"))
    lim = min(limits)
    return int(lim) if lim != float("inf") else 10**9


def evaluate_grid(
    budget: Budget,
    cost_model: CostModel,
    *,
    sigma: float,
    f_gap: float,
    grid: Sequence[Tuple[int, int]] = DEFAULT_GRID,
    compressors: Sequence[Optional[Compressor]] = (None,),
    gamma: float = 1.0,
    L: float = 1.0,
    eta: Optional[float] = None,
    availability: Optional[Availability] = None,
) -> List[Plan]:
    """Every feasible candidate as a Plan, in grid order (for tables).

    ``availability``: sporadic-participation rates forwarded to
    ``bounds.predicted_loss_decrement`` — degraded mixing, node-rate-scaled
    descent, and the tau2 = 0 drift credit that ranks outage rounds.

    An overlap-aware cost model (``cost_model.overlap == "pipeline"``)
    prices candidates on BOTH sides of the trade: the round cost uses the
    max-form round time (gossip hidden under compute), and the bound is
    charged the one-round-stale mixing penalty
    (``bounds.stale_mixing_zeta`` at staleness 1) — so the grid search
    weighs hidden wire time against slower mixing instead of getting the
    speedup for free.
    """
    topo = cost_model.topology
    model_dim = max(int(round(cost_model.model_bits / 32.0)), 1)
    staleness = 1.0 if cost_model.overlap == "pipeline" else 0.0
    out: List[Plan] = []
    for comp in compressors:
        for (t1, t2) in grid:
            rc = cost_model.round_cost(t1, t2, comp)
            r = rounds_within(budget, rc)
            if r < 1:
                continue
            T = r * (t1 + t2)
            ev = predicted_loss_decrement(
                t1, t2, topo, sigma, T=T, f_gap=f_gap, L=L, eta=eta,
                compressor=comp, gamma=gamma,
                model_dim=model_dim, availability=availability,
                staleness=staleness)
            out.append(Plan(tau1=t1, tau2=t2, compressor=comp, eta=ev.eta,
                            rounds=r, total_iters=T,
                            predicted_bound=ev.bound, round_cost=rc,
                            bound_eval=ev))
    return out


def select_plan(cands: Sequence[Plan]) -> Plan:
    """The winner among evaluated candidates — THE selection rule.

    Deterministic tie-breaking: lower predicted bound, then cheaper round
    time, then smaller (tau1, tau2) — so equal-bound candidates resolve
    stably across platforms. Callers that already hold an
    ``evaluate_grid`` result (for tables/reports) should select with this
    instead of re-running ``plan``.
    """
    if not cands:
        raise ValueError("no feasible schedule candidates to select from")
    return min(cands, key=lambda p: (p.predicted_bound, p.round_cost.time_s,
                                     p.tau1, p.tau2))


def plan(
    budget: Budget,
    cost_model: CostModel,
    *,
    sigma: float,
    f_gap: float,
    grid: Sequence[Tuple[int, int]] = DEFAULT_GRID,
    compressors: Sequence[Optional[Compressor]] = (None,),
    gamma: float = 1.0,
    L: float = 1.0,
    eta: Optional[float] = None,
    availability: Optional[Availability] = None,
) -> Plan:
    """The best feasible schedule under ``budget`` by predicted bound
    (``evaluate_grid`` then ``select_plan``)."""
    cands = evaluate_grid(
        budget, cost_model, sigma=sigma, f_gap=f_gap, grid=grid,
        compressors=compressors, gamma=gamma, L=L, eta=eta,
        availability=availability)
    if not cands:
        raise ValueError(
            f"no (tau1, tau2) grid point affords even one round in {budget}")
    return select_plan(cands)


# ---------------------------------------------------------------------------
# Per-round trajectories under time-varying costs (schedule as data)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrajectoryPlan:
    """A per-round schedule: ``steps[k]`` is the Plan chosen for round k.

    ``taus`` is the [K, 2] int32 array the fused executor consumes
    directly (``RoundExecutor.dispatch_trajectory``); the totals are the
    planner's PRICED spend over the whole trajectory (the simulated clock
    the episodes were evaluated against).
    """

    steps: Tuple[Plan, ...]
    total_time_s: float
    total_wire_bits: float
    total_energy_j: float

    @property
    def rounds(self) -> int:
        return len(self.steps)

    @property
    def taus(self) -> np.ndarray:
        return np.array([[p.tau1, p.tau2] for p in self.steps],
                        np.int32).reshape(-1, 2)

    @property
    def compressors(self) -> Tuple[Optional[Compressor], ...]:
        return tuple(p.compressor for p in self.steps)

    @property
    def tau_maxima(self) -> Tuple[int, int]:
        """(tau1_max, tau2_max) the executor must be compiled against."""
        if not self.steps:
            return (1, 0)
        return (max(p.tau1 for p in self.steps),
                max(p.tau2 for p in self.steps))


def _remaining(budget: Budget, t: float, bits: float,
               joules: float) -> Optional[Budget]:
    wall = (budget.wall_clock_s - t
            if budget.wall_clock_s is not None else None)
    wbits = (budget.wire_bits - bits
             if budget.wire_bits is not None else None)
    energy = (budget.energy_j - joules
              if budget.energy_j is not None else None)
    if any(rem is not None and rem <= 0.0
           for rem in (wall, wbits, energy)):
        return None
    return Budget(wall_clock_s=wall, wire_bits=wbits, energy_j=energy)


def plan_trajectory(
    budget: Budget,
    process: CostProcess,
    *,
    rounds: int,
    sigma: float,
    f_gap: float,
    grid: Sequence[Tuple[int, int]] = DEFAULT_GRID,
    compressors: Sequence[Optional[Compressor]] = (None,),
    gamma: float = 1.0,
    L: float = 1.0,
    eta: Optional[float] = None,
    availability: Optional[Availability] = None,
    t0: float = 0.0,
) -> TrajectoryPlan:
    """A per-round (tau1, tau2, compressor) trajectory of at most
    ``rounds`` rounds under a time-varying cost process.

    Receding-horizon rule: at round k, with the simulated deployment clock
    at t_k, the round's schedule is ``plan(remaining_budget,
    process.at(t_k))`` — the best fixed schedule if the rest of the run
    cost what this instant costs. Myopic by construction (a known future
    episode does not pre-shift the current round), but it is exactly the
    per-round adaptation of the resource-constrained wireless-DFL setting
    (Yan & Li arXiv:2308.06496): cheap links buy gossip-heavy rounds,
    straggler/fading/outage episodes shift the same budget toward local
    computation, and the clock advance prices each round at the tariff in
    force when it actually runs.

    A TIME-INVARIANT process degenerates EXACTLY to ``plan``: the fixed
    plan's schedule repeated min(plan.rounds, rounds) times (pinned by
    tests/test_planner.py). ``t0`` starts the deployment clock mid-process
    (the adaptive controller re-plans from its measured elapsed time).

    The trajectory ends early when the remaining budget affords no further
    round at the then-current tariff; an infeasible FIRST round raises
    ``ValueError`` like ``plan`` does.
    """
    assert rounds >= 1
    kw = dict(sigma=sigma, f_gap=f_gap, grid=grid, compressors=compressors,
              gamma=gamma, L=L, eta=eta, availability=availability)
    if process.is_static:   # t0 is irrelevant without episodes
        p = plan(budget, process.base, **kw)
        k = min(p.rounds, rounds)
        rc = p.round_cost
        return TrajectoryPlan(
            steps=(p,) * k,
            total_time_s=rc.time_s * k,
            total_wire_bits=rc.wire_bits * k,
            total_energy_j=rc.energy_j * k)
    steps: List[Plan] = []
    clock = float(t0)
    spent_bits = spent_j = 0.0
    remaining: Optional[Budget] = budget
    for _ in range(rounds):
        cm = process.at(clock)
        try:
            p = plan(remaining, cm, **kw)
        except ValueError:
            if not steps:
                raise
            break
        steps.append(p)
        rc = p.round_cost
        clock += rc.time_s
        spent_bits += rc.wire_bits
        spent_j += rc.energy_j
        remaining = _remaining(budget, clock - t0, spent_bits, spent_j)
        if remaining is None:
            break
    return TrajectoryPlan(
        steps=tuple(steps),
        total_time_s=clock - t0,
        total_wire_bits=spent_bits,
        total_energy_j=spent_j)
