"""Resource-constrained schedule planner (the paper's Sec. V trade-off as a
reusable subsystem).

The paper's headline claim — "the convergence rate of DFL can be optimized
to achieve the balance of communication and computing costs under
constrained resources" (abstract) — needs three ingredients, each of which
is a module here:

  * ``planner.cost``     — per-round wall-clock / energy / wire-bit cost
                           models, priced per engine (dense all-gather vs
                           sparse per-neighbor) and per compressor, with an
                           optional wireless per-edge bandwidth/SNR link
                           model (arXiv:2308.06496 spirit).
  * ``planner.bounds``   — Proposition 1 as a library: learning-rate
                           condition (19), bound (20), the C-DFL/CHOCO
                           linear-convergence constants, and
                           ``predicted_loss_decrement`` for planning.
  * ``planner.optimize`` — ``plan(budget, cost_model, ...)``: search the
                           (tau1, tau2, compressor) grid for the schedule
                           minimizing the predicted bound within a budget.
  * ``planner.adaptive`` — a runtime controller that re-fits the cost model
                           from *measured* round timings and re-plans every
                           K rounds (``train.py --plan-budget``).

``benchmarks/theory_check.py`` validates the bounds numerically and
``benchmarks/bench_balance.py`` validates the planner's picks empirically.
"""
from repro.planner.cost import (
    ComputeModel,
    CostModel,
    CostProcess,
    Episode,
    LinkModel,
    RoundCost,
    WirelessLinks,
    comm_compute_cost,
    edge_outage,
    faded_links,
    straggler_links,
    unit_cost_model,
    wireless_link,
)
from repro.planner.bounds import (
    Availability,
    BoundEval,
    bound_20,
    cdfl_contraction,
    choco_gamma_star,
    effective_zeta,
    lr_condition_19,
    max_eta_19,
    predicted_loss_decrement,
    sampling_availability,
    sporadic_zeta,
    stale_mixing_zeta,
)
from repro.planner.optimize import (
    DEFAULT_GRID,
    Budget,
    Plan,
    TrajectoryPlan,
    evaluate_grid,
    plan,
    plan_trajectory,
    rounds_within,
    select_plan,
)
from repro.planner.adaptive import AdaptiveController

__all__ = [
    "ComputeModel", "CostModel", "CostProcess", "Episode", "LinkModel",
    "RoundCost", "WirelessLinks",
    "comm_compute_cost", "edge_outage", "faded_links", "straggler_links",
    "unit_cost_model", "wireless_link",
    "Availability", "BoundEval", "bound_20", "cdfl_contraction",
    "choco_gamma_star", "effective_zeta", "lr_condition_19", "max_eta_19",
    "predicted_loss_decrement", "sampling_availability", "sporadic_zeta",
    "stale_mixing_zeta",
    "DEFAULT_GRID", "Budget", "Plan", "TrajectoryPlan", "evaluate_grid",
    "plan", "plan_trajectory", "rounds_within", "select_plan",
    "AdaptiveController",
]
