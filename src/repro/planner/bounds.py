"""Proposition 1 / Proposition 2 of the paper as a reusable library.

Moved out of ``benchmarks/theory_check.py`` (which now imports from here
and keeps only the quadratic simulation + CLI): the learning-rate condition
(19), the convergence bound (20), and the C-DFL (CHOCO) linear-convergence
constants, plus ``predicted_loss_decrement`` — the bound evaluated the way
the planner consumes it (auto-chosen eta, optional compression-adjusted
mixing).

Notation (paper Sec. II-III, Assumption 1): L-smooth objectives, stochastic
gradient variance sigma^2 measured against the GLOBAL gradient (so sigma
must include non-IID heterogeneity on top of sampling noise — see
``benchmarks/theory_check`` docstring), doubly-stochastic symmetric C with
``zeta = max{|lambda_2|, |lambda_N|} < 1``, rounds of tau1 local steps +
tau2 gossip steps, T total iterations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.compression import Compressor
from repro.core.topology import Topology

__all__ = [
    "lr_condition_19",
    "max_eta_19",
    "bound_20",
    "BoundEval",
    "predicted_loss_decrement",
    "choco_gamma_star",
    "cdfl_contraction",
    "effective_zeta",
    "Availability",
    "expected_mixing",
    "sampling_availability",
    "sporadic_zeta",
    "stale_mixing_zeta",
]


def _condition_19(eta: float, tau1: int, tau2: int, z: float,
                  L: float) -> bool:
    """Condition (19) with the mixing parameter passed as a scalar."""
    tau = tau1 + tau2
    if z >= 1.0 or (tau2 == 0 and z > 0.0):
        # zeta = 1 (disconnected components) never reaches consensus, and
        # tau2 = 0 with imperfect mixing never mixes AT ALL — as a
        # standing schedule both violate Assumption 1.6's premise, so no
        # eta > 0 qualifies. (Per-ROUND tau2 = 0 inside a trajectory is
        # fine; it is the never-gossip *policy* the bound rejects. NB the
        # scalar-z form can't distinguish a single node from a multi-node
        # graph whose zeta rounds to exactly 0.0 — the topology-aware
        # wrappers below guard on num_nodes > 1.)
        return eta <= 0.0
    if z == 0.0:
        lhs = eta * L + eta**2 * L**2 * tau * (tau - 1)
        return lhs <= 1.0
    lhs = eta * L + (eta**2 * L**2 * tau / (1 - z**tau2)) * (
        2 * tau1 * z ** (2 * tau2) / (1 + z**tau2)
        + 2 * tau1 * z**tau2 / (1 - z**tau2)
        + tau - 1)
    return lhs <= 1.0


def lr_condition_19(eta: float, tau1: int, tau2: int, topo: Topology,
                    L: float = 1.0, *, zeta: Optional[float] = None) -> bool:
    """Paper condition (19): eta small enough for bound (20) to hold.

    ``zeta`` overrides the topology's spectral value (used by the planner
    to price compression-degraded mixing, see ``effective_zeta``).
    """
    if tau2 == 0 and topo.num_nodes > 1:
        # never-gossip policy on a multi-node graph: no communication
        # steps at all, whatever the spectrum says (a complete graph's
        # zeta may compute to exactly 0.0 but tau2 = 0 never applies C).
        return eta <= 0.0
    z = topo.zeta if zeta is None else zeta
    return _condition_19(eta, tau1, tau2, z, L)


def max_eta_19(tau1: int, tau2: int, topo: Topology, L: float = 1.0, *,
               zeta: Optional[float] = None) -> float:
    """Largest eta satisfying condition (19), by bisection."""
    if tau2 == 0 and topo.num_nodes > 1:
        return 0.0   # see lr_condition_19: never-gossip admits no eta
    z = topo.zeta if zeta is None else zeta
    lo, hi = 0.0, 1.0 / L
    for _ in range(60):
        mid = (lo + hi) / 2
        if _condition_19(mid, tau1, tau2, z, L):
            lo = mid
        else:
            hi = mid
    return lo


def bound_20(eta: float, tau1: int, tau2: int, topo: Topology, T: int,
             f_gap: float, sigma: float, n: int, L: float = 1.0, *,
             zeta: Optional[float] = None) -> float:
    """Paper bound (20) on E[(1/T) sum_t ||nabla F(u_t)||^2]:

        2 (F(u_1) - F_inf) / (eta T)  +  eta L sigma^2 / n  +  drift,
        drift = 2 eta^2 L^2 sigma^2 (tau1 / (1 - zeta^(2 tau2)) - 1).
    """
    z = topo.zeta if zeta is None else zeta
    if z >= 1.0 or (tau2 == 0 and n > 1):
        # Assumption 1.6 violated, or no communication steps at all on a
        # multi-node graph: no finite bound.
        return float("inf")
    drift = 2 * eta**2 * L**2 * sigma**2 * (tau1 / (1 - z ** (2 * tau2)) - 1
                                            if z > 0 else tau1 - 1)
    return 2 * f_gap / (eta * T) + eta * L * sigma**2 / n + drift


@dataclasses.dataclass(frozen=True)
class Availability:
    """Sporadic-participation rates for planning degraded rounds.

    node_rate / edge_rate: the fraction of nodes doing local updates and
    of edges carrying gossip in a typical round (estimated online by
    ``planner.adaptive.AdaptiveController.observe_participation`` or read
    off a ``repro.faults.FaultPlan``).

    resume_tau2: how many gossip steps a round is EXPECTED to run once
    connectivity returns (>= its long-run average). It is the drift
    credit for pricing a tau2 = 0 outage round: instead of the
    paper-faithful infinite bound (a standing never-gossip schedule),
    the sporadic bound charges the round the drift of a schedule that
    gossips ``resume_tau2`` steps per round — finite, so the planner can
    RANK compute-only candidates by how much drift they bank rather
    than falling through to the tie-break.
    """

    node_rate: float = 1.0
    edge_rate: float = 1.0
    resume_tau2: float = 1.0

    def __post_init__(self):
        for name in ("node_rate", "edge_rate"):
            v = getattr(self, name)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.resume_tau2 < 0.0:
            raise ValueError(
                f"resume_tau2 must be >= 0, got {self.resume_tau2}")

    @property
    def is_full(self) -> bool:
        return self.node_rate >= 1.0 and self.edge_rate >= 1.0


def sampling_availability(population: int, cohort: int, *,
                          resume_tau2: float = 1.0) -> Availability:
    """Price cohort sampling as participation: sampling rate C/V IS the
    participation rate.

    A round that activates a uniformly-drawn C-of-V cohort does local
    work on a C/V fraction of the population and carries gossip on a
    C/V fraction of its (virtual) edges, so the batched engine's sampled
    rounds are planned with the SAME ``Availability`` machinery as
    sporadic participation — ``predicted_loss_decrement(...,
    availability=sampling_availability(V, C))`` engages ``sporadic_zeta``
    exactly as a Bernoulli(C/V) fault plan would. At full participation
    (``cohort == population``) the result ``is_full``, so the bound
    degenerates EXACTLY to the deterministic Proposition-1 evaluation
    (tests/test_planner.py pins the analogous mask degeneration).
    """
    if not (1 <= cohort <= population):
        raise ValueError(
            f"need 1 <= cohort <= population, got cohort={cohort} "
            f"population={population}")
    rate = cohort / population
    return Availability(node_rate=rate, edge_rate=rate,
                        resume_tau2=resume_tau2)


def expected_mixing(topology: Topology, edge_rate: float) -> np.ndarray:
    """E[C_masked] under i.i.d. Bernoulli(edge_rate) edge participation.

    Each off-diagonal weight survives w.p. ``edge_rate``; a masked edge's
    weight folds onto BOTH endpoints' diagonals
    (``core.mixing.masked_mixing_matrix``), so in expectation the
    diagonal absorbs the complementary mass and the matrix stays
    symmetric doubly stochastic.
    """
    if not (0.0 <= edge_rate <= 1.0):
        raise ValueError(f"edge_rate must be in [0, 1], got {edge_rate}")
    cm = np.asarray(topology.mixing, dtype=np.float64)
    off = cm - np.diag(np.diag(cm))
    exp = off * edge_rate
    return exp + np.diag(1.0 - exp.sum(axis=0))


def sporadic_zeta(topology: Topology, edge_rate: float) -> float:
    """zeta of the EXPECTED masked mixing matrix: the planning-grade
    mixing parameter of sporadic gossip (slower mixing as edges drop;
    exact spectral zeta at edge_rate = 1). Heuristic in the same spirit
    as ``effective_zeta`` — E[zeta(C_masked)] >= zeta(E[C_masked]) by
    convexity, so this flatters mixing slightly; it ranks schedules, it
    does not certify them.
    """
    if topology.num_nodes <= 1:
        return 0.0
    from repro.core.topology import zeta as spectral_zeta
    return float(min(1.0, spectral_zeta(expected_mixing(topology,
                                                        edge_rate))))


def stale_mixing_zeta(topology: Topology, staleness: float) -> float:
    """Planning-grade mixing parameter of S-round-STALE gossip.

    The pipelined executor (``RoundExecutor(overlap="pipeline")``) folds
    round k's gossip exchange into the parameters one round late: each
    mixing application contracts consensus error measured against state
    that is ``staleness`` rounds old (here always 1). The delayed-gossip
    analyses (DSpodFL arXiv:2402.03448; DFedAvg-style arXiv:2104.11375)
    show the effect is a DILUTED mixing operator: over 1 + S rounds only
    one round's worth of fresh contraction lands, i.e. the time-average
    mixing matrix is the expected masked matrix with participation rate
    1 / (1 + S). We therefore price staleness with the machinery already
    trusted for sporadic gossip:

        stale_mixing_zeta(G, S) = sporadic_zeta(G, edge_rate=1/(1+S))

    Exact at S = 0 (every edge fresh: edge_rate 1 recovers the spectral
    zeta); monotonically worse as S grows. Like ``sporadic_zeta`` this
    ranks schedules rather than certifying them.
    """
    if staleness < 0.0:
        raise ValueError(f"staleness must be >= 0, got {staleness}")
    return sporadic_zeta(topology, 1.0 / (1.0 + staleness))


@dataclasses.dataclass(frozen=True)
class BoundEval:
    """One evaluation of the planning objective: the value, its eta, and
    the three terms (optimization / statistical / local-drift)."""

    bound: float
    eta: float
    opt_term: float
    stat_term: float
    drift_term: float
    zeta: float


def predicted_loss_decrement(
    tau1: int,
    tau2: int,
    topology: Topology,
    sigma: float,
    *,
    T: int,
    f_gap: float,
    n: Optional[int] = None,
    L: float = 1.0,
    eta: Optional[float] = None,
    compressor: Optional[Compressor] = None,
    gamma: float = 1.0,
    model_dim: int = 1024,
    availability: Optional[Availability] = None,
    staleness: float = 0.0,
) -> BoundEval:
    """The planner's objective: bound (20) sharpened for prediction.

    Two deliberate departures from the paper-faithful certificate
    ``bound_20`` (which stays available, and is what
    ``benchmarks/theory_check`` verifies):

      * the optimization term counts DESCENT iterations only
        (T * tau1 / (tau1 + tau2)): bound (20)'s 1/(eta T) with total T
        credits gossip iterations with gradient progress, which makes
        comm-heavy schedules look free and mis-ranks them against
        measurement (validated on the quadratic testbed in
        tests/test_planner.py);
      * with ``eta=None`` the learning rate is chosen to MINIMIZE the
        objective over (0, max_eta_19] (log grid) — the paper's
        "convergence rate ... can be optimized" applies to eta too, and
        each grid candidate is compared at its own best rate.

    With a ``compressor`` the mixing parameter is degraded to
    ``effective_zeta`` (CHOCO gossip mixes slower per step; Prop. 2's
    mechanism) — a planning heuristic rather than a proved bound.

    With an ``availability`` (the sporadic-participation regime) three
    further planning-grade adjustments apply, all degenerating to the
    exact formulas at full participation:

      * mixing degrades to ``sporadic_zeta`` (the zeta of the expected
        masked mixing matrix) — never better than the exact zeta;
      * descent iterations and the variance-averaging population scale
        by ``node_rate`` (only participating nodes step / contribute);
      * a tau2 = 0 round is charged the drift of a schedule gossiping
        ``resume_tau2`` steps per round instead of going infinite, so
        outage rounds are RANKED by drift credit (see ``Availability``).

    ``staleness`` > 0 prices the pipelined executor's one-round-stale
    mixing (``overlap="pipeline"`` folds gossip in one round late):
    mixing degrades to ``stale_mixing_zeta`` — never better than the
    fresh zeta, exact at staleness 0. The planner sets it from the cost
    model's overlap mode so the overlap-aware round-time win is weighed
    against its convergence penalty on the same grid.
    """
    n = topology.num_nodes if n is None else n
    if compressor is None:
        z = effective_zeta(topology)
    else:
        z = effective_zeta(topology, delta=compressor.delta(model_dim),
                           gamma=gamma)
    if staleness > 0.0 and n > 1:
        z = float(min(1.0 - 1e-12,
                      max(z, stale_mixing_zeta(topology, staleness))))
    avail = availability
    if avail is not None and avail.is_full:
        avail = None
    if avail is not None and avail.edge_rate < 1.0 and n > 1:
        z = float(min(1.0 - 1e-12,
                      max(z, sporadic_zeta(topology, avail.edge_rate))))
    node_rate = 1.0 if avail is None else max(avail.node_rate, 1.0 / n)
    tau2_eff: float = float(tau2)
    if tau2 == 0 and avail is not None and avail.resume_tau2 > 0.0:
        tau2_eff = float(avail.resume_tau2)
    t_descent = T * tau1 / (tau1 + tau2) * node_rate
    if T <= 0 or t_descent <= 0 or z >= 1.0 or (tau2_eff == 0 and n > 1):
        # tau2 = 0 on a non-complete graph: a standing never-gossip
        # schedule has unbounded drift. Without an availability's drift
        # credit it stays a valid LAST-RESORT grid point for per-round
        # trajectory planning (an outage round that only computes): with
        # every bound infinite, ``select_plan``'s deterministic tie-break
        # (round time, then taus) chooses among the compute-only
        # candidates.
        return BoundEval(bound=float("inf"), eta=float(eta or 0.0),
                         opt_term=float("inf"), stat_term=0.0,
                         drift_term=0.0, zeta=z)
    n_eff = n * node_rate
    drift_coeff = 2 * L**2 * sigma**2 * (
        tau1 / (1 - z ** (2 * tau2_eff)) - 1 if z > 0 else tau1 - 1)

    def terms(e: float):
        return (2 * f_gap / (e * t_descent), e * L * sigma**2 / n_eff,
                e**2 * drift_coeff)

    if eta is None:
        emax = max_eta_19(tau1, tau2 if tau2 > 0 else tau2_eff, topology,
                          L, zeta=z)
        cands = emax * np.logspace(-3.0, 0.0, 64)
        eta = float(min(cands, key=lambda e: sum(terms(e))))
    elif eta <= 0.0:
        return BoundEval(bound=float("inf"), eta=float(eta),
                         opt_term=float("inf"), stat_term=0.0,
                         drift_term=0.0, zeta=z)
    opt, stat, drift = terms(float(eta))
    return BoundEval(bound=opt + stat + drift, eta=float(eta), opt_term=opt,
                     stat_term=stat, drift_term=drift, zeta=z)


# ---------------------------------------------------------------------------
# C-DFL (Proposition 2 / CHOCO) linear-convergence constants
# ---------------------------------------------------------------------------


def choco_gamma_star(topology: Topology, delta: float) -> float:
    """The CHOCO-Gossip consensus step size gamma* the C-DFL linear rate
    (Prop. 2) is stated with (Koloskova et al. 2019, Lemma A.3):

        gamma* = rho^2 delta / (16 rho + rho^2 + 4 beta^2
                                + 2 rho beta^2 - 8 rho delta)

    with rho = 1 - zeta the spectral gap, beta = ||I - C||_2, and delta the
    compression ratio of Assumption 2.
    """
    rho = topology.spectral_gap
    b = topology.beta
    denom = 16 * rho + rho**2 + 4 * b**2 + 2 * rho * b**2 - 8 * rho * delta
    if denom <= 0.0:
        return 1.0
    return rho**2 * delta / denom


def cdfl_contraction(topology: Topology, delta: float,
                     gamma: Optional[float] = None) -> float:
    """Per-gossip-step consensus contraction factor under CHOCO-G.

    At gamma = gamma* the CHOCO analysis contracts the consensus error by
    (1 - rho^2 delta / 16) per step — the constant behind C-DFL's linear
    convergence for strongly convex objectives (Prop. 2). For a smaller
    gamma the contraction degrades proportionally; tau2 steps contract by
    this factor to the tau2-th power.
    """
    rho = topology.spectral_gap
    full = rho**2 * delta / 16.0
    if gamma is None:
        return max(0.0, min(1.0, 1.0 - full))
    gstar = choco_gamma_star(topology, delta)
    frac = min(1.0, gamma / gstar) if gstar > 0 else 1.0
    return max(0.0, min(1.0, 1.0 - frac * full))


def effective_zeta(topology: Topology, delta: float = 1.0,
                   gamma: Optional[float] = None) -> float:
    """Mixing parameter to plug into the Prop-1 formulas for a schedule.

    Uncompressed gossip (delta = 1, gamma unset) mixes with the exact
    spectral zeta. CHOCO-compressed gossip contracts the consensus
    *squared* error by ``cdfl_contraction`` per step, so the per-step
    amplitude factor is its square root — never better than the exact zeta
    (compression cannot speed mixing up). A planning-grade bridge between
    Prop. 1 and Prop. 2, not a proved bound.
    """
    z = topology.zeta
    if delta >= 1.0 and gamma is None:
        return z
    c = cdfl_contraction(topology, delta, gamma)
    return float(min(1.0 - 1e-12, max(z, np.sqrt(c))))
