"""GQA attention: chunked (flash-style) training/prefill path + KV-cache
decode path, with sliding windows, qk-norm, RoPE and cross-attention.

The chunked path never materializes the full [S, T] score matrix: it scans
query chunks (optionally ``jax.checkpoint``ed so the backward pass recomputes
tiles — flash-attention's memory behaviour, expressed in pure jnp so the
same code serves CPU tests and the TPU dry-run).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    Annotated,
    LayerSpec,
    ModelConfig,
    ParamFactory,
    rms_norm,
    rope,
    softcap,
)

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attn_params(f: ParamFactory, cfg: ModelConfig, cross: bool = False) -> Dict:
    h_ax = "heads" if cfg.attn_shard == "heads" else None
    kv_ax = "kv_heads" if cfg.attn_shard == "heads" else None
    hd_ax = "head_dim" if cfg.attn_shard == "head_dim" else None
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": f.dense((d, h, hd), ("embed", h_ax, hd_ax)),
        "wk": f.dense((d, kvh, hd), ("embed", kv_ax, hd_ax)),
        "wv": f.dense((d, kvh, hd), ("embed", kv_ax, hd_ax)),
        "wo": f.dense((h, hd, d), (h_ax, hd_ax, "embed")),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = f.zeros((hd,), (None,))
        p["k_norm"] = f.zeros((hd,), (None,))
    return p


# ---------------------------------------------------------------------------
# Chunked attention core
# ---------------------------------------------------------------------------


def _pick_chunk(total: int, want: int) -> int:
    """Largest divisor of ``total`` that is <= want (>=1)."""
    c = min(want, total)
    while total % c:
        c -= 1
    return c


def chunked_attention(
    q: jnp.ndarray,                 # [B, S, H, hd]
    k: jnp.ndarray,                 # [B, T, KVH, hd]
    v: jnp.ndarray,                 # [B, T, KVH, hd]
    *,
    q_positions: jnp.ndarray,       # [S] absolute positions of queries
    kv_positions: jnp.ndarray,      # [T] absolute positions of keys (-1 = empty)
    causal: bool = True,
    window: int = 0,
    cap: float = 0.0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    checkpoint: bool = False,
) -> jnp.ndarray:
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qc = _pick_chunk(s, q_chunk)
    kc = _pick_chunk(t, kv_chunk)
    nq, nk = s // qc, t // kc
    scale = hd ** -0.5

    if nq == 1 and nk == 1:
        # single-block path (decode / short prefill): no chunk reshapes —
        # keeps a sharded KV sequence dim intact (GSPMD reduces the softmax
        # over the sharded axis instead of resharding dynamic slices).
        qr1 = q.reshape(b, s, kvh, g, hd)
        s_ = jnp.einsum("bqngd,bknd->bngqk", qr1, k,
                        preferred_element_type=jnp.float32) * scale
        s_ = softcap(s_, cap)
        valid = kv_positions[None, :] >= 0
        if causal:
            valid = valid & (kv_positions[None, :] <= q_positions[:, None])
        if window > 0:
            valid = valid & (kv_positions[None, :] >
                             q_positions[:, None] - window)
        s_ = jnp.where(valid[None, None, None], s_, NEG_INF)
        m = jnp.max(s_, axis=-1, keepdims=True)
        p = jnp.exp(s_ - m)
        p = jnp.where(valid[None, None, None], p, 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        out1 = jnp.einsum("bngqk,bknd->bngqd", p, v,
                          preferred_element_type=jnp.float32)
        out1 = out1 / jnp.maximum(l, 1e-20)     # l: [b,n,g,q,1]
        return (out1.transpose(0, 3, 1, 2, 4)
                .reshape(b, s, h, hd).astype(q.dtype))

    qr = q.reshape(b, nq, qc, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(b, nk, kc, kvh, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kc, kvh, hd).transpose(1, 0, 2, 3, 4)
    qp = q_positions.reshape(nq, qc)
    kp = kv_positions.reshape(nk, kc)

    def q_block(qblk, qpos):
        # qblk [B, qc, KVH, G, hd]; qpos [qc]
        m0 = jnp.full((b, kvh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qc, hd), jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            kblk, vblk, kpos = inp  # [B,kc,KVH,hd], [B,kc,KVH,hd], [kc]
            s_ = jnp.einsum(
                "bqngd,bknd->bngqk", qblk, kblk,
                preferred_element_type=jnp.float32,
            ) * scale
            s_ = softcap(s_, cap)
            valid = kpos[None, :] >= 0
            if causal:
                valid = valid & (kpos[None, :] <= qpos[:, None])
            if window > 0:
                valid = valid & (kpos[None, :] > qpos[:, None] - window)
            s_ = jnp.where(valid[None, None, None], s_, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            p = jnp.where(valid[None, None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bngqk,bknd->bngqd", p, vblk,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kr, vr, kp))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        # [B,KVH,G,qc,hd] -> [B,qc,KVH*G,hd]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, qc, h, hd)

    if checkpoint:
        q_block = jax.checkpoint(q_block)

    out = jax.lax.map(lambda args: q_block(*args), (qr, qp))
    # [nq, B, qc, H, hd] -> [B, S, H, hd]
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Self-attention layer (train/prefill + decode)
# ---------------------------------------------------------------------------


def _project_q(p, x, cfg: ModelConfig, positions, theta):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
    return rope(q, positions[None, :], theta)


def _project_kv(p, x, cfg: ModelConfig, positions, theta):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "k_norm" in p:
        k = rms_norm(k, p["k_norm"])
    k = rope(k, positions[None, :], theta)
    return k, v


def self_attention(
    p: Dict,
    x: jnp.ndarray,                 # [B, S, D]
    cfg: ModelConfig,
    spec: LayerSpec,
    *,
    positions: jnp.ndarray,         # [S]
    checkpoint: bool = False,
    causal: bool = True,
) -> jnp.ndarray:
    theta = spec.rope_theta or cfg.rope_theta
    q = _project_q(p, x, cfg, positions, theta)
    k, v = _project_kv(p, x, cfg, positions, theta)
    out = chunked_attention(
        q, k, v,
        q_positions=positions,
        kv_positions=positions,
        causal=causal,
        window=spec.window,
        cap=cfg.logit_softcap,
        q_chunk=cfg.attn_q_chunk,
        kv_chunk=cfg.attn_kv_chunk,
        checkpoint=checkpoint,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def init_kv_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                  max_len: int, abstract: bool = False) -> Dict:
    size = min(spec.window, max_len) if spec.window else max_len
    shape_kv = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    if abstract:
        return {
            "k": jax.ShapeDtypeStruct(shape_kv, cfg.dtype),
            "v": jax.ShapeDtypeStruct(shape_kv, cfg.dtype),
            "pos": jax.ShapeDtypeStruct((size,), jnp.int32),
        }
    return {
        "k": jnp.zeros(shape_kv, cfg.dtype),
        "v": jnp.zeros(shape_kv, cfg.dtype),
        "pos": jnp.full((size,), -1, jnp.int32),
    }


def prefill_attention(
    p: Dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    spec: LayerSpec,
    cache: Dict,
    *,
    positions: jnp.ndarray,
) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence attention that also populates the KV cache."""
    theta = spec.rope_theta or cfg.rope_theta
    q = _project_q(p, x, cfg, positions, theta)
    k, v = _project_kv(p, x, cfg, positions, theta)
    out = chunked_attention(
        q, k, v,
        q_positions=positions,
        kv_positions=positions,
        causal=True,
        window=spec.window,
        cap=cfg.logit_softcap,
        q_chunk=cfg.attn_q_chunk,
        kv_chunk=cfg.attn_kv_chunk,
    )
    size = cache["k"].shape[1]
    s = k.shape[1]
    if size >= s:
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
            "pos": jax.lax.dynamic_update_slice(cache["pos"], positions, (0,)),
        }
    else:
        # sliding-window ring buffer: slot(p) = p % size, matching decode.
        shift = (s - size) % size
        new_cache = {
            "k": jnp.roll(k[:, s - size:], shift, axis=1),
            "v": jnp.roll(v[:, s - size:], shift, axis=1),
            "pos": jnp.roll(positions[s - size:], shift, axis=0),
        }
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return proj, new_cache


def decode_attention(
    p: Dict,
    x: jnp.ndarray,                 # [B, 1, D]
    cfg: ModelConfig,
    spec: LayerSpec,
    cache: Dict,
    *,
    position: jnp.ndarray,          # scalar int32 current position
) -> Tuple[jnp.ndarray, Dict]:
    theta = spec.rope_theta or cfg.rope_theta
    pos_arr = position[None]
    q = _project_q(p, x, cfg, pos_arr, theta)
    k_new, v_new = _project_kv(p, x, cfg, pos_arr, theta)
    size = cache["k"].shape[1]
    slot = position % size if spec.window else position
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0)),
        "pos": jax.lax.dynamic_update_slice(cache["pos"], pos_arr, (slot,)),
    }
    out = chunked_attention(
        q, cache["k"], cache["v"],
        q_positions=pos_arr,
        kv_positions=cache["pos"],
        causal=True,
        window=spec.window,
        cap=cfg.logit_softcap,
        q_chunk=1,
        kv_chunk=cache["k"].shape[1] if cfg.decode_unchunked
        else cfg.attn_kv_chunk,
    )
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return proj, cache


# ---------------------------------------------------------------------------
# Cross-attention (VLM image layers / enc-dec decoder)
# ---------------------------------------------------------------------------


def cross_attention(
    p: Dict,
    x: jnp.ndarray,                 # [B, S, D]
    memory: jnp.ndarray,            # [B, M, D]
    cfg: ModelConfig,
    *,
    checkpoint: bool = False,
) -> jnp.ndarray:
    """No RoPE on cross-attention (memory has its own geometry)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bmd,dhk->bmhk", memory, p["wk"].astype(memory.dtype))
    v = jnp.einsum("bmd,dhk->bmhk", memory, p["wv"].astype(memory.dtype))
    m = memory.shape[1]
    out = chunked_attention(
        q, k, v,
        q_positions=jnp.zeros((x.shape[1],), jnp.int32),
        kv_positions=jnp.zeros((m,), jnp.int32),
        causal=False,
        window=0,
        cap=cfg.logit_softcap,
        q_chunk=cfg.attn_q_chunk,
        kv_chunk=cfg.attn_kv_chunk,
        checkpoint=checkpoint,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
