"""Model zoo: one generic stack, six architecture families."""
from repro.models.common import (
    Annotated,
    LayerSpec,
    ModelConfig,
    ParamFactory,
    pad_vocab,
    rms_norm,
    rope,
    split_annotations,
    swiglu,
)
from repro.models.transformer import (
    DecodeState,
    decode_step,
    forward,
    init_decode_state,
    init_params,
    prefill,
    train_loss,
)

__all__ = [
    "Annotated", "LayerSpec", "ModelConfig", "ParamFactory", "pad_vocab",
    "rms_norm", "rope", "split_annotations", "swiglu",
    "DecodeState", "decode_step", "forward", "init_decode_state",
    "init_params", "prefill", "train_loss",
]
