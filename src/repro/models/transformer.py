"""The generic decoder / encoder-decoder stack over LayerSpec patterns.

One code path serves all 10 assigned architectures: the per-arch config
chooses the repeating ``pattern`` of layers (attn/mamba mixer, mlp/moe FFN,
sliding windows, cross-attention) and the stack scans over pattern periods
with stacked parameters (``lax.scan`` keeps HLO size independent of depth —
a 100-layer model compiles as fast as a 2-layer one).

Entry points:
  init_params(cfg, key)                      -> (params, logical_axes)
  train_loss(params, batch, cfg, rng)        -> scalar loss (+aux)
  prefill(params, batch, cfg)                -> (last_logits, DecodeState)
  decode_step(params, state, tokens, cfg)    -> (logits, DecodeState)
  init_decode_state(cfg, batch, max_len)     -> DecodeState (zeros/abstract)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.policy import shard_hidden
from repro.models.common import (
    LayerSpec,
    ModelConfig,
    ParamFactory,
    pad_vocab,
    rms_norm,
    split_annotations,
    swiglu,
)

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _mlp_params(f: ParamFactory, cfg: ModelConfig) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "w_gate": f.dense((d, ff), ("embed", "mlp")),
        "w_up": f.dense((d, ff), ("embed", "mlp")),
        "w_down": f.dense((ff, d), ("mlp", "embed")),
    }


def _layer_params(f: ParamFactory, cfg: ModelConfig, spec: LayerSpec) -> Dict:
    p: Dict[str, Any] = {"ln1": f.zeros((cfg.d_model,), ("embed",))}
    if spec.mixer == "attn":
        p["mixer"] = attn_lib.attn_params(f, cfg)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_lib.mamba_params(f, cfg)
    else:
        raise ValueError(f"unknown mixer {spec.mixer!r}")
    if spec.cross_attn:
        p["ln_cross"] = f.zeros((cfg.d_model,), ("embed",))
        p["cross"] = attn_lib.attn_params(f, cfg, cross=True)
    if spec.ffn == "mlp":
        p["ln2"] = f.zeros((cfg.d_model,), ("embed",))
        p["ffn"] = _mlp_params(f, cfg)
    elif spec.ffn == "moe":
        p["ln2"] = f.zeros((cfg.d_model,), ("embed",))
        p["ffn"] = moe_lib.moe_params(f, cfg)
    elif spec.ffn != "none":
        raise ValueError(f"unknown ffn {spec.ffn!r}")
    return p


def _stack(trees: List[PyTree]) -> PyTree:
    """Stack a list of identical-structure param trees along a new axis 0,
    prepending the 'layers' logical axis to each Annotated leaf."""
    from repro.models.common import Annotated

    is_ann = lambda x: isinstance(x, Annotated)

    def stack_leaf(*leaves):
        vals = [l.value for l in leaves]
        axes = ("layers",) + leaves[0].axes
        if isinstance(vals[0], jax.ShapeDtypeStruct):
            v = jax.ShapeDtypeStruct((len(vals),) + vals[0].shape, vals[0].dtype)
        else:
            v = jnp.stack(vals)
        return Annotated(v, axes)

    return jax.tree_util.tree_map(stack_leaf, *trees, is_leaf=is_ann)


def init_params(cfg: ModelConfig, key: jax.Array, abstract: bool = False):
    """Returns (params, logical_axes) trees."""
    f = ParamFactory(key, cfg.dtype, abstract=abstract)
    v = pad_vocab(cfg.vocab_size)
    tree: Dict[str, Any] = {
        "embed": f.dense((v, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "final_norm": f.zeros((cfg.d_model,), ("embed",)),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = f.dense((cfg.d_model, v), ("embed", "vocab"))

    if cfg.has_memory_input:
        mem_dim = cfg.memory_dim or cfg.d_model
        tree["mem_proj"] = f.dense((mem_dim, cfg.d_model), (None, "embed"))

    if cfg.is_enc_dec:
        enc_spec = LayerSpec(mixer="attn", ffn="mlp")
        assert cfg.encoder_layers >= 1
        tree["encoder"] = _stack(
            [_layer_params(f, cfg, enc_spec) for _ in range(cfg.encoder_layers)]
        )
        tree["encoder_norm"] = f.zeros((cfg.d_model,), ("embed",))

    period_blocks = []
    for spec in cfg.pattern:
        period_blocks.append(_layer_params(f, cfg, spec))
    # one stacked tree per position in the period; stacked over num_periods.
    stacked = []
    for pos, spec in enumerate(cfg.pattern):
        copies = [period_blocks[pos]] + [
            _layer_params(f, cfg, spec) for _ in range(cfg.num_periods - 1)
        ]
        stacked.append(_stack(copies))
    tree["blocks"] = stacked

    return split_annotations(tree)


# ---------------------------------------------------------------------------
# Forward (training / evaluation)
# ---------------------------------------------------------------------------


def _encode_memory(params: Dict, memory: jnp.ndarray, cfg: ModelConfig,
                   checkpoint: bool) -> jnp.ndarray:
    """VLM: project frontend embeddings. Audio enc-dec: project then run the
    bidirectional encoder stack."""
    mem = jnp.einsum(
        "bmd,de->bme", memory.astype(cfg.dtype), params["mem_proj"].astype(cfg.dtype)
    )
    if not cfg.is_enc_dec:
        return mem
    positions = jnp.arange(mem.shape[1], dtype=jnp.int32)
    enc_spec = LayerSpec(mixer="attn", ffn="mlp")

    def enc_layer(h, layer_p):
        h = h + attn_lib.self_attention(
            layer_p["mixer"], rms_norm(h, layer_p["ln1"]), cfg, enc_spec,
            positions=positions, checkpoint=checkpoint, causal=False)
        h = h + swiglu(rms_norm(h, layer_p["ln2"]), layer_p["ffn"]["w_gate"],
                       layer_p["ffn"]["w_up"], layer_p["ffn"]["w_down"])
        return shard_hidden(h), None

    body = jax.checkpoint(enc_layer) if checkpoint else enc_layer
    mem, _ = jax.lax.scan(body, shard_hidden(mem), params["encoder"])
    return rms_norm(mem, params["encoder_norm"])


def _apply_layer(
    layer_p: Dict,
    spec: LayerSpec,
    h: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    memory: Optional[jnp.ndarray],
    checkpoint: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    x = rms_norm(h, layer_p["ln1"])
    if spec.mixer == "attn":
        mixed = attn_lib.self_attention(
            layer_p["mixer"], x, cfg, spec, positions=positions,
            checkpoint=checkpoint)
    else:
        mixed = mamba_lib.mamba_mixer(layer_p["mixer"], x, cfg,
                                      checkpoint=checkpoint)
    h = h + mixed
    if spec.cross_attn:
        assert memory is not None, f"{cfg.name}: cross-attn layer needs memory"
        xc = rms_norm(h, layer_p["ln_cross"])
        h = h + attn_lib.cross_attention(layer_p["cross"], xc, memory, cfg,
                                         checkpoint=checkpoint)
    if spec.ffn == "mlp":
        x2 = rms_norm(h, layer_p["ln2"])
        h = h + swiglu(x2, layer_p["ffn"]["w_gate"], layer_p["ffn"]["w_up"],
                       layer_p["ffn"]["w_down"])
    elif spec.ffn == "moe":
        x2 = rms_norm(h, layer_p["ln2"])
        out, aux_l = moe_lib.moe_ffn(layer_p["ffn"], x2, cfg)
        h = h + out
        aux = aux + aux_l
    return h, aux


def forward(
    params: Dict,
    tokens: jnp.ndarray,            # [B, S]
    cfg: ModelConfig,
    *,
    memory: Optional[jnp.ndarray] = None,  # [B, M, mem_dim]
    checkpoint: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (hidden [B,S,D], moe_aux scalar)."""
    checkpoint = cfg.remat if checkpoint is None else checkpoint
    h = params["embed"].astype(cfg.dtype)[tokens]
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    mem = None
    if cfg.has_memory_input:
        assert memory is not None, f"{cfg.name} requires memory input"
        mem = _encode_memory(params, memory, cfg, checkpoint)

    def period_body(carry, period_params):
        h, aux = carry
        for pos, spec in enumerate(cfg.pattern):
            h, aux_l = _apply_layer(period_params[pos], spec, h, cfg,
                                    positions, mem, checkpoint)
            aux = aux + aux_l
        return (shard_hidden(h), aux), None

    body = jax.checkpoint(period_body) if checkpoint else period_body
    (h, aux), _ = jax.lax.scan(
        body, (shard_hidden(h), jnp.zeros((), jnp.float32)),
        tuple(params["blocks"])
    )
    h = rms_norm(h, params["final_norm"])
    return h, aux


def _unembed(params: Dict, h: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["embed"].astype(h.dtype)  # [V, D]
        return jnp.einsum("...d,vd->...v", h, w)
    return jnp.einsum("...d,dv->...v", h, params["lm_head"].astype(h.dtype))


def train_loss(
    params: Dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    rng: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """Next-token cross-entropy, chunked over the sequence so the full
    [B,S,V] logit tensor never materializes."""
    del rng
    tokens = batch["tokens"]
    labels = batch["labels"]
    h, aux = forward(params, tokens, cfg, memory=batch.get("memory"))
    h = shard_hidden(h)
    b, s, d = h.shape
    v = pad_vocab(cfg.vocab_size)
    chunk = cfg.loss_seq_chunk
    while s % chunk:
        chunk -= 1
    n = s // chunk
    hc = h.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        hblk, lblk = inp
        hblk = shard_hidden(hblk)
        logits = _unembed(params, hblk, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lblk[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    body = jax.checkpoint(chunk_loss) if cfg.remat else chunk_loss
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    loss = total / (b * s)
    return loss + cfg.router_aux_coef * aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    caches: Tuple[PyTree, ...]      # per period position, stacked over periods
    memory: Optional[jnp.ndarray]   # encoder output / projected patches
    position: jnp.ndarray           # scalar int32: next position to write


def init_decode_state(
    cfg: ModelConfig, batch: int, max_len: int, abstract: bool = False
) -> DecodeState:
    caches = []
    for spec in cfg.pattern:
        if spec.mixer == "attn":
            one = attn_lib.init_kv_cache(cfg, spec, batch, max_len, abstract)
        else:
            one = mamba_lib.init_mamba_state(cfg, batch, abstract)

        def stack_leaf(x):
            if abstract:
                return jax.ShapeDtypeStruct((cfg.num_periods,) + x.shape, x.dtype)
            return jnp.broadcast_to(x[None], (cfg.num_periods,) + x.shape)

        caches.append(jax.tree_util.tree_map(stack_leaf, one))
    mem = None
    if cfg.has_memory_input:
        m = cfg.memory_tokens or 256
        shape = (batch, m, cfg.d_model)
        mem = (jax.ShapeDtypeStruct(shape, cfg.dtype) if abstract
               else jnp.zeros(shape, cfg.dtype))
    pos = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
           else jnp.zeros((), jnp.int32))
    return DecodeState(caches=tuple(caches), memory=mem, position=pos)


def prefill(
    params: Dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    max_len: int,
) -> Tuple[jnp.ndarray, DecodeState]:
    """Process the prompt; returns (logits of last token [B,V], state)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    h = params["embed"].astype(cfg.dtype)[tokens]
    mem = None
    if cfg.has_memory_input:
        mem = _encode_memory(params, batch["memory"], cfg, checkpoint=False)

    # Scan periods; within a period iterate positions (python loop).
    def scan_body(h, period_params):
        caches_out = []
        for pos_idx, spec in enumerate(cfg.pattern):
            layer_p = period_params[pos_idx]
            x = rms_norm(h, layer_p["ln1"])
            if spec.mixer == "attn":
                cache0 = attn_lib.init_kv_cache(cfg, spec, b, max_len)
                mixed, cache = attn_lib.prefill_attention(
                    layer_p["mixer"], x, cfg, spec, cache0, positions=positions)
            else:
                mixed, cache = mamba_lib.mamba_mixer(
                    layer_p["mixer"], x, cfg, return_state=True)
            h = h + mixed
            if spec.cross_attn:
                xc = rms_norm(h, layer_p["ln_cross"])
                h = h + attn_lib.cross_attention(layer_p["cross"], xc, mem, cfg)
            if spec.ffn in ("mlp", "moe"):
                x2 = rms_norm(h, layer_p["ln2"])
                if spec.ffn == "mlp":
                    h = h + swiglu(x2, layer_p["ffn"]["w_gate"],
                                   layer_p["ffn"]["w_up"], layer_p["ffn"]["w_down"])
                else:
                    out, _ = moe_lib.moe_ffn(layer_p["ffn"], x2, cfg)
                    h = h + out
            caches_out.append(cache)
        return shard_hidden(h), tuple(caches_out)

    h, caches = jax.lax.scan(scan_body, shard_hidden(h),
                             tuple(params["blocks"]))
    h = rms_norm(h, params["final_norm"])
    last_logits = _unembed(params, h[:, -1], cfg)
    state = DecodeState(
        caches=caches, memory=mem,
        position=jnp.asarray(s, jnp.int32))
    return last_logits, state


def decode_step(
    params: Dict,
    state: DecodeState,
    tokens: jnp.ndarray,            # [B, 1]
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, DecodeState]:
    """One-token decode against the KV cache / SSM state."""
    h = params["embed"].astype(cfg.dtype)[tokens]
    position = state.position
    mem = state.memory

    def scan_body(h, inp):
        period_params, caches = inp
        caches_out = []
        for pos_idx, spec in enumerate(cfg.pattern):
            layer_p = period_params[pos_idx]
            cache = caches[pos_idx]
            x = rms_norm(h, layer_p["ln1"])
            if spec.mixer == "attn":
                mixed, cache = attn_lib.decode_attention(
                    layer_p["mixer"], x, cfg, spec, cache, position=position)
            else:
                mixed, cache = mamba_lib.mamba_decode(layer_p["mixer"], x, cfg, cache)
            h = h + mixed
            if spec.cross_attn:
                xc = rms_norm(h, layer_p["ln_cross"])
                h = h + attn_lib.cross_attention(layer_p["cross"], xc, mem, cfg)
            if spec.ffn in ("mlp", "moe"):
                x2 = rms_norm(h, layer_p["ln2"])
                if spec.ffn == "mlp":
                    h = h + swiglu(x2, layer_p["ffn"]["w_gate"],
                                   layer_p["ffn"]["w_up"], layer_p["ffn"]["w_down"])
                else:
                    out, _ = moe_lib.moe_ffn(layer_p["ffn"], x2, cfg)
                    h = h + out
            caches_out.append(cache)
        return h, tuple(caches_out)

    h, new_caches = jax.lax.scan(
        scan_body, h, (tuple(params["blocks"]), state.caches))
    h = rms_norm(h, params["final_norm"])
    logits = _unembed(params, h[:, -1], cfg)
    new_state = DecodeState(
        caches=new_caches, memory=mem, position=position + 1)
    return logits, new_state
