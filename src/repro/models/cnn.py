"""The paper's CNNs (Appendix C, Table II) in pure JAX.

MNIST CNN : conv[1,16,3x3](same) -> ReLU -> maxpool 2x2
            conv[16,32,3x3](same) -> ReLU -> maxpool 2x2
            dense[32*7*7, 10]
CIFAR CNN : conv[3,64,5x5](valid) -> ReLU -> maxpool 3x3/2
            conv[64,64,5x5](valid) -> ReLU -> maxpool 3x3/2
            dense[64*4*4,384] -> ReLU -> dense[384,192] -> ReLU -> dense[192,10]
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _conv_init(key, kh, kw, cin, cout):
    std = 1.0 / math.sqrt(kh * kw * cin)
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def _dense_init(key, fin, fout):
    std = 1.0 / math.sqrt(fin)
    return jax.random.normal(key, (fin, fout), jnp.float32) * std


def init_cnn(key: jax.Array, flavor: str = "mnist") -> PyTree:
    ks = jax.random.split(key, 8)
    if flavor == "mnist":
        return {
            "c1": _conv_init(ks[0], 3, 3, 1, 16), "b1": jnp.zeros((16,)),
            "c2": _conv_init(ks[1], 3, 3, 16, 32), "b2": jnp.zeros((32,)),
            "d1": _dense_init(ks[2], 32 * 7 * 7, 10), "db1": jnp.zeros((10,)),
        }
    if flavor == "cifar":
        return {
            "c1": _conv_init(ks[0], 5, 5, 3, 64), "b1": jnp.zeros((64,)),
            "c2": _conv_init(ks[1], 5, 5, 64, 64), "b2": jnp.zeros((64,)),
            "d1": _dense_init(ks[2], 64 * 4 * 4, 384), "db1": jnp.zeros((384,)),
            "d2": _dense_init(ks[3], 384, 192), "db2": jnp.zeros((192,)),
            "d3": _dense_init(ks[4], 192, 10), "db3": jnp.zeros((10,)),
        }
    raise ValueError(flavor)


def _conv(x, w, b, padding):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def _maxpool(x, k, s):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "VALID")


def cnn_logits(params: PyTree, x: jnp.ndarray, flavor: str = "mnist"):
    if flavor == "mnist":
        h = jax.nn.relu(_conv(x, params["c1"], params["b1"], "SAME"))
        h = _maxpool(h, 2, 2)
        h = jax.nn.relu(_conv(h, params["c2"], params["b2"], "SAME"))
        h = _maxpool(h, 2, 2)
        h = h.reshape(h.shape[0], -1)
        return h @ params["d1"] + params["db1"]
    h = jax.nn.relu(_conv(x, params["c1"], params["b1"], "VALID"))
    h = _maxpool(h, 3, 2)
    h = jax.nn.relu(_conv(h, params["c2"], params["b2"], "VALID"))
    h = _maxpool(h, 3, 2)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["d1"] + params["db1"])
    h = jax.nn.relu(h @ params["d2"] + params["db2"])
    return h @ params["d3"] + params["db3"]


def cnn_loss(params: PyTree, batch: Tuple[jnp.ndarray, jnp.ndarray],
             flavor: str = "mnist") -> jnp.ndarray:
    x, y = batch
    logits = cnn_logits(params, x, flavor).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), 1)[:, 0]
    return jnp.mean(logz - gold)


def cnn_accuracy(params: PyTree, x: jnp.ndarray, y: jnp.ndarray,
                 flavor: str = "mnist") -> jnp.ndarray:
    logits = cnn_logits(params, x, flavor)
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
