"""Activation-sharding policy (launcher-injected, model-code-agnostic).

The transformer stack calls ``shard_hidden(h)`` on the residual stream at
period boundaries (the layer-scan carry). Without this, GSPMD materializes
the per-layer saved residuals UNSHARDED — observed 36 GiB/device on
qwen3-8b train_4k — because nothing pins the carry's layout. The launcher
sets a policy before tracing:

    with activation_sharding(mesh, batch=("data",), seq=("model",)):
        ... trace/lower ...

Inside ``vmap`` (the DFL node dimension) the constraint composes fine: jax
maps the spec under the batched dim. When no policy is set the call is a
no-op (CPU tests, examples).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Tuple

import jax

_STATE = threading.local()


def _current():
    return getattr(_STATE, "policy", None)


@contextlib.contextmanager
def activation_sharding(mesh, *, batch=None, seq=None, embed=None):
    """Context: constrain hidden states [B, S, D] at layer boundaries."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    hidden = NamedSharding(mesh, P(batch, seq, embed))
    # flattened-token tensors [B*S, ...] (MoE dispatch): shard the token dim
    # over batch-then-seq axes jointly (b-major flatten order).
    token_axes = tuple(a for a in (batch, seq) if a is not None) or None
    if isinstance(token_axes, tuple) and len(token_axes) == 1:
        token_axes = token_axes[0]
    tokens = NamedSharding(mesh, P(token_axes))
    prev = _current()
    _STATE.policy = {"hidden": hidden, "tokens": tokens}
    try:
        yield
    finally:
        _STATE.policy = prev


def shard_hidden(h: jax.Array) -> jax.Array:
    """Apply the active residual-stream constraint (no-op without policy)."""
    policy = _current()
    if policy is None:
        return h
    return jax.lax.with_sharding_constraint(h, policy["hidden"])


def shard_tokens(x: jax.Array) -> jax.Array:
    """Constrain a flattened-token tensor [T, ...] on its leading dim."""
    policy = _current()
    if policy is None:
        return x
    return jax.lax.with_sharding_constraint(x, policy["tokens"])
