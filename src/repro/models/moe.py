"""Mixture-of-Experts FFN: top-k routing with grouped GShard dispatch.

Tokens are blocked into groups of ``dispatch_group`` (default 256) and
dispatched to per-(group, expert) capacity buffers with one-hot einsums —
the GShard/MaxText formulation. With small groups the dispatch einsum costs
T * k * cf * Tg * d FLOPs ~= (Tg / (3*d_ff)) of the expert matmuls (~1-2%
at the assigned shapes), while keeping every op an einsum that GSPMD shards
cleanly (a scatter/gather formulation was tried first and forced
replicated 32 GiB/device buffers — einsums win on TPU).

Sharding: the group dim carries the token sharding (policy.shard_tokens);
the expert dim of weights/buffers carries logical axis "experts" -> `model`
mesh axis; GSPMD inserts the all-to-all between token-sharded dispatch and
expert-sharded compute. Router load-balance aux loss is returned per call.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamFactory
from repro.models.policy import shard_tokens


def moe_params(f: ParamFactory, cfg: ModelConfig) -> Dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": f.dense((d, e), ("embed", None), scale=0.02),
        "w_gate": f.dense((e, d, ff), ("experts", "embed", "mlp")),
        "w_up": f.dense((e, d, ff), ("experts", "embed", "mlp")),
        "w_down": f.dense((e, ff, d), ("experts", "mlp", "embed")),
    }


DISPATCH_GROUP = 256


def moe_ffn(
    p: Dict,
    x: jnp.ndarray,                 # [B, S, D]
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out [B,S,D], aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    tg = min(DISPATCH_GROUP, t)
    while t % tg:
        tg -= 1
    g = t // tg
    xg = shard_tokens(x.reshape(g, tg, d))

    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)     # [G,Tg,E]
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                 # [G,Tg,k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss (fraction routed vs mean prob).
    me = jnp.mean(probs, axis=(0, 1))                               # [E]
    assigned = jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32)
    ce = jnp.mean(assigned, axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    cap = max(1, int(cfg.capacity_factor * k * tg / e))
    cap = min(cap, tg)

    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)       # [G,Tg,k,E]
    # position of each (token, choice) within its (group, expert) buffer:
    # order: token-major then choice-major within token.
    flat = onehot.reshape(g, tg * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                           # [G,Tg*k,E]
    pos = pos.reshape(g, tg, k, e)
    within_cap = pos < cap
    slot = jnp.einsum("gtke,gtke->gtk", pos, onehot)                # slot idx
    keep = jnp.einsum("gtke,gtke->gtk", within_cap.astype(jnp.float32), onehot)

    slot_oh = jax.nn.one_hot(slot, cap, dtype=jnp.float32)          # [G,Tg,k,C]
    # dispatch [G,Tg,E,C] (0/1), combine adds the gate weights.
    dispatch = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, slot_oh, keep)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, slot_oh,
                         keep * gate_vals.astype(jnp.float32))

    xd = x.dtype
    expert_in = jnp.einsum("gtec,gtd->egcd", dispatch.astype(xd), xg)
    gg = jnp.einsum("egcd,edf->egcf", expert_in, p["w_gate"].astype(xd))
    uu = jnp.einsum("egcd,edf->egcf", expert_in, p["w_up"].astype(xd))
    hh = jax.nn.silu(gg.astype(jnp.float32)).astype(xd) * uu
    out_buf = jnp.einsum("egcf,efd->egcd", hh, p["w_down"].astype(xd))
    out = jnp.einsum("gtec,egcd->gtd", combine.astype(xd), out_buf)
    return out.reshape(b, s, d), aux
