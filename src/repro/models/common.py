"""Shared model-definition machinery.

Models are pure functions over pytree params. Every parameter is created
through ``Param`` helpers that record *logical axis names* alongside the
array; the launcher maps logical axes to mesh axes (see
``repro/launch/sharding_rules.py``). This mirrors MaxText's logical-axis
design without depending on flax.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating period block of a stack."""

    mixer: str = "attn"          # attn | mamba
    ffn: str = "mlp"             # mlp | moe | none
    window: int = 0              # sliding-window size; 0 = full attention
    cross_attn: bool = False     # adds a cross-attention sub-block
    rope_theta: float = 0.0      # 0 = use model default


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters, generic over the 6 assigned families."""

    name: str
    arch_type: str               # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int              # decoder layers (excludes encoder_layers)
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    qk_norm: bool = False
    rope_theta: float = 1e4
    logit_softcap: float = 0.0
    # repeating layer pattern; default = uniform (attn + cfg-default ffn)
    pattern: Tuple[LayerSpec, ...] = ()
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0             # 0 = ceil(d_model / 16)
    # encoder-decoder / multimodal
    encoder_layers: int = 0      # >0 => enc-dec (audio); encoder is bidirectional
    memory_tokens: int = 0       # VLM patches / audio frames expected (spec hint)
    memory_dim: int = 0          # frontend embedding dim (stub); 0 = d_model
    # embeddings / numerics
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    # training-time mechanics
    scan_layers: bool = True
    remat: bool = True
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    decode_unchunked: bool = False   # perf variant: single-block decode attn
    loss_seq_chunk: int = 512
    ssm_chunk: int = 128
    # attention sharding family: heads | head_dim | replicated
    attn_shard: str = "heads"
    # provenance
    citation: str = ""

    def __post_init__(self):
        if self.pattern == ():
            ffn = "moe" if self.num_experts > 0 else "mlp"
            mixer = "mamba" if self.arch_type == "ssm" else "attn"
            object.__setattr__(self, "pattern", (LayerSpec(mixer=mixer, ffn=ffn),))
        assert self.num_layers % len(self.pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"pattern period {len(self.pattern)}"
        )

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank or int(math.ceil(self.d_model / 16))

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def has_memory_input(self) -> bool:
        return self.arch_type in ("vlm", "audio")

    def layer_specs(self) -> List[LayerSpec]:
        return list(self.pattern) * self.num_periods

    def param_count(self) -> int:
        """Total parameter count (exact, from the init shapes)."""
        from repro.models.transformer import init_params  # cycle-free at call

        params, _ = init_params(self, jax.random.key(0), abstract=True)
        leaves = jax.tree_util.tree_leaves(params)
        return sum(int(np.prod(l.shape)) for l in leaves)

    def active_param_count(self) -> int:
        """Active params per token (MoE discounts inactive experts)."""
        total = self.param_count()
        if self.num_experts == 0:
            return total
        # expert weights: ffn mlp tensors in moe layers.
        specs = self.layer_specs()
        n_moe = sum(1 for s in specs if s.ffn == "moe")
        per_expert = 3 * self.d_model * self.d_ff
        expert_total = n_moe * self.num_experts * per_expert
        expert_active = n_moe * self.experts_per_token * per_expert
        return total - expert_total + expert_active


# ---------------------------------------------------------------------------
# Params with logical axes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Annotated:
    """A parameter leaf paired with its logical-axis names."""

    value: Any
    axes: Tuple[Optional[str], ...]


class ParamFactory:
    """Creates ``Annotated`` params; ``split_annotations`` separates the
    value tree from the logical-axes tree afterwards."""

    def __init__(self, key: jax.Array, dtype, abstract: bool = False):
        self._key = key
        self._dtype = dtype
        self._abstract = abstract

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def dense(self, shape: Sequence[int], axes: Sequence[Optional[str]],
              scale: Optional[float] = None) -> Annotated:
        assert len(shape) == len(axes), (shape, axes)
        fan_in = shape[0] if len(shape) > 1 else shape[0]
        std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        if self._abstract:
            v = jax.ShapeDtypeStruct(tuple(shape), self._dtype)
        else:
            v = (
                jax.random.normal(self._next_key(), tuple(shape), jnp.float32) * std
            ).astype(self._dtype)
        return Annotated(v, tuple(axes))

    def zeros(self, shape: Sequence[int], axes: Sequence[Optional[str]],
              dtype=None) -> Annotated:
        dt = dtype or self._dtype
        if self._abstract:
            v = jax.ShapeDtypeStruct(tuple(shape), dt)
        else:
            v = jnp.zeros(tuple(shape), dt)
        return Annotated(v, tuple(axes))

    def ones(self, shape: Sequence[int], axes: Sequence[Optional[str]],
             dtype=None) -> Annotated:
        dt = dtype or self._dtype
        if self._abstract:
            v = jax.ShapeDtypeStruct(tuple(shape), dt)
        else:
            v = jnp.ones(tuple(shape), dt)
        return Annotated(v, tuple(axes))

    def const(self, value: np.ndarray, axes: Sequence[Optional[str]]) -> Annotated:
        if self._abstract:
            v = jax.ShapeDtypeStruct(np.asarray(value).shape, jnp.float32)
        else:
            v = jnp.asarray(value, jnp.float32)
        return Annotated(v, tuple(axes))


def split_annotations(tree: PyTree) -> Tuple[PyTree, PyTree]:
    """Split a tree of ``Annotated`` into (values, logical_axes) trees."""
    is_ann = lambda x: isinstance(x, Annotated)
    values = jax.tree_util.tree_map(lambda a: a.value, tree, is_leaf=is_ann)
    axes = jax.tree_util.tree_map(lambda a: a.axes, tree, is_leaf=is_ann)
    return values, axes


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    sin = jnp.sin(ang)[..., None, :]  # broadcast over heads
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down.astype(x.dtype))


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


def pad_vocab(v: int, multiple: int = 128) -> int:
    return int(math.ceil(v / multiple) * multiple)
