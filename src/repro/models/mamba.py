"""Mamba-1 selective-SSM mixer (falcon-mamba / jamba layers).

TPU adaptation: the CUDA selective-scan kernel becomes a *chunked
associative scan* — sequences are processed in chunks of ``cfg.ssm_chunk``;
within a chunk the recurrence h_t = a_t h_{t-1} + u_t is evaluated with
``jax.lax.associative_scan`` (log-depth, MXU/VPU friendly) and chunks are
chained with a small ``lax.scan`` carry. The [B, chunk, d_inner, state]
intermediate lives only inside one chunk — the full [B, S, d_inner, state]
tensor is never materialized (it would be terabytes at the assigned shapes).

Decode is the O(1) recurrent step on (conv_state, ssm_state).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig, ParamFactory


def mamba_params(f: ParamFactory, cfg: ModelConfig) -> Dict:
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, k = cfg.dt_rank_actual, cfg.ssm_conv
    a_init = np.broadcast_to(np.arange(1, st + 1, dtype=np.float32), (di, st))
    return {
        "wx": f.dense((d, di), ("embed", "ssm_inner")),
        "wz": f.dense((d, di), ("embed", "ssm_inner")),
        "conv_w": f.dense((k, di), (None, "ssm_inner"), scale=0.2),
        "conv_b": f.zeros((di,), ("ssm_inner",)),
        "w_dt": f.dense((di, dtr), ("ssm_inner", None)),
        "w_bc": f.dense((di, 2 * st), ("ssm_inner", None)),
        "dt_proj": f.dense((dtr, di), (None, "ssm_inner")),
        "dt_bias": f.zeros((di,), ("ssm_inner",)),
        "a_log": f.const(np.log(a_init), ("ssm_inner", None)),
        "d_skip": f.ones((di,), ("ssm_inner",), dtype=jnp.float32),
        "out_proj": f.dense((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 history: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv along seq. x [B,S,di]; w [K,di]; history
    [B,K-1,di] carries the last inputs of the previous segment."""
    k = w.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for i in range(k):
        out = out + xp[:, i:i + s].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_inputs(p: Dict, xc: jnp.ndarray, cfg: ModelConfig):
    """xc [B,S,di] (post conv+silu) -> (dt [B,S,di], B/C [B,S,st])."""
    st = cfg.ssm_state
    dt_low = jnp.einsum("bsd,dr->bsr", xc, p["w_dt"].astype(xc.dtype))
    dt = jnp.einsum("bsr,rd->bsd", dt_low, p["dt_proj"].astype(xc.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    bc = jnp.einsum("bsd,dn->bsn", xc, p["w_bc"].astype(xc.dtype))
    bmat = bc[..., :st].astype(jnp.float32)
    cmat = bc[..., st:].astype(jnp.float32)
    return dt, bmat, cmat


def _scan_chunk(a: jnp.ndarray, u: jnp.ndarray, h0: jnp.ndarray):
    """h_t = a_t h_{t-1} + u_t within one chunk via associative scan.

    a, u: [B, Q, di, st]; h0: [B, di, st]. Returns (h_all [B,Q,di,st], h_last).
    """

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    a_cum, u_cum = jax.lax.associative_scan(combine, (a, u), axis=1)
    h_all = u_cum + a_cum * h0[:, None]
    return h_all, h_all[:, -1]


def mamba_mixer(
    p: Dict,
    x: jnp.ndarray,                 # [B, S, D]
    cfg: ModelConfig,
    *,
    checkpoint: bool = False,
    return_state: bool = False,
):
    """Full-sequence mamba block (train / prefill)."""
    b, s, _ = x.shape
    di, st = cfg.d_inner, cfg.ssm_state
    xin = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype))
    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(x.dtype))
    xc = _causal_conv(xin, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # [di, st]

    q = cfg.ssm_chunk
    while s % q:
        q -= 1
    nchunk = s // q

    def chunk_body(h_prev, inp):
        xc_c, x_raw_c = inp                                # [B, q, di] each
        dt, bmat, cmat = _ssm_inputs(p, xc_c, cfg)
        decay = jnp.exp(dt[..., None] * a)                 # [B,q,di,st]
        u = (dt * xc_c.astype(jnp.float32))[..., None] * bmat[:, :, None, :]
        h_all, h_last = _scan_chunk(decay, u, h_prev)
        y = jnp.einsum("bqds,bqs->bqd", h_all, cmat)
        y = y + p["d_skip"].astype(jnp.float32) * xc_c.astype(jnp.float32)
        return h_last, y.astype(x.dtype)

    if checkpoint:
        chunk_body = jax.checkpoint(chunk_body)

    xc_chunks = xc.reshape(b, nchunk, q, di).transpose(1, 0, 2, 3)
    xin_chunks = xin.reshape(b, nchunk, q, di).transpose(1, 0, 2, 3)
    h0 = jnp.zeros((b, di, st), jnp.float32)
    h_last, y_chunks = jax.lax.scan(chunk_body, h0, (xc_chunks, xin_chunks))
    y = y_chunks.transpose(1, 0, 2, 3).reshape(b, s, di)

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    if return_state:
        k = cfg.ssm_conv
        conv_state = xin[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
            xin, ((0, 0), (k - 1 - s, 0), (0, 0)))
        return out, {"conv": conv_state, "ssm": h_last}
    return out


def init_mamba_state(cfg: ModelConfig, batch: int, abstract: bool = False) -> Dict:
    k = cfg.ssm_conv
    shapes = {
        "conv": ((batch, k - 1, cfg.d_inner), cfg.dtype),
        "ssm": ((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
    if abstract:
        return {n: jax.ShapeDtypeStruct(sh, dt) for n, (sh, dt) in shapes.items()}
    return {n: jnp.zeros(sh, dt) for n, (sh, dt) in shapes.items()}


def mamba_decode(
    p: Dict,
    x: jnp.ndarray,                 # [B, 1, D]
    cfg: ModelConfig,
    state: Dict,
) -> Tuple[jnp.ndarray, Dict]:
    """Single-token recurrent step."""
    xin = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype))  # [B,1,di]
    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(x.dtype))
    conv_hist = state["conv"].astype(x.dtype)
    xc = _causal_conv(xin, p["conv_w"], p["conv_b"], history=conv_hist)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    new_conv = jnp.concatenate([conv_hist[:, 1:], xin], axis=1)

    dt, bmat, cmat = _ssm_inputs(p, xc, cfg)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt[:, 0, :, None] * a)                  # [B,di,st]
    u = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * bmat[:, 0, None, :]
    h = decay * state["ssm"] + u
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])
    y = y + p["d_skip"].astype(jnp.float32) * xc[:, 0].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, {"conv": new_conv.astype(cfg.dtype), "ssm": h}
