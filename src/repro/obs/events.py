"""The telemetry event schema: typed, timestamped, append-only JSONL.

One run = one stream of event records. Every record is a flat JSON
object:

``type``   (required) one of ``EVENT_TYPES`` — the event's kind.
``t``      (required) seconds since the run's monotonic origin
           (``time.perf_counter`` based — NEVER wall clock, so a
           wall-clock jump can't corrupt durations).
``track``  (required) the concern this event belongs to (one timeline
           row in the trace export): ``dispatch``, ``prefetch``,
           ``metrics``, ``planner``, ``checkpoint``, ``rounds``,
           ``run``, or any caller-chosen string.
``name``   (optional) human label; spans REQUIRE it.
``dur``    (optional) span duration in seconds; events with ``dur``
           render as slices, events without as instants.
``data``   (optional) dict of JSON scalars/lists — the typed payload;
           ``REQUIRED_DATA`` lists the per-type mandatory keys.

The stream's first record is the ``run`` header, whose data carries
``schema`` (= ``SCHEMA_VERSION``) and ``wall_start`` (the ONE absolute
unix timestamp — every other time in the stream is monotonic-relative).
Events are appended in emission order; because background threads
(``HostPrefetcher``) emit spans stamped at their *start* time, ``t`` is
NOT required to be monotone across records.

This module is intentionally jax-free and stdlib-only: readers
(validators, CI, the report CLI) must work on boxes where the library
itself may not import.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "SCHEMA_VERSION",
    "KNOWN_SCHEMAS",
    "EVENT_TYPES",
    "REQUIRED_DATA",
    "make_event",
    "validate_event",
    "validate_events",
    "validate_stream",
    "read_events",
    "write_events",
]

# Schema 2 adds the fault-tolerance vocabulary: ``fault`` (an injected
# fault window opening/closing) and ``degraded`` (a round that ran with
# masked nodes/edges — realized participation attached). Schema 3 adds
# ``overlap`` (a pipelined superstep's in-flight gossip slice — rendered
# on its own track so the trace shows the wire riding under compute).
# Older streams stay readable: the new types are additive and every
# schema-1/2 record is schema-3 valid.
SCHEMA_VERSION = 3
KNOWN_SCHEMAS = frozenset({1, 2, 3})

# The typed vocabulary. Each type is a kind of thing that happens in a
# run; anything else is a schema violation (add the type HERE, with its
# required payload, before emitting it).
EVENT_TYPES = frozenset({
    "run",         # stream header: schema version + wall-clock anchor
    "round",       # one completed DFL round (realized schedule + metrics)
    "superstep",   # one fused K-round dispatch (the executor hot path)
    "plan",        # an initial/trajectory schedule decision
    "replan",      # a boundary re-plan that may change the schedule
    "probe",       # an identifiability probe round injection
    "compile",     # an XLA trace/compile of a dispatch executable
    "checkpoint",  # a checkpoint write
    "prefetch",    # host batch prefetch activity (build/cancel/stale)
    "flush",       # a MetricsBuffer host-sync flush
    "span",        # a generic named timed region (with telemetry.span)
    "counters",    # a counter snapshot attributed to its superstep
    "fault",       # an injected fault window opening or closing (schema 2)
    "degraded",    # a round run with masked nodes/edges (schema 2)
    "overlap",     # a pipelined superstep's in-flight gossip slice (schema 3)
})

# Per-type mandatory ``data`` keys (beyond the top-level type/t/track).
REQUIRED_DATA: Dict[str, Tuple[str, ...]] = {
    "run": ("schema", "wall_start"),
    "round": ("round", "tau1", "tau2", "round_s"),
    "superstep": ("k",),
    "plan": ("tau1", "tau2"),
    "replan": ("tau1", "tau2"),
    "probe": ("tau1", "tau2"),
    "compile": ("count",),
    "checkpoint": ("round",),
    "prefetch": ("action",),
    "flush": ("rounds",),
    "span": (),
    "counters": (),
    "fault": ("kind", "phase"),
    "degraded": ("round", "active_nodes", "masked_edges"),
    "overlap": ("mode", "k"),
}


def make_event(type_: str, t: float, track: str, *,
               name: Optional[str] = None, dur: Optional[float] = None,
               data: Optional[dict] = None) -> dict:
    """Build one schema-shaped event record (no validation — see
    ``validate_event``)."""
    ev: Dict[str, Any] = {"type": type_, "t": float(t), "track": track}
    if name is not None:
        ev["name"] = name
    if dur is not None:
        ev["dur"] = float(dur)
    if data:
        ev["data"] = data
    return ev


def validate_event(ev: Any) -> List[str]:
    """All schema problems with one record (empty list == valid)."""
    problems: List[str] = []
    if not isinstance(ev, dict):
        return [f"event is {type(ev).__name__}, not an object"]
    etype = ev.get("type")
    if etype not in EVENT_TYPES:
        problems.append(f"unknown type {etype!r} (know {sorted(EVENT_TYPES)})")
    t = ev.get("t")
    if not isinstance(t, (int, float)) or isinstance(t, bool) or t < 0:
        problems.append(f"t={t!r} must be a non-negative number "
                        "(monotonic seconds since run start)")
    if not isinstance(ev.get("track"), str) or not ev.get("track"):
        problems.append(f"track={ev.get('track')!r} must be a non-empty "
                        "string")
    dur = ev.get("dur")
    if dur is not None and (not isinstance(dur, (int, float))
                            or isinstance(dur, bool) or dur < 0):
        problems.append(f"dur={dur!r} must be a non-negative number")
    if etype == "span":
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            problems.append("span events require a non-empty 'name'")
        if dur is None:
            problems.append("span events require 'dur'")
    data = ev.get("data", {})
    if not isinstance(data, dict):
        problems.append(f"data={data!r} must be an object")
        data = {}
    for key in REQUIRED_DATA.get(etype, ()):
        if key not in data:
            problems.append(f"{etype!r} event missing required data key "
                            f"{key!r}")
    return problems


def validate_events(events: Iterable[Any]) -> List[Tuple[int, str]]:
    """``(index, problem)`` for every schema violation in the sequence."""
    out: List[Tuple[int, str]] = []
    for i, ev in enumerate(events):
        for p in validate_event(ev):
            out.append((i, p))
    return out


def validate_stream(events: Sequence[Any]) -> List[Tuple[int, str]]:
    """``validate_events`` plus the stream-level contract: non-empty,
    starts with a ``run`` header whose ``schema`` we can read."""
    events = list(events)
    out = validate_events(events)
    if not events:
        return [(0, "empty stream: no 'run' header event")]
    head = events[0]
    if isinstance(head, dict):
        if head.get("type") != "run":
            out.append((0, f"stream must start with a 'run' header event, "
                           f"got {head.get('type')!r}"))
        else:
            schema = head.get("data", {}).get("schema")
            if schema not in KNOWN_SCHEMAS:
                out.append((0, f"run header schema={schema!r}, this reader "
                               f"knows schemas {sorted(KNOWN_SCHEMAS)}"))
    return out


def read_events(path: str) -> List[dict]:
    """Parse a JSONL event file (raises ValueError with the offending
    line number on malformed JSON; schema validation is separate)."""
    events: List[dict] = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(
                    f"{path}:{lineno}: malformed JSONL event: {e}") from None
    return events


def write_events(path: str, events: Iterable[dict]) -> int:
    """Write events as JSONL; returns the count written."""
    n = 0
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev) + "\n")
            n += 1
    return n
