"""Run report: a per-phase cost breakdown computed from the event stream.

Answers "where did the wall-clock go" (span time per track), "what did
the run cost" (wire bits, compiles, kernel-counter deltas, prefetch
hit/stale), and "what happened" (rounds, schedule usage, plan/replan/
probe decisions) — all from the JSONL stream, no live process needed.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, Iterable, List, Tuple

__all__ = ["run_report", "format_report"]


def run_report(events: Iterable[dict]) -> dict:
    """Aggregate a stream into a report dict (see ``format_report``)."""
    events = list(events)
    header = next((e for e in events if e.get("type") == "run"), None)

    # Wall-clock attribution: total duration per (track, name) over every
    # event that carries a dur (spans, supersteps, flushes, checkpoints,
    # prefetch builds...).
    spans: Dict[Tuple[str, str], Dict[str, float]] = {}
    t_end = 0.0
    for ev in events:
        t_end = max(t_end, float(ev.get("t", 0.0)) + float(ev.get("dur") or 0.0))
        if ev.get("dur") is None:
            continue
        key = (ev.get("track", "run"), ev.get("name") or ev.get("type"))
        slot = spans.setdefault(key, {"count": 0, "total_s": 0.0})
        slot["count"] += 1
        slot["total_s"] += float(ev["dur"])

    # Rounds: realized schedule + losses.
    rounds = [e["data"] for e in events
              if e.get("type") == "round" and isinstance(e.get("data"), dict)]
    round_summary = {}
    if rounds:
        taus = Counter((r.get("tau1"), r.get("tau2")) for r in rounds)
        losses = [r["loss"] for r in rounds if isinstance(
            r.get("loss"), (int, float))]
        round_summary = {
            "rounds": len(rounds),
            "round_s_total": sum(float(r.get("round_s", 0.0)) for r in rounds),
            "schedule_counts": {f"({t1},{t2})": n
                                for (t1, t2), n in sorted(taus.items(),
                                                          key=lambda kv: -kv[1])},
        }
        if losses:
            round_summary["loss_first"] = losses[0]
            round_summary["loss_last"] = losses[-1]

    # Availability attribution (schema 2): how much of the run's loss
    # progress happened in full vs degraded rounds, and the realized
    # participation rates — the report's answer to "did the sporadic
    # engine actually keep learning through the faults".
    part = [r for r in rounds
            if isinstance(r.get("active_nodes"), (int, float))
            and isinstance(r.get("masked_edges"), (int, float))]
    availability = {}
    if part:
        degraded = [r for r in part if r.get("degraded")]
        availability = {
            "rounds_tracked": len(part),
            "rounds_degraded": len(degraded),
            "mean_active_nodes": (sum(r["active_nodes"] for r in part)
                                  / len(part)),
            "mean_masked_edges": (sum(r["masked_edges"] for r in part)
                                  / len(part)),
        }
        for name, sel in (("full", [r for r in part
                                    if not r.get("degraded")]),
                          ("degraded", degraded)):
            ls = [r["loss"] for r in sel
                  if isinstance(r.get("loss"), (int, float))]
            if len(ls) >= 1:
                availability[f"loss_delta_{name}"] = ls[-1] - ls[0]
    faults = Counter(
        f"{e['data'].get('kind', '?')}:{e['data'].get('phase', '?')}"
        for e in events
        if e.get("type") == "fault" and isinstance(e.get("data"), dict))

    # Overlap (schema 3): pipelined supersteps' in-flight gossip slices —
    # how much of the run executed with the wire riding under compute.
    overlap_evs = [e for e in events if e.get("type") == "overlap"]
    overlap = {}
    if overlap_evs:
        overlap = {
            "supersteps": len(overlap_evs),
            "mode": (overlap_evs[-1].get("data") or {}).get("mode", "?"),
            "inflight_s": sum(float(e.get("dur") or 0.0)
                              for e in overlap_evs),
        }

    # Planner decisions.
    plan_counts = Counter(e.get("data", {}).get("cause", e["type"])
                          for e in events
                          if e.get("type") in ("plan", "replan", "probe"))

    # Counters: the final snapshot wins for cumulative values; kernel_*
    # keys are per-superstep deltas so they sum.
    counters: Dict[str, float] = {}
    kernel_totals: Dict[str, float] = {}
    for ev in events:
        if ev.get("type") != "counters":
            continue
        for k, v in (ev.get("data") or {}).items():
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                continue
            if k.startswith("kernel_"):
                kernel_totals[k] = kernel_totals.get(k, 0) + v
            else:
                counters[k] = v
    counters.update(kernel_totals)

    compiles = [e["data"]["count"] for e in events
                if e.get("type") == "compile"
                and isinstance(e.get("data"), dict) and "count" in e["data"]]

    return {
        "meta": (header or {}).get("data", {}),
        "duration_s": t_end,
        "events": len(events),
        "tracks": sorted({e.get("track", "run") for e in events}),
        "spans": {f"{track}:{name}": stat
                  for (track, name), stat in sorted(
                      spans.items(), key=lambda kv: -kv[1]["total_s"])},
        "rounds": round_summary,
        "availability": availability,
        "overlap": overlap,
        "faults": dict(faults),
        "plans": dict(plan_counts),
        "counters": counters,
        "compiles_seen": max(compiles) if compiles else 0,
    }


def format_report(rep: dict) -> str:
    """Human-readable rendering of ``run_report`` output."""
    lines: List[str] = []
    meta = rep.get("meta", {})
    label = meta.get("arch") or meta.get("name") or "run"
    lines.append(f"run report — {label}")
    lines.append(f"  duration {rep['duration_s']:.3f}s over {rep['events']} "
                 f"events on tracks: {', '.join(rep['tracks'])}")

    if rep.get("rounds"):
        r = rep["rounds"]
        lines.append(f"  rounds: {r['rounds']} "
                     f"({r['round_s_total']:.3f}s amortized)")
        if "loss_first" in r:
            lines.append(f"    loss {r['loss_first']:.4f} -> "
                         f"{r['loss_last']:.4f}")
        sched = ", ".join(f"{k}x{n}" for k, n in r["schedule_counts"].items())
        lines.append(f"    schedule (tau1,tau2): {sched}")

    if rep.get("availability"):
        a = rep["availability"]
        lines.append(
            f"  availability: {a['rounds_degraded']}/{a['rounds_tracked']} "
            f"rounds degraded, mean active nodes "
            f"{a['mean_active_nodes']:.2f}, mean masked edges "
            f"{a['mean_masked_edges']:.2f}")
        for name in ("full", "degraded"):
            key = f"loss_delta_{name}"
            if key in a:
                lines.append(f"    loss delta over {name} rounds: "
                             f"{a[key]:+.4f}")
    if rep.get("overlap"):
        o = rep["overlap"]
        lines.append(f"  overlap: mode={o['mode']} over {o['supersteps']} "
                     f"superstep(s), {o['inflight_s']:.3f}s gossip in "
                     f"flight under compute")

    if rep.get("faults"):
        fl = ", ".join(f"{k}x{n}" for k, n in sorted(rep["faults"].items()))
        lines.append(f"  faults: {fl}")

    if rep.get("plans"):
        plans = ", ".join(f"{k}={n}" for k, n in sorted(rep["plans"].items()))
        lines.append(f"  planner: {plans}")

    if rep.get("spans"):
        lines.append("  wall-clock by span (track:name  count  total):")
        for key, stat in rep["spans"].items():
            lines.append(f"    {key:<32s} {stat['count']:>5d}  "
                         f"{stat['total_s']:>9.3f}s")

    if rep.get("counters"):
        lines.append("  counters (final / summed deltas):")
        for k, v in sorted(rep["counters"].items()):
            lines.append(f"    {k:<32s} {v}")
    if rep.get("compiles_seen"):
        lines.append(f"  XLA traces observed: {rep['compiles_seen']}")
    return "\n".join(lines)
