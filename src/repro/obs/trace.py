"""Chrome trace-event export: the run timeline as Perfetto loads it.

One telemetry track == one named thread row (``ph: "M"``/``thread_name``
metadata). Events with ``dur`` become complete slices (``ph: "X"``);
events without become instants (``ph: "i"``). All timestamps are the
run's monotonic seconds scaled to microseconds, so the Perfetto ruler
reads as time-since-run-start.

Format reference: the Trace Event Format JSON accepted by
``ui.perfetto.dev`` and ``chrome://tracing`` — an object with a
``traceEvents`` list.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List

__all__ = ["to_chrome_trace", "export_chrome_trace", "trace_track_names"]

_PID = 0  # single-process timeline; tracks are threads under it


def to_chrome_trace(events: Iterable[dict]) -> dict:
    """Render schema events as a Chrome trace-event JSON object."""
    events = list(events)
    # Stable track -> tid mapping in first-appearance order.
    tids: Dict[str, int] = {}
    for ev in events:
        track = ev.get("track", "run")
        if track not in tids:
            tids[track] = len(tids)

    out: List[dict] = [{
        "ph": "M", "name": "process_name", "pid": _PID, "tid": 0,
        "args": {"name": "repro-dfl run"},
    }]
    for track, tid in tids.items():
        out.append({"ph": "M", "name": "thread_name", "pid": _PID,
                    "tid": tid, "args": {"name": track}})

    for ev in events:
        rec = {
            "pid": _PID,
            "tid": tids[ev.get("track", "run")],
            "ts": float(ev.get("t", 0.0)) * 1e6,
            "name": ev.get("name") or ev.get("type", "event"),
            "cat": ev.get("type", "event"),
            "args": ev.get("data", {}),
        }
        if ev.get("dur") is not None:
            rec["ph"] = "X"
            rec["dur"] = float(ev["dur"]) * 1e6
        else:
            rec["ph"] = "i"
            rec["s"] = "t"  # thread-scoped instant
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def trace_track_names(trace: dict) -> List[str]:
    """Named tracks in an exported trace (the thread_name metadata)."""
    return [m["args"]["name"] for m in trace.get("traceEvents", [])
            if m.get("ph") == "M" and m.get("name") == "thread_name"]


def export_chrome_trace(events: Iterable[dict], path: str) -> dict:
    trace = to_chrome_trace(events)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace
