"""repro.obs — the unified telemetry subsystem.

Three pillars (see docs/ARCHITECTURE.md "Observability"):

1. **Event stream** (``events``, ``telemetry``): a ``Telemetry`` sink
   collects typed, timestamped events as append-only JSONL;
   ``RoundExecutor``/``MetricsBuffer``/``HostPrefetcher``,
   ``AdaptiveController`` and ``launch/train.py`` emit into it, and the
   ``--history-out`` JSON is a schema-versioned view over the stream
   (``history.history_view``).
2. **Span tracing** (``telemetry.span``, ``trace``): host-side spans on
   monotonic ``perf_counter`` clocks, exported as Chrome trace-event /
   Perfetto-loadable JSON — one track per concern.
3. **Counter attribution** (``report``): kernel ``op_stats`` deltas,
   compile counts, wire-bit totals and prefetch hit/stale snapshots
   attributed to their superstep; ``python -m repro.obs report`` prints
   the per-phase cost breakdown.

Contract: telemetry adds ZERO host syncs and ZERO recompiles on the
round path (this package never imports jax; the ``telemetry-neutrality``
audit in ``repro.analysis`` proves the instrumented superstep HLO is
fingerprint-identical to the uninstrumented one).

CLI::

    python -m repro.obs validate events.jsonl [--min-tracks N]
    python -m repro.obs trace export events.jsonl --out trace.json
    python -m repro.obs report events.jsonl
"""
from repro.obs.events import (EVENT_TYPES, KNOWN_SCHEMAS, REQUIRED_DATA,
                              SCHEMA_VERSION, make_event, read_events,
                              validate_event, validate_events,
                              validate_stream, write_events)
from repro.obs.history import HISTORY_SCHEMA_VERSION, history_view
from repro.obs.report import format_report, run_report
from repro.obs.telemetry import NullTelemetry, Telemetry
from repro.obs.trace import (export_chrome_trace, to_chrome_trace,
                             trace_track_names)

__all__ = [
    "EVENT_TYPES",
    "REQUIRED_DATA",
    "SCHEMA_VERSION",
    "KNOWN_SCHEMAS",
    "HISTORY_SCHEMA_VERSION",
    "Telemetry",
    "NullTelemetry",
    "make_event",
    "read_events",
    "write_events",
    "validate_event",
    "validate_events",
    "validate_stream",
    "history_view",
    "run_report",
    "format_report",
    "to_chrome_trace",
    "export_chrome_trace",
    "trace_track_names",
]
