"""Telemetry CLI: validate / trace export / report over a JSONL stream.

    python -m repro.obs validate events.jsonl [--min-tracks 4]
    python -m repro.obs trace export events.jsonl --out trace.json
    python -m repro.obs report events.jsonl [--json report.json]

Stdlib-only (no jax): runs anywhere the JSONL file can be copied.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.events import read_events, validate_stream
from repro.obs.report import format_report, run_report
from repro.obs.trace import export_chrome_trace, trace_track_names


def _load(path: str):
    try:
        return read_events(path)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        raise SystemExit(1)


def _cmd_validate(args) -> int:
    events = _load(args.events)
    problems = validate_stream(events)
    for i, msg in problems:
        print(f"{args.events}:{i + 1}: {msg}")
    tracks = sorted({e.get("track") for e in events
                     if isinstance(e, dict) and e.get("track")})
    if args.min_tracks and len(tracks) < args.min_tracks:
        problems.append((0, "tracks"))
        print(f"{args.events}: only {len(tracks)} tracks "
              f"({', '.join(tracks)}), need >= {args.min_tracks}")
    if problems:
        print(f"INVALID: {len(problems)} problem(s) in {len(events)} events")
        return 1
    print(f"OK: {len(events)} events, {len(tracks)} tracks "
          f"({', '.join(tracks)})")
    return 0


def _cmd_trace_export(args) -> int:
    events = _load(args.events)
    trace = export_chrome_trace(events, args.out)
    names = trace_track_names(trace)
    print(f"trace -> {args.out} ({len(trace['traceEvents'])} trace events, "
          f"{len(names)} tracks: {', '.join(names)})")
    print("load it at https://ui.perfetto.dev or chrome://tracing")
    return 0


def _cmd_report(args) -> int:
    events = _load(args.events)
    rep = run_report(events)
    print(format_report(rep))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=1)
        print(f"report json -> {args.json}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    v = sub.add_parser("validate",
                       help="schema-validate a JSONL event stream")
    v.add_argument("events")
    v.add_argument("--min-tracks", type=int, default=0,
                   help="also require at least N distinct tracks")
    v.set_defaults(fn=_cmd_validate)

    t = sub.add_parser("trace", help="timeline export")
    tsub = t.add_subparsers(dest="trace_cmd", required=True)
    te = tsub.add_parser("export",
                         help="render Chrome trace-event / Perfetto JSON")
    te.add_argument("events")
    te.add_argument("--out", required=True)
    te.set_defaults(fn=_cmd_trace_export)

    r = sub.add_parser("report", help="per-phase run cost breakdown")
    r.add_argument("events")
    r.add_argument("--json", default="")
    r.set_defaults(fn=_cmd_report)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
