"""The --history-out JSON as a VIEW derived from the event stream.

``launch/train.py`` used to assemble its history dict by hand alongside
the telemetry; now the stream is the single source of truth and this
module projects it back into the legacy shape (same fields, same
values — ``round`` stays 1-based, ``plan_events`` is the controller's
emission order) plus a ``schema_version`` key so downstream readers can
detect the provenance change.

View schema_version 2 == legacy fields derived from event-stream
schema 1 (``events.SCHEMA_VERSION``). View schema_version 3 adds the
per-round realized-participation columns ``active_nodes`` /
``masked_edges`` (from event-stream schema 2's sporadic rounds; None on
rounds that ran before participation tracking, so full-participation
streams project losslessly) — they are what lets ``repro.obs report``
attribute loss progress to availability. View schema_version 4 adds the
mega-scale cohort columns ``cohort_size`` / ``population`` (batched-
engine rounds sample a C-of-V cohort; ``train.py --virtual-nodes``
stamps both on every round event; None on non-sampled runs, so legacy
streams keep projecting losslessly).
"""
from __future__ import annotations

from typing import Iterable, List

__all__ = ["HISTORY_SCHEMA_VERSION", "history_view"]

HISTORY_SCHEMA_VERSION = 4

# Planner decision types that legacy plan_events carried (the
# controller's ``history`` list mirrored every cause, including
# trajectory chunks and probes).
_PLAN_TYPES = ("plan", "replan", "probe")


def history_view(events: Iterable[dict]) -> dict:
    """Project an event stream into the legacy train.py history JSON."""
    events = list(events)
    history: dict = {
        "schema_version": HISTORY_SCHEMA_VERSION,
        "round": [], "loss": [], "consensus_sq": [],
        "tau1": [], "tau2": [], "round_s": [],
        "active_nodes": [], "masked_edges": [],
        "cohort_size": [], "population": [],
    }
    for ev in events:
        if ev.get("type") != "round":
            continue
        d = ev.get("data", {})
        # Stream records the 0-based realized round index; the legacy
        # column was 1-based.
        history["round"].append(d.get("round", -1) + 1)
        history["loss"].append(d.get("loss"))
        history["consensus_sq"].append(d.get("consensus_sq"))
        history["tau1"].append(d.get("tau1"))
        history["tau2"].append(d.get("tau2"))
        history["round_s"].append(d.get("round_s"))
        # schema-2 sporadic rounds carry realized participation; rounds
        # from older streams (or full-participation executors that don't
        # track it) project as None.
        history["active_nodes"].append(d.get("active_nodes"))
        history["masked_edges"].append(d.get("masked_edges"))
        # schema-4 cohort columns (batched engine / --virtual-nodes).
        history["cohort_size"].append(d.get("cohort_size"))
        history["population"].append(d.get("population"))

    plan_events: List[dict] = [ev.get("data", {}) for ev in events
                               if ev.get("type") in _PLAN_TYPES]
    if plan_events:
        history["plan_events"] = plan_events

    history["schedule"] = [[t1, t2] for t1, t2 in
                           zip(history["tau1"], history["tau2"])]

    # Run-level summary counters (train.py emits one "run-summary"
    # counters event at the end; last writer wins).
    for ev in events:
        if ev.get("type") != "counters":
            continue
        d = ev.get("data", {})
        for key in ("schedule_mode", "compile_count_warmup", "compile_count"):
            if key in d:
                history[key] = d[key]
    return history
