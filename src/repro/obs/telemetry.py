"""The run-wide telemetry sink: thread-safe, monotonic, optionally JSONL.

``Telemetry`` collects schema-shaped events (see ``events.py``) into an
in-memory list and, when given a path, appends each one to a JSONL file
as it is emitted (so a crashed run still leaves a readable stream).

Clock discipline: every ``t`` is ``time.perf_counter()`` seconds since
the sink was constructed — monotonic, immune to wall-clock jumps. The
ONE absolute timestamp lives in the ``run`` header's
``data["wall_start"]`` so exported timelines can still be anchored to
calendar time.

The emit path is deliberately cheap (build a dict, append under a lock,
optionally one buffered ``write``): it is called from the training
loop's host side and from the prefetcher's worker thread, and the
telemetry-overhead bench holds it under 2% of superstep dispatch
throughput. It must never touch jax — the zero-sync / zero-recompile
contract on the round path is audited (``telemetry-neutrality`` in
``repro.analysis``), and keeping this module jax-free makes the failure
mode structurally impossible to introduce here.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Iterator, List, Optional

from .events import SCHEMA_VERSION, make_event

__all__ = ["Telemetry", "NullTelemetry"]


class Telemetry:
    """Typed event sink with span tracing on a monotonic clock.

    >>> tel = Telemetry(meta={"arch": "quad"})
    >>> with tel.span("gossip-flush", track="metrics"):
    ...     pass
    >>> [e["type"] for e in tel.events]
    ['run', 'span']
    """

    def __init__(self, path: Optional[str] = None, meta: Optional[dict] = None):
        self._t0 = time.perf_counter()
        self.wall_start = time.time()
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._file = open(path, "w", buffering=1) if path else None
        self.path = path
        header = {"schema": SCHEMA_VERSION, "wall_start": self.wall_start}
        if meta:
            header.update(meta)
        self.emit("run", track="run", name="run", t=0.0, **header)

    # -- clock ---------------------------------------------------------
    def now(self) -> float:
        """Monotonic seconds since this run's origin."""
        return time.perf_counter() - self._t0

    # -- emission ------------------------------------------------------
    def emit(self, type_: str, *, track: str = "run",
             name: Optional[str] = None, t: Optional[float] = None,
             dur: Optional[float] = None, **data) -> dict:
        """Record one event; ``data`` kwargs become the typed payload.

        ``t`` defaults to now; pass an explicit earlier ``t`` (plus
        ``dur``) for span-like events stamped at their start.
        """
        ev = make_event(type_, self.now() if t is None else t, track,
                        name=name, dur=dur, data=data or None)
        with self._lock:
            self._events.append(ev)
            if self._file is not None:
                self._file.write(json.dumps(ev) + "\n")
        return ev

    @contextlib.contextmanager
    def span(self, name: str, track: str = "run", **data) -> Iterator[None]:
        """Time a host-side region as a named span on ``track``."""
        t0 = self.now()
        try:
            yield
        finally:
            self.emit("span", track=track, name=name, t=t0,
                      dur=self.now() - t0, **data)

    # -- access --------------------------------------------------------
    @property
    def events(self) -> List[dict]:
        """Snapshot of the events emitted so far (copy — safe to mutate)."""
        with self._lock:
            return list(self._events)

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class NullTelemetry:
    """No-op drop-in: same surface as ``Telemetry``, records nothing.

    Instrumented code may take ``telemetry=None`` OR a ``NullTelemetry``;
    the former skips even the call, the latter keeps call sites
    unconditional where branching would be noisier.
    """

    path = None
    wall_start = 0.0

    def now(self) -> float:
        return 0.0

    def emit(self, type_: str, **kwargs) -> dict:
        return {}

    @contextlib.contextmanager
    def span(self, name: str, track: str = "run", **data) -> Iterator[None]:
        yield

    @property
    def events(self) -> List[dict]:
        return []

    def close(self) -> None:
        pass

    def __enter__(self) -> "NullTelemetry":
        return self

    def __exit__(self, *exc) -> None:
        pass
