"""Deterministic fault injection for sporadic DFL rounds.

One spec, two consumers: a ``FaultPlan`` turns a list of declarative fault
windows into (a) per-round participation masks — the ``[node_mask,
edge_mask]`` columns of the sporadic trajectory scanned by
``core.executor.RoundExecutor(participation=True)`` — and (b) priced
``planner.cost.Episode`` tariffs for the SAME windows, so the planner's
blocking baseline pays for exactly the outages the sporadic engine routes
around. That single-source-of-truth coupling is the point: a benchmark
(``benchmarks.bench_faults``) that injects faults from one object and
prices them from another can silently drift; here both derive from the
same ``FaultPlan``.

Semantics (matching ``core.dfl.round_body``):

- node_mask[i] = 0  — node i skips its local SGD steps this round (its
  params/opt state carry over); it STILL gossips. A crashed node that
  can neither compute nor talk is ``NodeCrash``: node mask + every
  incident edge masked.
- edge_mask[e] = 0  — edge e (canonical ``Topology.edges()`` order)
  gossips identity: its weight folds onto both endpoints' diagonals, so
  the effective mixing matrix stays symmetric doubly stochastic
  (``core.mixing.masked_mixing_matrix``).

Everything is deterministic: windowed faults are pure functions of the
round index; ``SporadicParticipation`` draws its Bernoulli masks from
``np.random.SeedSequence([seed, round_idx])`` so round r's masks never
depend on which rounds were evaluated before it (resume-safe, and
identical across the dense and sparse engines, which consume the same
trajectory rows).

JAX-free on purpose (numpy only): fault plans are host-side schedule
producers, importable from ``train.py`` argument parsing and from
``repro.obs`` tooling without touching the accelerator stack.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Tuple, Union

import numpy as np

from repro.core.topology import Topology
from repro.planner.cost import (
    CostModel,
    CostProcess,
    Episode,
    edge_outage,
)

__all__ = [
    "NodeCrash",
    "LinkOutage",
    "StragglerDelay",
    "LinkFlap",
    "SporadicParticipation",
    "FaultPlan",
    "CohortSampler",
    "load_fault_spec",
]


def _check_window(r_start: int, r_stop: int) -> None:
    if not (0 <= r_start < r_stop):
        raise ValueError(
            f"empty or negative fault window [{r_start}, {r_stop})")


@dataclasses.dataclass(frozen=True)
class NodeCrash:
    """Node ``node`` is down for rounds [r_start, r_stop): no local steps,
    and every incident edge is severed (the crashed node can't talk)."""

    node: int
    r_start: int
    r_stop: int

    def __post_init__(self):
        _check_window(self.r_start, self.r_stop)

    def active(self, r: int) -> bool:
        return self.r_start <= r < self.r_stop


@dataclasses.dataclass(frozen=True)
class LinkOutage:
    """The listed undirected edges are down for rounds [r_start, r_stop).
    Endpoints keep computing and keep gossiping over surviving edges."""

    edges: Tuple[Tuple[int, int], ...]
    r_start: int
    r_stop: int

    def __post_init__(self):
        _check_window(self.r_start, self.r_stop)
        object.__setattr__(
            self, "edges",
            tuple((min(i, j), max(i, j)) for (i, j) in self.edges))

    def active(self, r: int) -> bool:
        return self.r_start <= r < self.r_stop


@dataclasses.dataclass(frozen=True)
class StragglerDelay:
    """Node ``node`` runs ``slowdown``x slower for rounds [r_start,
    r_stop): it completes its local epoch only every ``slowdown``-th
    round (duty-cycle mask), but keeps gossiping its (stale) model.

    The duty cycle is phase-locked to the window: within it, node ``node``
    is unmasked on rounds where ``(r - r_start) % slowdown ==
    slowdown - 1`` — i.e. after each ``slowdown``-round stretch it has
    finally finished one epoch.
    """

    node: int
    slowdown: int
    r_start: int
    r_stop: int

    def __post_init__(self):
        _check_window(self.r_start, self.r_stop)
        if self.slowdown < 1:
            raise ValueError(f"slowdown must be >= 1, got {self.slowdown}")

    def active(self, r: int) -> bool:
        return self.r_start <= r < self.r_stop

    def computes(self, r: int) -> bool:
        return (r - self.r_start) % self.slowdown == self.slowdown - 1


@dataclasses.dataclass(frozen=True)
class LinkFlap:
    """Edge ``edge`` oscillates for rounds [r_start, r_stop): up for the
    first ``up_rounds`` of every ``period``-round cycle, down for the
    rest (an intermittently-associating wireless link)."""

    edge: Tuple[int, int]
    period: int
    up_rounds: int
    r_start: int
    r_stop: int

    def __post_init__(self):
        _check_window(self.r_start, self.r_stop)
        if not (1 <= self.up_rounds < self.period):
            raise ValueError(
                f"need 1 <= up_rounds < period, got up_rounds="
                f"{self.up_rounds} period={self.period}")
        i, j = self.edge
        object.__setattr__(self, "edge", (min(i, j), max(i, j)))

    def active(self, r: int) -> bool:
        return self.r_start <= r < self.r_stop

    def is_up(self, r: int) -> bool:
        return (r - self.r_start) % self.period < self.up_rounds


@dataclasses.dataclass(frozen=True)
class SporadicParticipation:
    """I.i.d. Bernoulli participation for rounds [r_start, r_stop): each
    node is up w.p. ``p_node``, each edge w.p. ``p_edge``, drawn from a
    per-round seed stream (see module docstring). This is the paper's
    sporadic-availability regime; the expected mixing matrix it induces
    is ``planner.bounds.expected_mixing``."""

    p_node: float
    p_edge: float
    r_start: int
    r_stop: int

    def __post_init__(self):
        _check_window(self.r_start, self.r_stop)
        for name in ("p_node", "p_edge"):
            p = getattr(self, name)
            if not (0.0 <= p <= 1.0):
                raise ValueError(f"{name} must be in [0, 1], got {p}")

    def active(self, r: int) -> bool:
        return self.r_start <= r < self.r_stop


Fault = Union[NodeCrash, LinkOutage, StragglerDelay, LinkFlap,
              SporadicParticipation]

_KINDS = {
    "crash": NodeCrash,
    "outage": LinkOutage,
    "straggler": StragglerDelay,
    "flap": LinkFlap,
    "sporadic": SporadicParticipation,
}
_KIND_OF = {v: k for k, v in _KINDS.items()}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An ordered set of fault windows over a fixed topology.

    ``masks(r)`` is the AND-composition of every active fault's masks at
    round ``r`` (a node masked by any fault is masked; an edge masked by
    any fault — or incident to a crashed node — is masked).
    """

    topology: Topology
    faults: Tuple[Fault, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))
        eidx = self.topology.edge_index()
        for f in self.faults:
            if isinstance(f, NodeCrash) or isinstance(f, StragglerDelay):
                if not (0 <= f.node < self.topology.num_nodes):
                    raise ValueError(
                        f"fault names node {f.node} but "
                        f"{self.topology.name} has "
                        f"{self.topology.num_nodes} nodes")
            elif isinstance(f, LinkOutage):
                for e in f.edges:
                    if e not in eidx:
                        raise ValueError(
                            f"fault names edge {e} absent from "
                            f"{self.topology.name}")
            elif isinstance(f, LinkFlap):
                if f.edge not in eidx:
                    raise ValueError(
                        f"fault names edge {f.edge} absent from "
                        f"{self.topology.name}")

    # -- mask production ----------------------------------------------------

    def masks(self, round_idx: int) -> Tuple[np.ndarray, np.ndarray]:
        """(node_mask [N], edge_mask [E]) int32 at ``round_idx``."""
        topo = self.topology
        eidx = topo.edge_index()
        node_mask = np.ones(topo.num_nodes, dtype=np.int32)
        edge_mask = np.ones(topo.num_edges, dtype=np.int32)
        for f in self.faults:
            if not f.active(round_idx):
                continue
            if isinstance(f, NodeCrash):
                node_mask[f.node] = 0
                for e, k in eidx.items():
                    if f.node in e:
                        edge_mask[k] = 0
            elif isinstance(f, LinkOutage):
                for e in f.edges:
                    edge_mask[eidx[e]] = 0
            elif isinstance(f, StragglerDelay):
                if not f.computes(round_idx):
                    node_mask[f.node] = 0
            elif isinstance(f, LinkFlap):
                if not f.is_up(round_idx):
                    edge_mask[eidx[f.edge]] = 0
            elif isinstance(f, SporadicParticipation):
                rng = np.random.default_rng(
                    np.random.SeedSequence([self.seed, round_idx]))
                up_n = rng.random(topo.num_nodes) < f.p_node
                up_e = rng.random(topo.num_edges) < f.p_edge
                node_mask &= up_n.astype(np.int32)
                edge_mask &= up_e.astype(np.int32)
        return node_mask, edge_mask

    def mask_trajectory(
        self, taus: np.ndarray, round0: int = 0
    ) -> np.ndarray:
        """Widen a ``[K, 2]`` tau trajectory to the ``[K, 2 + N + E]``
        participation rows ``RoundExecutor(participation=True)`` scans
        (row k carries the masks of absolute round ``round0 + k``)."""
        taus = np.asarray(taus, dtype=np.int32)
        if taus.ndim != 2 or taus.shape[1] != 2:
            raise ValueError(
                f"expected a [K, 2] tau trajectory, got {taus.shape}")
        rows = []
        for k in range(taus.shape[0]):
            nm, em = self.masks(round0 + k)
            rows.append(np.concatenate([taus[k], nm, em]))
        return np.stack(rows).astype(np.int32) if rows else np.zeros(
            (0, 2 + self.topology.num_nodes + self.topology.num_edges),
            dtype=np.int32)

    def events(self, round_idx: int) -> List[Dict[str, Any]]:
        """Telemetry payloads for faults whose window STARTS or STOPS at
        ``round_idx`` (emitted as ``fault`` events by ``train.py``)."""
        out = []
        for f in self.faults:
            if round_idx == f.r_start:
                out.append(dict(self._spec_of(f), phase="start"))
            if round_idx == f.r_stop:
                out.append(dict(self._spec_of(f), phase="stop"))
        return out

    # -- pricing ------------------------------------------------------------

    def episodes(self, seconds_per_round: float, base_link=None,
                 residual: float = 1e-3) -> Tuple[Episode, ...]:
        """The same fault windows as deployment-clock ``Episode`` tariffs,
        for pricing the BLOCKING baseline: a run that refuses to skip
        work waits out every outage at the residual link rate, and waits
        for every straggler's slow epoch. ``base_link`` is the healthy
        LinkModel/WirelessLinks table tariffs derate from (unit LinkModel
        when omitted).

        ``SporadicParticipation`` contributes no tariff; its cost story
        lives in the masks (skipped work), not in a degraded link.
        """
        spr = float(seconds_per_round)
        if spr <= 0.0:
            raise ValueError(f"seconds_per_round must be > 0, got {spr}")
        link0 = base_link if base_link is not None else _unit_link()
        eps: List[Episode] = []
        # Compute stragglers compose natively (Episode compute scales
        # multiply), so each gets its own episode.
        for f in self.faults:
            if isinstance(f, StragglerDelay):
                eps.append(Episode(
                    t_start=f.r_start * spr, t_stop=f.r_stop * spr,
                    compute_scale=float(f.slowdown),
                    label=f"straggler@r{f.r_start}-{f.r_stop}"))
        # Link tariffs do NOT compose across episodes (a later episode's
        # link table replaces the earlier one's), so overlapping link
        # faults are flattened here into piecewise-constant windows, each
        # carrying the FULL composed table of every fault active in it.
        linky = [f for f in self.faults
                 if isinstance(f, (NodeCrash, LinkOutage, LinkFlap))]
        bounds = sorted({f.r_start for f in linky}
                        | {f.r_stop for f in linky})
        for a, b in zip(bounds, bounds[1:]):
            active = [f for f in linky
                      if f.r_start <= a and b <= f.r_stop]
            if not active:
                continue
            link = link0
            for f in active:
                if isinstance(f, NodeCrash):
                    down = [e for e in self.topology.edges() if f.node in e]
                    link = edge_outage(link, down, residual=residual)
                elif isinstance(f, LinkOutage):
                    link = edge_outage(link, list(f.edges),
                                       residual=residual)
                else:  # LinkFlap: time-averaged tariff — full rate for
                    # the up fraction of the cycle, residual for the rest
                    frac_down = 1.0 - f.up_rounds / f.period
                    res = (1.0 - frac_down) + frac_down * residual
                    link = edge_outage(link, [f.edge], residual=res)
            eps.append(Episode(
                t_start=a * spr, t_stop=b * spr, link=link,
                label="degraded@r{}-{}:{}".format(
                    a, b, "+".join(_KIND_OF[type(f)] for f in active))))
        return tuple(eps)

    def cost_process(self, base: CostModel, seconds_per_round: float,
                     residual: float = 1e-3) -> CostProcess:
        """Attach this plan's tariffs to ``base`` (episode link tables
        derate ``base.link``, so per-edge overrides survive)."""
        return CostProcess(base=base, episodes=self.episodes(
            seconds_per_round, base_link=base.link, residual=residual))

    # -- (de)serialization --------------------------------------------------

    @staticmethod
    def _spec_of(f: Fault) -> Dict[str, Any]:
        d = dataclasses.asdict(f)
        if "edges" in d:
            d["edges"] = [list(e) for e in d["edges"]]
        if "edge" in d:
            d["edge"] = list(d["edge"])
        d["kind"] = _KIND_OF[type(f)]
        return d

    def to_spec(self) -> Dict[str, Any]:
        return {"seed": self.seed,
                "faults": [self._spec_of(f) for f in self.faults]}

    @classmethod
    def from_spec(cls, topology: Topology,
                  spec: Dict[str, Any]) -> "FaultPlan":
        faults = []
        for fd in spec.get("faults", ()):
            fd = dict(fd)
            kind = fd.pop("kind")
            if kind not in _KINDS:
                raise ValueError(
                    f"unknown fault kind {kind!r}; "
                    f"expected one of {sorted(_KINDS)}")
            if "edges" in fd:
                fd["edges"] = tuple(tuple(e) for e in fd["edges"])
            if "edge" in fd:
                fd["edge"] = tuple(fd["edge"])
            faults.append(_KINDS[kind](**fd))
        return cls(topology=topology, faults=tuple(faults),
                   seed=int(spec.get("seed", 0)))


@dataclasses.dataclass(frozen=True)
class CohortSampler:
    """Uniform-without-replacement cohort sampling over a virtual
    population (the DFedAvg client-sampling regime, arXiv:2104.11375).

    Each round draws ``cohort`` distinct node ids from ``[0, population)``
    via ``np.random.SeedSequence([seed, round_idx])`` — the SAME per-round
    seed-stream discipline as ``SporadicParticipation``, so round r's
    cohort never depends on which rounds were evaluated before it
    (resume-safe: a checkpoint restart at round r redraws r's cohort
    bit-identically from (seed, r), with no sampler state to persist
    beyond ``DFLState.round_idx``).

    Draws are SORTED so that at full participation (``cohort ==
    population``) the draw is exactly ``arange(population)`` — the
    batched engine's identity cohort, which makes the sampled trajectory
    row degenerate bitwise to the legacy participation row
    (tests/test_cohort_sampling.py).

    ``cohort_trajectory`` composes with ``FaultPlan.mask_trajectory``:
    feed it the chaos plan's ``[K, 2 + C + E]`` rows and it splices the
    cohort ids in front of the masks, yielding the ``[K, 2 + 2C + E]``
    rows ``RoundExecutor(engine="batched")`` scans. Mask semantics are
    then *within-cohort*: ``node_mask[j]`` gates cohort slot j (i.e.
    virtual node ``ids[j]``), so a chaos plan built over the C-node
    cohort topology applies to whichever nodes were drawn this round.
    """

    population: int
    cohort: int
    seed: int = 0

    def __post_init__(self):
        if not (1 <= self.cohort <= self.population):
            raise ValueError(
                f"need 1 <= cohort <= population, got cohort={self.cohort} "
                f"population={self.population}")

    @property
    def rate(self) -> float:
        """Sampling rate C/V — the participation rate the planner prices
        via ``planner.bounds.sampling_availability``."""
        return self.cohort / self.population

    def draw(self, round_idx: int) -> np.ndarray:
        """Sorted int32 cohort ids for absolute round ``round_idx``."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, round_idx]))
        ids = rng.choice(self.population, size=self.cohort, replace=False)
        return np.sort(ids).astype(np.int32)

    def cohort_trajectory(self, taus: np.ndarray, round0: int = 0,
                          num_edges: int = 0) -> np.ndarray:
        """Widen a trajectory with per-round cohort ids.

        Accepts ``[K, 2]`` rows (tau1, tau2) — padded with all-ones
        masks — or ``[K, 2 + C + E]`` participation rows (e.g. from
        ``FaultPlan.mask_trajectory`` over the cohort topology), and
        returns the ``[K, 2 + 2C + E]`` cohort rows of the batched
        engine (row k carries the draw of absolute round ``round0 + k``).
        ``num_edges`` (E) is required to disambiguate the input layout.
        """
        taus = np.asarray(taus, dtype=np.int32)
        c, e = self.cohort, int(num_edges)
        if taus.ndim != 2 or taus.shape[1] not in (2, 2 + c + e):
            raise ValueError(
                f"expected [K, 2] or [K, {2 + c + e}] rows "
                f"(tau1, tau2, node mask [{c}], edge mask [{e}]), "
                f"got shape {taus.shape}")
        if taus.shape[1] == 2:
            taus = np.concatenate(
                [taus, np.ones((taus.shape[0], c + e), np.int32)], axis=1)
        rows = [np.concatenate([taus[k, :2], self.draw(round0 + k),
                                taus[k, 2:]])
                for k in range(taus.shape[0])]
        return (np.stack(rows).astype(np.int32) if rows
                else np.zeros((0, 2 + 2 * c + e), dtype=np.int32))

    # -- (de)serialization ---------------------------------------------------

    def to_spec(self) -> Dict[str, Any]:
        return {"population": self.population, "cohort": self.cohort,
                "seed": self.seed}

    @classmethod
    def from_spec(cls, spec: Dict[str, Any]) -> "CohortSampler":
        return cls(population=int(spec["population"]),
                   cohort=int(spec["cohort"]),
                   seed=int(spec.get("seed", 0)))


def _unit_link():
    from repro.planner.cost import LinkModel
    return LinkModel(bytes_per_s=1.0)


def load_fault_spec(arg: str) -> Dict[str, Any]:
    """Parse ``train.py --faults``: inline JSON, or ``@path`` to a JSON
    file."""
    text = arg
    if arg.startswith("@"):
        with open(arg[1:], "r", encoding="utf-8") as fh:
            text = fh.read()
    spec = json.loads(text)
    if not isinstance(spec, dict) or "faults" not in spec:
        raise ValueError(
            'fault spec must be an object with a "faults" list, e.g. '
            '{"seed": 0, "faults": [{"kind": "crash", "node": 3, '
            '"r_start": 2, "r_stop": 6}]}')
    return spec
