"""Beyond-paper performance variants for the §Perf hillclimbs.

Each builder returns a ``Built`` comparable 1:1 against the baseline from
``steps.py`` (same abstract signature), so EXPERIMENTS.md can report
before/after roofline terms per optimization:

  * ``build_gossip_step_sparse``   — ring gossip as per-neighbor
    ``collective-permute`` inside shard_map (traffic ~ deg/(N-1) of the
    dense all-gather lowering).
  * ``build_gossip_step_bf16``     — dense mixing with bf16 accumulate
    (halves gossip wire bytes; weight-averaging tolerates bf16).
  * ``build_gossip_step_power``    — C^tau2 collapsed into one contraction
    (plain DFL only): tau2 gossip rounds for the price of one.
  * ``build_decode_unchunked``     — decode attention without the KV-chunk
    scan: one masked softmax over the model-sharded cache (removes the
    involuntary resharding XLA reports for dynamic-slice over a sharded
    sequence dim).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES
from repro.core import mixing as mixing_lib
from repro.core import substrate as substrate_lib
from repro.launch import sharding as shard_lib
from repro.launch.steps import (Built, _abstract_state, _act_policy,
                                dfl_setup)
from repro.models import transformer as tf_lib
from repro.optim import sgd


def build_gossip_step_sparse(arch: ArchConfig, mesh: Mesh, *,
                             reduced: bool = False) -> Built:
    """Ring gossip via ppermute over the node mesh axes (shard_map)."""
    cfg = arch.reduced if reduced else arch.model
    mode, n, dcfg = dfl_setup(arch, mesh, tau1=1, tau2=1, compression=None,
                              mixing_impl="dense")
    assert mode == "gossip-dp", "sparse path needs node dim on mesh axes"
    opt = sgd(1e-3)
    state_abs, state_sh, _ = _abstract_state(arch, cfg, mesh, mode, n, opt,
                                             compressed=False)
    topo = dcfg.topology
    shifts = topo.shifts()
    assert shifts, f"{topo.name} is not circulant"
    self_w = float(topo.self_weights[0])
    naxes = shard_lib.node_axes_for(mode, mesh)

    # shard_map in/out specs: the node dim is manual over the node axes;
    # every other dim is manual over whatever the params sharding says.
    in_specs = jax.tree_util.tree_map(lambda s: s.spec, state_sh.params)
    axis_name = naxes if len(naxes) > 1 else naxes[0]

    def gossip_sparse(params):
        return mixing_lib.mix_ppermute_shifts(params, shifts, self_w,
                                              axis_name)

    fn = jax.jit(
        substrate_lib.shard_map(gossip_sparse, mesh, (in_specs,), in_specs,
                                check=False),
        donate_argnums=(0,),
    )
    return Built(fn, (state_abs.params,), {
        "kind": "gossip", "arch": arch.arch_id, "mode": mode, "nodes": n,
        "mixing": "ppermute", "compressed": False,
    })


def build_gossip_step_bf16(arch: ArchConfig, mesh: Mesh, *,
                           reduced: bool = False) -> Built:
    """Dense mixing with bf16 contraction (halve the gathered bytes)."""
    cfg = arch.reduced if reduced else arch.model
    mode, n, dcfg = dfl_setup(arch, mesh, tau1=1, tau2=1, compression=None,
                              mixing_impl="dense")
    opt = sgd(1e-3)
    state_abs, state_sh, _ = _abstract_state(arch, cfg, mesh, mode, n, opt,
                                             compressed=False)
    cm = jnp.asarray(dcfg.topology.mixing, jnp.bfloat16)

    def gossip_bf16(params):
        return jax.tree_util.tree_map(
            lambda x: jnp.einsum("ji,j...->i...", cm.astype(x.dtype)
                                 if x.dtype == jnp.float32 else cm,
                                 x).astype(x.dtype),
            params)

    fn = jax.jit(gossip_bf16, in_shardings=(state_sh.params,),
                 out_shardings=state_sh.params, donate_argnums=(0,))
    return Built(fn, (state_abs.params,), {
        "kind": "gossip", "arch": arch.arch_id, "mode": mode, "nodes": n,
        "mixing": "dense-bf16", "compressed": False,
    })


def build_gossip_step_power(arch: ArchConfig, mesh: Mesh, tau2: int, *,
                            reduced: bool = False) -> Built:
    """One contraction with C^tau2 — amortizes tau2 gossip rounds."""
    cfg = arch.reduced if reduced else arch.model
    mode, n, dcfg = dfl_setup(arch, mesh, tau1=1, tau2=tau2, compression=None,
                              mixing_impl="dense_power")
    opt = sgd(1e-3)
    state_abs, state_sh, _ = _abstract_state(arch, cfg, mesh, mode, n, opt,
                                             compressed=False)

    def gossip_pow(params):
        return mixing_lib.mix_dense_power(params, dcfg.topology, tau2)

    fn = jax.jit(gossip_pow, in_shardings=(state_sh.params,),
                 out_shardings=state_sh.params, donate_argnums=(0,))
    return Built(fn, (state_abs.params,), {
        "kind": "gossip", "arch": arch.arch_id, "mode": mode, "nodes": n,
        "mixing": f"dense-power-{tau2}", "compressed": False,
    })
