"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
undercounts every ``lax.scan``-structured model (layer stacks, attention
chunk scans, mamba chunk scans, the tau1/tau2 DFL loops) by the trip
count. This module re-derives flops / bytes / collective-bytes from the
optimized HLO text, multiplying loop bodies by their
``backend_config={"known_trip_count":{"n":...}}`` annotation (present for
all lax.scan/fori loops after XLA's loop analysis).

Accounting model (documented, deliberately simple):
  * flops: 2 * prod(result_shape) * prod(lhs contracting dims) per `dot`
    (convolutions ignored — none in the production models); recursion into
    fusions / called computations / while bodies (x trip count).
  * bytes: per *scheduled* instruction (i.e. NOT inside fusion bodies),
    2 x result bytes (one write + one read by the consumer), excluding pure
    bookkeeping ops (parameter/constant/tuple/get-tuple-element/bitcast);
    recursion as above. Counting full operand bytes per consumer was tried
    first and overcounts shared operands (a gathered weight read by k
    consumers billed k times) by 3-20x; the 2x-result model matches XLA's
    own per-dot accounting within ~1.5x on calibration cases.
  * collective bytes: result bytes per collective instruction (tuple
    results halved for async (in, out) pairs), x enclosing trip counts.

Validation: tests/test_hloanalysis.py checks a 7-iteration scanned matmul
reports exactly 7x the flops of the unrolled cost, and that the corrected
flops of an unrolled model match cost_analysis within a few %.
"""
from __future__ import annotations

import dataclasses
import json
import re
import warnings
from typing import Dict, FrozenSet, List, Optional, Tuple


class HloParseWarning(UserWarning):
    """The HLO text had a construct the accounting model can only
    approximate (e.g. a while loop without ``known_trip_count``) — the
    result is a lower bound there, never a silent drop."""

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_SINGLE_SHAPE_RE = re.compile(r"([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")
_OPCODE_RE = re.compile(r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_BOOKKEEPING = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    """All (dtype, dims) components of a (possibly tuple) shape string."""
    return [
        (m.group(1), [int(d) for d in m.group(2).split(",")] if m.group(2)
         else [])
        for m in _SHAPE_RE.finditer(shape_str)
    ]


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    shape: str
    opcode: str
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]


def _parse_instr_line(line: str):
    """Balanced-paren instruction parser (regex fails on nested tuple
    shapes like while-carry tuples, silently dropping the layer scans)."""
    st = line.strip()
    if st.startswith("ROOT "):
        st = st[5:]
    if not st.startswith("%"):
        return None
    eq = st.find(" = ")
    if eq < 0:
        return None
    name = st[1:eq]
    rest = st[eq + 3:]
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape = rest[:end + 1]
        rest2 = rest[end + 1:].lstrip()
    else:
        m = _SINGLE_SHAPE_RE.match(rest)
        if not m:
            return None
        shape = m.group(1)
        rest2 = rest[m.end():].lstrip()
    m = _OPCODE_RE.match(rest2)
    if not m:
        return None
    return name, shape, m.group(1)


def _parse_operands(line: str, opcode: str) -> List[str]:
    start = line.index(opcode + "(") + len(opcode) + 1
    depth = 1
    i = start
    while i < len(line) and depth:
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
        i += 1
    args = line[start:i - 1]
    return re.findall(r"%([\w.\-]+)", args)


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line)
        if m and " = " not in line:
            cur = Computation(name=m.group(2), instructions=[])
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_instr_line(line)
        if parsed is None:
            continue
        name, shape, opcode = parsed
        try:
            operands = _parse_operands(line, opcode)
        except ValueError:
            operands = []
        cur.instructions.append(Instruction(name, shape, opcode, line,
                                            operands))
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    unknown_trip_loops: int = 0

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in _COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k]
        self.unknown_trip_loops += other.unknown_trip_loops
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    {n: v * k for n, v in self.coll_bytes.items()},
                    self.unknown_trip_loops)

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def _dot_flops(instr: Instruction, shapes: Dict[str, str]) -> float:
    result = _shape_dims(instr.shape)
    out_elems = 1
    for _, dims in result:
        for d in dims:
            out_elems *= d
    mc = _LHS_CONTRACT_RE.search(instr.line)
    k = 1
    if mc and instr.operands:
        lhs_shape = shapes.get(instr.operands[0], "")
        lhs_dims_all = _shape_dims(lhs_shape)
        if lhs_dims_all:
            lhs_dims = lhs_dims_all[0][1]
            for idx in (int(x) for x in mc.group(1).split(",") if x):
                if idx < len(lhs_dims):
                    k *= lhs_dims[idx]
    return 2.0 * out_elems * k


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        # global result-shape table (names are module-unique in practice).
        self.shapes: Dict[str, str] = {}
        for comp in self.comps.values():
            for ins in comp.instructions:
                self.shapes[ins.name] = ins.shape
        self._fusion_bodies = set()
        for comp in self.comps.values():
            for ins in comp.instructions:
                if ins.opcode == "fusion":
                    mc = _CALLS_RE.search(ins.line)
                    if mc:
                        self._fusion_bodies.add(mc.group(1))
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    def computation_cost(self, name: str, fused: bool = False) -> Cost:
        key = (name, fused)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()  # cycle guard
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            return total
        for ins in comp.instructions:
            total += self._instruction_cost(ins, fused)
        self._memo[key] = total
        return total

    def _instruction_cost(self, ins: Instruction, fused: bool) -> Cost:
        c = Cost()
        op = ins.opcode
        if op == "while":
            mb = _BODY_RE.search(ins.line)
            mt = _TRIP_RE.search(ins.line)
            trip = int(mt.group(1)) if mt else 1
            if not mt:
                c.unknown_trip_loops += 1
                warnings.warn(
                    f"while loop {ins.name!r} has no known_trip_count "
                    "annotation; its body is counted ONCE (cost is a lower "
                    "bound — check unknown_trip_loops in the result)",
                    HloParseWarning, stacklevel=2)
            if mb:
                c += self.computation_cost(mb.group(1)).scaled(trip)
            return c
        if op in ("fusion", "call", "custom-call", "conditional",
                  "async-start", "map", "reduce", "scatter", "sort",
                  "reduce-window", "select-and-scatter"):
            for mc in _CALLS_RE.finditer(ins.line):
                c += self.computation_cost(
                    mc.group(1), fused=(op == "fusion") or fused)
            # also to_apply= computations (reduce etc.) are tiny; skip.
        if op == "dot":
            c.flops += _dot_flops(ins, self.shapes)
        clean = op.replace("-start", "").replace("-done", "")
        if clean in _COLLECTIVES:
            if "-done(" in ins.line:
                pass  # counted at -start
            else:
                b = _shape_bytes(ins.shape)
                if ins.shape.startswith("("):
                    b /= 2.0  # async (in, out) tuples double-count
                c.coll_bytes[clean] += b
        if not fused and op not in _BOOKKEEPING and op != "while":
            rb = _shape_bytes(ins.shape)
            # in-place accumulator heuristic: a fusion/DUS whose result
            # shape equals an operand's (loop-carried KV caches, scan
            # accumulators) aliases that operand in-place — real traffic is
            # bounded by the OTHER operands (the updated slice), not the
            # whole buffer (observed 2x516 GB/token phantom traffic on the
            # stacked decode cache without this).
            def _elems(sh):
                n = 0
                for _, dims in _shape_dims(sh):
                    e = 1
                    for d in dims:
                        e *= d
                    n += e
                return n

            res_elems = _elems(ins.shape)
            op_shapes = [self.shapes.get(o, "") for o in ins.operands]
            if any(_elems(o) == res_elems and res_elems > 0
                   for o in op_shapes):
                others = sum(_shape_bytes(o) for o in op_shapes
                             if _elems(o) != res_elems)
                rb = min(rb, others)
            c.bytes += 2.0 * rb
        return c

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.computation_cost(self.entry)


_ST_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\}[, ]*)*)\}")
_PAIR_RE = re.compile(r"\{(\d+),(\d+)\}")


@dataclasses.dataclass(frozen=True)
class CollectiveSite:
    """One collective instruction, located: where it sits (computation,
    fusion nesting), how often it runs (product of enclosing loop trips),
    and — for collective-permute — its (source, target) pairs."""

    opcode: str                                   # canonical (no -start)
    name: str                                     # instruction name
    computation: str                              # enclosing computation
    pairs: Optional[Tuple[Tuple[int, int], ...]]  # permutes only
    trip_product: int                             # enclosing loop trips
    in_fusion: bool
    known_trips: bool   # False if ANY enclosing loop lacked a trip count


def collective_sites(hlo_text: str, warn: bool = True
                     ) -> List[CollectiveSite]:
    """Every collective in the module, walked through while bodies,
    fusions and called computations — the auditor's parsing entry point
    (``repro.analysis.audits`` matches permute pairs against
    ``Topology.shifts()``).

    Collectives nested in fusion bodies are reported (flagged
    ``in_fusion``), and a while loop missing ``known_trip_count`` warns
    (``HloParseWarning``) and counts its body ONCE with
    ``known_trips=False`` — never a silent drop either way. Async
    ``-done`` halves are skipped (their ``-start`` is the site).
    """
    comps, entry = parse_module(hlo_text)
    sites: List[CollectiveSite] = []

    def visit(name: str, trip: int, in_fusion: bool, known: bool,
              stack: FrozenSet[str]):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        stack = stack | {name}
        for ins in comp.instructions:
            op = ins.opcode
            if op == "while":
                mb = _BODY_RE.search(ins.line)
                mt = _TRIP_RE.search(ins.line)
                t = int(mt.group(1)) if mt else 1
                if not mt and warn:
                    warnings.warn(
                        f"while loop {ins.name!r} has no known_trip_count; "
                        "collectives in its body are counted once "
                        "(known_trips=False)", HloParseWarning, stacklevel=2)
                if mb:
                    visit(mb.group(1), trip * t, in_fusion,
                          known and bool(mt), stack)
                continue
            clean = op.replace("-start", "").replace("-done", "")
            if clean in _COLLECTIVES and not op.endswith("-done"):
                m = _ST_PAIRS_RE.search(ins.line)
                pairs = (tuple((int(a), int(b))
                               for a, b in _PAIR_RE.findall(m.group(1)))
                         if m else None)
                sites.append(CollectiveSite(
                    opcode=clean, name=ins.name, computation=name,
                    pairs=pairs, trip_product=trip, in_fusion=in_fusion,
                    known_trips=known))
            for mc in _CALLS_RE.finditer(ins.line):
                visit(mc.group(1), trip, in_fusion or op == "fusion",
                      known, stack)

    if entry is not None:
        visit(entry, 1, False, True, frozenset())
    return sites


def analyze_text(hlo_text: str) -> Dict:
    cost = HloAnalyzer(hlo_text).entry_cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.total_coll_bytes,
        "collective_bytes_per_kind": dict(cost.coll_bytes),
        "unknown_trip_loops": cost.unknown_trip_loops,
    }
