"""Roofline-term extraction from compiled XLA artifacts (DESIGN.md sec. 6).

compute    = HLO_FLOPs_per_device / 197e12            [bf16 peak, v5e]
memory     = HLO_bytes_per_device / 819e9
collective = collective_bytes_per_device / 50e9

CALIBRATION (verified empirically on this jax/xla build): under SPMD
partitioning ``cost_analysis()`` / ``memory_analysis()`` / ``as_text()``
describe the PER-DEVICE module, so the terms above do NOT divide by chip
count; the spec formulas (global numerator / chips) are algebraically
identical.

``collective_bytes`` is parsed from the optimized HLO text: the summed
*result-shape* bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (documented accounting choice:
result bytes ~ bytes landed per op; ppermute and reduce-scatter are counted
at their true wire size, all-gather at its fan-in size).

NOTE on loops: XLA cost_analysis counts a while-loop body ONCE (trip counts
are dynamic); the launchers therefore lower *unit* steps (one local step,
one gossip step) and the round composes analytically (steps.py docstring).
Collectives inside scanned layers are handled the same way: the per-layer
scan in the model means HLO text contains the body once; we multiply by the
statically-known trip count below.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.launch.mesh import DCN_BW, HBM_BW, HBM_PER_CHIP, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.:  %all-reduce.5 = f32[16,1024,512]{2,1,0} all-reduce(...)
_INSTR_RE = re.compile(
    r"=\s+(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
# tuple-result collectives:  = (f32[..], f32[..]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_RE = re.compile(r"trip_count=(\d+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Sum result bytes per collective kind from (optimized) HLO text."""
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            # async pairs: count only the -start (has the full shape).
            continue
        m = _INSTR_RE.search(line)
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            per_kind[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.group(1), m.group(2)
            total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(shapes))
            # tuple results of N-operand collectives count payload once:
            # (in, out) tuples for async ops double-count; halve.
            per_kind[kind] += total / 2.0
            counts[kind] += 1
    return {
        "bytes_per_kind": per_kind,
        "counts": counts,
        "total_bytes": float(sum(per_kind.values())),
    }


_ANY_SHAPE_RE = re.compile(r"^\s*%?[\w.\-]+ = ([a-z0-9]+)\[([0-9,]+)\]")


def largest_buffers(hlo_text: str, top: int = 8) -> List[Dict[str, Any]]:
    """Top-N single instruction result buffers (per device) — catches
    accidentally-replicated tensors that the no-liveness temp sum hides."""
    found = []
    for line in hlo_text.splitlines():
        m = _ANY_SHAPE_RE.match(line)
        if not m:
            continue
        b = _shape_bytes(m.group(1), m.group(2))
        if b >= 1 << 20:
            op = line.split("=", 1)[1].strip()
            opname = op.split("(")[0].split()[-1] if "(" in op else "?"
            found.append((b, m.group(1), m.group(2), opname))
    found.sort(reverse=True)
    out = []
    seen = set()
    for b, dt, dims, opname in found:
        key = (dt, dims, opname)
        if key in seen:
            continue
        seen.add(key)
        out.append({"bytes": b, "dtype": dt, "shape": dims, "op": opname})
        if len(out) >= top:
            break
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    link_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        # flops/bytes are per-device (see module docstring calibration).
        return self.flops / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def analyze_compiled(compiled, chips: int) -> Dict[str, Any]:
    """Extract cost/memory/collective numbers from a compiled executable."""
    cost = {}
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        cost = dict(c)
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("generated_code_size_in_bytes",
                     "argument_size_in_bytes",
                     "output_size_in_bytes",
                     "alias_size_in_bytes",
                     "peak_memory_in_bytes",
                     "temp_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes_from_hlo(hlo)
    flops = float(cost.get("flops", 0.0))
    # bytes accessed: prefer the explicit key; CPU-XLA sometimes omits it,
    # fall back to one-pass traffic = args + outputs + temps.
    hbm = float(cost.get("bytes accessed", 0.0))
    if hbm <= 0.0 and not mem.get("error"):
        hbm = float(sum(mem.get(k, 0) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes")))
    roof_raw = Roofline(flops=flops, hbm_bytes=hbm,
                        collective_bytes=coll["total_bytes"], chips=chips)
    # loop-aware (trip-count-corrected) analysis — the headline numbers.
    from repro.launch import hloanalysis

    try:
        corr = hloanalysis.analyze_text(hlo)
    except Exception as e:  # pragma: no cover
        corr = {"error": str(e)}
    if "error" not in corr:
        roof = Roofline(flops=corr["flops"], hbm_bytes=corr["bytes"],
                        collective_bytes=corr["collective_bytes"],
                        chips=chips)
    else:
        roof = roof_raw
    return {
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "memory": mem,
        "collectives": coll,
        "corrected": corr,
        "roofline": roof.as_dict(),
        "roofline_raw": roof_raw.as_dict(),
        "largest_buffers": largest_buffers(hlo),
        "hlo_bytes": len(hlo),
    }


def _as_roofline(obj) -> Roofline:
    """Coerce an ``analyze_compiled`` result dict (or a Roofline) to a
    Roofline so the overlap predictor takes either."""
    if isinstance(obj, Roofline):
        return obj
    if isinstance(obj, dict):
        d = obj.get("roofline", obj)
        return Roofline(
            flops=float(d.get("flops", 0.0)),
            hbm_bytes=float(d.get("hbm_bytes", 0.0)),
            collective_bytes=float(d.get("collective_bytes", 0.0)),
            chips=int(d.get("chips", 1)))
    raise TypeError(f"expected Roofline or analyze_compiled dict, got "
                    f"{type(obj).__name__}")


@dataclasses.dataclass
class OverlapPrediction:
    """Predicted round times of a (tau1, tau2) round under both executor
    overlap modes, from compiled-artifact roofline terms alone.

    additive_s  = tau1*t_local + tau2*t_gossip          (overlap="none")
    pipelined_s = tau1*t_local + max(0, tau2*t_gossip - tau1*t_local)
                                                        (overlap="pipeline")

    This is the same max-form model ``planner.cost.CostModel`` prices with
    — evaluated here from MEASURED per-collective wire bytes (parsed out
    of the optimized HLO by ``collective_bytes_from_hlo``) and the
    device's roofline terms, so the win is predicted before a single
    round runs.
    """

    t_local_step_s: float
    t_gossip_step_s: float
    tau1: int
    tau2: int

    @property
    def additive_s(self) -> float:
        return self.tau1 * self.t_local_step_s + self.tau2 * self.t_gossip_step_s

    @property
    def pipelined_s(self) -> float:
        window = self.tau1 * self.t_local_step_s
        return window + max(0.0, self.tau2 * self.t_gossip_step_s - window)

    @property
    def hidden_s(self) -> float:
        return self.additive_s - self.pipelined_s

    @property
    def speedup(self) -> float:
        return (self.additive_s / self.pipelined_s
                if self.pipelined_s > 0.0 else 1.0)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "t_local_step_s": self.t_local_step_s,
            "t_gossip_step_s": self.t_gossip_step_s,
            "tau1": self.tau1,
            "tau2": self.tau2,
            "additive_s": self.additive_s,
            "pipelined_s": self.pipelined_s,
            "hidden_s": self.hidden_s,
            "speedup": self.speedup,
        }


def predict_overlap(local_step, gossip_step, tau1: int, tau2: int,
                    *, t_local_step_s: Optional[float] = None,
                    ) -> OverlapPrediction:
    """Predict the overlap="pipeline" win for a (tau1, tau2) round.

    local_step / gossip_step: ``Roofline``s (or ``analyze_compiled``
    dicts) of ONE lowered local-update step and ONE gossip step — the
    unit artifacts the launchers already lower (steps.py docstring: XLA
    counts loop bodies once, so rounds compose analytically from unit
    steps).

    The local step is priced at its roofline bound max(compute_s,
    memory_s); the gossip step at its wire time collective_s (measured
    result bytes of its collective-permutes over the link bandwidth).
    ``t_local_step_s`` overrides the modeled local-step time with a
    measured one (the bench calibrates it from wall-clock tau2=0 runs)
    while keeping the gossip side byte-measured.
    """
    rl = _as_roofline(local_step)
    rg = _as_roofline(gossip_step)
    tl = (t_local_step_s if t_local_step_s is not None
          else max(rl.compute_s, rl.memory_s))
    return OverlapPrediction(t_local_step_s=float(tl),
                             t_gossip_step_s=float(rg.collective_s),
                             tau1=int(tau1), tau2=int(tau2))


def model_flops_train(active_params: int, tokens: int) -> float:
    """MODEL_FLOPS = 6 * N_active * D for one optimizer step."""
    return 6.0 * active_params * tokens


def model_flops_decode(active_params: int, batch: int) -> float:
    """2 * N_active per generated token (fwd only)."""
    return 2.0 * active_params * batch


def per_device_hbm_gib(mem: Dict[str, Any]) -> Optional[float]:
    """Bytes/device from memory_analysis (args+outputs+temps, aliases out)."""
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes")
    if not all(k in mem for k in keys):
        return None
    total = sum(mem[k] for k in keys) - mem.get("alias_size_in_bytes", 0)
    return total / 2**30
