"""Roofline-term extraction from compiled XLA artifacts (DESIGN.md sec. 6).

compute    = HLO_FLOPs_per_device / 197e12            [bf16 peak, v5e]
memory     = HLO_bytes_per_device / 819e9
collective = collective_bytes_per_device / 50e9

CALIBRATION (verified empirically on this jax/xla build): under SPMD
partitioning ``cost_analysis()`` / ``memory_analysis()`` / ``as_text()``
describe the PER-DEVICE module, so the terms above do NOT divide by chip
count; the spec formulas (global numerator / chips) are algebraically
identical.

``collective_bytes`` is parsed from the optimized HLO text: the summed
*result-shape* bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (documented accounting choice:
result bytes ~ bytes landed per op; ppermute and reduce-scatter are counted
at their true wire size, all-gather at its fan-in size).

NOTE on loops: XLA cost_analysis counts a while-loop body ONCE (trip counts
are dynamic); the launchers therefore lower *unit* steps (one local step,
one gossip step) and the round composes analytically (steps.py docstring).
Collectives inside scanned layers are handled the same way: the per-layer
scan in the model means HLO text contains the body once; we multiply by the
statically-known trip count below.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.launch.mesh import DCN_BW, HBM_BW, HBM_PER_CHIP, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.:  %all-reduce.5 = f32[16,1024,512]{2,1,0} all-reduce(...)
_INSTR_RE = re.compile(
    r"=\s+(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
# tuple-result collectives:  = (f32[..], f32[..]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_RE = re.compile(r"trip_count=(\d+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Sum result bytes per collective kind from (optimized) HLO text."""
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            # async pairs: count only the -start (has the full shape).
            continue
        m = _INSTR_RE.search(line)
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            per_kind[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.group(1), m.group(2)
            total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(shapes))
            # tuple results of N-operand collectives count payload once:
            # (in, out) tuples for async ops double-count; halve.
            per_kind[kind] += total / 2.0
            counts[kind] += 1
    return {
        "bytes_per_kind": per_kind,
        "counts": counts,
        "total_bytes": float(sum(per_kind.values())),
    }


_ANY_SHAPE_RE = re.compile(r"^\s*%?[\w.\-]+ = ([a-z0-9]+)\[([0-9,]+)\]")


def largest_buffers(hlo_text: str, top: int = 8) -> List[Dict[str, Any]]:
    """Top-N single instruction result buffers (per device) — catches
    accidentally-replicated tensors that the no-liveness temp sum hides."""
    found = []
    for line in hlo_text.splitlines():
        m = _ANY_SHAPE_RE.match(line)
        if not m:
            continue
        b = _shape_bytes(m.group(1), m.group(2))
        if b >= 1 << 20:
            op = line.split("=", 1)[1].strip()
            opname = op.split("(")[0].split()[-1] if "(" in op else "?"
            found.append((b, m.group(1), m.group(2), opname))
    found.sort(reverse=True)
    out = []
    seen = set()
    for b, dt, dims, opname in found:
        key = (dt, dims, opname)
        if key in seen:
            continue
        seen.add(key)
        out.append({"bytes": b, "dtype": dt, "shape": dims, "op": opname})
        if len(out) >= top:
            break
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    link_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        # flops/bytes are per-device (see module docstring calibration).
        return self.flops / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def analyze_compiled(compiled, chips: int) -> Dict[str, Any]:
    """Extract cost/memory/collective numbers from a compiled executable."""
    cost = {}
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        cost = dict(c)
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("generated_code_size_in_bytes",
                     "argument_size_in_bytes",
                     "output_size_in_bytes",
                     "alias_size_in_bytes",
                     "peak_memory_in_bytes",
                     "temp_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes_from_hlo(hlo)
    flops = float(cost.get("flops", 0.0))
    # bytes accessed: prefer the explicit key; CPU-XLA sometimes omits it,
    # fall back to one-pass traffic = args + outputs + temps.
    hbm = float(cost.get("bytes accessed", 0.0))
    if hbm <= 0.0 and not mem.get("error"):
        hbm = float(sum(mem.get(k, 0) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes")))
    roof_raw = Roofline(flops=flops, hbm_bytes=hbm,
                        collective_bytes=coll["total_bytes"], chips=chips)
    # loop-aware (trip-count-corrected) analysis — the headline numbers.
    from repro.launch import hloanalysis

    try:
        corr = hloanalysis.analyze_text(hlo)
    except Exception as e:  # pragma: no cover
        corr = {"error": str(e)}
    if "error" not in corr:
        roof = Roofline(flops=corr["flops"], hbm_bytes=corr["bytes"],
                        collective_bytes=corr["collective_bytes"],
                        chips=chips)
    else:
        roof = roof_raw
    return {
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "memory": mem,
        "collectives": coll,
        "corrected": corr,
        "roofline": roof.as_dict(),
        "roofline_raw": roof_raw.as_dict(),
        "largest_buffers": largest_buffers(hlo),
        "hlo_bytes": len(hlo),
    }


def model_flops_train(active_params: int, tokens: int) -> float:
    """MODEL_FLOPS = 6 * N_active * D for one optimizer step."""
    return 6.0 * active_params * tokens


def model_flops_decode(active_params: int, batch: int) -> float:
    """2 * N_active per generated token (fwd only)."""
    return 2.0 * active_params * batch


def per_device_hbm_gib(mem: Dict[str, Any]) -> Optional[float]:
    """Bytes/device from memory_analysis (args+outputs+temps, aliases out)."""
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes")
    if not all(k in mem for k in keys):
        return None
    total = sum(mem[k] for k in keys) - mem.get("alias_size_in_bytes", 0)
    return total / 2**30
