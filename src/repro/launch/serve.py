"""Batched serving driver: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --batch 4 --prompt-len 64 --gen 32

Serves the REDUCED config for real on host devices; the full configs'
serving path is exercised (lower+compile) by dryrun.py on the production
mesh. Greedy sampling; reports tokens/s and per-phase wall-clock.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.models import decode_step, init_params, prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.reduced
    params, _ = init_params(cfg, jax.random.key(args.seed))
    max_len = args.prompt_len + args.gen

    key = jax.random.key(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.has_memory_input:
        m = cfg.memory_tokens or 16
        batch["memory"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (args.batch, m, cfg.memory_dim or cfg.d_model), jnp.float32)

    prefill_fn = jax.jit(lambda p, b: prefill(p, b, cfg, max_len=max_len))
    step_fn = jax.jit(lambda p, s, t: decode_step(p, s, t, cfg))

    t0 = time.time()
    logits, state = prefill_fn(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    tok = (jnp.argmax(logits, -1)[:, None] % cfg.vocab_size).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, state = step_fn(params, state, tok)
        tok = (jnp.argmax(logits, -1)[:, None] % cfg.vocab_size).astype(jnp.int32)
        out.append(tok)
    jnp.concatenate(out, 1).block_until_ready()
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, 1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.0f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.0f} ms "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
