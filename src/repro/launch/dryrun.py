"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST be the process entrypoint (sets the fake-device flag before any other
import, including jax):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun

Per combination it records memory_analysis, cost_analysis, and the parsed
collective schedule into a JSON file that benchmarks/roofline.py renders
into EXPERIMENTS.md tables.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402


def run_one(arch_id: str, shape_name: str, multi_pod: bool, *,
            kinds=("headline",), mixing: str = "dense",
            tau1: int = 4, tau2: int = 4, compression: str = "",
            out_dir: str = "", tag: str = "") -> dict:
    from repro.configs import get_arch
    from repro.configs.base import SHAPES
    from repro.core.compression import make_compressor
    from repro.launch import roofline as roof_lib
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh

    arch = get_arch(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(len(mesh.devices.flatten()))
    comp = make_compressor(compression) if compression else None
    results = {}
    for kind in kinds:
        t0 = time.time()
        try:
            if kind == "headline":
                built = steps_lib.build_for(
                    arch, shape_name, mesh, tau1=tau1, tau2=tau2,
                    mixing_impl=mixing, compression=comp,
                ) if SHAPES[shape_name].kind == "train" else steps_lib.build_for(
                    arch, shape_name, mesh)
            elif kind == "local":
                built = steps_lib.build_local_step(arch, shape_name, mesh)
            elif kind == "gossip":
                built = steps_lib.build_gossip_step(
                    arch, mesh, mixing_impl=mixing, compression=comp)
            else:
                raise ValueError(kind)
            lowered = built.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            rec = roof_lib.analyze_compiled(compiled, chips)
            if out_dir and os.environ.get("DRYRUN_DUMP_HLO", "1") == "1":
                pod_s = "2pod" if multi_pod else "1pod"
                hdir = os.path.join(out_dir, "hlo")
                os.makedirs(hdir, exist_ok=True)
                hname = f"{arch_id}__{shape_name}__{pod_s}__{kind}"
                if tag:
                    hname += f"__{tag}"
                with open(os.path.join(hdir, hname + ".hlo"), "w") as hf:
                    hf.write(compiled.as_text())
            rec.update(built.meta)
            rec.update({
                "ok": True, "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1), "chips": chips,
                "multi_pod": multi_pod,
            })
            # free compile artifacts eagerly (big HLO texts).
            del compiled, lowered, built
        except Exception as e:
            rec = {
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
                "kind": kind, "arch": arch_id, "shape": shape_name,
                "multi_pod": multi_pod,
            }
        results[kind] = rec
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        pod = "2pod" if multi_pod else "1pod"
        name = f"{arch_id}__{shape_name}__{pod}"
        if tag:
            name += f"__{tag}"
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(results, f, indent=1, default=str)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every runnable (arch x shape) on this mesh")
    ap.add_argument("--kinds", default="headline",
                    help="comma list: headline,local,gossip")
    ap.add_argument("--mixing", default="dense",
                    choices=["dense", "dense_power"])
    ap.add_argument("--compression", default="")
    ap.add_argument("--tau1", type=int, default=4)
    ap.add_argument("--tau2", type=int, default=4)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.configs import REGISTRY, get_arch

    kinds = tuple(args.kinds.split(","))
    combos = []
    if args.all:
        for aid, arch in sorted(REGISTRY.items()):
            for shape in arch.shapes():
                combos.append((aid, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos.append((args.arch, args.shape))

    n_ok = n_fail = 0
    for aid, shape in combos:
        res = run_one(aid, shape, args.multi_pod, kinds=kinds,
                      mixing=args.mixing, tau1=args.tau1, tau2=args.tau2,
                      compression=args.compression, out_dir=args.out,
                      tag=args.tag)
        for kind, rec in res.items():
            if rec.get("ok"):
                n_ok += 1
                roof = rec.get("roofline", {})
                print(f"OK   {aid:26s} {shape:12s} {kind:8s} "
                      f"compile={rec['compile_s']:.0f}s "
                      f"dom={roof.get('dominant','?'):10s} "
                      f"flops={roof.get('flops',0):.3g}", flush=True)
            else:
                n_fail += 1
                print(f"FAIL {aid:26s} {shape:12s} {kind:8s} "
                      f"{rec['error']}", flush=True)
    print(f"\n{n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
