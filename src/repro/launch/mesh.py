"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). The dry-run entrypoint (``dryrun.py``) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
everything else (tests, benches, examples) sees the single real device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants for the roofline (DESIGN.md section 6).
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (intra-pod)
DCN_BW = 6.25e9                # bytes/s per chip (inter-pod, ~50 Gb/s)
HBM_PER_CHIP = 16 * 2**30      # v5e: 16 GiB
