"""Builders that assemble (jitted fn, abstract args) pairs for every
(architecture x input shape x mesh) combination.

For training shapes three functions are built:
  * ``round``   — one full DFL round (tau1 local scans + tau2 gossip):
                  the compile-proof artifact of the dry-run.
  * ``local``   — ONE local SGD step on all nodes: the roofline compute unit.
  * ``gossip``  — ONE gossip (mixing) step: the roofline collective unit.
Roofline terms compose analytically: round = tau1*local + tau2*gossip,
sidestepping XLA cost_analysis' while-loop trip-count blindness.

For serving shapes: ``prefill`` / ``decode`` steps.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape, SHAPES
from repro.core import dfl as dfl_lib
from repro.core import mixing as mixing_lib
from repro.core import substrate as substrate_lib
from repro.core import topology as topo_lib
from repro.core.compression import Compressor
from repro.launch import sharding as shard_lib
from repro.models import transformer as tf_lib
from repro.models.policy import activation_sharding
from repro.models.common import ModelConfig
from repro.optim import sgd

PyTree = Any

KEY_DTYPE = jax.eval_shape(lambda: jax.random.key(0)).dtype


@dataclasses.dataclass
class Built:
    """A jitted function plus the abstract args to lower it with."""

    fn: Callable
    args: Tuple
    meta: Dict[str, Any]
    ctx: Optional[Callable] = None   # context manager active during tracing

    def lower(self):
        if self.ctx is None:
            return self.fn.lower(*self.args)
        with self.ctx():
            return self.fn.lower(*self.args)


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def memory_tokens_for(cfg: ModelConfig, shape: InputShape) -> int:
    if cfg.arch_type == "audio":
        return max(16, shape.seq_len // 4)
    return cfg.memory_tokens


def kernelize_compressor(compression: Optional[Compressor],
                         use_kernels: bool) -> Optional[Compressor]:
    """``use_kernels`` engages the kernel-backed TopK on ANY engine: the
    sparse substrate fuses compress-and-move itself
    (``substrate.ShardedSubstrate.choco_step``), but the dense engine
    compresses through the Compressor instance — so carry the flag on it
    (the kernel TopK is bitwise-identical to the reference, see
    ``repro.core.compression.TopK``). Other compressors pass through."""
    from repro.core.compression import TopK

    if use_kernels and isinstance(compression, TopK):
        return dataclasses.replace(compression, use_kernels=True)
    return compression


def dfl_setup(arch: ArchConfig, mesh: Mesh, *, tau1: int, tau2: int,
              compression: Optional[Compressor], mixing_impl: str,
              topology: str = "ring"):
    mode = arch.sharding_mode
    n = shard_lib.num_nodes_for(mode, mesh, arch.fsdp_nodes)
    if n == 1:  # degenerate single-node mesh (host tests)
        topo = topo_lib.fully_connected(1)
    else:
        topo = {
            "ring": topo_lib.ring,
            "full": topo_lib.fully_connected,
            "torus": lambda k: (topo_lib.torus(2, k // 2) if k >= 4
                                else topo_lib.ring(k)),
        }[topology](n)
    dcfg = dfl_lib.DFLConfig(
        tau1=tau1, tau2=tau2, topology=topo,
        mixing_impl=mixing_impl, compression=compression)
    return mode, n, dcfg


def _abstract_state(arch: ArchConfig, cfg: ModelConfig, mesh: Mesh, mode: str,
                    n: int, opt, compressed: bool):
    params_abs, axes = tf_lib.init_params(cfg, jax.random.key(0), abstract=True)
    stacked = shard_lib.stack_node_dim_abstract(params_abs, n)
    opt_abs = jax.eval_shape(jax.vmap(opt.init), stacked)
    hat_abs = stacked if compressed else None
    state_abs = dfl_lib.DFLState(
        params=stacked,
        opt_state=opt_abs,
        hat_params=hat_abs,
        rng=jax.ShapeDtypeStruct((), KEY_DTYPE),
        round_idx=jax.ShapeDtypeStruct((), jnp.int32),
    )

    p_sh = shard_lib.params_shardings(axes, stacked, mode, mesh, node_dim=True)
    naxes = shard_lib.node_axes_for(mode, mesh)
    node_entry = (naxes if len(naxes) > 1 else naxes[0]) if naxes else None

    def opt_leaf_sh(leaf):
        if leaf.shape and leaf.shape[0] == n and node_entry is not None:
            return NamedSharding(mesh, P(node_entry))
        return shard_lib.replicated(mesh)

    opt_sh = jax.tree_util.tree_map(opt_leaf_sh, opt_abs)
    state_sh = dfl_lib.DFLState(
        params=p_sh,
        opt_state=opt_sh,
        hat_params=p_sh if compressed else None,
        rng=shard_lib.replicated(mesh),
        round_idx=shard_lib.replicated(mesh),
    )
    return state_abs, state_sh, axes


def _abstract_batch(arch: ArchConfig, cfg: ModelConfig, shape: InputShape,
                    mesh: Mesh, mode: str, n: int, tau1: Optional[int]):
    """Training batches [tau1?, N, B/N, ...]."""
    per_node = shape.global_batch // n
    assert per_node >= 1, (
        f"{arch.arch_id}/{shape.name}: global batch {shape.global_batch} < "
        f"{n} nodes")
    lead = (tau1,) if tau1 is not None else ()
    tok_shape = lead + (n, per_node, shape.seq_len)
    batch = {
        "tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
        "labels": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
    }
    if cfg.has_memory_input:
        m = memory_tokens_for(cfg, shape)
        mem_dim = cfg.memory_dim or cfg.d_model
        batch["memory"] = jax.ShapeDtypeStruct(
            lead + (n, per_node, m, mem_dim), jnp.bfloat16)
    sh = shard_lib.batch_sharding(mesh, mode, has_tau_dim=tau1 is not None)
    batch_sh = {k: sh for k in batch}
    return batch, batch_sh


def _act_policy(mesh: Mesh, mode: str, kind: str):
    """Residual-stream sharding policy per mode/step kind (see policy.py).

    train gossip-dp  : [B,S,D] d_model over `model` (Megatron-style sharded
                       residual; batch is per-node, node dim rides `data`
                       via vmap). Sharding seq instead was tried first but
                       fights the flash-attention chunk reshape (nq < mesh
                       model size -> GSPMD all-gathers, 16 GiB/device).
    train gossip-fsdp: batch over `data`, d_model over `model`.
    prefill          : batch over `data`(x`pod`), d_model over `model`.
    decode           : batch over `data`(x`pod`) only (S=1).
    """
    has_pod = "pod" in mesh.axis_names
    data_entry = ("pod", "data") if has_pod else "data"
    if kind == "train":
        if mode == "gossip-dp":
            return lambda: activation_sharding(mesh, embed="model")
        return lambda: activation_sharding(mesh, batch="data", embed="model")
    if kind == "prefill":
        return lambda: activation_sharding(mesh, batch=data_entry,
                                           embed="model")
    return lambda: activation_sharding(mesh, batch=data_entry)


def _make_constrain(sharding_tree):
    """Re-assert stacked-param shardings (applied to grads/params inside the
    round; prevents GSPMD from replicating scan carries)."""

    def constrain(tree):
        return jax.tree_util.tree_map(
            lambda x, sh: jax.lax.with_sharding_constraint(x, sh), tree,
            sharding_tree)

    return constrain


# ---------------------------------------------------------------------------
# Training builders
# ---------------------------------------------------------------------------


def select_engine(engine: str, dcfg, mesh: Mesh, mode: str) -> str:
    """Resolve the round-engine choice (see core.sharded module docstring).

    "auto" picks the sparse (shard_map + ppermute, deg neighbor copies)
    engine whenever the topology is shift-structured (circulant) AND the
    node mesh axes enumerate all N > 1 nodes (gossip-dp); everything else —
    single-node host meshes, replicated-node gossip-fsdp, non-circulant
    topologies, dense-only features — falls back to the dense engine.
    """
    if engine != "auto":
        return engine
    node_axes = shard_lib.node_axes_for(mode, mesh)
    if not node_axes:
        return "dense"
    # Train rounds built here always re-assert stacked-param shardings
    # (``_make_constrain``); the sparse engine refuses a constrain on
    # meshes with >1-sized auto (GSPMD) axes rather than silently dropping
    # it (core.sharded), so auto-selection must not steer those meshes
    # into the raise — dense stays the tensor-parallel path until the
    # sharded engine grows an auto-axis constrain.
    if any(mesh.shape[a] > 1 for a in mesh.axis_names
           if a not in node_axes):
        return "dense"
    return ("sparse"
            if dfl_lib.sparse_engine_eligible(dcfg, mesh, node_axes)
            else "dense")


def roofline_cost_inputs(
    arch: ArchConfig,
    shape_name: str,
    mesh: Mesh,
    *,
    topology: str = "ring",
    reduced: bool = False,
) -> Dict[str, float]:
    """MEASURED planner cost inputs from compiled XLA artifacts.

    Lowers+compiles the unit steps (``build_local_step`` /
    ``build_gossip_step``) and reads the roofline terms off the optimized
    HLO (``launch.roofline``): ``step_flops`` is one local step's FLOPs
    PER NODE — the roofline's per-device number rescaled by
    mesh.size / N, since the per-device program carries all N vmapped
    node updates split over mesh.size devices (on gossip-dp meshes,
    nodes == devices and the factor is the model-parallel share; on a
    1-device host mesh it divides the stacked work back out) — matching
    ``ComputeModel.step_flops``'s one-node contract.
    ``gossip_collective_bytes`` is one gossip step's per-device
    collective bytes. These replace the planner's a-priori 6*P*tokens /
    fp32-tree estimates — the same numbers, measured instead of assumed
    (``plan_train_schedule(..., use_roofline=True)``).

    ``gossip_collective_bytes`` is 0.0 when the lowering emits no
    collectives (single-device host meshes mix in registers); callers must
    fall back to the analytic wire size then.
    """
    from repro.launch import roofline as roof_lib

    n = shard_lib.num_nodes_for(arch.sharding_mode, mesh, arch.fsdp_nodes)
    local = build_local_step(arch, shape_name, mesh, reduced=reduced)
    la = roof_lib.analyze_compiled(local.lower().compile(),
                                   chips=mesh.size)
    gossip = build_gossip_step(arch, mesh, topology=topology,
                               reduced=reduced)
    ga = roof_lib.analyze_compiled(gossip.lower().compile(),
                                   chips=mesh.size)
    return {
        "step_flops": float(la["roofline"]["flops"]) * mesh.size / max(n, 1),
        "step_hbm_bytes": float(la["roofline"]["hbm_bytes"]),
        "gossip_collective_bytes": float(
            ga["roofline"]["collective_bytes"]),
        "nodes": n,
    }


def plan_train_schedule(
    arch: ArchConfig,
    shape_name: str,
    mesh: Mesh,
    *,
    budget_s: float,
    topology: str = "ring",
    compression: Optional[Compressor] = None,
    flops_per_s: Optional[float] = None,
    link_bytes_per_s: Optional[float] = None,
    sigma: float = 1.0,
    f_gap: float = 1.0,
    reduced: bool = False,
    grid=None,
    wire_engine: str = "auto",
    use_roofline: bool = False,
):
    """Pick (tau1, tau2) for a (arch, shape, mesh) deployment with the
    planner (``repro.planner``) before building anything.

    By default the compute side is priced analytically — 6 * params *
    tokens FLOPs per local step per node at the chip's bf16 peak — and the
    gossip side from the model's fp32 wire size over one ICI link; the
    same first-order estimates the roofline uses. With
    ``use_roofline=True`` both sides come MEASURED off the compiled HLO
    instead (``roofline_cost_inputs``): the local step's actual per-NODE
    FLOPs, and the gossip step's actual collective bytes folded back into
    an effective per-copy wire size (so wire-bit budgets price what the
    lowering really ships; falls back to the analytic size when the
    lowering has no collectives — e.g. single-device host meshes — or
    when a ``compression`` is set, since the compressor's model_dim is
    derived from model_bits). Returns
    the planner ``Plan``; ``build_planned_round`` turns it straight into a
    Built round.
    """
    from repro.launch import mesh as mesh_lib
    from repro.planner import (Budget, ComputeModel, CostModel, LinkModel,
                               plan)

    cfg = arch.reduced if reduced else arch.model
    shape = SHAPES[shape_name]
    _mode, n, dcfg = dfl_setup(arch, mesh, tau1=1, tau2=1,
                               compression=compression,
                               mixing_impl="dense", topology=topology)
    params = cfg.param_count()
    tokens_per_node = shape.global_batch * shape.seq_len / max(n, 1)
    step_flops = 6.0 * params * tokens_per_node
    model_bits = 32.0 * params
    if use_roofline:
        measured = roofline_cost_inputs(arch, shape_name, mesh,
                                        topology=topology, reduced=reduced)
        step_flops = measured["step_flops"]
        copies = mixing_lib.gossip_copies_per_step(dcfg.topology,
                                                   wire_engine)
        if (measured["gossip_collective_bytes"] > 0.0 and copies > 0
                and compression is None):
            # effective per-copy wire size: what the compiled gossip step
            # actually moves, spread over the engine's copy count, so
            # round_cost's copies * model_bits reproduces the measurement.
            # Compressed planning keeps the analytic size: the planner
            # derives the compressor's model_dim from model_bits/32, so
            # overriding it with wire bytes would corrupt delta/zeta.
            model_bits = (8.0 * measured["gossip_collective_bytes"]
                          / copies)
    cost_model = CostModel(
        compute=ComputeModel(
            step_flops=step_flops,
            flops_per_s=flops_per_s or mesh_lib.PEAK_FLOPS_BF16),
        link=LinkModel(
            bytes_per_s=link_bytes_per_s or mesh_lib.ICI_BW),
        topology=dcfg.topology,
        model_bits=model_bits,
        engine=wire_engine)
    kw = dict(sigma=sigma, f_gap=f_gap)
    if grid is not None:
        kw["grid"] = grid
    if compression is not None:
        kw["compressors"] = (compression,)
    return plan(Budget(wall_clock_s=budget_s), cost_model, **kw)


def build_planned_round(
    arch: ArchConfig,
    shape_name: str,
    mesh: Mesh,
    *,
    budget_s: float,
    topology: str = "ring",
    compression: Optional[Compressor] = None,
    reduced: bool = False,
    **plan_kw,
) -> Built:
    """``build_train_round`` with (tau1, tau2) chosen by the planner; the
    chosen Plan's knobs and prediction land in ``meta["plan"]``."""
    p = plan_train_schedule(
        arch, shape_name, mesh, budget_s=budget_s, topology=topology,
        compression=compression, reduced=reduced, **plan_kw)
    built = build_train_round(
        arch, shape_name, mesh, tau1=p.tau1, tau2=p.tau2,
        compression=p.compressor, topology=topology, reduced=reduced)
    built.meta["plan"] = {
        "tau1": p.tau1, "tau2": p.tau2, "eta": p.eta,
        "compressor": p.compressor_name, "rounds": p.rounds,
        "predicted_bound": p.predicted_bound,
        "round_time_s": p.round_cost.time_s,
        "round_wire_bits": p.round_cost.wire_bits,
        "budget_s": budget_s,
        "use_roofline": bool(plan_kw.get("use_roofline", False)),
    }
    return built


def build_train_round(
    arch: ArchConfig,
    shape_name: str,
    mesh: Mesh,
    *,
    tau1: int = 4,
    tau2: int = 4,
    compression: Optional[Compressor] = None,
    mixing_impl: str = "dense",
    topology: str = "ring",
    lr: float = 1e-3,
    reduced: bool = False,
    engine: str = "auto",
    use_kernels: bool = False,
) -> Built:
    cfg = arch.reduced if reduced else arch.model
    shape = SHAPES[shape_name]
    compression = kernelize_compressor(compression, use_kernels)
    mode, n, dcfg = dfl_setup(arch, mesh, tau1=tau1, tau2=tau2,
                              compression=compression,
                              mixing_impl=mixing_impl, topology=topology)
    opt = sgd(lr)
    loss_fn = lambda p, b, k: tf_lib.train_loss(p, b, cfg, k)
    state_abs, state_sh, _ = _abstract_state(
        arch, cfg, mesh, mode, n, opt, compressed=dcfg.is_compressed)
    constrain = _make_constrain(state_sh.params)
    engine = select_engine(engine, dcfg, mesh, mode)
    round_fn = dfl_lib.make_round_fn(
        dcfg, loss_fn, opt, constrain=constrain, engine=engine, mesh=mesh,
        node_axes=shard_lib.node_axes_for(mode, mesh),
        use_kernels=use_kernels)
    batch_abs, batch_sh = _abstract_batch(arch, cfg, shape, mesh, mode, n, tau1)
    fn = jax.jit(
        round_fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    return Built(fn, (state_abs, batch_abs), {
        "kind": "round", "arch": arch.arch_id, "shape": shape_name,
        "mode": mode, "nodes": n, "tau1": tau1, "tau2": tau2,
        "mixing": mixing_impl, "engine": engine,
        "compressed": dcfg.is_compressed,
    }, ctx=_act_policy(mesh, mode, "train"))


def build_train_superstep(
    arch: ArchConfig,
    shape_name: str,
    mesh: Mesh,
    *,
    rounds: int = 4,
    tau1_max: int = 8,
    tau2_max: int = 8,
    compression: Optional[Compressor] = None,
    topology: str = "ring",
    lr: float = 1e-3,
    reduced: bool = False,
    engine: str = "auto",
    use_kernels: bool = False,
    overlap: str = "none",
) -> Built:
    """The fused K-round superstep as a lowerable production artifact.

    One executable covers EVERY length-K schedule trajectory within
    (tau1_max, tau2_max): the schedule is a replicated [K, 2] int32 device
    array scanned as ``lax.scan`` xs alongside the batches, so round k
    runs (taus[k, 0], taus[k, 1]) dynamic trip counts
    (``make_round_fn(dynamic_taus=True)``) and a heterogeneous per-round
    schedule costs zero extra compiles over a uniform one. The ``DFLState``
    carry is DONATED (params+opt buffers aliased in place — the
    peak-memory fix the per-round jit was missing) and the per-round
    metrics come back stacked [K], tagged with the realized tau1/tau2
    rows, so the host syncs once per superstep. Batch leaves are
    [K, tau1_max, N, B, ...] with rows >= taus[k, 0] never read. This is
    the compile-proof artifact of what
    ``repro.core.executor.RoundExecutor.dispatch_trajectory`` dispatches
    at runtime.

    ``overlap="pipeline"`` lowers the double-buffered variant instead
    (``dfl.make_pipeline_fns`` scanned by
    ``executor.make_pipeline_superstep``): round k's gossip exchange is
    issued alongside round k+1's local phase and folded one round late,
    with the final in-flight exchange drained inside the same executable.
    Same signature, same [K, 2] row layout, one-round-stale mixing
    semantics (docs/ARCHITECTURE.md "Overlapped execution").
    """
    if overlap not in ("none", "pipeline"):
        raise ValueError(
            f"overlap must be 'none' or 'pipeline', got {overlap!r}")
    cfg = arch.reduced if reduced else arch.model
    shape = SHAPES[shape_name]
    compression = kernelize_compressor(compression, use_kernels)
    mode, n, dcfg = dfl_setup(arch, mesh, tau1=tau1_max, tau2=tau2_max,
                              compression=compression,
                              mixing_impl="dense", topology=topology)
    opt = sgd(lr)
    loss_fn = lambda p, b, k: tf_lib.train_loss(p, b, cfg, k)
    state_abs, state_sh, _ = _abstract_state(
        arch, cfg, mesh, mode, n, opt, compressed=dcfg.is_compressed)
    constrain = _make_constrain(state_sh.params)
    engine = select_engine(engine, dcfg, mesh, mode)
    if overlap == "pipeline":
        from repro.core.executor import make_pipeline_superstep

        pipe_fn, drain_fn = dfl_lib.make_pipeline_fns(
            dcfg, loss_fn, opt, constrain=constrain, engine=engine,
            mesh=mesh, node_axes=shard_lib.node_axes_for(mode, mesh),
            use_kernels=use_kernels)
        superstep = make_pipeline_superstep(pipe_fn, drain_fn)
    else:
        round_fn = dfl_lib.make_round_fn(
            dcfg, loss_fn, opt, constrain=constrain, engine=engine,
            mesh=mesh, node_axes=shard_lib.node_axes_for(mode, mesh),
            use_kernels=use_kernels, dynamic_taus=True)

        def superstep(state, batches, taus):
            def body(st, xs):
                b, tau = xs
                st, metrics = round_fn(st, b, tau[0], tau[1])
                return st, dict(metrics, tau1=tau[0], tau2=tau[1])

            return jax.lax.scan(body, state, (batches, taus))

    batch_abs, batch_sh = _abstract_batch(arch, cfg, shape, mesh, mode, n,
                                          tau1_max)
    # prepend the K (rounds) dim: replicated, like the tau1 dim.
    batch_abs = {k: jax.ShapeDtypeStruct((rounds,) + v.shape, v.dtype)
                 for k, v in batch_abs.items()}
    batch_sh = {k: NamedSharding(mesh, P(None, *sh.spec))
                for k, sh in batch_sh.items()}
    taus_abs = jax.ShapeDtypeStruct((rounds, 2), jnp.int32)
    fn = jax.jit(
        superstep,
        in_shardings=(state_sh, batch_sh, shard_lib.replicated(mesh)),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    return Built(fn, (state_abs, batch_abs, taus_abs), {
        "kind": "superstep", "arch": arch.arch_id, "shape": shape_name,
        "mode": mode, "nodes": n, "rounds": rounds,
        "tau1_max": tau1_max, "tau2_max": tau2_max, "engine": engine,
        "schedule": "trajectory", "overlap": overlap,
        "compressed": dcfg.is_compressed,
    }, ctx=_act_policy(mesh, mode, "train"))


def build_local_step(
    arch: ArchConfig, shape_name: str, mesh: Mesh, *,
    lr: float = 1e-3, reduced: bool = False,
) -> Built:
    """ONE local SGD step on all nodes (roofline compute unit)."""
    cfg = arch.reduced if reduced else arch.model
    shape = SHAPES[shape_name]
    mode, n, _ = dfl_setup(arch, mesh, tau1=1, tau2=1, compression=None,
                           mixing_impl="dense")
    opt = sgd(lr)
    state_abs, state_sh, _ = _abstract_state(
        arch, cfg, mesh, mode, n, opt, compressed=False)
    batch_abs, batch_sh = _abstract_batch(arch, cfg, shape, mesh, mode, n, None)

    constrain = _make_constrain(state_sh.params)

    def local_step(params, opt_state, batch):
        def loss_one(p, b):
            return tf_lib.train_loss(p, b, cfg)
        losses, grads = jax.vmap(jax.value_and_grad(loss_one))(params, batch)
        grads = constrain(grads)
        updates, opt_state = jax.vmap(opt.update)(grads, opt_state, params)
        params = jax.tree_util.tree_map(
            lambda p, u: (p + u).astype(p.dtype), params, updates)
        return params, opt_state, jnp.mean(losses)

    fn = jax.jit(
        local_step,
        in_shardings=(state_sh.params, state_sh.opt_state, batch_sh),
        out_shardings=(state_sh.params, state_sh.opt_state, None),
        donate_argnums=(0, 1),
    )
    return Built(fn, (state_abs.params, state_abs.opt_state, batch_abs), {
        "kind": "local", "arch": arch.arch_id, "shape": shape_name,
        "mode": mode, "nodes": n,
    }, ctx=_act_policy(mesh, mode, "train"))


def build_gossip_step(
    arch: ArchConfig, mesh: Mesh, *,
    mixing_impl: str = "dense",
    topology: str = "ring",
    compression: Optional[Compressor] = None,
    reduced: bool = False,
) -> Built:
    """ONE gossip step over the stacked params (roofline collective unit)."""
    cfg = arch.reduced if reduced else arch.model
    mode, n, dcfg = dfl_setup(arch, mesh, tau1=1, tau2=1,
                              compression=compression,
                              mixing_impl="dense", topology=topology)
    opt = sgd(1e-3)
    state_abs, state_sh, _ = _abstract_state(
        arch, cfg, mesh, mode, n, opt, compressed=compression is not None)

    if compression is None:
        def gossip_step(params):
            return mixing_lib.mix_dense(params, dcfg.topology)

        fn = jax.jit(gossip_step, in_shardings=(state_sh.params,),
                     out_shardings=state_sh.params, donate_argnums=(0,))
        args = (state_abs.params,)
    else:
        def gossip_step(params, hat, key):
            from repro.core.dfl import _communicate_choco
            c = dataclasses.replace(dcfg, tau2=1)
            return _communicate_choco(c, params, hat, key)

        fn = jax.jit(
            gossip_step,
            in_shardings=(state_sh.params, state_sh.params, None),
            out_shardings=(state_sh.params, state_sh.params),
            donate_argnums=(0, 1))
        args = (state_abs.params, state_abs.params,
                jax.ShapeDtypeStruct((), KEY_DTYPE))
    return Built(fn, args, {
        "kind": "gossip", "arch": arch.arch_id, "mode": mode, "nodes": n,
        "mixing": mixing_impl,
        "compressed": compression is not None,
    })


# ---------------------------------------------------------------------------
# Serving builders
# ---------------------------------------------------------------------------


def _serve_param_shardings(arch: ArchConfig, cfg: ModelConfig, mesh: Mesh):
    params_abs, axes = tf_lib.init_params(cfg, jax.random.key(0), abstract=True)
    mode = arch.sharding_mode  # fsdp archs shard embed over data at serve too
    p_sh = shard_lib.params_shardings(axes, params_abs, mode, mesh,
                                      node_dim=False)
    return params_abs, p_sh


def _batch_entry(mesh: Mesh, batch: int):
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    size = substrate_lib.mesh_axis_size(mesh, axes)
    if batch % size == 0:
        return axes if len(axes) > 1 else axes[0]
    if batch % mesh.shape["data"] == 0:
        return "data"
    return None


def decode_state_shardings(cfg: ModelConfig, state_abs, mesh: Mesh,
                           batch_entry, seq_entry):
    """Sharding tree matching a DecodeState, by leaf geometry."""

    def cache_leaf(leaf):
        shp = leaf.shape[1:]  # strip the stacked periods dim
        if len(shp) == 4:        # kv cache [B, T, KVH, hd]
            return NamedSharding(mesh, P(None, batch_entry, seq_entry))
        if len(shp) == 1:        # pos [T]
            return NamedSharding(mesh, P(None, seq_entry))
        if len(shp) == 3 and shp[1:] == (cfg.d_inner, cfg.ssm_state):
            model_ok = cfg.d_inner % mesh.shape["model"] == 0
            return NamedSharding(
                mesh, P(None, batch_entry, "model" if model_ok else None))
        if len(shp) == 3:        # conv state [B, K-1, di]
            model_ok = shp[-1] % mesh.shape["model"] == 0
            return NamedSharding(
                mesh, P(None, batch_entry, None, "model" if model_ok else None))
        return shard_lib.replicated(mesh)

    caches_sh = tuple(
        jax.tree_util.tree_map(cache_leaf, c) for c in state_abs.caches)
    mem_sh = (NamedSharding(mesh, P(batch_entry, None, None))
              if state_abs.memory is not None else None)
    return tf_lib.DecodeState(
        caches=caches_sh, memory=mem_sh, position=shard_lib.replicated(mesh))


def build_prefill(arch: ArchConfig, shape_name: str, mesh: Mesh, *,
                  reduced: bool = False) -> Built:
    cfg = arch.reduced if reduced else arch.model
    shape = SHAPES[shape_name]
    params_abs, p_sh = _serve_param_shardings(arch, cfg, mesh)
    b = shape.global_batch
    batch_entry = _batch_entry(mesh, b)
    batch = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)}
    batch_sh = {"tokens": NamedSharding(mesh, P(batch_entry, None))}
    if cfg.has_memory_input:
        m = memory_tokens_for(cfg, shape)
        mem_dim = cfg.memory_dim or cfg.d_model
        batch["memory"] = jax.ShapeDtypeStruct((b, m, mem_dim), jnp.bfloat16)
        batch_sh["memory"] = NamedSharding(mesh, P(batch_entry, None, None))

    def prefill_step(params, batch):
        return tf_lib.prefill(params, batch, cfg, max_len=shape.seq_len)

    fn = jax.jit(prefill_step, in_shardings=(p_sh, batch_sh))
    return Built(fn, (params_abs, batch), {
        "kind": "prefill", "arch": arch.arch_id, "shape": shape_name,
        "batch": b, "seq": shape.seq_len,
    }, ctx=_act_policy(mesh, arch.sharding_mode, "prefill"))


def build_decode(arch: ArchConfig, shape_name: str, mesh: Mesh, *,
                 reduced: bool = False,
                 seq_shard: Optional[Any] = "auto") -> Built:
    cfg = arch.reduced if reduced else arch.model
    shape = SHAPES[shape_name]
    params_abs, p_sh = _serve_param_shardings(arch, cfg, mesh)
    b = shape.global_batch
    batch_entry = _batch_entry(mesh, b)
    if seq_shard == "auto":
        # baseline: KV-cache sequence dim over `model` (works for every
        # GQA head count); long-context batch=1 leaves `data` idle (a
        # hillclimb target, see EXPERIMENTS.md section Perf).
        seq_entry = "model"
    else:
        seq_entry = seq_shard
    state_abs = tf_lib.init_decode_state(cfg, b, shape.seq_len, abstract=True)
    if cfg.has_memory_input:
        m = memory_tokens_for(cfg, shape)
        state_abs = state_abs._replace(memory=jax.ShapeDtypeStruct(
            (b, m, cfg.d_model), cfg.dtype))
    state_sh = decode_state_shardings(cfg, state_abs, mesh, batch_entry,
                                      seq_entry)
    tokens_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tokens_sh = NamedSharding(mesh, P(batch_entry, None))

    def serve_step(params, state, tokens):
        return tf_lib.decode_step(params, state, tokens, cfg)

    fn = jax.jit(
        serve_step,
        in_shardings=(p_sh, state_sh, tokens_sh),
        out_shardings=(None, state_sh),
        donate_argnums=(1,),
    )
    return Built(fn, (params_abs, state_abs, tokens_abs), {
        "kind": "decode", "arch": arch.arch_id, "shape": shape_name,
        "batch": b, "seq": shape.seq_len, "seq_entry": str(seq_entry),
    }, ctx=_act_policy(mesh, arch.sharding_mode, "decode"))


def build_for(arch: ArchConfig, shape_name: str, mesh: Mesh, **kw) -> Built:
    """The headline function for a (arch, shape, mesh) combination."""
    kind = SHAPES[shape_name].kind
    if kind == "train":
        return build_train_round(arch, shape_name, mesh, **kw)
    if kind == "prefill":
        return build_prefill(arch, shape_name, mesh,
                             reduced=kw.get("reduced", False))
    return build_decode(arch, shape_name, mesh,
                        reduced=kw.get("reduced", False))
