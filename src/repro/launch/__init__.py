"""Launchers: production meshes, dry-run, training and serving CLIs."""
