"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Two parameter-placement modes (DESIGN.md section 3):

* ``gossip-dp``  : the DFL node dimension (leading, added by
  ``core.dfl.replicate``) is sharded over the node mesh axes
  (``data`` / ``pod``+``data``); weight dims shard over ``model`` only.
* ``gossip-fsdp``: few replicated nodes; weight dims shard over ``model``
  (tensor/expert parallel) AND ``data`` (FSDP on the embed dim).

A rule is skipped when the dim is not divisible by the mesh-axis size or the
mesh axis is already used by an earlier dim of the same param (PartitionSpec
must not repeat axes).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.substrate import mesh_axis_size

PyTree = Any

# logical axis -> mesh axis, per mode (applied left-to-right per param).
RULES: Dict[str, Dict[str, str]] = {
    "gossip-dp": {
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": "model",
        "experts": "model",
        "ssm_inner": "model",
    },
    "gossip-fsdp": {
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": "model",
        "experts": "model",
        "ssm_inner": "model",
        "embed": "data",
    },
    # serving uses the fsdp ruleset for big archs, dp ruleset for small.
}


def node_axes_for(mode: str, mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that enumerate DFL nodes."""
    has_pod = "pod" in mesh.axis_names
    if mode == "gossip-dp":
        return ("pod", "data") if has_pod else ("data",)
    if mode == "gossip-fsdp":
        # hierarchical DFL: nodes = pods on the multi-pod mesh, replicated
        # node dim on a single pod.
        return ("pod",) if has_pod else ()
    raise ValueError(mode)


def num_nodes_for(mode: str, mesh: Mesh, fsdp_nodes: int) -> int:
    axes = node_axes_for(mode, mesh)
    if mode == "gossip-dp" or axes:
        return mesh_axis_size(mesh, axes)
    # gossip-fsdp on a single pod: fsdp_nodes replicated nodes.
    return fsdp_nodes


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    return mesh_axis_size(mesh, axis)


def spec_for_param(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mode: str,
    mesh: Mesh,
    node_dim: bool,
) -> P:
    """PartitionSpec for one (possibly node-stacked) parameter leaf."""
    rules = RULES[mode]
    entries = []
    used = set()
    offset = 0
    if node_dim:
        naxes = node_axes_for(mode, mesh)
        if naxes and shape[0] == _axis_size(mesh, tuple(naxes)):
            entries.append(naxes if len(naxes) > 1 else naxes[0])
            used.update(naxes)
        else:
            entries.append(None)
        offset = 1
    # the stacked 'layers' axis (if present) is in logical_axes already.
    for i, name in enumerate(logical_axes):
        dim = shape[offset + i]
        mesh_axis = rules.get(name) if name else None
        if (
            mesh_axis is not None
            and mesh_axis in mesh.axis_names
            and mesh_axis not in used
            and dim % mesh.shape[mesh_axis] == 0
        ):
            entries.append(mesh_axis)
            used.add(mesh_axis)
        else:
            entries.append(None)
    return P(*entries)


def params_shardings(
    axes_tree: PyTree,
    params_tree: PyTree,
    mode: str,
    mesh: Mesh,
    node_dim: bool,
) -> PyTree:
    """NamedSharding tree for a (stacked) parameter tree."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def one(axes, leaf):
        spec = spec_for_param(axes, leaf.shape, mode, mesh, node_dim)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, axes_tree, params_tree, is_leaf=is_axes)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh, mode: str, *, has_tau_dim: bool) -> NamedSharding:
    """DFL training batches [tau1, N, B, ...]: shard N over the node axes in
    gossip-dp; shard B over `data` in gossip-fsdp (node dim replicated)."""
    naxes = node_axes_for(mode, mesh)
    lead = (None,) if has_tau_dim else ()
    if mode == "gossip-dp":
        n_entry = naxes if len(naxes) > 1 else naxes[0]
        spec = P(*lead, n_entry, None, None)
    else:
        spec = P(*lead, naxes[0] if naxes else None, "data", None)
    return NamedSharding(mesh, spec)


def stack_node_dim_abstract(tree: PyTree, n: int) -> PyTree:
    """Prepend the node dimension to abstract params."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree
    )
