"""DFL training CLI (runs for real at reduced scale; lowers-only at full).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --nodes 4 --tau1 4 --tau2 4 --rounds 20 --batch 4 --seq 128

Full-scale configs on the production mesh are exercised via dryrun.py; this
driver actually executes on the host devices (CPU here, TPU unchanged).

Engine selection (--engine): "auto" (default) runs the sparse shard_map +
ppermute engine when the host has exactly --nodes devices and the topology
is circulant (``sparse_engine_eligible``), else the dense stacked-array
engine; "sparse" forces it (errors if ineligible); "dense" forces the
reference path. --use-kernels routes the sparse hot path through the
Pallas kernels (interpret mode off-TPU).

Mega-scale (--virtual-nodes V [--cohort C]): the node-batched engine
stacks model state over V virtual nodes on one host and activates a
uniformly-sampled C-node cohort per round (C defaults to --nodes; the
gossip topology is built over the cohort). Cohort ids are schedule data
on the ``[K, 2+2C+E]`` trajectory rows, so every draw rides ONE compiled
executable (the ``cohort-recompile`` audit), data shards stream lazily by
global node id, and --faults compose (masks apply within the cohort).
Needs --dispatch fused; see benchmarks/bench_megascale.py for the
rounds/s / host-memory envelope up to 1M nodes.

Dispatch (--dispatch, --superstep): the hot loop runs on
``repro.core.executor.RoundExecutor``. "fused" (default) compiles ONE
dynamic-(tau1, tau2) round executable and dispatches --superstep rounds per
call as a donated-carry ``lax.scan`` — schedule changes never recompile,
and the host syncs with the device once per superstep (logging, checkpoints
and re-plans all happen at superstep boundaries). "static" is the legacy
keyed-compile-cache fallback: one compile per distinct (tau1, tau2).
Next-superstep batches are prefetched on a background thread while the
device runs.

Adaptive planning (--plan-budget SECONDS): hands (tau1, tau2) control to
``repro.planner.adaptive``. The controller plans the first schedule from a
neutral cost prior, measures real round wall-clock, re-fits per-step
compute/gossip times, and re-plans until the budget is spent; the schedule
trajectory lands in the history JSON (--history-out, ``schedule`` field =
the realized per-round [tau1, tau2] rows). With the fused executor a
re-plan is schedule DATA, so no round is ever compile-contaminated and
every measured round enters the controller's cost fit.

Schedule control (--schedule): "adaptive" (default with --plan-budget)
re-plans at superstep boundaries every --replan-every rounds;
"trajectory" re-plans INSIDE the superstep — each dispatch executes a
per-round [K, 2] (tau1, tau2) trajectory from
``AdaptiveController.next_trajectory`` via
``executor.dispatch_trajectory`` (probe rounds for identifiability ride
the last round of a chunk), still with zero recompiles. "fixed" pins the
CLI taus.

Telemetry (--telemetry-out events.jsonl): every run streams typed events
through ``repro.obs.Telemetry`` — rounds, supersteps, plan/replan/probe
decisions, compiles, prefetch builds, metric flushes, checkpoints, and
per-superstep counter snapshots (kernel op_stats deltas, compile count,
wire-bit totals, prefetch hit/stale). The --history-out JSON is a
schema-versioned VIEW over that stream (``repro.obs.history_view``) with
the same fields as before plus ``schema_version``. Inspect a stream with
``python -m repro.obs report|validate|trace export``. --profile-dir DIR
additionally wraps the run in ``jax.profiler`` so XLA device activity
can be lined up under the same timeline. All telemetry is host-side:
zero syncs, zero recompiles on the round path (audited —
``telemetry-neutrality`` in ``repro.analysis``).

All durations here are measured on the monotonic ``time.perf_counter``
clock (a wall-clock jump must never corrupt ``round_s`` or poison the
controller's least-squares fit); the only absolute timestamp is the
telemetry run header's ``wall_start``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_arch, list_archs
from repro.core import (DFLConfig, HostPrefetcher, MetricsBuffer,
                        RoundExecutor, init_state, make_compressor, ring,
                        round_wire_bits, sparse_engine_eligible,
                        stack_round_batches, fully_connected,
                        paper_quasi_ring)
from repro.core.compression import Identity, tree_wire_bits
from repro.data.lm import (SyntheticLM, lm_batches_for_cohort,
                           lm_batches_for_dfl)
from repro.faults import CohortSampler, FaultPlan, load_fault_spec
from repro.kernels.ops import op_stats_delta
from repro.launch.steps import kernelize_compressor
from repro.models import train_loss, init_params
from repro.obs import Telemetry, history_view
from repro.optim import sgd, momentum_sgd, adamw
from repro.planner import AdaptiveController, Budget, unit_cost_model
from repro.planner.optimize import DEFAULT_GRID


def make_topology(name: str, n: int):
    return {
        "ring": lambda: ring(n),
        "full": lambda: fully_connected(n),
        "quasi": lambda: paper_quasi_ring(),
    }[name]()


def make_optimizer(name: str, lr: float):
    return {
        "sgd": lambda: sgd(lr),
        "momentum": lambda: momentum_sgd(lr),
        "adamw": lambda: adamw(lr),
    }[name]()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--tau1", type=int, default=4)
    ap.add_argument("--tau2", type=int, default=4)
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "full", "quasi"])
    ap.add_argument("--compression", default="",
                    choices=["", "top_k", "rand_k", "qsgd", "rand_gossip"])
    ap.add_argument("--gamma", type=float, default=0.6)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "dense", "sparse"])
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas kernels: sparse-engine gossip + fused "
                         "CHOCO compress-and-move, and the kernel-backed "
                         "TopK compressor on either engine (dispatch per "
                         "repro.kernels.registry; interpret mode off-TPU)")
    ap.add_argument("--optimizer", default="sgd",
                    choices=["sgd", "momentum", "adamw"])
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4, help="per node")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--noniid", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--superstep", type=int, default=4,
                    help="rounds fused into one dispatch (K); logging / "
                         "checkpoint / re-plan granularity")
    ap.add_argument("--dispatch", default="fused",
                    choices=["fused", "static"],
                    help="fused: compile-once dynamic-tau executor; "
                         "static: legacy keyed per-(tau1,tau2) compile cache")
    ap.add_argument("--overlap", default="none",
                    choices=["none", "pipeline"],
                    help="superstep execution: 'pipeline' double-buffers "
                         "the scan so round k's gossip exchange overlaps "
                         "round k+1's local updates and folds in one round "
                         "late (one-round-stale mixing; the planner prices "
                         "both the hidden wire time and the staleness "
                         "penalty); 'none' is the paper-faithful "
                         "sequential round (bitwise the legacy path)")
    ap.add_argument("--plan-budget", type=float, default=0.0,
                    help="wall-clock budget (s); enables the adaptive "
                         "(tau1, tau2) planner (repro.planner.adaptive)")
    ap.add_argument("--replan-every", type=int, default=5,
                    help="rounds between re-plans when --plan-budget is set")
    ap.add_argument("--schedule", default="auto",
                    choices=["auto", "fixed", "adaptive", "trajectory"],
                    help="schedule control: fixed CLI taus, adaptive "
                         "boundary re-plans, or per-round [K, 2] "
                         "trajectories dispatched inside each superstep "
                         "(needs --plan-budget and --dispatch fused); "
                         "auto = adaptive iff --plan-budget is set")
    ap.add_argument("--virtual-nodes", type=int, default=0,
                    help="mega-scale mode: simulate this many virtual "
                         "nodes on one host via the node-batched engine — "
                         "model state is stacked [V, ...] and each round "
                         "activates a sampled --cohort over the --nodes "
                         "topology, with cohort ids as schedule data "
                         "(zero recompiles across draws; needs "
                         "--dispatch fused)")
    ap.add_argument("--cohort", type=int, default=0,
                    help="nodes sampled per round under --virtual-nodes "
                         "(default: --nodes, the cohort topology size)")
    ap.add_argument("--cohort-seed", type=int, default=0,
                    help="seed of the per-round cohort draw stream "
                         "(SeedSequence([seed, round]) — resume-safe)")
    ap.add_argument("--faults", default="",
                    help="deterministic fault injection: a JSON fault spec "
                         "(or @file.json) — see repro.faults. Rounds run "
                         "SPORADICALLY: crashed/masked nodes skip local "
                         "updates, dead edges gossip identity (mixing "
                         "renormalized), all with zero recompiles "
                         "(needs --dispatch fused)")
    ap.add_argument("--faults-seed", type=int, default=None,
                    help="override the fault spec's seed (the "
                         "SporadicParticipation Bernoulli stream)")
    ap.add_argument("--history-out", default="",
                    help="write the round/plan history JSON here (a "
                         "schema-versioned view over the telemetry stream)")
    ap.add_argument("--telemetry-out", default="",
                    help="append the full typed event stream here as JSONL "
                         "(inspect with `python -m repro.obs ...`)")
    ap.add_argument("--profile-dir", default="",
                    help="also capture a jax.profiler trace of the run "
                         "(XLA device activity) into this directory")
    args = ap.parse_args(argv)

    # The telemetry sink exists unconditionally (in-memory if no
    # --telemetry-out): the history JSON is derived from it either way.
    tel = Telemetry(path=args.telemetry_out or None, meta=dict(vars(args)))

    arch = get_arch(args.arch)
    cfg = arch.reduced
    n = args.nodes
    population = args.virtual_nodes
    sampler = None
    if args.cohort and not population:
        raise SystemExit("--cohort samples a virtual population; set "
                         "--virtual-nodes V")
    if population:
        if args.dispatch != "fused":
            raise SystemExit("--virtual-nodes runs cohort ids as schedule "
                             "data through the dynamic executor; the "
                             "static keyed cache can't (use --dispatch "
                             "fused)")
        if args.engine != "auto":
            raise SystemExit("--virtual-nodes selects the node-batched "
                             "engine; leave --engine auto")
        if args.overlap == "pipeline":
            raise SystemExit("--overlap pipeline double-buffers a fixed "
                             "node set; sampled cohorts change every round "
                             "(use --overlap none)")
        # the gossip topology is built over the COHORT (n becomes the
        # per-round active set size); the population only sizes the
        # stacked state and the shard id space.
        n = args.cohort or args.nodes
        sampler = CohortSampler(population=population, cohort=n,
                                seed=args.cohort_seed)
        print(f"mega-scale: population={population} cohort={n} "
              f"(sampling rate {sampler.rate:.4f})")
    comp = kernelize_compressor(
        make_compressor(args.compression) if args.compression else None,
        args.use_kernels)
    topology = make_topology(args.topology, n)
    opt = make_optimizer(args.optimizer, args.lr)

    fault_plan = None
    if args.faults:
        if args.dispatch != "fused":
            raise SystemExit("--faults runs sporadic rounds through the "
                             "participation trajectory path; the static "
                             "keyed cache can't (use --dispatch fused)")
        spec = load_fault_spec(args.faults)
        if args.faults_seed is not None:
            spec["seed"] = args.faults_seed
        fault_plan = FaultPlan.from_spec(topology, spec)
        print(f"fault plan: {len(fault_plan.faults)} fault(s), "
              f"seed={fault_plan.seed}")

    # mega-scale: shards are keyed by GLOBAL virtual node id, built lazily
    # (a 1M-node corpus costs O(cohort) host memory, and shard content is
    # independent of construction/access order — prefetcher-thread safe).
    corpus = SyntheticLM(vocab_size=cfg.vocab_size, num_nodes=population or n,
                         noniid_alpha=args.noniid, lazy=bool(population))

    def loss_fn(p, b, k):
        return train_loss(p, b, cfg, k)

    params0, _ = init_params(cfg, jax.random.key(0))
    state = init_state(params0, population or n, opt, jax.random.key(1),
                       compressed=comp is not None)
    start_round = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        restored, start_round = restore_checkpoint(args.ckpt_dir, state.params)
        state = state._replace(
            params=jax.tree_util.tree_map(jnp.asarray, restored))
        print(f"restored round {start_round} from {args.ckpt_dir}")

    mesh = None
    if not population and args.engine != "dense" and len(jax.devices()) == n:
        mesh = jax.make_mesh((n,), ("nodes",))

    schedule_mode = args.schedule
    if schedule_mode == "auto":
        schedule_mode = "adaptive" if args.plan_budget > 0 else "fixed"
    if schedule_mode in ("adaptive", "trajectory") and args.plan_budget <= 0:
        raise SystemExit(f"--schedule {schedule_mode} needs --plan-budget")
    if schedule_mode == "trajectory" and args.dispatch != "fused":
        raise SystemExit("--schedule trajectory dispatches per-round "
                         "[K, 2] schedules through the dynamic executor; "
                         "the static keyed cache can't (use --dispatch "
                         "fused)")
    if args.overlap == "pipeline" and args.dispatch != "fused":
        raise SystemExit("--overlap pipeline rides the fused superstep "
                         "scan's double-buffered carry; the static keyed "
                         "cache has nothing to overlap (use --dispatch "
                         "fused)")

    # Adaptive planner: --plan-budget hands (tau1, tau2) control to
    # repro.planner.adaptive, which re-fits per-step compute/gossip times
    # from measured round wall-clock and re-plans every --replan-every
    # rounds (or emits per-round trajectories under --schedule
    # trajectory). The CLI taus seed the neutral prior's first schedule.
    controller = None
    tau1, tau2 = args.tau1, args.tau2
    if schedule_mode in ("adaptive", "trajectory"):
        model_bits = tree_wire_bits(Identity(), params0)
        # neutral prior: t_compute_step = t_gossip_step = 1 s, with the
        # real topology and model wire size (same accounting as planner).
        # The executor's overlap mode rides the prior so every (re)plan
        # prices the max-form round time AND the staleness penalty
        # (planner.cost / planner.bounds) — the fitted model preserves it
        # (dataclasses.replace).
        prior = unit_cost_model(topology, 1.0,
                                rep_dim=max(int(model_bits // 32), 1),
                                overlap=args.overlap)
        controller = AdaptiveController(
            Budget(wall_clock_s=args.plan_budget), prior,
            sigma=1.0, f_gap=1.0, replan_every=args.replan_every,
            compressors=(comp,), telemetry=tel)
        p = controller.initial_plan()
        tau1, tau2 = p.tau1, p.tau2
        print(f"planned tau=({tau1},{tau2}) for budget "
              f"{args.plan_budget:.1f}s (predicted bound "
              f"{p.predicted_bound:.4f})")

    # The executor compiles ONCE against the (tau1_max, tau2_max) bounds:
    # with a planner those are the schedule grid's maxima so any re-plan
    # dispatches against the same executable; without, the CLI taus.
    if controller is not None:
        tau1_max = max(max(t1 for t1, _ in DEFAULT_GRID), tau1)
        tau2_max = max(max(t2 for _, t2 in DEFAULT_GRID), tau2)
    else:
        tau1_max, tau2_max = tau1, tau2
    dcfg_max = DFLConfig(tau1=tau1_max, tau2=tau2_max, topology=topology,
                         compression=comp, gamma=args.gamma)
    eligible = (mesh is not None
                and sparse_engine_eligible(dcfg_max, mesh, ("nodes",)))
    if args.engine == "sparse" and not eligible:
        raise SystemExit(
            "sparse engine needs #devices == --nodes and a circulant "
            f"topology (devices={len(jax.devices())}, nodes={n}, "
            f"topology={topology.name})")
    if population:
        engine = "batched"
    else:
        engine = "sparse" if (args.engine != "dense" and eligible) else "dense"
    executor = RoundExecutor(
        dcfg_max, loss_fn, opt, engine=engine, mesh=mesh,
        node_axes=("nodes",), use_kernels=args.use_kernels,
        dynamic=args.dispatch == "fused",
        participation=fault_plan is not None, telemetry=tel,
        overlap=args.overlap, population=population or None)

    # Wire accounting is DEPLOYMENT cost (what a real DFL network ships:
    # engine="auto" = per-neighbor when circulant), not the host-simulation
    # engine's, so the printed MB/round is host-device-count independent
    # and comparable with benchmarks/common.py.
    import dataclasses as _dc

    wire_cache = {}

    def wire_bits_for(t1: int, t2: int) -> float:
        """Deployment wire bits for one (tau1, tau2) round (memoized —
        the schedule grid is tiny)."""
        key = (int(t1), int(t2))
        if key not in wire_cache:
            wire_cache[key] = round_wire_bits(
                _dc.replace(dcfg_max, tau1=key[0], tau2=key[1]),
                params0, engine="auto")
        return wire_cache[key]

    bits = wire_bits_for(tau1, tau2)
    print(f"arch={cfg.name} nodes={n} tau=({tau1},{tau2}) "
          f"zeta={topology.zeta:.3f} comp={args.compression or 'none'} "
          f"engine={engine} dispatch={args.dispatch} "
          f"overlap={args.overlap} schedule={schedule_mode} "
          f"superstep={args.superstep} wire={bits/8e6:.1f} MB/round/node")

    def round_batch(r: int, t1: int):
        """One round's [t1, N, B, ...] batch tree (same data stream the
        legacy per-round loop fetched). Mega-scale: cohort slot j streams
        the shard of the GLOBAL node ``sampler.draw(r)[j]`` — a pure
        function of (seed, node, step), so prefetch threading cannot
        reorder shards."""
        if sampler is not None:
            b = dict(lm_batches_for_cohort(corpus, t1, sampler.draw(r),
                                           args.batch, args.seq, r))
        else:
            b = dict(lm_batches_for_dfl(corpus, t1, n, args.batch,
                                        args.seq, r))
        if cfg.has_memory_input:
            m = cfg.memory_tokens or 16
            key = jax.random.key(1000 + r)
            b["memory"] = jax.random.normal(
                key, (t1, n, args.batch, m, cfg.memory_dim or cfg.d_model),
                jnp.float32)
        return b

    def build_batches(r0: int, k: int, t1: int):
        """[k, tau1_max, N, B, ...] superstep batches for rounds
        r0..r0+k-1 (rows >= t1 zero-padded, never read)."""
        return stack_round_batches([round_batch(r0 + i, t1)
                                    for i in range(k)], tau1_max)

    def dummy_batches(k: int):
        """Zeros in the superstep batch shape — executor warmup only."""
        zero = jax.tree_util.tree_map(jnp.zeros_like, round_batch(0, 1))
        return stack_round_batches([zero] * k, tau1_max)

    end = start_round + args.rounds

    def chunk_len(r: int, rounds_done: int) -> int:
        k = min(max(args.superstep, 1), end - r)
        if schedule_mode == "adaptive":
            # cut at re-plan boundaries so rounds_done % replan_every == 0
            # lands exactly at a superstep edge (trajectory mode re-plans
            # inside every superstep instead, so no cut there).
            to_replan = args.replan_every - rounds_done % args.replan_every
            k = min(k, to_replan)
        return k

    # Warm every superstep shape the run will dispatch (the chunk-length
    # sequence is deterministic in (rounds, superstep, replan boundaries))
    # with a throwaway dummy dispatch, so no MEASURED round ever contains a
    # trace/compile: that is what lets every observed round enter the
    # controller's cost fit. The static fallback compiles per (tau1, tau2)
    # key, so it re-warms after every re-plan (one dummy superstep of
    # compute instead of a contaminated measurement).
    def remaining_chunk_lens(rr: int, done: int):
        """Distinct superstep sizes the run will still dispatch from round
        rr (deterministic in (rounds, superstep, replan boundaries))."""
        ks = set()
        while rr < end:
            kk = chunk_len(rr, done)
            ks.add(kk)
            rr += kk
            done += kk
        return sorted(ks, reverse=True)

    warmed_shapes = set()   # superstep lengths K already compiled

    def warm_executables(ks, t1: int, t2: int) -> None:
        """Pre-pay compiles on dummy data so no MEASURED round contains
        one. Fused compiles per SHAPE only (the schedule args are
        irrelevant — one executable serves every (tau1, tau2)); static
        compiles per (shape, (tau1, tau2)) key. Warmup wall-clock is real
        budget spend and is charged to the controller, but never enters
        the per-round cost fit."""
        tw0 = time.perf_counter()
        before = executor.compile_count
        for kk in ks:
            if args.dispatch == "fused":
                executor.warmup(state, dummy_batches(kk))
            else:
                executor.warmup(state, dummy_batches(kk), t1, t2)
            warmed_shapes.add(kk)
        if executor.compile_count > before:
            print(f"warmed {executor.compile_count - before} superstep "
                  f"executable(s) in {time.perf_counter()-tw0:.1f}s")
        if controller is not None:
            controller.spend_overhead(time.perf_counter() - tw0)

    profiling = False
    if args.profile_dir:
        try:
            jax.profiler.start_trace(args.profile_dir)
            profiling = True
            print(f"jax profiler trace -> {args.profile_dir}")
        except Exception as e:  # profiler backends vary; never fatal
            print(f"profiler unavailable ({e}); continuing without")

    if args.rounds > 0:
        warm_executables(remaining_chunk_lens(start_round, 0), tau1, tau2)
    compiles_after_warmup = executor.compile_count

    buffer = MetricsBuffer(telemetry=tel)
    # transient host batch-build failures retry with backoff on the
    # worker thread; the close() in the finally below joins any pending
    # worker on EVERY exit path (no thread leak past the run).
    prefetch = HostPrefetcher(telemetry=tel, retries=2)
    t0 = time.perf_counter()
    rounds_done = 0
    wire_total = 0.0
    last_ckpt = start_round
    last_loss = float("nan")

    def emit_counters(round0: int, kk: int, opd) -> None:
        """Per-superstep counter attribution: kernel op_stats deltas from
        the enclosing dispatch, cumulative compile/wire/prefetch state."""
        tel.emit("counters", track="dispatch", name="superstep-counters",
                 round0=round0, k=kk,
                 compile_count=executor.compile_count,
                 wire_bits_total=wire_total,
                 prefetch_taken=prefetch.stats["taken"],
                 prefetch_stale=prefetch.stats["stale"],
                 prefetch_cancelled=prefetch.stats["cancelled"],
                 **{f"kernel_{key}": v for key, v in opd.as_dict().items()})

    def do_checkpoint(step: int, extra: dict) -> None:
        ck0 = tel.now()
        save_checkpoint(args.ckpt_dir, step, state.params, extra)
        tel.emit("checkpoint", track="checkpoint", name=f"ckpt-{step}",
                 t=ck0, dur=tel.now() - ck0, round=step)

    def flush_rows():
        """Materialize buffered metrics into round events/logs and feed
        the controller. Adaptive mode observes per round (uniform chunks,
        so the amortized round_s is exact); trajectory mode observes per
        CHUNK (heterogeneous schedules share one fused dispatch — only
        the chunk total is measurable, and ``observe_chunk``'s aggregated
        fit row keeps the least-squares fit exact). The history JSON is
        reconstructed from these events at the end (history_view)."""
        nonlocal last_loss, wire_total
        rows = buffer.flush()
        for row in rows:
            r = row["round"]
            wire_total += wire_bits_for(row["tau1"], row["tau2"])
            extra = {}
            if "active_nodes" in row:
                # sporadic run: realized participation rides every round
                # event (history/report attribute loss to availability).
                degraded = (row["active_nodes"] < n
                            or row["masked_edges"] > 0)
                extra = dict(active_nodes=row["active_nodes"],
                             masked_edges=row["masked_edges"],
                             degraded=degraded)
            if sampler is not None:
                # mega-scale: the history view's schema-4 cohort columns.
                extra.update(cohort_size=n, population=population)
            tel.emit("round", track="rounds", name=f"round-{r}",
                     round=r, tau1=row["tau1"], tau2=row["tau2"],
                     loss=row["loss"], consensus_sq=row["consensus_sq"],
                     round_s=row["round_s"],
                     wire_bits=wire_bits_for(row["tau1"], row["tau2"]),
                     **extra)
            if extra.get("degraded"):
                tel.emit("degraded", track="faults", name=f"degraded-{r}",
                         round=r, active_nodes=row["active_nodes"],
                         masked_edges=row["masked_edges"])
            if fault_plan is not None:
                for payload in fault_plan.events(r):
                    tel.emit("fault", track="faults",
                             name=f"{payload['kind']}-{payload['phase']}",
                             round=r, **payload)
                if controller is not None:
                    nm, em = fault_plan.masks(r)
                    controller.observe_participation(nm, em)
            last_loss = row["loss"]
            if (r + 1) % args.log_every == 0:
                done = r + 1 - start_round
                print(f"round {r+1:4d} tau=({row['tau1']},{row['tau2']}) "
                      f"loss={row['loss']:.4f} "
                      f"consensus={row['consensus_sq']:.3e} "
                      f"({(time.perf_counter()-t0)/max(done,1):.1f}s/round)",
                      flush=True)
            if controller is not None and schedule_mode != "trajectory":
                controller.observe(row["tau1"], row["tau2"], row["round_s"])
        if rows and controller is not None and schedule_mode == "trajectory":
            controller.observe_chunk(
                [(row["tau1"], row["tau2"]) for row in rows],
                sum(row["round_s"] for row in rows))

    try:
        if schedule_mode == "trajectory":
            # Per-round schedule control: every superstep dispatches a [k, 2]
            # trajectory planned by the controller — the re-plan happens
            # INSIDE the superstep (probe rounds included), not at its
            # boundary, and the realized per-round schedule comes back in the
            # metrics rows. Host batch build overlaps the device via the
            # prefetcher, keyed on the controller's PREDICTED next
            # trajectory (``predict_trajectory`` runs the exact planning
            # the next ``next_trajectory`` will commit, so after
            # ``flush_rows`` the prediction matches unless new overhead
            # spend shifted the budget — a mismatch rebuilds inline and
            # counts as a stale take).

            def build_traj_batches(r0: int, t1_rows):
                """[k, tau1_max, N, B, ...] batches for a [k]-row tau1
                column (batch content depends only on tau1, not tau2)."""
                return stack_round_batches(
                    [round_batch(r0 + i, int(t1))
                     for i, t1 in enumerate(t1_rows)], tau1_max)

            def tau1_key(r0: int, rows) -> tuple:
                return (r0, tuple(int(t1) for t1, *_rest in rows))

            def schedule_predicted(r0: int, done: int) -> bool:
                """Prefetch against the predicted next chunk; False when
                no further chunk is predicted (end / budget)."""
                if r0 >= end or controller.exhausted:
                    return False
                pred = controller.predict_trajectory(chunk_len(r0, done))
                if pred is None:
                    return False
                prefetch.schedule(build_traj_batches, r0, pred[:, 0],
                                  meta=tau1_key(r0, pred))
                return True

            r = start_round
            pending = schedule_predicted(r, rounds_done)
            while r < end:
                k = chunk_len(r, rounds_done)
                taus = controller.next_trajectory(k, round_idx=rounds_done)
                if taus is None:
                    print(f"budget exhausted after {rounds_done} rounds "
                          f"({controller.spent_s:.1f}s)")
                    break
                if len(taus) not in warmed_shapes:
                    # a superstep length the pre-loop warmup never saw (a
                    # budget-paced short chunk, or the shifted chunk grid
                    # after one): a new batch SHAPE — warm it on dummy data
                    # so the measured rounds stay compile-free.
                    tw0 = time.perf_counter()
                    executor.warmup(state, dummy_batches(len(taus)))
                    warmed_shapes.add(len(taus))
                    controller.spend_overhead(time.perf_counter() - tw0)
                # host batch build is real wall-clock the budget pays for —
                # charge the take-stall (or inline rebuild) as overhead,
                # not as round time.
                tb0 = time.perf_counter()
                batches = None
                if pending:
                    got, meta = prefetch.take()
                    if meta == tau1_key(r, taus):
                        batches = got
                    else:
                        prefetch.mark_stale()
                if batches is None:
                    span = "stale-rebuild" if pending else "batch-build"
                    with tel.span(span, track="prefetch"):
                        batches = build_traj_batches(r, taus[:, 0])
                controller.spend_overhead(time.perf_counter() - tb0)
                sched_rows = (fault_plan.mask_trajectory(taus, r)
                              if fault_plan is not None else taus)
                if sampler is not None:
                    # splice the per-round cohort draws in front of the
                    # (possibly fault-masked) participation columns.
                    sched_rows = sampler.cohort_trajectory(
                        sched_rows, r, num_edges=topology.num_edges)
                t_dispatch = time.perf_counter()
                with op_stats_delta() as opd:
                    state, metrics = executor.dispatch_trajectory(
                        state, batches, sched_rows)
                buffer.push(r, len(taus), None, None, metrics,
                            dispatched_at=t_dispatch)
                r += len(taus)
                rounds_done += len(taus)
                flush_rows()   # every realized round enters the cost fit
                # predict + schedule the NEXT chunk right after the flush:
                # the controller state now equals what the next
                # next_trajectory call will see, so the prediction is
                # deterministic-identical barring later overhead spend.
                pending = schedule_predicted(r, rounds_done)
                emit_counters(r - len(taus), len(taus), opd)
                if (args.ckpt_every and args.ckpt_dir
                        and r // args.ckpt_every
                        > last_ckpt // args.ckpt_every):
                    do_checkpoint(r, {"loss": last_loss})
                    last_ckpt = r

        # fixed/adaptive modes: the prefetched uniform-schedule superstep loop
        # (trajectory mode already ran above; r = end skips it).
        r = end if schedule_mode == "trajectory" else start_round
        k = chunk_len(r, rounds_done) if r < end else 0
        if k > 0:
            prefetch.schedule(build_batches, r, k, tau1, meta=(r, k, tau1))
        while r < end:
            batches, meta = prefetch.take()
            if meta != (r, k, tau1):   # stale after a re-plan changed tau1
                prefetch.mark_stale()
                with tel.span("stale-rebuild", track="prefetch"):
                    batches = build_batches(r, k, tau1)
            t_dispatch = time.perf_counter()  # sync backends EXECUTE inside
            with op_stats_delta() as opd:     # dispatch
                if fault_plan is not None or sampler is not None:
                    # widen the uniform chunk to masked participation /
                    # sampled cohort rows — same executable, the masks and
                    # cohort ids are just more xs columns.
                    rows = np.tile(np.array([[tau1, tau2]], np.int32),
                                   (k, 1))
                    if fault_plan is not None:
                        rows = fault_plan.mask_trajectory(rows, r)
                    if sampler is not None:
                        rows = sampler.cohort_trajectory(
                            rows, r, num_edges=topology.num_edges)
                    state, metrics = executor.dispatch_trajectory(
                        state, batches, rows)
                else:
                    state, metrics = executor.dispatch(state, batches, tau1,
                                                       tau2)
            buffer.push(r, k, tau1, tau2, metrics, dispatched_at=t_dispatch)
            emit_counters(r, k, opd)
            r += k
            rounds_done += k
            # overlap: build the NEXT superstep's batches while the device runs
            # this one (a later re-plan invalidates at most this one chunk).
            k_next = chunk_len(r, rounds_done)
            if k_next > 0:
                prefetch.schedule(build_batches, r, k_next, tau1,
                                  meta=(r, k_next, tau1))
            # host sync boundary: re-plans need per-round timings each chunk;
            # otherwise only log/checkpoint boundaries (or the end) block.
            boundary = (controller is not None
                        or any((rr + 1) % args.log_every == 0
                               for rr in range(r - k, r))
                        or (args.ckpt_every
                            and r // args.ckpt_every > last_ckpt // args.ckpt_every)
                        or r >= end)
            if boundary:
                flush_rows()
            if (args.ckpt_every and args.ckpt_dir
                    and r // args.ckpt_every > last_ckpt // args.ckpt_every):
                # superstep granularity: the checkpoint lands at the first
                # superstep edge at/after the --ckpt-every multiple.
                do_checkpoint(r, {"loss": last_loss})
                last_ckpt = r
            if controller is not None:
                new = controller.maybe_replan(rounds_done)
                if controller.exhausted:
                    print(f"budget exhausted after {rounds_done} rounds "
                          f"({controller.spent_s:.1f}s)")
                    break
                if new is not None:
                    tau1, tau2 = new.tau1, new.tau2
                    print(f"replanned tau=({tau1},{tau2}) at round {r} "
                          f"(t_step={new.round_cost.t_compute_step:.3f}s, "
                          f"t_gossip={new.round_cost.t_gossip_step:.3f}s, "
                          f"predicted bound {new.predicted_bound:.4f}, "
                          f"recompiles so far: {executor.compile_count})")
                    if args.dispatch == "static" and r < end:
                        # the static cache compiles per (tau1, tau2): pay the
                        # new key on dummy data now — for the chunk sizes
                        # still ahead only — not inside a measured round.
                        warm_executables(remaining_chunk_lens(r, rounds_done),
                                         tau1, tau2)
            k = chunk_len(r, rounds_done)
            flush_rows()
    finally:
        prefetch.close()
    if args.ckpt_dir:
        do_checkpoint(start_round + rounds_done, {})
    if profiling:
        try:
            jax.profiler.stop_trace()
        except Exception as e:
            print(f"profiler stop failed ({e})")
    # run-level summary counters: the stream-derived history view reads
    # schedule_mode / compile counts from here, and reports read the
    # final wire/prefetch totals. compile_count must equal
    # compile_count_warmup under fused dispatch: every re-plan reused the
    # warmed executables.
    tel.emit("counters", track="run", name="run-summary",
             schedule_mode=schedule_mode,
             rounds_done=rounds_done,
             engine=engine,
             compile_count_warmup=compiles_after_warmup,
             compile_count=executor.compile_count,
             wire_bits_total=wire_total,
             prefetch_taken=prefetch.stats["taken"],
             prefetch_stale=prefetch.stats["stale"],
             prefetch_cancelled=prefetch.stats["cancelled"],
             wall_s=time.perf_counter() - t0)
    # the history JSON is a VIEW over the event stream now: same legacy
    # fields (round/loss/consensus_sq/tau1/tau2/round_s, plan_events, the
    # realized [tau1, tau2] schedule rows, schedule_mode, compile counts)
    # plus schema_version.
    history = history_view(tel.events)
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)
        print(f"history -> {args.history_out}")
    if args.telemetry_out:
        print(f"telemetry -> {args.telemetry_out} "
              f"({len(tel.events)} events)")
    tel.close()
    print("done")


if __name__ == "__main__":
    main()
