"""DFL training CLI (runs for real at reduced scale; lowers-only at full).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --nodes 4 --tau1 4 --tau2 4 --rounds 20 --batch 4 --seq 128

Full-scale configs on the production mesh are exercised via dryrun.py; this
driver actually executes on the host devices (CPU here, TPU unchanged).

Engine selection (--engine): "auto" (default) runs the sparse shard_map +
ppermute engine when the host has exactly --nodes devices and the topology
is circulant (``sparse_engine_eligible``), else the dense stacked-array
engine; "sparse" forces it (errors if ineligible); "dense" forces the
reference path. --use-kernels routes the sparse hot path through the
Pallas kernels (interpret mode off-TPU).

Adaptive planning (--plan-budget SECONDS): hands (tau1, tau2) control to
``repro.planner.adaptive``. The controller plans the first schedule from a
neutral cost prior, measures real round wall-clock, re-fits per-step
compute/gossip times, and re-plans every --replan-every rounds until the
budget is spent; the schedule trajectory lands in the history JSON
(--history-out).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_arch, list_archs
from repro.core import (DFLConfig, average_model, init_state,
                        make_compressor, make_round_fn, ring,
                        round_wire_bits, sparse_engine_eligible,
                        fully_connected, paper_quasi_ring)
from repro.core.compression import Identity, tree_wire_bits
from repro.data.lm import SyntheticLM, lm_batches_for_dfl
from repro.models import train_loss, init_params
from repro.optim import sgd, momentum_sgd, adamw
from repro.planner import AdaptiveController, Budget, unit_cost_model


def make_topology(name: str, n: int):
    return {
        "ring": lambda: ring(n),
        "full": lambda: fully_connected(n),
        "quasi": lambda: paper_quasi_ring(),
    }[name]()


def make_optimizer(name: str, lr: float):
    return {
        "sgd": lambda: sgd(lr),
        "momentum": lambda: momentum_sgd(lr),
        "adamw": lambda: adamw(lr),
    }[name]()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--tau1", type=int, default=4)
    ap.add_argument("--tau2", type=int, default=4)
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "full", "quasi"])
    ap.add_argument("--compression", default="",
                    choices=["", "top_k", "rand_k", "qsgd", "rand_gossip"])
    ap.add_argument("--gamma", type=float, default=0.6)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "dense", "sparse"])
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas kernels on the sparse hot path")
    ap.add_argument("--optimizer", default="sgd",
                    choices=["sgd", "momentum", "adamw"])
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4, help="per node")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--noniid", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--plan-budget", type=float, default=0.0,
                    help="wall-clock budget (s); enables the adaptive "
                         "(tau1, tau2) planner (repro.planner.adaptive)")
    ap.add_argument("--replan-every", type=int, default=5,
                    help="rounds between re-plans when --plan-budget is set")
    ap.add_argument("--history-out", default="",
                    help="write the round/plan history JSON here")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.reduced
    n = args.nodes
    comp = make_compressor(args.compression) if args.compression else None
    topology = make_topology(args.topology, n)
    opt = make_optimizer(args.optimizer, args.lr)

    corpus = SyntheticLM(vocab_size=cfg.vocab_size, num_nodes=n,
                         noniid_alpha=args.noniid)

    def loss_fn(p, b, k):
        return train_loss(p, b, cfg, k)

    params0, _ = init_params(cfg, jax.random.key(0))
    state = init_state(params0, n, opt, jax.random.key(1),
                       compressed=comp is not None)
    start_round = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        restored, start_round = restore_checkpoint(args.ckpt_dir, state.params)
        state = state._replace(
            params=jax.tree_util.tree_map(jnp.asarray, restored))
        print(f"restored round {start_round} from {args.ckpt_dir}")

    mesh = None
    if args.engine != "dense" and len(jax.devices()) == n:
        mesh = jax.make_mesh((n,), ("nodes",))

    def build(tau1: int, tau2: int):
        """(dcfg, jitted round_fn, engine) for one (tau1, tau2) schedule."""
        dcfg = DFLConfig(tau1=tau1, tau2=tau2, topology=topology,
                         compression=comp, gamma=args.gamma)
        eligible = (mesh is not None
                    and sparse_engine_eligible(dcfg, mesh, ("nodes",)))
        if args.engine == "sparse" and not eligible:
            raise SystemExit(
                "sparse engine needs #devices == --nodes and a circulant "
                f"topology (devices={len(jax.devices())}, nodes={n}, "
                f"topology={dcfg.topology.name})")
        engine = "sparse" if (args.engine != "dense" and eligible) else "dense"
        round_fn = jax.jit(make_round_fn(
            dcfg, loss_fn, opt, engine=engine, mesh=mesh,
            node_axes=("nodes",), use_kernels=args.use_kernels))
        return dcfg, round_fn, engine

    # Adaptive planner: --plan-budget hands (tau1, tau2) control to
    # repro.planner.adaptive, which re-fits per-step compute/gossip times
    # from measured round wall-clock and re-plans every --replan-every
    # rounds. The CLI taus seed the neutral prior's first schedule.
    controller = None
    tau1, tau2 = args.tau1, args.tau2
    if args.plan_budget > 0:
        model_bits = tree_wire_bits(Identity(), params0)
        # neutral prior: t_compute_step = t_gossip_step = 1 s, with the
        # real topology and model wire size (same accounting as planner).
        prior = unit_cost_model(topology, 1.0,
                                rep_dim=max(int(model_bits // 32), 1))
        controller = AdaptiveController(
            Budget(wall_clock_s=args.plan_budget), prior,
            sigma=1.0, f_gap=1.0, replan_every=args.replan_every,
            compressors=(comp,))
        p = controller.initial_plan()
        tau1, tau2 = p.tau1, p.tau2
        print(f"planned tau=({tau1},{tau2}) for budget "
              f"{args.plan_budget:.1f}s (predicted bound "
              f"{p.predicted_bound:.4f})")

    dcfg, round_fn, engine = build(tau1, tau2)
    # Wire accounting is DEPLOYMENT cost (what a real DFL network ships:
    # engine="auto" = per-neighbor when circulant), not the host-simulation
    # engine's, so the printed MB/round is host-device-count independent
    # and comparable with benchmarks/common.py.
    bits = round_wire_bits(dcfg, params0, engine="auto")
    print(f"arch={cfg.name} nodes={n} tau=({tau1},{tau2}) "
          f"zeta={dcfg.topology.zeta:.3f} comp={args.compression or 'none'} "
          f"engine={engine} wire={bits/8e6:.1f} MB/round/node")

    history = {"round": [], "loss": [], "consensus_sq": [], "tau1": [],
               "tau2": [], "round_s": []}
    t0 = time.time()
    rounds_done = 0
    freshly_built = True   # first round after a (re)build pays jit compile
    for r in range(start_round, start_round + args.rounds):
        def fetch(mem_needed=cfg.has_memory_input):
            b = lm_batches_for_dfl(corpus, tau1, n, args.batch,
                                   args.seq, r)
            if mem_needed:
                m = cfg.memory_tokens or 16
                key = jax.random.key(1000 + r)
                b["memory"] = jax.random.normal(
                    key, (tau1, n, args.batch, m,
                          cfg.memory_dim or cfg.d_model), jnp.float32)
            return b

        tr0 = time.time()
        state, metrics = round_fn(state, fetch())
        loss = float(metrics["loss"])          # blocks on the round
        round_s = time.time() - tr0
        rounds_done += 1
        history["round"].append(r + 1)
        history["loss"].append(loss)
        history["consensus_sq"].append(float(metrics["consensus_sq"]))
        history["tau1"].append(tau1)
        history["tau2"].append(tau2)
        history["round_s"].append(round_s)
        if (r + 1) % args.log_every == 0:
            print(f"round {r+1:4d} tau=({tau1},{tau2}) loss={loss:.4f} "
                  f"consensus={float(metrics['consensus_sq']):.3e} "
                  f"({(time.time()-t0)/rounds_done:.1f}s/round)",
                  flush=True)
        if args.ckpt_dir and args.ckpt_every and (r + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, r + 1, state.params,
                            {"loss": loss})
        if controller is not None:
            # compile-contaminated rounds spend budget but don't enter the
            # least-squares cost fit.
            controller.observe(tau1, tau2, round_s, fit=not freshly_built)
            freshly_built = False
            new = controller.maybe_replan(rounds_done)
            if controller.exhausted:
                print(f"budget exhausted after {rounds_done} rounds "
                      f"({controller.spent_s:.1f}s)")
                break
            if new is not None:
                tau1, tau2 = new.tau1, new.tau2
                dcfg, round_fn, engine = build(tau1, tau2)
                freshly_built = True
                print(f"replanned tau=({tau1},{tau2}) at round {r+1} "
                      f"(t_step={new.round_cost.t_compute_step:.3f}s, "
                      f"t_gossip={new.round_cost.t_gossip_step:.3f}s, "
                      f"predicted bound {new.predicted_bound:.4f})")
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, start_round + rounds_done,
                        state.params, {})
    if controller is not None:
        history["plan_events"] = controller.history
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)
        print(f"history -> {args.history_out}")
    print("done")


if __name__ == "__main__":
    main()
