"""DFL training CLI (runs for real at reduced scale; lowers-only at full).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --nodes 4 --tau1 4 --tau2 4 --rounds 20 --batch 4 --seq 128

Full-scale configs on the production mesh are exercised via dryrun.py; this
driver actually executes on the host devices (CPU here, TPU unchanged).

Engine selection (--engine): "auto" (default) runs the sparse shard_map +
ppermute engine when the host has exactly --nodes devices and the topology
is circulant (``sparse_engine_eligible``), else the dense stacked-array
engine; "sparse" forces it (errors if ineligible); "dense" forces the
reference path. --use-kernels routes the sparse hot path through the
Pallas kernels (interpret mode off-TPU).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_arch, list_archs
from repro.core import (DFLConfig, average_model, init_state,
                        make_compressor, make_round_fn, ring,
                        round_wire_bits, sparse_engine_eligible,
                        fully_connected, paper_quasi_ring)
from repro.data.lm import SyntheticLM, lm_batches_for_dfl
from repro.models import train_loss, init_params
from repro.optim import sgd, momentum_sgd, adamw


def make_topology(name: str, n: int):
    return {
        "ring": lambda: ring(n),
        "full": lambda: fully_connected(n),
        "quasi": lambda: paper_quasi_ring(),
    }[name]()


def make_optimizer(name: str, lr: float):
    return {
        "sgd": lambda: sgd(lr),
        "momentum": lambda: momentum_sgd(lr),
        "adamw": lambda: adamw(lr),
    }[name]()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--tau1", type=int, default=4)
    ap.add_argument("--tau2", type=int, default=4)
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "full", "quasi"])
    ap.add_argument("--compression", default="",
                    choices=["", "top_k", "rand_k", "qsgd", "rand_gossip"])
    ap.add_argument("--gamma", type=float, default=0.6)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "dense", "sparse"])
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas kernels on the sparse hot path")
    ap.add_argument("--optimizer", default="sgd",
                    choices=["sgd", "momentum", "adamw"])
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4, help="per node")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--noniid", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.reduced
    n = args.nodes
    comp = make_compressor(args.compression) if args.compression else None
    dcfg = DFLConfig(tau1=args.tau1, tau2=args.tau2,
                     topology=make_topology(args.topology, n),
                     compression=comp, gamma=args.gamma)
    opt = make_optimizer(args.optimizer, args.lr)

    corpus = SyntheticLM(vocab_size=cfg.vocab_size, num_nodes=n,
                         noniid_alpha=args.noniid)

    def loss_fn(p, b, k):
        return train_loss(p, b, cfg, k)

    params0, _ = init_params(cfg, jax.random.key(0))
    state = init_state(params0, n, opt, jax.random.key(1),
                       compressed=dcfg.is_compressed)
    start_round = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        restored, start_round = restore_checkpoint(args.ckpt_dir, state.params)
        state = state._replace(
            params=jax.tree_util.tree_map(jnp.asarray, restored))
        print(f"restored round {start_round} from {args.ckpt_dir}")

    mesh = None
    if args.engine != "dense" and len(jax.devices()) == n:
        mesh = jax.make_mesh((n,), ("nodes",))
    eligible = (mesh is not None
                and sparse_engine_eligible(dcfg, mesh, ("nodes",)))
    if args.engine == "sparse" and not eligible:
        raise SystemExit(
            "sparse engine needs #devices == --nodes and a circulant "
            f"topology (devices={len(jax.devices())}, nodes={n}, "
            f"topology={dcfg.topology.name})")
    engine = "sparse" if (args.engine != "dense" and eligible) else "dense"
    round_fn = jax.jit(make_round_fn(
        dcfg, loss_fn, opt, engine=engine, mesh=mesh, node_axes=("nodes",),
        use_kernels=args.use_kernels))
    # Wire accounting is DEPLOYMENT cost (what a real DFL network ships:
    # engine="auto" = per-neighbor when circulant), not the host-simulation
    # engine's, so the printed MB/round is host-device-count independent
    # and comparable with benchmarks/common.py.
    bits = round_wire_bits(dcfg, params0, engine="auto")
    print(f"arch={cfg.name} nodes={n} tau=({args.tau1},{args.tau2}) "
          f"zeta={dcfg.topology.zeta:.3f} comp={args.compression or 'none'} "
          f"engine={engine} wire={bits/8e6:.1f} MB/round/node")

    t0 = time.time()
    for r in range(start_round, start_round + args.rounds):
        def fetch(mem_needed=cfg.has_memory_input):
            b = lm_batches_for_dfl(corpus, args.tau1, n, args.batch,
                                   args.seq, r)
            if mem_needed:
                m = cfg.memory_tokens or 16
                key = jax.random.key(1000 + r)
                b["memory"] = jax.random.normal(
                    key, (args.tau1, n, args.batch, m,
                          cfg.memory_dim or cfg.d_model), jnp.float32)
            return b

        state, metrics = round_fn(state, fetch())
        if (r + 1) % args.log_every == 0:
            print(f"round {r+1:4d} loss={float(metrics['loss']):.4f} "
                  f"consensus={float(metrics['consensus_sq']):.3e} "
                  f"({(time.time()-t0)/(r-start_round+1):.1f}s/round)",
                  flush=True)
        if args.ckpt_dir and args.ckpt_every and (r + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, r + 1, state.params,
                            {"loss": float(metrics["loss"])})
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, start_round + args.rounds,
                        state.params, {})
    print("done")


if __name__ == "__main__":
    main()
