"""Learning-rate schedules, including the paper's eta_k = 4 / (mu (a + k))
decay used by C-DFL's Proposition 2."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "cosine_decay", "warmup_cosine", "step_decay", "cdfl_decay"]


def constant(value: float):
    def sched(step):
        return jnp.asarray(value, jnp.float32)

    return sched


def cosine_decay(peak: float, total_steps: int, floor: float = 0.0):
    def sched(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))

    return sched


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    cos = cosine_decay(peak, max(total_steps - warmup_steps, 1), floor)

    def sched(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup_steps, 1)
        return jnp.where(s < warmup_steps, warm, cos(step - warmup_steps))

    return sched


def step_decay(base: float, drop: float, every: int):
    def sched(step):
        k = (step // every).astype(jnp.float32)
        return base * (drop**k)

    return sched


def cdfl_decay(mu: float, a: float):
    """eta_k = 4 / (mu (a + k))  [Prop. 2; a >= 16 kappa]."""

    def sched(step):
        return 4.0 / (mu * (a + step.astype(jnp.float32)))

    return sched
