"""Minimal optax-style optimizers.

Pure-functional ``Optimizer = (init, update)`` pairs whose states are plain
pytrees, so they compose with ``jax.vmap`` over the DFL node axis (every
node carries its own slots) and with pjit sharding (slots inherit the
parameter sharding).

The paper trains with plain SGD (Sec. VI-A); momentum/AdamW are provided as
framework substrate and for the LM examples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]

__all__ = [
    "Optimizer",
    "sgd",
    "momentum_sgd",
    "adamw",
    "apply_updates",
    "clip_by_global_norm",
]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]
    """update(grads, state, params) -> (updates, new_state); params' = params + updates."""


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


class _SGDState(NamedTuple):
    step: jnp.ndarray


def sgd(lr) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        del params
        return _SGDState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        del params
        eta = sched(state.step)
        updates = jax.tree_util.tree_map(lambda g: -eta * g, grads)
        return updates, _SGDState(step=state.step + 1)

    return Optimizer(init, update)


class _MomentumState(NamedTuple):
    step: jnp.ndarray
    velocity: PyTree


def momentum_sgd(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        v = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return _MomentumState(step=jnp.zeros((), jnp.int32), velocity=v)

    def update(grads, state, params):
        del params
        eta = sched(state.step)
        v = jax.tree_util.tree_map(
            lambda vv, g: beta * vv + g.astype(jnp.float32), state.velocity, grads
        )
        if nesterov:
            eff = jax.tree_util.tree_map(
                lambda vv, g: beta * vv + g.astype(jnp.float32), v, grads
            )
        else:
            eff = v
        updates = jax.tree_util.tree_map(lambda e: -eta * e, eff)
        return updates, _MomentumState(step=state.step + 1, velocity=v)

    return Optimizer(init, update)


class _AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


def adamw(
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return _AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads, state, params):
        step = state.step + 1
        eta = sched(state.step)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        t = step.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1.0 - b1**t)
        nu_hat_scale = 1.0 / (1.0 - b2**t)

        def upd(m, v, p):
            adam = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)
            return -eta * (adam + weight_decay * p.astype(jnp.float32))

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, _AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)
