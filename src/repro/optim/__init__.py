"""Optimizers and LR schedules (minimal optax-style, vmap-friendly)."""
from repro.optim.optimizers import (
    Optimizer,
    sgd,
    momentum_sgd,
    adamw,
    apply_updates,
    clip_by_global_norm,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine, step_decay

__all__ = [
    "Optimizer", "sgd", "momentum_sgd", "adamw", "apply_updates",
    "clip_by_global_norm",
    "constant", "cosine_decay", "warmup_cosine", "step_decay",
]
