"""Pure-jnp oracles for the Pallas kernels (the source of truth in tests).

Each kernel in this package has exactly one oracle here, registered next
to it in ``repro.kernels.registry`` so the parity harness (and
``benchmarks/bench_kernels``) can sweep kernel-vs-oracle agreement
mechanically. Oracles take the SAME explicit randomness (noise tensors,
thresholds) as the kernels, so agreement is checked bitwise where the
arithmetic allows, not just statistically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qsgd_ref(x: jnp.ndarray, noise: jnp.ndarray, *, levels: int,
             c: float) -> jnp.ndarray:
    """Matches repro.core.compression.QSGD with explicit noise."""
    flat = x.reshape(-1).astype(jnp.float32)
    s = float(levels)
    norm = jnp.linalg.norm(flat)
    safe = jnp.where(norm > 0, norm, 1.0)
    lvl = jnp.floor(s * jnp.abs(flat) / safe + noise.reshape(-1))
    q = jnp.sign(flat) * safe * lvl / (s * c)
    q = jnp.where(norm > 0, q, 0.0)
    return q.reshape(x.shape).astype(x.dtype)


def gossip_mix_ref(x: jnp.ndarray, neighbors: jnp.ndarray,
                   weights: jnp.ndarray) -> jnp.ndarray:
    """weights [deg+1]: [self, n_1 ... n_deg]; neighbors [deg, *x.shape]."""
    acc = weights[0] * x.astype(jnp.float32)
    for j in range(neighbors.shape[0]):
        acc = acc + weights[j + 1] * neighbors[j].astype(jnp.float32)
    return acc.astype(x.dtype)


def choco_move_ref(x: jnp.ndarray, y: jnp.ndarray, mixed_y: jnp.ndarray,
                   gamma: float):
    x32, y32, my32 = (t.astype(jnp.float32) for t in (x, y, mixed_y))
    x_new = x32 + gamma * (my32 - y32)
    return x_new.astype(x.dtype), (x_new - y32).astype(x.dtype)


def top_k_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Matches repro.core.compression.TopK.__call__ for a given k:
    threshold = k-th largest |x| (input dtype), ties kept inclusively."""
    flat = x.reshape(-1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(x.shape).astype(x.dtype)


def choco_qsgd_ref(x: jnp.ndarray, y: jnp.ndarray, mixed_y: jnp.ndarray,
                   gamma: float, noise: jnp.ndarray, *, levels: int,
                   c: float):
    """Unfused composition the fused QSGD kernel must reproduce:
    choco_move -> materialize diff in the leaf dtype -> qsgd_ref on it ->
    y_new = y + q in the leaf dtype. Returns (x_new, y_new)."""
    x_new, diff = choco_move_ref(x, y, mixed_y, gamma)
    q = qsgd_ref(diff, noise, levels=levels, c=c)
    return x_new, y + q


def choco_topk_ref(x: jnp.ndarray, y: jnp.ndarray, mixed_y: jnp.ndarray,
                   gamma: float, k: int):
    """Unfused composition the fused TopK kernel must reproduce:
    choco_move -> top_k_ref on the leaf-dtype diff -> y_new = y + q.
    Returns (x_new, y_new)."""
    x_new, diff = choco_move_ref(x, y, mixed_y, gamma)
    q = top_k_ref(diff, k)
    return x_new, y + q
