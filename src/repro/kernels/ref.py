"""Pure-jnp oracles for the Pallas kernels (the source of truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qsgd_ref(x: jnp.ndarray, noise: jnp.ndarray, *, levels: int,
             c: float) -> jnp.ndarray:
    """Matches repro.core.compression.QSGD with explicit noise."""
    flat = x.reshape(-1).astype(jnp.float32)
    s = float(levels)
    norm = jnp.linalg.norm(flat)
    safe = jnp.where(norm > 0, norm, 1.0)
    lvl = jnp.floor(s * jnp.abs(flat) / safe + noise.reshape(-1))
    q = jnp.sign(flat) * safe * lvl / (s * c)
    q = jnp.where(norm > 0, q, 0.0)
    return q.reshape(x.shape).astype(x.dtype)


def gossip_mix_ref(x: jnp.ndarray, neighbors: jnp.ndarray,
                   weights: jnp.ndarray) -> jnp.ndarray:
    """weights [deg+1]: [self, n_1 ... n_deg]; neighbors [deg, *x.shape]."""
    acc = weights[0] * x.astype(jnp.float32)
    for j in range(neighbors.shape[0]):
        acc = acc + weights[j + 1] * neighbors[j].astype(jnp.float32)
    return acc.astype(x.dtype)


def choco_move_ref(x: jnp.ndarray, y: jnp.ndarray, mixed_y: jnp.ndarray,
                   gamma: float):
    x32, y32, my32 = (t.astype(jnp.float32) for t in (x, y, mixed_y))
    x_new = x32 + gamma * (my32 - y32)
    return x_new.astype(x.dtype), (x_new - y32).astype(x.dtype)
