"""Fused CHOCO-G consensus move — Pallas TPU kernel.

C-DFL's inner communication step (Alg. 2 lines 6-7) per node i:

    x_new = x + gamma * (mixed_y - y)      # mixed_y = sum_j c_ji y_j
    d     = x_new - y                      # the tensor Q compresses next

Unfused: 3 reads + 2 intermediate writes over the model; the kernel emits
both outputs in a single VMEM pass. gamma arrives as a (1,1) scalar tile.

For QSGD/TopK compressors the whole inner iteration (move + compress +
estimate update) is further fused into one pass by
``repro.kernels.choco_fused`` — this kernel remains the building block
for every OTHER compressor on the ``use_kernels`` path, and the
reference the fused kernels are tested against. Dispatch (Mosaic /
interpret / fallback) is decided per call by ``repro.kernels.registry``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _choco_kernel(gamma_ref, x_ref, y_ref, my_ref, xout_ref, dout_ref):
    gamma = gamma_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    my = my_ref[...].astype(jnp.float32)
    x_new = x + gamma * (my - y)
    xout_ref[...] = x_new.astype(xout_ref.dtype)
    dout_ref[...] = (x_new - y).astype(dout_ref.dtype)


def choco_move_2d(x2d: jnp.ndarray, y2d: jnp.ndarray, mixed_y2d: jnp.ndarray,
                  gamma: jnp.ndarray, *, interpret: bool = False):
    """Returns (x_new, d); all operands (rows, 128), gamma (1,1)."""
    rows, lanes = x2d.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, x2d.shape
    grid = (rows // BLOCK_ROWS,)
    blk = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _choco_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)), blk, blk, blk],
        out_specs=(blk, blk),
        out_shape=(jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
                   jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)),
        interpret=interpret,
    )(gamma, x2d, y2d, mixed_y2d)
