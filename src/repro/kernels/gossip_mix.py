"""Fused weighted gossip mixing — Pallas TPU kernel.

One gossip step at a node combines its own parameters with deg received
neighbor copies:  out = w_self * x + sum_j w_j * nbr_j.  Unfused this is
deg+1 HBM read-passes + deg intermediate writes over the full parameter
vector; the kernel performs the whole weighted sum in one VMEM pass with a
f32 accumulator (the per-byte hot loop of the paper's inter-node
communication stage, run tau2 times per round).

Neighbors arrive stacked [deg, rows, 128]; weights as a (1, deg+1) tile.
Entry point: ``repro.kernels.ops.gossip_mix`` (pad/unpad handling,
per-call Mosaic/interpret dispatch via ``repro.kernels.registry``);
consumed by ``ShardedSubstrate.mix`` under ``use_kernels=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _mix_kernel(w_ref, x_ref, nbr_ref, out_ref, *, deg: int):
    acc = w_ref[0, 0] * x_ref[...].astype(jnp.float32)
    for j in range(deg):
        acc = acc + w_ref[0, j + 1] * nbr_ref[j].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def gossip_mix_2d(x2d: jnp.ndarray, neighbors: jnp.ndarray,
                  weights: jnp.ndarray, *, interpret: bool = False
                  ) -> jnp.ndarray:
    """x2d (rows,128); neighbors (deg,rows,128); weights (1, deg+1) with
    weights[0,0] = self weight, weights[0,1:] matching neighbor order."""
    rows, lanes = x2d.shape
    deg = neighbors.shape[0]
    assert lanes == LANES and rows % BLOCK_ROWS == 0, x2d.shape
    assert weights.shape == (1, deg + 1), weights.shape
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        functools.partial(_mix_kernel, deg=deg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, deg + 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((deg, BLOCK_ROWS, LANES), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(weights, x2d, neighbors)
