"""Jitted public wrappers around the Pallas kernels.

Handle arbitrary-shaped inputs: flatten, pad to the (BLOCK_ROWS x 128)
tile grid, run the kernel, unpad. Every wrapper takes ``interpret=None``
by default, resolved PER CALL through ``repro.kernels.registry`` — the
backend is detected lazily on first use (never at import time), and each
op carries its own interpret/Mosaic/XLA-fallback guard
(``registry.resolve_mode``). Off-TPU the kernels run in interpret mode
(the kernel body evaluated in Python, validated against the
``repro.kernels.ref`` oracles); on TPU the same call sites compile to
Mosaic, except ops the registry marks ``mosaic=False`` which dispatch to
an equivalent plain-XLA path.

Entry points
  * ``qsgd_quantize``   — QSGD random quantization (norm fed as scalar).
  * ``gossip_mix``      — fused weighted neighbor accumulate.
  * ``choco_move``      — CHOCO consensus move, (x_new, diff) one pass.
  * ``topk_threshold``  — k-th largest |x| via the two-pass candidate
                          select (``repro.kernels.topk``).
  * ``top_k_compress``  — kernel-backed TopK sparsifier; bitwise-matches
                          ``repro.core.compression.TopK``.
  * ``choco_qsgd_move`` / ``choco_topk_move`` — the FUSED CHOCO
    compress-and-move step, (x, y, mixed_y) -> (x_new, y_new) in a
    single kernel pass (``repro.kernels.choco_fused``) instead of the
    three separate padded round-trips the unfused composition pays.

``op_stats()`` exposes pad-roundtrip / pallas-call counters so benchmarks
and tests can ASSERT the fused paths touch the buffer fewer times; they
tick when wrapper bodies execute, so count over ``eager_impl`` calls
(un-jitted, deterministic per call) — see ``benchmarks/bench_kernels``.
Scope a measurement with ``with op_stats_delta() as d:`` — snapshot
arithmetic, no global reset, so concurrent/nested measurement scopes
can't clobber each other (``reset_op_stats()`` is deprecated for exactly
that race).
"""
from __future__ import annotations

import contextlib
import warnings
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import choco_fused as _fused
from repro.kernels import choco_update as _choco
from repro.kernels import gossip_mix as _mix
from repro.kernels import qsgd as _qsgd
from repro.kernels import registry
from repro.kernels import topk as _topk

_TILE = _qsgd.BLOCK_ROWS * _qsgd.LANES
# _to_2d pads every buffer to THIS tile grid for every kernel, and the
# TopK candidate bound (cand = min(k, _TILE)) leans on it for the
# bitwise-superset property — so all kernel modules must agree on it.
assert all(m.BLOCK_ROWS * m.LANES == _TILE
           for m in (_choco, _fused, _mix, _topk)), (
    "kernel modules disagree on the (BLOCK_ROWS x LANES) tile size")

_STATS: Dict[str, int] = {"pad_roundtrips": 0, "pallas_calls": 0}


def op_stats() -> Dict[str, int]:
    """Counters of buffer work: ``pad_roundtrips`` (flatten/pad/unpad
    cycles through ``_to_2d``) and ``pallas_calls`` (kernel launches).
    Python-side: they tick when a wrapper body EXECUTES — once per jit
    trace through the public entry points, or once per call through
    ``eager_impl`` (how ``benchmarks/bench_kernels`` counts
    fused-vs-unfused buffer passes deterministically)."""
    return dict(_STATS)


class OpStatsDelta:
    """Counter deltas observed inside an ``op_stats_delta()`` block.

    Values are populated at context EXIT; reading earlier raises (there
    is no meaningful partial answer while the block is still counting).
    """

    def __init__(self):
        self._delta: Optional[Dict[str, int]] = None

    def _close(self, delta: Dict[str, int]) -> None:
        self._delta = delta

    def as_dict(self) -> Dict[str, int]:
        if self._delta is None:
            raise RuntimeError(
                "op_stats_delta block still open — deltas exist only "
                "after the with-block exits")
        return dict(self._delta)

    def __getitem__(self, key: str) -> int:
        return self.as_dict()[key]

    @property
    def pad_roundtrips(self) -> int:
        return self["pad_roundtrips"]

    @property
    def pallas_calls(self) -> int:
        return self["pallas_calls"]


@contextlib.contextmanager
def op_stats_delta() -> Iterator[OpStatsDelta]:
    """Scoped counter attribution: yields an ``OpStatsDelta`` whose
    per-key deltas (work done INSIDE the block) are readable after exit.

    Pure snapshot arithmetic against the module counters — nothing is
    reset, so nested scopes and interleaved measurement sites (the
    benchmark suite, per-superstep telemetry in ``launch.train``) each
    see exactly their own window::

        with op_stats_delta() as d:
            ops.eager_impl("choco_move")(x, y, my, 0.5, interpret=True)
        assert d.pad_roundtrips == 3
    """
    before = dict(_STATS)
    d = OpStatsDelta()
    try:
        yield d
    finally:
        d._close({k: _STATS[k] - before.get(k, 0) for k in _STATS})


def reset_op_stats() -> None:
    """Deprecated: zeroes the GLOBAL counters, which races every other
    measurement scope in the process (two bench sections resetting under
    each other read garbage). Use ``op_stats_delta()``."""
    warnings.warn(
        "repro.kernels.ops.reset_op_stats() is deprecated: a global reset "
        "races across concurrent measurement scopes — use "
        "`with op_stats_delta() as d:` snapshot/delta attribution instead.",
        DeprecationWarning, stacklevel=2)
    for k in _STATS:
        _STATS[k] = 0


def __getattr__(name: str):
    if name == "ON_TPU":
        warnings.warn(
            "repro.kernels.ops.ON_TPU is deprecated: it was computed at "
            "import time and went stale when backends initialized later. "
            "Backend detection is lazy now — use "
            "repro.kernels.registry.on_tpu() / resolve_mode().",
            DeprecationWarning, stacklevel=2)
        return registry.on_tpu()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _to_2d(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    _STATS["pad_roundtrips"] += 1
    flat = x.reshape(-1)
    n = flat.size
    pad = (-n) % _TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _qsgd.LANES), n


def _from_2d(x2d: jnp.ndarray, n: int, shape, dtype) -> jnp.ndarray:
    return x2d.reshape(-1)[:n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# QSGD / gossip / CHOCO move (PR-1 kernels, now lazily dispatched)
# ---------------------------------------------------------------------------


def _qsgd_quantize_impl(x, noise, *, levels: int, interpret: bool):
    d = x.size
    s = float(levels)
    c = 1.0 + min(d / (s * s), (d ** 0.5) / s)
    x2d, n = _to_2d(x)
    n2d, _ = _to_2d(noise)
    norm = jnp.linalg.norm(x.reshape(-1).astype(jnp.float32)).reshape(1, 1)
    _STATS["pallas_calls"] += 1
    out = _qsgd.qsgd_quantize_2d(x2d, n2d, norm, levels=levels, c=c,
                                 interpret=interpret)
    return _from_2d(out, n, x.shape, x.dtype)


_qsgd_quantize = jax.jit(_qsgd_quantize_impl,
                         static_argnames=("levels", "interpret"))


def qsgd_quantize(x: jnp.ndarray, noise: jnp.ndarray, *, levels: int = 16,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """QSGD with delta = 1/c, c = 1 + min(d/s^2, sqrt(d)/s); same output
    as ``repro.core.compression.QSGD`` given the same uniform ``noise``."""
    interpret = registry.resolve_interpret("qsgd_quantize", interpret)
    return _qsgd_quantize(x, noise, levels=levels, interpret=interpret)


def _gossip_mix_impl(x, neighbors, weights, *, interpret: bool):
    deg = neighbors.shape[0]
    x2d, n = _to_2d(x)
    nbr2d = jax.vmap(lambda t: _to_2d(t)[0])(neighbors.reshape(deg, -1))
    w = weights.reshape(1, deg + 1).astype(jnp.float32)
    _STATS["pallas_calls"] += 1
    out = _mix.gossip_mix_2d(x2d, nbr2d, w, interpret=interpret)
    return _from_2d(out, n, x.shape, x.dtype)


_gossip_mix = jax.jit(_gossip_mix_impl, static_argnames=("interpret",))


def gossip_mix(x: jnp.ndarray, neighbors: jnp.ndarray,
               weights: jnp.ndarray, *,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """out = weights[0]*x + sum_j weights[1+j]*neighbors[j], one pass."""
    interpret = registry.resolve_interpret("gossip_mix", interpret)
    return _gossip_mix(x, neighbors, weights, interpret=interpret)


def _choco_move_impl(x, y, mixed_y, gamma, *, interpret: bool):
    x2d, n = _to_2d(x)
    y2d, _ = _to_2d(y)
    my2d, _ = _to_2d(mixed_y)
    g = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    _STATS["pallas_calls"] += 1
    xo, do = _choco.choco_move_2d(x2d, y2d, my2d, g, interpret=interpret)
    return (_from_2d(xo, n, x.shape, x.dtype),
            _from_2d(do, n, x.shape, x.dtype))


_choco_move = jax.jit(_choco_move_impl, static_argnames=("interpret",))


def choco_move(x: jnp.ndarray, y: jnp.ndarray, mixed_y: jnp.ndarray,
               gamma, *, interpret: Optional[bool] = None):
    """Fused CHOCO consensus step: returns (x_new, d = x_new - y)."""
    interpret = registry.resolve_interpret("choco_move", interpret)
    return _choco_move(x, y, mixed_y, gamma, interpret=interpret)


# ---------------------------------------------------------------------------
# TopK (two-pass: per-tile candidates -> global select -> mask)
# ---------------------------------------------------------------------------


def _threshold_impl(x: jnp.ndarray, k: int, mode: str) -> jnp.ndarray:
    """k-th largest |x| as a scalar in x's dtype. ``mode`` per
    ``registry.resolve_mode("topk_partials", ...)``: the candidate pass
    runs as a kernel ("interpret"/"mosaic") or collapses to the plain
    full-vector ``lax.top_k`` ("fallback"); all three produce the SAME
    threshold bit-for-bit (see repro.kernels.topk)."""
    flat = x.reshape(-1)
    if not 1 <= k <= flat.size:
        raise ValueError(
            f"TopK k={k} out of range for a size-{flat.size} vector")
    if mode == "fallback":
        return jax.lax.top_k(jnp.abs(flat), k)[0][k - 1]
    x2d, _ = _to_2d(x)
    cand = min(k, _TILE)
    _STATS["pallas_calls"] += 1
    parts = _topk.topk_partials_2d(x2d, cand=cand,
                                   interpret=(mode == "interpret"))
    return jax.lax.top_k(parts.reshape(-1), k)[0][k - 1]


def _topk_threshold_impl(x, *, k: int, mode: str):
    return _threshold_impl(x, k, mode)


_topk_threshold = jax.jit(_topk_threshold_impl,
                          static_argnames=("k", "mode"))


def topk_threshold(x: jnp.ndarray, k: int, *,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """The TopK mask threshold: the k-th largest |x| (ties inclusive)."""
    mode = registry.resolve_mode("topk_partials", interpret)
    return _topk_threshold(x, k=int(k), mode=mode)


def _top_k_compress_impl(x, *, k: int, tmode: str, imask: bool):
    thresh = _threshold_impl(x, k, tmode)
    x2d, n = _to_2d(x)
    _STATS["pallas_calls"] += 1
    out = _topk.topk_mask_2d(x2d, thresh.reshape(1, 1), interpret=imask)
    return _from_2d(out, n, x.shape, x.dtype)


_top_k_compress = jax.jit(_top_k_compress_impl,
                          static_argnames=("k", "tmode", "imask"))


def top_k_compress(x: jnp.ndarray, k: int, *,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Keep the k largest-|.| coordinates of ``x``, zero the rest —
    BITWISE-equal to ``repro.core.compression.TopK`` (same threshold,
    same inclusive tie handling), on every shape/dtype the parity suite
    sweeps."""
    tmode = registry.resolve_mode("topk_partials", interpret)
    imask = registry.resolve_interpret("topk_mask", interpret)
    return _top_k_compress(x, k=int(k), tmode=tmode, imask=imask)


# ---------------------------------------------------------------------------
# Fused CHOCO compress-and-move
# ---------------------------------------------------------------------------


def _fused_diff(x, y, mixed_y, g32):
    """The compressed gap diff = (x + gamma (my - y)) - y, flat, in the
    LEAF dtype — exactly the tensor the unfused path materializes and
    hands to the compressor (so thresholds/norms computed on it match the
    unfused kernels bit-for-bit)."""
    x32 = x.reshape(-1).astype(jnp.float32)
    y32 = y.reshape(-1).astype(jnp.float32)
    my32 = mixed_y.reshape(-1).astype(jnp.float32)
    return ((x32 + g32 * (my32 - y32)) - y32).astype(x.dtype)


def _choco_qsgd_move_impl(x, y, mixed_y, gamma, noise, *, levels: int,
                          interpret: bool):
    d = x.size
    s = float(levels)
    c = 1.0 + min(d / (s * s), (d ** 0.5) / s)
    g32 = jnp.asarray(gamma, jnp.float32)
    diff = _fused_diff(x, y, mixed_y, g32)
    norm = jnp.linalg.norm(diff.astype(jnp.float32))
    scal = jnp.stack([g32, norm]).reshape(1, 2)
    x2d, n = _to_2d(x)
    y2d, _ = _to_2d(y)
    my2d, _ = _to_2d(mixed_y)
    n2d, _ = _to_2d(noise)
    _STATS["pallas_calls"] += 1
    xo, yo = _fused.choco_qsgd_2d(x2d, y2d, my2d, n2d, scal, levels=levels,
                                  c=c, interpret=interpret)
    return (_from_2d(xo, n, x.shape, x.dtype),
            _from_2d(yo, n, x.shape, x.dtype))


_choco_qsgd_move = jax.jit(_choco_qsgd_move_impl,
                           static_argnames=("levels", "interpret"))


def choco_qsgd_move(x: jnp.ndarray, y: jnp.ndarray, mixed_y: jnp.ndarray,
                    gamma, noise: jnp.ndarray, *, levels: int = 16,
                    interpret: Optional[bool] = None):
    """Fused CHOCO step with QSGD compression: ONE kernel pass over
    (x, y, mixed_y, noise) emitting (x_new, y_new) — vs the unfused
    choco_move -> qsgd_quantize -> XLA-add chain (3 padded round-trips,
    2 kernel launches, 2 HBM intermediates)."""
    interpret = registry.resolve_interpret("choco_qsgd", interpret)
    return _choco_qsgd_move(x, y, mixed_y, gamma, noise, levels=levels,
                            interpret=interpret)


def _choco_topk_move_impl(x, y, mixed_y, gamma, *, k: int, tmode: str,
                          interpret: bool):
    g32 = jnp.asarray(gamma, jnp.float32)
    diff = _fused_diff(x, y, mixed_y, g32)
    if not 1 <= k <= diff.size:
        raise ValueError(
            f"TopK k={k} out of range for a size-{diff.size} vector")
    # ONE pad round-trip for the gap: the padded diff feeds both the
    # candidate select and the mask input of the fused kernel, so the
    # threshold and the kept-set decisions read the identical tensor.
    d2d, n = _to_2d(diff)
    if tmode == "fallback":
        thresh = jax.lax.top_k(jnp.abs(diff), k)[0][k - 1]
    else:
        cand = min(k, _TILE)
        _STATS["pallas_calls"] += 1
        parts = _topk.topk_partials_2d(d2d, cand=cand,
                                       interpret=(tmode == "interpret"))
        thresh = jax.lax.top_k(parts.reshape(-1), k)[0][k - 1]
    x2d, _ = _to_2d(x)
    y2d, _ = _to_2d(y)
    my2d, _ = _to_2d(mixed_y)
    _STATS["pallas_calls"] += 1
    xo, yo = _fused.choco_topk_2d(x2d, y2d, my2d, d2d, g32.reshape(1, 1),
                                  thresh.reshape(1, 1), interpret=interpret)
    return (_from_2d(xo, n, x.shape, x.dtype),
            _from_2d(yo, n, x.shape, x.dtype))


_choco_topk_move = jax.jit(_choco_topk_move_impl,
                           static_argnames=("k", "tmode", "interpret"))


def choco_topk_move(x: jnp.ndarray, y: jnp.ndarray, mixed_y: jnp.ndarray,
                    gamma, k: int, *, interpret: Optional[bool] = None):
    """Fused CHOCO step with TopK compression: the threshold select reads
    the gap once (reduction to one scalar), then ONE kernel pass emits
    (x_new, y_new) — vs choco_move -> top_k_compress -> XLA-add."""
    tmode = registry.resolve_mode("topk_partials", interpret)
    interp = registry.resolve_interpret("choco_topk", interpret)
    return _choco_topk_move(x, y, mixed_y, gamma, k=int(k), tmode=tmode,
                            interpret=interp)


# ---------------------------------------------------------------------------
# Instrumentation access
# ---------------------------------------------------------------------------

_EAGER_IMPLS = {
    "qsgd_quantize": _qsgd_quantize_impl,
    "gossip_mix": _gossip_mix_impl,
    "choco_move": _choco_move_impl,
    "topk_threshold": _topk_threshold_impl,
    "top_k_compress": _top_k_compress_impl,
    "choco_qsgd_move": _choco_qsgd_move_impl,
    "choco_topk_move": _choco_topk_move_impl,
}


def eager_impl(name: str):
    """The UN-JITTED wrapper body behind a public entry point, for
    instrumentation: calling it executes the Python body every time, so
    the ``op_stats`` counters tick deterministically per call (the jitted
    publics only tick per trace). Callers pass the dispatch statics
    explicitly (``interpret=True`` / ``tmode="interpret"`` etc.) — no
    registry resolution happens here. Used by ``benchmarks/bench_kernels``
    to count fused-vs-unfused buffer passes; not a performance surface."""
    try:
        return _EAGER_IMPLS[name]
    except KeyError:
        raise ValueError(
            f"no eager impl {name!r}; options: {sorted(_EAGER_IMPLS)}"
        ) from None
