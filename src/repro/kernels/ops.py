"""Jitted public wrappers around the Pallas kernels.

Handle arbitrary-shaped inputs: flatten, pad to the (BLOCK_ROWS x 128)
tile grid, run the kernel, unpad. ``interpret=True`` (the CPU default
here) executes the kernel body in Python for validation; on TPU the same
call sites compile to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import choco_update as _choco
from repro.kernels import gossip_mix as _mix
from repro.kernels import qsgd as _qsgd

_TILE = _qsgd.BLOCK_ROWS * _qsgd.LANES

ON_TPU = jax.default_backend() == "tpu"


def _to_2d(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    n = flat.size
    pad = (-n) % _TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _qsgd.LANES), n


def _from_2d(x2d: jnp.ndarray, n: int, shape, dtype) -> jnp.ndarray:
    return x2d.reshape(-1)[:n].reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("levels", "interpret"))
def qsgd_quantize(x: jnp.ndarray, noise: jnp.ndarray, *, levels: int = 16,
                  interpret: bool = not ON_TPU) -> jnp.ndarray:
    """QSGD with delta = 1/c, c = 1 + min(d/s^2, sqrt(d)/s)."""
    d = x.size
    s = float(levels)
    c = 1.0 + min(d / (s * s), (d ** 0.5) / s)
    x2d, n = _to_2d(x)
    n2d, _ = _to_2d(noise)
    norm = jnp.linalg.norm(x.reshape(-1).astype(jnp.float32)).reshape(1, 1)
    out = _qsgd.qsgd_quantize_2d(x2d, n2d, norm, levels=levels, c=c,
                                 interpret=interpret)
    return _from_2d(out, n, x.shape, x.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gossip_mix(x: jnp.ndarray, neighbors: jnp.ndarray, weights: jnp.ndarray,
               *, interpret: bool = not ON_TPU) -> jnp.ndarray:
    """out = weights[0]*x + sum_j weights[1+j]*neighbors[j]."""
    deg = neighbors.shape[0]
    x2d, n = _to_2d(x)
    nbr2d = jax.vmap(lambda t: _to_2d(t)[0])(
        neighbors.reshape(deg, -1))
    w = weights.reshape(1, deg + 1).astype(jnp.float32)
    out = _mix.gossip_mix_2d(x2d, nbr2d, w, interpret=interpret)
    return _from_2d(out, n, x.shape, x.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def choco_move(x: jnp.ndarray, y: jnp.ndarray, mixed_y: jnp.ndarray,
               gamma: float, *, interpret: bool = not ON_TPU):
    """Fused CHOCO step: returns (x_new, d = x_new - y)."""
    x2d, n = _to_2d(x)
    y2d, _ = _to_2d(y)
    my2d, _ = _to_2d(mixed_y)
    g = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    xo, do = _choco.choco_move_2d(x2d, y2d, my2d, g, interpret=interpret)
    return (_from_2d(xo, n, x.shape, x.dtype),
            _from_2d(do, n, x.shape, x.dtype))
