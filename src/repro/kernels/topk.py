"""TopK sparsification — two-pass Pallas TPU kernels.

The ``TopK`` compressor (paper Sec. V-A "Sparsification") keeps the
``k`` largest-magnitude coordinates of the flattened parameter vector and
zeros the rest. Its reference implementation sorts the WHOLE vector
(``jax.lax.top_k`` over d elements); the kernel path splits the work into
two tile passes so the O(d log d) select touches only a candidate subset:

  1. ``topk_partials_2d`` — per (BLOCK_ROWS x 128) tile, emit the tile's
     ``cand = min(k, tile)`` largest magnitudes. Every element of the
     GLOBAL top-k has per-tile rank <= k, so the union of per-tile
     partials is a superset of the global top-k and the k-th largest of
     the candidates is bit-identical to the k-th largest of the full
     vector — the select that follows (a plain ``lax.top_k`` over
     ``num_tiles * cand`` values, like the QSGD norm a single fused XLA
     reduction) therefore reproduces the reference threshold EXACTLY,
     ties included.
  2. ``topk_mask_2d`` — element-wise keep-or-zero against the threshold
     scalar, ``out = where(|x| >= thresh, x, 0)``, one VMEM pass.

Magnitudes are compared in the INPUT dtype (no f32 upcast): the reference
``jax.lax.top_k(jnp.abs(flat), k)`` sorts bf16 magnitudes as bf16, and
matching its tie behaviour bitwise requires comparing the same values.
Zero padding from the tile grid is harmless: pad magnitudes are 0, so a
pad can enter the candidate set only when the true threshold is already
0 — in which case the threshold is 0 either way.

Pass 1 calls ``lax.top_k`` inside the kernel body, which the Mosaic TPU
compiler does not lower — the registry marks it ``mosaic=False`` and the
dispatcher falls back to the plain-XLA select on TPU (pass 2 stays a
kernel there). Off-TPU both passes run in interpret mode, where they are
validated bitwise against the ``repro.core.compression.TopK`` oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _partials_kernel(x_ref, out_ref, *, cand: int):
    mag = jnp.abs(x_ref[...]).reshape(-1)
    out_ref[...] = jax.lax.top_k(mag, cand)[0].reshape(1, cand)


def topk_partials_2d(x2d: jnp.ndarray, *, cand: int,
                     interpret: bool = False) -> jnp.ndarray:
    """Per-tile top-``cand`` magnitudes: (rows, 128) -> (num_tiles, cand).

    ``cand`` must be ``min(k, BLOCK_ROWS * LANES)`` for the candidate-set
    superset property (module docstring) to hold.
    """
    rows, lanes = x2d.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, x2d.shape
    assert 1 <= cand <= BLOCK_ROWS * LANES, cand
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        functools.partial(_partials_kernel, cand=cand),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, cand), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows // BLOCK_ROWS, cand),
                                       x2d.dtype),
        interpret=interpret,
    )(x2d)


def _mask_kernel(thresh_ref, x_ref, out_ref):
    x = x_ref[...]
    keep = jnp.abs(x) >= thresh_ref[0, 0]
    out_ref[...] = jnp.where(keep, x, jnp.zeros_like(x))


def topk_mask_2d(x2d: jnp.ndarray, thresh: jnp.ndarray, *,
                 interpret: bool = False) -> jnp.ndarray:
    """Keep-or-zero against the threshold: all operands the input dtype.

    ``thresh``: (1, 1) scalar tile — the k-th largest |x| from the select
    pass. Keeps ``|x| >= thresh`` (ties INCLUSIVE, matching the reference
    compressor — a few extra tied coordinates still satisfy Assumption 2).
    """
    rows, lanes = x2d.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, x2d.shape
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(thresh, x2d)
