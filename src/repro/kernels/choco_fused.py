"""Fused CHOCO-G compress-and-move — Pallas TPU kernels.

One C-DFL inner communication step (Alg. 2 lines 6-11) per node is, after
the neighbor estimates have been mixed (``mixed_y = sum_j c_ji y_j``):

    x_new = x + gamma * (mixed_y - y)        # consensus move   (l.6)
    q     = Q(x_new - y)                     # compress the gap (l.7)
    y_new = y + q                            # estimate update  (l.11)

The unfused kernel path runs this as THREE separate padded round-trips
over the flattened parameter buffer (``choco_update`` kernel -> ``qsgd``
or ``topk`` kernel -> an XLA add), materializing the intermediate ``diff``
and ``q`` tensors in HBM. These kernels emit ``(x_new, y_new)`` directly
in a single VMEM pass over ``(x, y, mixed_y)``:

  * ``choco_qsgd_2d`` — Q = QSGD random quantization. The global vector
    norm (a reduction) is computed outside and arrives with gamma as a
    (1, 2) f32 scalar tile; the per-leaf uniform noise rides in as a
    tensor so the kernel stays deterministic against the oracle.
  * ``choco_topk_2d`` — Q = TopK sparsification. The threshold (the k-th
    largest |x_new - y|, a global select — see ``repro.kernels.topk``)
    arrives as a (1, 1) scalar tile in the LEAF dtype.

Bit-compat contract with the unfused kernels: ``x_new`` is computed in
f32 and cast once to the leaf dtype; the compressed gap is quantized on
``(x_new - y)`` CAST TO THE LEAF DTYPE first (the unfused path
materializes ``diff`` in the leaf dtype before compressing it), and
``y_new = y + q`` is accumulated in the leaf dtype (matching the unfused
XLA tree add). For f32 leaves the fused and unfused paths are bitwise
identical; bf16 agrees to the same one-ulp rounding the unfused kernels
already exhibit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _choco_qsgd_kernel(scal_ref, x_ref, y_ref, my_ref, noise_ref,
                       xout_ref, yout_ref, *, levels: float, c: float):
    gamma = scal_ref[0, 0]
    norm = scal_ref[0, 1]
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    my = my_ref[...].astype(jnp.float32)
    x_new = x + gamma * (my - y)
    xout_ref[...] = x_new.astype(xout_ref.dtype)
    # quantize the gap exactly as the unfused path sees it: materialized
    # in the leaf dtype, then upcast inside the quantizer.
    d = (x_new - y).astype(xout_ref.dtype).astype(jnp.float32)
    xi = noise_ref[...].astype(jnp.float32)
    safe = jnp.where(norm > 0.0, norm, 1.0)
    lvl = jnp.floor(levels * jnp.abs(d) / safe + xi)
    q = jnp.sign(d) * safe * lvl / (levels * c)
    q = jnp.where(norm > 0.0, q, 0.0).astype(yout_ref.dtype)
    yout_ref[...] = y_ref[...] + q


def choco_qsgd_2d(x2d: jnp.ndarray, y2d: jnp.ndarray, my2d: jnp.ndarray,
                  noise2d: jnp.ndarray, scal: jnp.ndarray, *, levels: int,
                  c: float, interpret: bool = False):
    """Fused CHOCO step with QSGD compression: returns (x_new, y_new).

    All tensor operands (rows, 128) with rows % BLOCK_ROWS == 0;
    ``scal`` = [[gamma, norm]] as a (1, 2) f32 tile, where ``norm`` is
    ``||(x + gamma (my - y) - y).astype(dtype)||_2`` over the UNPADDED
    flat leaf (the same norm the unfused qsgd wrapper computes on the
    materialized diff).
    """
    rows, lanes = x2d.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, x2d.shape
    assert scal.shape == (1, 2), scal.shape
    grid = (rows // BLOCK_ROWS,)
    blk = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_choco_qsgd_kernel, levels=float(levels),
                          c=float(c)),
        grid=grid,
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0)), blk, blk, blk,
                  blk],
        out_specs=(blk, blk),
        out_shape=(jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
                   jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)),
        interpret=interpret,
    )(scal, x2d, y2d, my2d, noise2d)


def _choco_topk_kernel(gamma_ref, thresh_ref, x_ref, y_ref, my_ref, d_ref,
                       xout_ref, yout_ref):
    gamma = gamma_ref[0, 0]
    thresh = thresh_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    my = my_ref[...].astype(jnp.float32)
    x_new = x + gamma * (my - y)
    xout_ref[...] = x_new.astype(xout_ref.dtype)
    d = d_ref[...]
    q = jnp.where(jnp.abs(d) >= thresh, d, jnp.zeros_like(d))
    yout_ref[...] = y_ref[...] + q


def choco_topk_2d(x2d: jnp.ndarray, y2d: jnp.ndarray, my2d: jnp.ndarray,
                  d2d: jnp.ndarray, gamma: jnp.ndarray,
                  thresh: jnp.ndarray, *, interpret: bool = False):
    """Fused CHOCO step with TopK compression: returns (x_new, y_new).

    ``gamma``: (1, 1) f32 tile; ``d2d``: the gap
    diff = (x + gamma (my - y) - y) MATERIALIZED in the leaf dtype —
    the same tensor the threshold select reduced, fed back in rather
    than recomputed in-kernel so the ``|d| >= thresh`` mask decisions
    are exactly consistent with the threshold (a 1-ulp divergence
    between two compilations of the diff arithmetic could otherwise
    flip a boundary element in or out of the kept set); ``thresh``:
    (1, 1) tile in the LEAF dtype, the k-th largest |d| (magnitude
    comparison in the dtype the reference compressor sorts, see
    ``repro.kernels.topk``).
    """
    rows, lanes = x2d.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, x2d.shape
    grid = (rows // BLOCK_ROWS,)
    blk = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    scal = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _choco_topk_kernel,
        grid=grid,
        in_specs=[scal, scal, blk, blk, blk, blk],
        out_specs=(blk, blk),
        out_shape=(jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
                   jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)),
        interpret=interpret,
    )(gamma, thresh, x2d, y2d, my2d, d2d)
