"""QSGD stochastic quantization — Pallas TPU kernel.

Computes, element-wise over VMEM tiles of the flattened parameter vector,

    q(x) = sign(x) * ||x|| / (s * c) * floor(s |x| / ||x|| + xi)

(paper Sec. V-A "Random quantization"), with the vector norm computed by a
first-pass jnp reduction (a single fused reduction XLA already emits
optimally) and fed to the kernel as a (1,1) scalar tile. The uniform noise
xi enters as an input tensor so the kernel is deterministic and verifiable
against the pure-jnp oracle in interpret mode.

TPU tiling: the flat vector is reshaped to (rows, 128) lanes and blocked
(BLOCK_ROWS, 128) = 256x128 f32 = 128 KiB per buffer — three live buffers
(x, xi, out) with double buffering stay well under the ~16 MiB VMEM budget.

Entry point: ``repro.kernels.ops.qsgd_quantize`` (pad/unpad handling,
per-call Mosaic/interpret dispatch via ``repro.kernels.registry``). The
CHOCO hot path uses the fused variant in ``choco_fused`` instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _qsgd_kernel(norm_ref, x_ref, noise_ref, out_ref, *, levels: float,
                 c: float):
    x = x_ref[...].astype(jnp.float32)
    xi = noise_ref[...].astype(jnp.float32)
    norm = norm_ref[0, 0]
    safe = jnp.where(norm > 0.0, norm, 1.0)
    lvl = jnp.floor(levels * jnp.abs(x) / safe + xi)
    q = jnp.sign(x) * safe * lvl / (levels * c)
    out_ref[...] = jnp.where(norm > 0.0, q, 0.0).astype(out_ref.dtype)


def qsgd_quantize_2d(x2d: jnp.ndarray, noise2d: jnp.ndarray,
                     norm: jnp.ndarray, *, levels: int, c: float,
                     interpret: bool = False) -> jnp.ndarray:
    """x2d, noise2d: (rows, 128) with rows % BLOCK_ROWS == 0; norm: (1,1)."""
    rows, lanes = x2d.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, x2d.shape
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        functools.partial(_qsgd_kernel, levels=float(levels), c=float(c)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(norm, x2d, noise2d)
