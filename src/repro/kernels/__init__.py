"""Compression/gossip kernel subsystem (Pallas TPU + jnp oracles).

The per-byte hot loops of the paper's communication stage — quantize,
sparsify, mix, CHOCO error-feedback — as Pallas kernels with a registry
that decides, per op and per call, whether to run Mosaic-compiled (TPU),
interpret-mode (validation, the off-TPU default), or a plain-XLA fallback
(ops Mosaic cannot lower). Layout:

  ``registry``     — lazy backend detection, per-op dispatch guards,
                     the reference-parity harness (start here).
  ``ops``          — the public entry points (pad/tile/unpad handling,
                     jitted): ``qsgd_quantize``, ``gossip_mix``,
                     ``choco_move``, ``top_k_compress``,
                     ``topk_threshold``, ``choco_qsgd_move``,
                     ``choco_topk_move``.
  ``qsgd`` / ``gossip_mix`` / ``choco_update`` / ``topk`` /
  ``choco_fused`` — the kernel bodies (tile shapes, BlockSpecs).
  ``ref``          — pure-jnp oracles, one per kernel, the source of
                     truth for the parity suite.

Consumers: ``repro.core.substrate.ShardedSubstrate`` (``use_kernels=True``
routes gossip/CHOCO through here), ``repro.core.compression.TopK``
(``use_kernels=True`` field), ``benchmarks/bench_kernels`` (parity +
throughput + buffer-pass accounting). See docs/ARCHITECTURE.md for the
dispatch path end-to-end.
"""
from repro.kernels.ops import (
    choco_move,
    choco_qsgd_move,
    choco_topk_move,
    gossip_mix,
    op_stats,
    op_stats_delta,
    qsgd_quantize,
    reset_op_stats,
    top_k_compress,
    topk_threshold,
)
from repro.kernels.registry import (
    KernelOp,
    backend,
    get_op,
    list_ops,
    on_tpu,
    parity_suite,
    reset_backend_cache,
    resolve_interpret,
    resolve_mode,
)

__all__ = [
    "backend",
    "on_tpu",
    "reset_backend_cache",
    "KernelOp",
    "get_op",
    "list_ops",
    "resolve_mode",
    "resolve_interpret",
    "parity_suite",
    "qsgd_quantize",
    "gossip_mix",
    "choco_move",
    "topk_threshold",
    "top_k_compress",
    "choco_qsgd_move",
    "choco_topk_move",
    "op_stats",
    "op_stats_delta",
    "reset_op_stats",
]
