"""Kernel registry: lazy backend detection, per-op dispatch guards, parity.

This module is the single place that decides HOW a compression/gossip
kernel runs:

  * ``backend()`` — the JAX default backend, detected LAZILY on first use
    and cached (``reset_backend_cache()`` un-caches, for tests and for
    programs that initialize jax backends after import). The old
    ``ops.ON_TPU`` module constant was computed at import time, so
    importing ``repro.kernels`` before backend selection silently pinned
    every kernel to interpret mode forever — the failure mode this
    module exists to remove.
  * ``KernelOp`` / ``get_op`` / ``list_ops`` — one registry entry per
    public kernel with its Mosaic-compilability flag, parity oracle, and
    bitwise contract.
  * ``resolve_mode(name, interpret)`` — the per-op dispatch rule:

        explicit interpret=True   -> "interpret"  (Python-eval the kernel)
        explicit interpret=False  -> "mosaic"     (force TPU compile)
        None, off TPU             -> "interpret"
        None, on TPU, op.mosaic   -> "mosaic"
        None, on TPU, not mosaic  -> "fallback"   (plain-XLA reference
                                     path; e.g. the TopK candidate pass
                                     calls lax.top_k in-kernel, which
                                     Mosaic does not lower)

  * ``parity_suite()`` — the reference-parity harness: every registered
    op is run in interpret mode against its ``repro.kernels.ref`` oracle
    over a shape/dtype sweep; ops with ``bitwise=True`` must match
    EXACTLY. ``tests/test_kernels.py`` and ``benchmarks/bench_kernels``
    both consume this, so a new kernel cannot land without a
    mechanically-checked oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "backend",
    "reset_backend_cache",
    "on_tpu",
    "KernelOp",
    "get_op",
    "list_ops",
    "resolve_mode",
    "resolve_interpret",
    "parity_suite",
    "PARITY_SHAPES",
    "PARITY_DTYPES",
]

_BACKEND_CACHE: Optional[str] = None


def backend() -> str:
    """The jax default backend ("cpu"/"gpu"/"tpu"), cached on FIRST CALL —
    never at import time, so backend selection that happens after
    ``import repro.kernels`` (distributed init, ``jax.config`` updates,
    test harnesses) is still honored by kernel dispatch."""
    global _BACKEND_CACHE
    if _BACKEND_CACHE is None:
        _BACKEND_CACHE = jax.default_backend()
    return _BACKEND_CACHE


def reset_backend_cache() -> None:
    """Forget the cached backend (next ``backend()`` call re-detects)."""
    global _BACKEND_CACHE
    _BACKEND_CACHE = None


def on_tpu() -> bool:
    return backend() == "tpu"


def _max_err(got, want) -> float:
    return float(jnp.max(jnp.abs(jnp.asarray(got, jnp.float32).reshape(-1)
                                 - jnp.asarray(want,
                                               jnp.float32).reshape(-1))))


@dataclasses.dataclass(frozen=True)
class KernelOp:
    """One registered kernel op.

    mosaic:  the kernel body lowers under the Mosaic TPU compiler (ops
             with ``mosaic=False`` dispatch to a plain-XLA fallback on
             TPU instead of crashing the compile).
    bitwise: the interpret-mode kernel must match its oracle EXACTLY
             (parity_suite enforces err == 0.0).
    parity:  (key, shape, dtype) -> max |kernel - oracle| in f32, running
             the kernel in interpret mode against the ref oracle.
    """

    name: str
    mosaic: bool
    bitwise: bool
    doc: str
    parity: Callable[[jax.Array, Tuple[int, ...], Any], float]


def _parity_qsgd(key, shape, dtype) -> float:
    from repro.kernels import ops, ref

    k1, k2 = jax.random.split(key)
    x = (jax.random.normal(k1, shape, jnp.float32) * 3).astype(dtype)
    noise = jax.random.uniform(k2, shape)
    d = int(np.prod(shape))
    s = 16.0
    c = 1.0 + min(d / (s * s), d ** 0.5 / s)
    got = ops.qsgd_quantize(x, noise, levels=16, interpret=True)
    want = ref.qsgd_ref(x, noise, levels=16, c=c)
    return _max_err(got, want)


def _parity_gossip_mix(key, shape, dtype) -> float:
    from repro.kernels import ops, ref

    deg = 2
    x = jax.random.normal(jax.random.fold_in(key, 0), shape).astype(dtype)
    nbrs = jax.random.normal(jax.random.fold_in(key, 1),
                             (deg,) + tuple(shape)).astype(dtype)
    w = jnp.concatenate([jnp.asarray([0.5]), jnp.full((deg,), 0.25)])
    got = ops.gossip_mix(x, nbrs, w, interpret=True)
    want = ref.gossip_mix_ref(x, nbrs, w)
    return _max_err(got, want)


def _parity_choco_move(key, shape, dtype) -> float:
    from repro.kernels import ops, ref

    x, y, my = (jax.random.normal(jax.random.fold_in(key, i),
                                  shape).astype(dtype) for i in range(3))
    got = ops.choco_move(x, y, my, 0.37, interpret=True)
    want = ref.choco_move_ref(x, y, my, 0.37)
    return max(_max_err(got[0], want[0]), _max_err(got[1], want[1]))


def _parity_topk(key, shape, dtype) -> float:
    from repro.kernels import ops, ref

    x = jax.random.normal(key, shape).astype(dtype)
    k = max(1, int(np.prod(shape)) // 4)
    got = ops.top_k_compress(x, k, interpret=True)
    want = ref.top_k_ref(x, k)
    return _max_err(got, want)


def _parity_topk_mask(key, shape, dtype) -> float:
    # the mask kernel ALONE against a hand-built threshold (what the TPU
    # fallback mode keeps as a compiled kernel), independent of the
    # candidate-select pass.
    from repro.kernels import ops, topk as topk_mod

    x = jax.random.normal(key, shape).astype(dtype)
    flat = x.reshape(-1)
    thresh = jnp.sort(jnp.abs(flat))[flat.size // 2]
    x2d, n = ops._to_2d(x)
    out2d = topk_mod.topk_mask_2d(x2d, thresh.reshape(1, 1),
                                  interpret=True)
    got = ops._from_2d(out2d, n, x.shape, x.dtype)
    want = jnp.where(jnp.abs(flat) >= thresh, flat,
                     0.0).reshape(x.shape).astype(x.dtype)
    return _max_err(got, want)


def _parity_choco_qsgd(key, shape, dtype) -> float:
    from repro.kernels import ops, ref

    ks = [jax.random.fold_in(key, i) for i in range(4)]
    x, y, my = (jax.random.normal(k, shape).astype(dtype) for k in ks[:3])
    noise = jax.random.uniform(ks[3], shape)
    d = int(np.prod(shape))
    s = 16.0
    c = 1.0 + min(d / (s * s), d ** 0.5 / s)
    got = ops.choco_qsgd_move(x, y, my, 0.5, noise, levels=16,
                              interpret=True)
    want = ref.choco_qsgd_ref(x, y, my, 0.5, noise, levels=16, c=c)
    return max(_max_err(got[0], want[0]), _max_err(got[1], want[1]))


def _parity_choco_topk(key, shape, dtype) -> float:
    from repro.kernels import ops, ref

    x, y, my = (jax.random.normal(jax.random.fold_in(key, i),
                                  shape).astype(dtype) for i in range(3))
    k = max(1, int(np.prod(shape)) // 4)
    got = ops.choco_topk_move(x, y, my, 0.5, k, interpret=True)
    want = ref.choco_topk_ref(x, y, my, 0.5, k)
    return max(_max_err(got[0], want[0]), _max_err(got[1], want[1]))


_REGISTRY: Dict[str, KernelOp] = {}


def _register(name: str, **kw) -> None:
    _REGISTRY[name] = KernelOp(name=name, **kw)


_register("qsgd_quantize", mosaic=True, bitwise=False,
          doc="QSGD stochastic quantization (element-wise, norm fed in)",
          parity=_parity_qsgd)
_register("gossip_mix", mosaic=True, bitwise=False,
          doc="fused weighted gossip accumulate over deg neighbor copies",
          parity=_parity_gossip_mix)
_register("choco_move", mosaic=True, bitwise=False,
          doc="CHOCO consensus move, (x_new, diff) in one pass",
          parity=_parity_choco_move)
_register("topk_partials", mosaic=False, bitwise=True,
          doc="per-tile top-cand magnitude candidates (lax.top_k "
              "in-kernel: interpret/XLA-fallback only)",
          parity=_parity_topk)
_register("topk_mask", mosaic=True, bitwise=True,
          doc="keep-or-zero against the TopK threshold scalar",
          parity=_parity_topk_mask)
_register("choco_qsgd", mosaic=True, bitwise=False,
          doc="fused CHOCO move + QSGD compress + estimate update",
          parity=_parity_choco_qsgd)
_register("choco_topk", mosaic=True, bitwise=False,
          doc="fused CHOCO move + TopK compress + estimate update",
          parity=_parity_choco_topk)


def get_op(name: str) -> KernelOp:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel op {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def list_ops() -> List[KernelOp]:
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def resolve_mode(name: str, interpret: Optional[bool] = None) -> str:
    """Per-op dispatch decision: "interpret" | "mosaic" | "fallback".

    ``interpret=None`` (the default everywhere) resolves from the LAZILY
    detected backend and the op's Mosaic flag; an explicit bool always
    wins (tests force interpret=True; a TPU power user may force
    interpret=False to surface Mosaic lowering errors eagerly).
    """
    op = get_op(name)
    if interpret is True:
        return "interpret"
    if interpret is False:
        return "mosaic"
    if on_tpu():
        return "mosaic" if op.mosaic else "fallback"
    return "interpret"


def resolve_interpret(name: str, interpret: Optional[bool] = None) -> bool:
    """``resolve_mode`` narrowed to the ops that never fall back."""
    mode = resolve_mode(name, interpret)
    assert mode != "fallback", (
        f"op {name!r} resolved to the XLA fallback; call its fallback-aware"
        " dispatcher instead of forcing a pallas_call")
    return mode == "interpret"


PARITY_SHAPES: Tuple[Tuple[int, ...], ...] = (
    (64,), (1000,), (256, 128), (3, 5, 7), (32768,), (300, 70), (32769,))
PARITY_DTYPES = (jnp.float32, jnp.bfloat16)


def parity_suite(
    shapes: Sequence[Tuple[int, ...]] = PARITY_SHAPES,
    dtypes: Sequence[Any] = PARITY_DTYPES,
    seed: int = 0,
    ops: Optional[Sequence[str]] = None,
) -> List[Dict[str, Any]]:
    """Run every registered op's interpret-mode kernel against its oracle.

    Returns one record per (op, shape, dtype):
    ``{"op", "shape", "dtype", "max_err", "bitwise", "ok"}`` where ``ok``
    requires ``max_err == 0.0`` for bitwise ops and ``max_err < tol``
    (1e-5 f32 / 1e-2 bf16 — the one-ulp bf16 rounding the unfused
    kernels already exhibit) otherwise.
    """
    import zlib

    records: List[Dict[str, Any]] = []
    names = [o.name for o in list_ops()] if ops is None else list(ops)
    for name in names:
        op = get_op(name)
        for shape in shapes:
            for dtype in dtypes:
                # deterministic across processes (str hash() is salted)
                case = f"{name}:{tuple(shape)}".encode()
                key = jax.random.key(
                    (seed * 7919 + zlib.crc32(case)) % 2 ** 31)
                err = op.parity(key, tuple(shape), dtype)
                tol = 0.0 if op.bitwise else (
                    1e-5 if dtype == jnp.float32 else 1e-2)
                records.append({
                    "op": name,
                    "shape": list(shape),
                    "dtype": np.dtype(dtype).name,
                    "max_err": err,
                    "bitwise": op.bitwise,
                    "ok": bool(err <= tol),
                })
    return records
