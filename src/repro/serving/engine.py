"""Batched serving engine over the model zoo's prefill/decode steps.

Design (deliberately matching what the dry-run lowers at scale):
  * requests are grouped into equal-prompt-length buckets (right-padding
    within a bucket up to the configured granularity);
  * each bucket is served as one batched prefill + greedy/temperature
    decode loop with per-request EOS masking and early stop when every
    request in the flight is finished;
  * the decode step reuses jitted executables across buckets of the same
    (batch, prompt_len) signature — steady-state serving never re-traces.

Continuous batching (per-slot positions) is intentionally out of scope:
``DecodeState.position`` is flight-global, which is exactly the shape the
production decode dry-run (decode_32k / long_500k) exercises.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_params, prefill
from repro.models.common import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    tokens: List[int]
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1 = never stop early

    def __post_init__(self):
        assert len(self.tokens) >= 1


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]
    prompt_len: int
    latency_s: float


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 bucket: int = 32, max_len: int = 512,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.bucket = bucket
        self.max_len = max_len
        self.temperature = temperature
        self._queue: List[Request] = []
        self._done: Dict[int, Completion] = {}
        self._rng = jax.random.key(seed)
        self._prefill_cache: Dict = {}
        self._decode_fn = jax.jit(
            lambda p, s, t: decode_step(p, s, t, self.cfg))

    # -- client API --------------------------------------------------------

    def submit(self, req: Request) -> None:
        assert len(req.tokens) + req.max_new_tokens <= self.max_len, (
            "request exceeds engine max_len")
        self._queue.append(req)

    def run_until_drained(self) -> Dict[int, Completion]:
        while self._queue:
            self._serve_one_flight()
        return dict(self._done)

    # -- internals ----------------------------------------------------------

    def _bucket_len(self, n: int) -> int:
        return int(np.ceil(n / self.bucket) * self.bucket)

    def _take_flight(self) -> List[Request]:
        """Pop up to max_batch requests sharing a padded prompt length."""
        by_len = defaultdict(list)
        for r in self._queue:
            by_len[self._bucket_len(len(r.tokens))].append(r)
        # serve the largest group first (throughput).
        plen = max(by_len, key=lambda k: len(by_len[k]))
        flight = by_len[plen][: self.max_batch]
        for r in flight:
            self._queue.remove(r)
        return flight

    def _prefill_fn(self, batch: int, plen: int):
        key = (batch, plen)
        if key not in self._prefill_cache:
            self._prefill_cache[key] = jax.jit(
                lambda p, b: prefill(p, b, self.cfg, max_len=self.max_len))
        return self._prefill_cache[key]

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.temperature <= 0.0:
            tok = jnp.argmax(logits, -1)
        else:
            self._rng, sub = jax.random.split(self._rng)
            tok = jax.random.categorical(sub, logits / self.temperature)
        return (tok[:, None] % self.cfg.vocab_size).astype(jnp.int32)

    def _serve_one_flight(self) -> None:
        t0 = time.time()
        flight = self._take_flight()
        b = len(flight)
        plen = self._bucket_len(max(len(r.tokens) for r in flight))
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(flight):
            toks[i, plen - len(r.tokens):] = r.tokens   # left pad = repeat
            toks[i, : plen - len(r.tokens)] = r.tokens[0]
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.has_memory_input:
            m = self.cfg.memory_tokens or 16
            batch["memory"] = jnp.zeros(
                (b, m, self.cfg.memory_dim or self.cfg.d_model), jnp.float32)

        logits, state = self._prefill_fn(b, plen)(self.params, batch)
        out: List[List[int]] = [[] for _ in range(b)]
        finished = np.zeros(b, bool)
        budget = max(r.max_new_tokens for r in flight)
        tok = self._sample(logits)
        for step in range(budget):
            t_np = np.asarray(tok)[:, 0]
            for i, r in enumerate(flight):
                if finished[i] or step >= r.max_new_tokens:
                    finished[i] = True
                    continue
                out[i].append(int(t_np[i]))
                if r.eos_id >= 0 and int(t_np[i]) == r.eos_id:
                    finished[i] = True
            if finished.all() or step == budget - 1:
                break
            logits, state = self._decode_fn(self.params, state, tok)
            tok = self._sample(logits)
        dt = time.time() - t0
        for i, r in enumerate(flight):
            self._done[r.uid] = Completion(
                uid=r.uid, tokens=out[i], prompt_len=len(r.tokens),
                latency_s=dt)
