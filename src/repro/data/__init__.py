"""Data pipeline: synthetic corpora + federated non-IID partitioning."""
from repro.data.lm import SyntheticLM, lm_batches_for_cohort, lm_batches_for_dfl
from repro.data.federated import dirichlet_partition, label_shard_partition
from repro.data.images import SyntheticImages, image_batches_for_dfl

__all__ = [
    "SyntheticLM", "lm_batches_for_cohort", "lm_batches_for_dfl",
    "dirichlet_partition", "label_shard_partition",
    "SyntheticImages", "image_batches_for_dfl",
]
