"""Federated non-IID dataset partitioners (the paper's statistical
heterogeneity setup, Sec. VI-A)."""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(
    labels: np.ndarray, num_nodes: int, alpha: float, seed: int = 0
) -> List[np.ndarray]:
    """Partition sample indices across nodes with Dirichlet(alpha) class
    proportions per node (small alpha = highly non-IID)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    node_indices: List[List[int]] = [[] for _ in range(num_nodes)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_nodes, alpha))
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for node, part in enumerate(np.split(idx, cuts)):
            node_indices[node].extend(part.tolist())
    out = []
    for node in range(num_nodes):
        arr = np.asarray(node_indices[node], np.int64)
        rng.shuffle(arr)
        out.append(arr)
    return out


def label_shard_partition(
    labels: np.ndarray, num_nodes: int, shards_per_node: int = 2, seed: int = 0
) -> List[np.ndarray]:
    """McMahan-style pathological non-IID: sort by label, split into
    num_nodes*shards_per_node shards, deal shards to nodes."""
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, num_nodes * shards_per_node)
    shard_ids = rng.permutation(len(shards))
    out = []
    for node in range(num_nodes):
        take = shard_ids[node * shards_per_node:(node + 1) * shards_per_node]
        idx = np.concatenate([shards[s] for s in take])
        rng.shuffle(idx)
        out.append(idx)
    return out
