"""Synthetic language-modeling corpus with learnable structure.

The container is offline, so LM examples/benches train on a synthetic
corpus with real statistical structure (a sampled order-2 Markov chain over
the vocabulary): losses decrease with training and differ measurably across
non-IID shards, which is what the DFL experiments need. Deterministic given
the seed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Order-2 Markov-chain corpus, optionally non-IID across nodes.

    Non-IID scheme: each node gets its own transition-matrix mixture
    (alpha -> 1 means nodes nearly disjoint distributions), modelling the
    statistical heterogeneity the paper simulates (Sec. VI-A).
    """

    vocab_size: int
    num_nodes: int = 1
    noniid_alpha: float = 0.5
    branching: int = 16
    seed: int = 0
    # lazy=True is the mega-scale mode (--virtual-nodes): per-node chains
    # are built on first use from np.random.SeedSequence([seed, node]) —
    # a pure function of (seed, node), so shard content is independent of
    # CONSTRUCTION and ACCESS order (a 1M-node corpus costs O(cohort)
    # memory, and prefetcher threading cannot reorder shards). The eager
    # default draws every chain sequentially from one seed stream and is
    # kept bit-identical for existing runs; the two modes intentionally
    # produce different shards.
    lazy: bool = False

    def __post_init__(self):
        v, k = self.vocab_size, min(self.branching, self.vocab_size)

        # shared backbone chain + per-node perturbation chains.
        def chain(rng):
            nxt = rng.integers(0, v, size=(v, k))
            logits = rng.normal(size=(v, k)).astype(np.float32)
            probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
            return nxt, np.cumsum(probs, axis=-1)

        self._chain = chain
        if self.lazy:
            self._shared = chain(np.random.default_rng(
                np.random.SeedSequence([self.seed, self.num_nodes])))
            self._per_node_cache: Dict[int, Tuple[np.ndarray,
                                                  np.ndarray]] = {}
        else:
            rng = np.random.default_rng(self.seed)
            self._shared = chain(rng)
            self._per_node = [chain(rng) for _ in range(self.num_nodes)]

    def _node_chain(self, node: int) -> Tuple[np.ndarray, np.ndarray]:
        node = node % self.num_nodes
        if not self.lazy:
            return self._per_node[node]
        hit = self._per_node_cache.get(node)
        if hit is None:
            hit = self._chain(np.random.default_rng(
                np.random.SeedSequence([self.seed, node])))
            self._per_node_cache[node] = hit
        return hit

    def _sample_stream(self, rng: np.random.Generator, node: int,
                       length: int) -> np.ndarray:
        v = self.vocab_size
        out = np.empty(length, np.int64)
        cur = int(rng.integers(0, v))
        s_nxt, s_cum = self._shared
        n_nxt, n_cum = self._node_chain(node)
        use_node = rng.random(length) < self.noniid_alpha
        u = rng.random(length)
        for i in range(length):
            nxt, cum = (n_nxt, n_cum) if use_node[i] else (s_nxt, s_cum)
            j = int(np.searchsorted(cum[cur], u[i]))
            cur = int(nxt[cur, min(j, nxt.shape[1] - 1)])
            out[i] = cur
        return out

    def batch(self, node: int, batch_size: int, seq_len: int,
              step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + node * 101 + step) % (2**63))
        stream = self._sample_stream(rng, node, batch_size * (seq_len + 1))
        arr = stream.reshape(batch_size, seq_len + 1)
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}


def lm_batches_for_dfl(
    corpus: SyntheticLM,
    tau1: int,
    num_nodes: int,
    batch_per_node: int,
    seq_len: int,
    round_idx: int,
) -> Dict[str, jnp.ndarray]:
    """Batches shaped [tau1, N, B, S] for one DFL round."""
    toks = np.empty((tau1, num_nodes, batch_per_node, seq_len), np.int32)
    labs = np.empty_like(toks)
    for t in range(tau1):
        for n in range(num_nodes):
            b = corpus.batch(n, batch_per_node, seq_len,
                             step=round_idx * tau1 + t)
            toks[t, n] = b["tokens"]
            labs[t, n] = b["labels"]
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}


def lm_batches_for_cohort(
    corpus: SyntheticLM,
    tau1: int,
    cohort_ids: np.ndarray,
    batch_per_node: int,
    seq_len: int,
    round_idx: int,
) -> Dict[str, jnp.ndarray]:
    """Batches shaped [tau1, C, B, S] for one batched-engine round.

    Cohort slot j streams the shard of GLOBAL virtual node
    ``cohort_ids[j]`` — the same ``corpus.batch(node, ..., step)`` pure
    function ``lm_batches_for_dfl`` uses, so the slot's data depends only
    on (seed, global node id, step), never on which cohort it was drawn
    into (the shard-order pinning property: tests/test_determinism.py).
    """
    ids = np.asarray(cohort_ids, dtype=np.int64)
    if ids.ndim != 1:
        raise ValueError(f"cohort_ids must be 1-D, got shape {ids.shape}")
    c = ids.shape[0]
    toks = np.empty((tau1, c, batch_per_node, seq_len), np.int32)
    labs = np.empty_like(toks)
    for t in range(tau1):
        for j, n in enumerate(ids):
            b = corpus.batch(int(n), batch_per_node, seq_len,
                             step=round_idx * tau1 + t)
            toks[t, j] = b["tokens"]
            labs[t, j] = b["labels"]
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}
