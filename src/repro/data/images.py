"""Synthetic image-classification datasets standing in for MNIST / CIFAR-10.

The container is offline, so the paper-reproduction experiments (Figs 7-10)
train the paper's CNNs on generated datasets with the same tensor geometry
(28x28x1 "mnist-like", 32x32x3 "cifar-like") and honest difficulty: each
class is a smooth random template field plus per-sample elastic-ish jitter
and noise, giving a task a small CNN can learn but not trivially.
EXPERIMENTS.md states claims are validated qualitatively on these stand-ins.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.data.federated import dirichlet_partition, label_shard_partition


def _smooth_field(rng: np.random.Generator, h: int, w: int, c: int,
                  cutoff: int = 6) -> np.ndarray:
    """Low-frequency random field via truncated DCT-like mixing."""
    coef = rng.normal(size=(cutoff, cutoff, c))
    ys = np.linspace(0, np.pi, h)[:, None]
    xs = np.linspace(0, np.pi, w)[None, :]
    field = np.zeros((h, w, c))
    for i in range(cutoff):
        for j in range(cutoff):
            basis = np.cos(i * ys) * np.cos(j * xs)
            field += basis[..., None] * coef[i, j]
    field -= field.mean()
    field /= (np.abs(field).max() + 1e-9)
    return field.astype(np.float32)


@dataclasses.dataclass
class SyntheticImages:
    """num_classes templated images; 'mnist' (28x28x1) or 'cifar' (32x32x3)."""

    flavor: str = "mnist"
    num_classes: int = 10
    train_size: int = 10_000
    test_size: int = 2_000
    noise: float = 0.9
    seed: int = 0

    def __post_init__(self):
        h, w, c = (28, 28, 1) if self.flavor == "mnist" else (32, 32, 3)
        self.shape = (h, w, c)
        rng = np.random.default_rng(self.seed)
        self._templates = np.stack(
            [_smooth_field(rng, h, w, c) for _ in range(self.num_classes)])
        self.train_x, self.train_y = self._gen(rng, self.train_size)
        self.test_x, self.test_y = self._gen(rng, self.test_size)

    def _gen(self, rng: np.random.Generator, n: int):
        h, w, c = self.shape
        y = rng.integers(0, self.num_classes, size=n)
        x = self._templates[y].copy()
        # per-sample global shift + amplitude jitter + pixel noise
        amp = rng.uniform(0.5, 1.5, size=(n, 1, 1, 1)).astype(np.float32)
        x *= amp
        shifts = rng.integers(-4, 5, size=(n, 2))
        for i in range(n):  # cheap roll-based jitter
            x[i] = np.roll(x[i], shifts[i], axis=(0, 1))
        x += rng.normal(scale=self.noise, size=x.shape).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)

    def partition(self, num_nodes: int, scheme: str = "dirichlet",
                  alpha: float = 0.3, seed: int = 0) -> List[np.ndarray]:
        if scheme == "dirichlet":
            return dirichlet_partition(self.train_y, num_nodes, alpha, seed)
        if scheme == "label_shard":
            return label_shard_partition(self.train_y, num_nodes, seed=seed)
        if scheme == "iid":
            rng = np.random.default_rng(seed)
            idx = rng.permutation(len(self.train_y))
            return [np.asarray(p) for p in np.array_split(idx, num_nodes)]
        raise ValueError(f"unknown scheme {scheme!r}")


def image_batches_for_dfl(
    data: SyntheticImages,
    parts: List[np.ndarray],
    tau1: int,
    batch_per_node: int,
    round_idx: int,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Mini-batches [tau1, N, B, H, W, C] / labels [tau1, N, B] for a round."""
    n_nodes = len(parts)
    h, w, c = data.shape
    xs = np.empty((tau1, n_nodes, batch_per_node, h, w, c), np.float32)
    ys = np.empty((tau1, n_nodes, batch_per_node), np.int32)
    for node, idx in enumerate(parts):
        rng = np.random.default_rng(seed * 7919 + node * 101 + round_idx)
        for t in range(tau1):
            take = rng.choice(idx, size=batch_per_node, replace=True)
            xs[t, node] = data.train_x[take]
            ys[t, node] = data.train_y[take]
    return xs, ys
