"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision (family card); 90B variant geometry]

The ViT vision encoder + adapter are a STUB per the assignment: the backbone
consumes pre-computed patch embeddings (memory_dim=1280, the vision tower
width) through the trained projector.
"""
from repro.configs.base import ArchConfig, reduced_from
from repro.models.common import LayerSpec, ModelConfig

_SELF = LayerSpec(mixer="attn", ffn="mlp")
_CROSS = LayerSpec(mixer="attn", ffn="mlp", cross_attn=True)

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    pattern=(_SELF, _SELF, _SELF, _SELF, _CROSS),   # 20 periods of 5
    memory_dim=1280,
    memory_tokens=4096,          # patch embeddings per request (stub frontend)
    tie_embeddings=False,
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
)

ARCH = ArchConfig(
    arch_id="llama-3.2-vision-90b",
    model=CONFIG,
    reduced=reduced_from(
        CONFIG, num_layers=2, pattern=(_SELF, _CROSS), memory_tokens=16),
    sharding_mode="gossip-fsdp",
    fsdp_nodes=4,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention decoder; no sliding-window variant in "
                "the source model card (DESIGN.md section 4)",
)
