"""qwen3-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936; qk_norm. [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ArchConfig, reduced_from
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    citation="hf:Qwen/Qwen3-8B",
)

ARCH = ArchConfig(
    arch_id="qwen3-8b",
    model=CONFIG,
    reduced=reduced_from(CONFIG),
    sharding_mode="gossip-dp",
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention stack; no sub-quadratic variant in the "
                "source model card (DESIGN.md section 4)",
)
