"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
(per expert) vocab=32064, MoE 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct]
"""
from repro.configs.base import ArchConfig, reduced_from
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    experts_per_token=2,
    rope_theta=10_000.0,
    tie_embeddings=False,
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
)

ARCH = ArchConfig(
    arch_id="phi3.5-moe-42b-a6.6b",
    model=CONFIG,
    reduced=reduced_from(CONFIG),
    sharding_mode="gossip-fsdp",
    fsdp_nodes=4,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention stack; no sub-quadratic variant in the "
                "source model card (DESIGN.md section 4)",
)
