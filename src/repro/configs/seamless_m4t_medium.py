"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (kv=16, MHA) d_ff=4096
vocab=256206; encoder-decoder, multimodal. [arXiv:2308.11596]

Backbone interpretation: 12 encoder layers (bidirectional, over speech-frame
embeddings) + 12 decoder layers (causal self-attn + cross-attn to the
encoder memory). The mel-spectrogram + conv feature extractor frontend is a
STUB per the assignment — ``input_specs`` supplies frame embeddings
(memory_dim = 1024) directly; frames = seq_len // 4.

Skips: long_500k (full-attention enc-dec speech model; 512k-token decode is
out of scope for the family) — see DESIGN.md section 4.
"""
from repro.configs.base import ArchConfig, reduced_from
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    num_layers=12,               # decoder
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    rope_theta=10_000.0,
    memory_dim=1024,             # conv feature extractor output width (stub)
    memory_tokens=1024,          # default; launcher scales to seq_len // 4
    tie_embeddings=True,
    citation="arXiv:2308.11596",
)

ARCH = ArchConfig(
    arch_id="seamless-m4t-medium",
    model=CONFIG,
    reduced=reduced_from(CONFIG),
    sharding_mode="gossip-dp",
    skip_shapes=("long_500k",),
    skip_reason="full-attention encoder-decoder speech model; 512k-token "
                "decode out of scope for the family (DESIGN.md section 4)",
)
