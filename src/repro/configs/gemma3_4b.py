"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144; 5:1 local(sliding-window 1024):global attention, 128k context.
[hf:google/gemma-3-1b-pt family card; 4B geometry]

The 5:1 pattern over 34 layers is not periodic, so the full per-layer
pattern is materialized (scan period = 34, num_periods = 1): global
attention at layers 5, 11, 17, 23, 29 (0-indexed), sliding-window 1024
elsewhere. Local layers use rope_theta=10k, global layers 1M (model card).

long_500k RUNS for this arch: the sliding-window layers keep a 1024-slot
cache; only the 5 global layers carry the full 512k KV (sharded over the
`model` mesh axis).
"""
from repro.configs.base import ArchConfig, reduced_from
from repro.models.common import LayerSpec, ModelConfig

_LOCAL = LayerSpec(mixer="attn", ffn="mlp", window=1024, rope_theta=10_000.0)
_GLOBAL = LayerSpec(mixer="attn", ffn="mlp", rope_theta=1_000_000.0)

_PATTERN = tuple(
    _GLOBAL if (i % 6) == 5 else _LOCAL for i in range(34)
)

CONFIG = ModelConfig(
    name="gemma3-4b",
    arch_type="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    qk_norm=True,
    rope_theta=1_000_000.0,
    pattern=_PATTERN,
    tie_embeddings=True,
    attn_shard="head_dim",       # 8 heads don't divide the 16-way model axis
    citation="hf:google/gemma-3-1b-pt",
)

ARCH = ArchConfig(
    arch_id="gemma3-4b",
    model=CONFIG,
    reduced=reduced_from(
        CONFIG, num_layers=2, pattern=(_LOCAL, _GLOBAL), head_dim=32),
    sharding_mode="gossip-dp",
)
