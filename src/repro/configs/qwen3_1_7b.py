"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936; qk_norm. [hf:Qwen/Qwen3-8B family card]"""
from repro.configs.base import ArchConfig, reduced_from
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    arch_type="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    citation="hf:Qwen/Qwen3-8B",
)

ARCH = ArchConfig(
    arch_id="qwen3-1.7b",
    model=CONFIG,
    reduced=reduced_from(CONFIG),
    sharding_mode="gossip-dp",
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention stack; no sub-quadratic variant in the "
                "source model card (DESIGN.md section 4)",
)
