"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16, mamba-1 architecture. [arXiv:2410.05355]

long_500k RUNS: decode state is O(1) in sequence length (the arch the
assignment's sub-quadratic rule is made for).
"""
from repro.configs.base import ArchConfig, reduced_from
from repro.models.common import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                      # mamba block has no separate FFN
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,                # d_inner = 8192
    pattern=(LayerSpec(mixer="mamba", ffn="none"),),
    tie_embeddings=False,
    citation="arXiv:2410.05355",
)

ARCH = ArchConfig(
    arch_id="falcon-mamba-7b",
    model=CONFIG,
    reduced=reduced_from(CONFIG),
    sharding_mode="gossip-dp",
)
