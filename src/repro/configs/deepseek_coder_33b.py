"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256; llama-architecture. [arXiv:2401.14196]

56 heads do not divide the 16-way `model` mesh axis, so attention shards on
head_dim (contraction-dim sharding; GSPMD inserts the psum) — see DESIGN.md.
"""
from repro.configs.base import ArchConfig, reduced_from
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100_000.0,
    tie_embeddings=False,
    attn_shard="head_dim",
    citation="arXiv:2401.14196",
)

ARCH = ArchConfig(
    arch_id="deepseek-coder-33b",
    model=CONFIG,
    reduced=reduced_from(CONFIG),
    sharding_mode="gossip-fsdp",
    fsdp_nodes=4,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention stack; no sub-quadratic variant in the "
                "source model card (DESIGN.md section 4)",
)
