"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 (per expert) vocab=65536, MoE 16 experts top-2; Mamba:attention
1:7 interleave. [arXiv:2403.19887]

Period-8 block (HF Jamba: attn_layer_period=8 offset=4; expert_layer_period=2
offset=1): position 4 is attention, the rest Mamba; odd positions carry the
MoE FFN, even positions a dense FFN of the same width.

long_500k RUNS: 63/72 layers are O(1)-state Mamba; the 9 attention layers
keep the 512k KV cache sharded over the `model` mesh axis (decode is linear).
"""
from repro.configs.base import ArchConfig, reduced_from
from repro.models.common import LayerSpec, ModelConfig

def _spec(pos: int) -> LayerSpec:
    mixer = "attn" if pos == 4 else "mamba"
    ffn = "moe" if pos % 2 == 1 else "mlp"
    return LayerSpec(mixer=mixer, ffn=ffn)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,                # d_inner = 16384
    pattern=tuple(_spec(i) for i in range(8)),
    tie_embeddings=False,
    citation="arXiv:2403.19887",
)

ARCH = ArchConfig(
    arch_id="jamba-1.5-large-398b",
    model=CONFIG,
    reduced=reduced_from(
        CONFIG, num_layers=2,
        pattern=(LayerSpec(mixer="mamba", ffn="moe"),
                 LayerSpec(mixer="attn", ffn="mlp"))),
    sharding_mode="gossip-fsdp",
    fsdp_nodes=2,                # 2 x 796 GB bf16 replicas / 256 chips
)
