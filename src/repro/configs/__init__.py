"""Architecture registry: the 10 assigned configs + the paper's own CNN."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import SHAPES, ArchConfig, InputShape

from repro.configs import (
    granite_moe_1b_a400m,
    llama_3_2_vision_90b,
    qwen3_1_7b,
    qwen3_8b,
    gemma3_4b,
    seamless_m4t_medium,
    falcon_mamba_7b,
    jamba_1_5_large_398b,
    deepseek_coder_33b,
    phi3_5_moe_42b_a6_6b,
)

_MODULES = [
    granite_moe_1b_a400m,
    llama_3_2_vision_90b,
    qwen3_1_7b,
    qwen3_8b,
    gemma3_4b,
    seamless_m4t_medium,
    falcon_mamba_7b,
    jamba_1_5_large_398b,
    deepseek_coder_33b,
    phi3_5_moe_42b_a6_6b,
]

REGISTRY: Dict[str, ArchConfig] = {m.ARCH.arch_id: m.ARCH for m in _MODULES}


def get_arch(arch_id: str) -> ArchConfig:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise ValueError(
            f"unknown arch {arch_id!r}; options: {sorted(REGISTRY)}"
        ) from None


def list_archs() -> List[str]:
    return sorted(REGISTRY)


__all__ = ["SHAPES", "ArchConfig", "InputShape", "REGISTRY", "get_arch", "list_archs"]
