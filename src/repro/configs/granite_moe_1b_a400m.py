"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ArchConfig, reduced_from
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,                    # per-expert FFN width
    vocab_size=49155,
    num_experts=32,
    experts_per_token=8,
    rope_theta=10_000.0,
    tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

ARCH = ArchConfig(
    arch_id="granite-moe-1b-a400m",
    model=CONFIG,
    reduced=reduced_from(CONFIG),
    sharding_mode="gossip-dp",
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention stack; no sub-quadratic variant in the "
                "source model card (DESIGN.md section 4)",
)
