"""Per-architecture deployment config: model + DFL mapping + shape policy."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned input shapes."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """An assigned architecture + its production mapping."""

    arch_id: str
    model: ModelConfig
    reduced: ModelConfig          # smoke-test variant (<=2 periods, d<=512)
    # DFL node mapping (see DESIGN.md section 3):
    #   gossip-dp   — node axis = mesh data axis (16 / 32 divergent replicas)
    #   gossip-fsdp — few replicated nodes; weights FSDP x TP sharded
    sharding_mode: str = "gossip-dp"
    fsdp_nodes: int = 4           # node count in gossip-fsdp mode
    # which shapes run (long_500k gated on sub-quadratic support)
    skip_shapes: Tuple[str, ...] = ()
    skip_reason: str = ""

    def shapes(self) -> Tuple[str, ...]:
        return tuple(s for s in SHAPES if s not in self.skip_shapes)


def reduced_from(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Derive the CPU smoke-test variant of a full config."""
    base = dict(
        name=cfg.name + "-reduced",
        num_layers=2 * len(cfg.pattern) if len(cfg.pattern) <= 2 else len(cfg.pattern),
        d_model=min(cfg.d_model, 256),
        num_heads=min(cfg.num_heads, 4) or 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 0,
        head_dim=min(cfg.head_dim, 32) or 0,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        ssm_state=min(cfg.ssm_state, 8),
        encoder_layers=min(cfg.encoder_layers, 2),
        memory_dim=min(cfg.memory_dim, 64) if cfg.memory_dim else 0,
        memory_tokens=min(cfg.memory_tokens, 16) if cfg.memory_tokens else 0,
        attn_q_chunk=16,
        attn_kv_chunk=16,
        loss_seq_chunk=16,
        ssm_chunk=8,
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
