"""Minimal, dependency-free pytree checkpointing.

Leaves are flattened to a single .npz (keyed by the joined tree path); a
sidecar manifest.json records step, metrics and the treedef os the pytree
can be restored into the same structure.

Crash safety: writes are ATOMIC (temp file in the same directory +
``os.replace``), so a run killed mid-save never leaves a truncated
``ckpt_*.npz`` under the canonical name; and restore is DEFENSIVE — with
``step=None`` it walks the available steps newest-first and falls back
past any checkpoint that fails to load or validate (a torn file from a
pre-atomic writer, a partial copy, bit rot), so a fault-injected run
resumes from the newest checkpoint that is actually intact.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _key_of(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _atomic_write(path: str, write_fn) -> None:
    """Write via a temp file in the SAME directory, fsync, os.replace —
    the canonical name only ever points at a complete file."""
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    metrics: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {_key_of(p): np.asarray(v) for p, v in flat}
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    _atomic_write(path, lambda f: np.savez(f, **arrays))
    manifest = {
        "step": step,
        "metrics": metrics or {},
        "num_leaves": len(arrays),
    }
    _atomic_write(
        os.path.join(directory, f"ckpt_{step:08d}.json"),
        lambda f: f.write(json.dumps(manifest, indent=2).encode("utf-8")))
    return path


def available_steps(directory: str) -> List[int]:
    """All checkpoint steps present in ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    return sorted(
        int(m.group(1))
        for fn in os.listdir(directory)
        if (m := re.match(r"ckpt_(\d+)\.npz$", fn)))


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return max(steps) if steps else None


# what a torn/corrupt .npz (or a manifest mismatch) surfaces as across
# numpy versions: BadZipFile for truncated archives, ValueError/KeyError/
# EOFError/OSError for header damage and short reads.
_CORRUPT_ERRORS = (zipfile.BadZipFile, ValueError, KeyError, EOFError,
                   OSError)


class ShapeMismatchError(ValueError):
    """Checkpoint/template structural disagreement — caller error (the
    model changed), not data damage; the newest-first fallback never
    skips past it."""


def _load_step(directory: str, step: int, template: PyTree) -> PyTree:
    with np.load(os.path.join(directory, f"ckpt_{step:08d}.npz")) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, tmpl in flat:
            key = _key_of(path)
            arr = data[key]
            if (hasattr(tmpl, "shape")
                    and tuple(arr.shape) != tuple(tmpl.shape)):
                raise ShapeMismatchError(
                    f"{key}: checkpoint shape {arr.shape} != "
                    f"template {tmpl.shape}")
            if arr.dtype.kind == "V" and hasattr(tmpl, "dtype"):
                # ml_dtypes leaves (bfloat16 & co) come back from .npz as
                # raw void bytes; reinterpret via the template's dtype.
                arr = arr.view(tmpl.dtype)
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_checkpoint(directory: str, template: PyTree,
                       step: Optional[int] = None) -> Tuple[PyTree, int]:
    """Restore into the structure of ``template`` (shapes are validated).

    ``step=None`` restores the newest VALID checkpoint: steps are tried
    newest-first and unreadable/corrupt ones are skipped (an explicit
    ``step`` is trusted and raises on damage — the caller asked for that
    exact file). Raises FileNotFoundError when the directory holds no
    loadable checkpoint at all.
    """
    if step is not None:
        return _load_step(directory, step, template), step
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    failures: List[str] = []
    for s in reversed(steps):
        try:
            return _load_step(directory, s, template), s
        except ShapeMismatchError:
            raise  # wrong template, not a torn file — older ckpts won't fit
        except _CORRUPT_ERRORS as e:
            failures.append(f"step {s}: {type(e).__name__}: {e}")
    raise FileNotFoundError(
        f"no loadable checkpoint in {directory}; "
        f"tried {len(failures)} (newest first): " + "; ".join(failures))
