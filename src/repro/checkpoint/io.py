"""Minimal, dependency-free pytree checkpointing.

Leaves are flattened to a single .npz (keyed by the joined tree path); a
sidecar manifest.json records step, metrics and the treedef os the pytree
can be restored into the same structure.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _key_of(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    metrics: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {_key_of(p): np.asarray(v) for p, v in flat}
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **arrays)
    manifest = {
        "step": step,
        "metrics": metrics or {},
        "num_leaves": len(arrays),
    }
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for fn in os.listdir(directory)
        if (m := re.match(r"ckpt_(\d+)\.npz$", fn))
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: PyTree,
                       step: Optional[int] = None) -> Tuple[PyTree, int]:
    """Restore into the structure of ``template`` (shapes are validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in flat:
        key = _key_of(path)
        arr = data[key]
        if hasattr(tmpl, "shape") and tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"template {tmpl.shape}")
        if arr.dtype.kind == "V" and hasattr(tmpl, "dtype"):
            # ml_dtypes leaves (bfloat16 & co) come back from .npz as raw
            # void bytes; reinterpret via the template's dtype.
            arr = arr.view(tmpl.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
