"""JAX version-compat shims + the node-axis substrate shared by both engines.

Supported JAX: the pinned 0.4.37 (this container) up through current
releases. Two API drifts are papered over here so the rest of the codebase
never touches them again (``jax.lax.axis_size`` landing silently broke the
whole sparse engine once — see tests/test_multidevice.py):

  * ``jax.lax.axis_size``    — absent in 0.4.37; ``lax.psum(1, axis)`` is
                               the portable spelling (returns a static int
                               for a concrete operand inside shard_map).
  * ``jax.shard_map``        — 0.4.37 only has
                               ``jax.experimental.shard_map.shard_map`` with
                               ``check_rep=``/``auto=``; newer JAX renames
                               these to ``check_vma=``/``axis_names=``.

The second half of the module is the *node substrate*: one small object
that abstracts "the node axis" so the DFL algorithm (local-update scan,
CHOCO-G step, RNG folding, metrics) is written exactly once in
``repro.core.dfl`` and executed by two engines:

  * ``DenseSubstrate``   — nodes stacked on a leading [N, ...] array axis;
                           node ops are vmap / einsum-with-C / mean(axis=0).
                           Works for ANY doubly stochastic C.
  * ``ShardedSubstrate`` — nodes enumerated by manual mesh axes inside
                           ``shard_map``; node ops are identity / ppermute /
                           pmean. Requires a circulant (shift-structured) C
                           and moves only deg neighbor copies per gossip
                           step instead of the dense all-gather's N-1.

Both substrates fold PRNG keys identically (per-node key =
``fold_in(step_key, node_index)``), which is what makes dense-vs-sparse
parity exact even for stochastic losses and compressors.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
AxisName = Union[str, Tuple[str, ...]]

__all__ = [
    "axis_size",
    "mesh_axis_size",
    "shard_map",
    "supports_partial_auto",
    "NodeSubstrate",
    "DenseSubstrate",
    "BatchedSubstrate",
    "ShardedSubstrate",
]


# ---------------------------------------------------------------------------
# Version compat
# ---------------------------------------------------------------------------


def axis_size(axis_name: AxisName) -> int:
    """Size of a named mesh axis (or product over a tuple of axes), valid
    inside shard_map/pmap on every supported JAX version."""
    if isinstance(axis_name, (tuple, list)):
        return int(np.prod([axis_size(a) for a in axis_name]))
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_name))
    # psum of a concrete scalar is evaluated statically: the axis size.
    return int(jax.lax.psum(1, axis_name))


def mesh_axis_size(mesh, axes: Optional[AxisName] = None) -> int:
    """Device count of ``axes`` on ``mesh`` (all axes when None) — THE one
    spelling of "how many nodes do these mesh axes enumerate". Works on
    ``jax.sharding.Mesh`` and ``AbstractMesh`` alike (both expose
    ``.shape``); an unknown axis raises ``KeyError``. Callers outside
    ``shard_map`` must use this, not ``axis_size`` (which needs a bound
    axis context) and not ad-hoc ``np.prod(mesh.shape[...])`` spellings
    (which drifted into four copies once)."""
    if axes is None:
        axes = tuple(mesh.axis_names)
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= int(mesh.shape[a])
    return n


def shard_map(
    f: Callable,
    mesh,
    in_specs,
    out_specs,
    *,
    manual_axes: Optional[Sequence[str]] = None,
    check: bool = False,
):
    """``shard_map`` across the check_rep->check_vma / auto->axis_names
    renames. ``manual_axes``: mesh axes the body is manual over (all axes
    when None); the rest stay auto (GSPMD-partitioned)."""
    if hasattr(jax, "shard_map"):  # JAX >= 0.6
        import inspect

        kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
        params = inspect.signature(jax.shard_map).parameters
        kwargs["check_vma" if "check_vma" in params else "check_rep"] = check
        if manual_axes is not None and set(manual_axes) != set(mesh.axis_names):
            kwargs["axis_names"] = set(manual_axes)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs,
              "check_rep": check}
    if manual_axes is not None and set(manual_axes) != set(mesh.axis_names):
        kwargs["auto"] = frozenset(mesh.axis_names) - set(manual_axes)
    return _shard_map(f, **kwargs)


def supports_partial_auto() -> bool:
    """Whether shard_map with a non-trivial auto (GSPMD) axis set is usable.

    The pinned jaxlib 0.4.37 hard-crashes compiling scan+ppermute bodies
    under partial-manual shard_map when an auto axis has size > 1
    (``Check failed: sharding.IsManualSubgroup()`` in hlo_sharding_util);
    size-1 auto axes are fine. Newer JAX (with top-level ``jax.shard_map``)
    handles partial-manual properly. Engine auto-selection consults this so
    a tensor-parallel mesh falls back to the dense engine on the old pin
    instead of aborting the process.
    """
    return hasattr(jax, "shard_map")


# ---------------------------------------------------------------------------
# Node substrates
# ---------------------------------------------------------------------------


class NodeSubstrate:
    """Abstracts the DFL node axis for the shared algorithm in core.dfl.

    Contract (N = number of nodes):
      * ``vmap(fn)``            — lift a per-node fn over the node axis.
      * ``node_keys(key)``      — per-node PRNG keys, fold_in(key, node_idx).
      * ``mix(tree, edge_mask=None)`` — one uncompressed gossip step
                                  X <- X C; ``edge_mask`` (traced [E] 0/1
                                  over ``topology.edges()``) drops masked
                                  edges and renormalizes onto the diagonal
                                  (bitwise the plain step at all ones).
      * ``mean_over_nodes(x)``  — mean over the node axis of per-node
                                  scalars (dense: leading array axis;
                                  sparse: pmean collective).
      * ``sum_per_node(x)``     — sum an array down to one scalar per node.
      * ``mean_tree(tree)``     — per-leaf f32 mean over nodes.

    Participation hooks (sporadic rounds; see docs/ARCHITECTURE.md):
      * ``node_mask_local(node_mask)``  — project the round's replicated
        [N] node mask to this substrate's local view (dense: the [N]
        vector itself; sparse: this node's scalar entry).
      * ``select_nodes(mask, new, old)`` — per-node select between two
        same-shaped trees (masked nodes keep ``old``); a bitwise identity
        for ``new`` wherever the mask is one.
      * ``masked_mean_over_nodes(x, mask)`` — mean of per-node scalars
        over ACTIVE nodes only; bitwise ``mean_over_nodes`` at all ones.
    """

    num_nodes: int

    def vmap(self, fn: Callable) -> Callable:
        raise NotImplementedError

    def node_keys(self, key: jax.Array):
        raise NotImplementedError

    def mix(self, tree: PyTree,
            edge_mask: Optional[jnp.ndarray] = None) -> PyTree:
        raise NotImplementedError

    def mean_over_nodes(self, x: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def sum_per_node(self, x: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def mean_tree(self, tree: PyTree) -> PyTree:
        raise NotImplementedError

    def node_mask_local(self, node_mask: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def select_nodes(self, mask_local: jnp.ndarray, new: PyTree,
                     old: PyTree) -> PyTree:
        raise NotImplementedError

    def masked_mean_over_nodes(self, x: jnp.ndarray,
                               mask_local: jnp.ndarray) -> jnp.ndarray:
        """mean(x * m) / max(mean(m), 1/N): exact ``/ 1.0`` at all ones,
        and 0 (not NaN) when every node is masked."""
        m = mask_local.astype(jnp.float32)
        num = self.mean_over_nodes(x * m)
        den = jnp.maximum(self.mean_over_nodes(m),
                          jnp.float32(1.0 / max(self.num_nodes, 1)))
        return num / den

    # -- shared derived ops (identical formulas on both engines) ----------

    def choco_move(self, x: PyTree, y: PyTree, mixed_y: PyTree,
                   gamma: float) -> Tuple[PyTree, PyTree]:
        """Fused CHOCO-G move (Alg. 2 l.6): x += gamma (C y - y); returns
        (x_new, x_new - y)."""

        def move(a, my, yy):
            return (a.astype(jnp.float32)
                    + gamma * (my.astype(jnp.float32) - yy.astype(jnp.float32))
                    ).astype(a.dtype)

        x_new = jax.tree_util.tree_map(move, x, mixed_y, y)
        diff = jax.tree_util.tree_map(lambda a, b: a - b, x_new, y)
        return x_new, diff

    def compress(self, comp, tree: PyTree, key: jax.Array) -> PyTree:
        """Apply the compressor Q leaf-wise (one node's tree + key)."""
        from repro.core.compression import compress_tree

        return compress_tree(comp, tree, key)

    def choco_step(self, comp, x: PyTree, y: PyTree, mixed_y: PyTree,
                   gamma: float, keys) -> Tuple[PyTree, PyTree]:
        """One full CHOCO-G inner iteration AFTER the mix (Alg. 2
        l.6-7,11): consensus move, compress the gap, update the shared
        estimates. Returns (x_new, y_new). The default is the unfused
        composition both engines executed historically (bit-identical);
        ``ShardedSubstrate`` overrides it with the single-pass fused
        kernel when ``use_kernels`` and Q is QSGD/TopK."""
        x_new, diff = self.choco_move(x, y, mixed_y, gamma)
        q = self.vmap(lambda d, k: self.compress(comp, d, k))(diff, keys)
        y_new = jax.tree_util.tree_map(lambda b, qq: b + qq, y, q)
        return x_new, y_new

    def consensus_sq(self, params: PyTree) -> jnp.ndarray:
        """||X (I - J)||_F^2 / N (Lemma 1's drift), via per-node deviation
        from the node mean."""
        mean = self.mean_tree(params)
        dev = None
        for leaf, m in zip(jax.tree_util.tree_leaves(params),
                           jax.tree_util.tree_leaves(mean)):
            d = (leaf.astype(jnp.float32) - m.astype(jnp.float32)) ** 2
            per_node = self.sum_per_node(d)
            dev = per_node if dev is None else dev + per_node
        return self.mean_over_nodes(dev)


class DenseSubstrate(NodeSubstrate):
    """Stacked-array node axis: every leaf [N, ...]; any topology."""

    def __init__(self, topology):
        self.topology = topology
        self.num_nodes = topology.num_nodes

    def vmap(self, fn):
        return jax.vmap(fn)

    def node_keys(self, key):
        return jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.arange(self.num_nodes, dtype=jnp.int32))

    def mix(self, tree, edge_mask=None):
        from repro.core import mixing as mixing_lib

        return mixing_lib.mix_dense(tree, self.topology,
                                    edge_mask=edge_mask)

    def mean_over_nodes(self, x):
        return jnp.mean(x, axis=0)

    def sum_per_node(self, x):
        return jnp.sum(x, axis=tuple(range(1, x.ndim)))

    def mean_tree(self, tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.mean(x.astype(jnp.float32), axis=0), tree)

    def node_mask_local(self, node_mask):
        return node_mask

    def select_nodes(self, mask_local, new, old):
        def sel(nw, od):
            m = mask_local.astype(bool).reshape(
                (self.num_nodes,) + (1,) * (nw.ndim - 1))
            return jnp.where(m, nw, od)

        return jax.tree_util.tree_map(sel, new, old)


class BatchedSubstrate(DenseSubstrate):
    """Dense substrate over a SAMPLED cohort drawn from a virtual
    population (the node-batched mega-scale engine).

    The population is DATA, not hardware: training state stays stacked
    ``[population, ...]`` on the host device, and each round gathers the
    ``cohort_ids`` rows (a traced ``[C]`` int32 vector of GLOBAL node
    ids), runs the ordinary dense round over the C-node cohort
    ``topology``, and scatters the results back — non-cohort nodes are
    bitwise frozen. Per-round compute, gossip, and host data are all
    C-sized, so one machine simulates 10k-1M lightweight virtual nodes
    (the DFedAvg client-sampling regime, arXiv:2104.11375).

    Every node op is inherited from ``DenseSubstrate`` EXCEPT
    ``node_keys``, which folds the GLOBAL virtual-node id of each cohort
    slot instead of the slot index: a virtual node's per-step RNG stream
    is a function of its population identity, not of where a draw seated
    it. Two consequences, both load-bearing for the parity harness
    (tests/test_batched_parity.py):

      * at full population (``cohort_ids == arange(C)``, C == population)
        the gathers/scatters are identities and the folded ids equal the
        dense engine's slot indices, so a batched round is BITWISE the
        dense round — plain and CHOCO, masked and unmasked;
      * under a real C-of-V draw, a node's local-gradient/compressor
        noise is reproducible across different cohorts containing it.

    ``cohort_ids`` may be traced (the executor scans them as schedule
    xs — one executable across cohort draws, audited by
    ``cohort-recompile``) or ``None`` for the identity cohort.
    """

    def __init__(self, topology, population: int, cohort_ids=None):
        super().__init__(topology)
        population = int(population)
        if population < topology.num_nodes:
            raise ValueError(
                f"population {population} smaller than the cohort "
                f"topology's {topology.num_nodes} nodes")
        self.population = population
        self.cohort_ids = cohort_ids

    def _ids(self):
        if self.cohort_ids is None:
            return jnp.arange(self.num_nodes, dtype=jnp.int32)
        return jnp.asarray(self.cohort_ids, jnp.int32)

    def node_keys(self, key):
        return jax.vmap(lambda i: jax.random.fold_in(key, i))(self._ids())

    # -- population <-> cohort movement ------------------------------------

    def gather_cohort(self, tree: PyTree) -> PyTree:
        """Cohort rows of a ``[population, ...]``-stacked tree (identity
        when ``cohort_ids`` is None and C == population)."""
        if self.cohort_ids is None and self.num_nodes == self.population:
            return tree
        ids = self._ids()
        return jax.tree_util.tree_map(
            lambda x: jnp.take(x, ids, axis=0), tree)

    def scatter_cohort(self, full: PyTree, cohort: PyTree) -> PyTree:
        """Write cohort rows back into the population-stacked tree;
        non-cohort rows are untouched (bitwise)."""
        if self.cohort_ids is None and self.num_nodes == self.population:
            return cohort
        ids = self._ids()
        return jax.tree_util.tree_map(
            lambda f, c: f.at[ids].set(c), full, cohort)


class ShardedSubstrate(NodeSubstrate):
    """shard_map-manual node axis: leaves are one node's local shard; the
    mesh axes in ``node_axes`` enumerate nodes. Requires a circulant C
    (``topology.is_shift_structured()``); gossip is one ppermute per shift.

    ``use_kernels`` routes the hot path through the Pallas kernels in
    ``repro.kernels.ops`` (dispatch per ``repro.kernels.registry``:
    Mosaic on TPU, interpret off-TPU, validated against kernels/ref.py
    oracles in tests/test_kernels.py): the gossip accumulate
    (``gossip_mix``), and for C-DFL the FUSED compress-and-move step
    (``choco_qsgd_move`` / ``choco_topk_move`` — one kernel pass emits
    (x_new, y_new) instead of the move -> compress -> add chain). Other
    compressors fall back to the unfused ``choco_move`` kernel plus the
    library compressor.
    """

    def __init__(self, topology, node_axes: Sequence[str],
                 use_kernels: bool = False):
        assert topology.is_shift_structured(), (
            f"{topology.name} is not circulant; the sharded engine needs a "
            "shift-structured C (use the dense engine otherwise)")
        self.topology = topology
        self.node_axes = tuple(node_axes)
        self.axis: AxisName = (self.node_axes if len(self.node_axes) > 1
                               else self.node_axes[0])
        self.shifts = topology.shifts()
        self.self_weight = (float(topology.self_weights[0])
                            if topology.num_nodes else 1.0)
        self.num_nodes = topology.num_nodes
        self.use_kernels = use_kernels
        # Per-shift edge lookup for participation masks: entry [k, i] is
        # the canonical ``topology.edges()`` index of the edge node i
        # receives over on shift k (from node (i - s_k) mod N). Both
        # endpoints of an undirected edge resolve to the same entry, so a
        # masked edge renormalizes symmetrically on both sides.
        if self.shifts and topology.num_edges:
            eix = topology.edge_index()
            n = self.num_nodes
            self.shift_edge_idx = np.asarray(
                [[eix[tuple(sorted(((i - s) % n, i)))] for i in range(n)]
                 for (s, _) in self.shifts], dtype=np.int32)
        else:
            self.shift_edge_idx = np.zeros((0, self.num_nodes), np.int32)

    def shift_masks(self, edge_mask: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
        """This node's traced 0/1 scalar per shift, gathered from the
        round's replicated [E] edge mask."""
        idx = self.node_index()
        table = jnp.asarray(self.shift_edge_idx)
        return tuple(edge_mask[table[k, idx]].astype(jnp.float32)
                     for k in range(len(self.shifts)))

    def node_index(self) -> jnp.ndarray:
        idx = jnp.zeros((), jnp.int32)
        for a in self.node_axes:
            idx = idx * axis_size(a) + jax.lax.axis_index(a)
        return idx

    def vmap(self, fn):
        return fn  # already per-node under shard_map

    def node_keys(self, key):
        return jax.random.fold_in(key, self.node_index())

    def mix(self, tree, edge_mask=None):
        from repro.core import mixing as mixing_lib

        masks = (self.shift_masks(edge_mask)
                 if edge_mask is not None else None)
        if not self.use_kernels:
            return mixing_lib.mix_ppermute_shifts(
                tree, self.shifts, self.self_weight, self.axis,
                shift_masks=masks)

        from repro.kernels import ops as kernel_ops

        n_total = axis_size(self.axis)
        if masks is None:
            weights = jnp.asarray(
                [self.self_weight] + [w for _, w in self.shifts],
                jnp.float32)
        else:
            w_self, w_shift = mixing_lib.masked_shift_weights(
                self.shifts, self.self_weight, masks)
            weights = jnp.stack([w_self] + list(w_shift))

        def mix_leaf(x):
            if not self.shifts:
                return (self.self_weight * x.astype(jnp.float32)).astype(x.dtype)
            moved = [
                jax.lax.ppermute(
                    x, self.axis,
                    perm=[(src, (src + int(s)) % n_total)
                          for src in range(n_total)])
                for (s, _) in self.shifts
            ]
            return kernel_ops.gossip_mix(x, jnp.stack(moved), weights)

        return jax.tree_util.tree_map(mix_leaf, tree)

    def choco_move(self, x, y, mixed_y, gamma):
        if not self.use_kernels:
            return super().choco_move(x, y, mixed_y, gamma)
        from repro.kernels import ops as kernel_ops

        flat_x, treedef = jax.tree_util.tree_flatten(x)
        flat_y = jax.tree_util.tree_leaves(y)
        flat_my = jax.tree_util.tree_leaves(mixed_y)
        moved = [kernel_ops.choco_move(a, b, m, gamma)
                 for a, b, m in zip(flat_x, flat_y, flat_my)]
        x_new = jax.tree_util.tree_unflatten(treedef, [m[0] for m in moved])
        diff = jax.tree_util.tree_unflatten(treedef, [m[1] for m in moved])
        return x_new, diff

    def compress(self, comp, tree, key):
        from repro.core.compression import QSGD, TopK

        if not (self.use_kernels and isinstance(comp, (QSGD, TopK))):
            return super().compress(comp, tree, key)
        from repro.kernels import ops as kernel_ops

        # Same per-leaf key split and uniform noise as compression.QSGD, so
        # the kernel output is bit-identical to the library compressor
        # (tests/test_kernels.py::test_qsgd_kernel_agrees_with_library_compressor);
        # the TopK kernel path is bitwise by construction (same threshold,
        # same inclusive tie mask — see repro.kernels.topk).
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, max(len(leaves), 1))
        if isinstance(comp, TopK):
            out = [kernel_ops.top_k_compress(leaf, comp._k(leaf.size))
                   for leaf in leaves]
        else:
            out = [
                kernel_ops.qsgd_quantize(
                    leaf, jax.random.uniform(k, leaf.shape),
                    levels=comp.levels)
                for leaf, k in zip(leaves, keys)
            ]
        return jax.tree_util.tree_unflatten(treedef, out)

    def choco_step(self, comp, x, y, mixed_y, gamma, keys):
        """Fused CHOCO compress-and-move: one kernel pass per leaf emits
        (x_new, y_new) directly (``repro.kernels.choco_fused``) instead
        of the move -> compress -> add chain with its three separate
        padded buffer round-trips. Engaged for QSGD and TopK under
        ``use_kernels``; other compressors keep the unfused composition.
        RNG discipline matches ``compression.compress_tree`` exactly: one
        fold_in'ed key per leaf, uniform noise drawn per QSGD leaf (TopK
        draws nothing). Numerics vs the unfused chain (f32, under jit):
        x_new bitwise for both compressors; y_new bitwise for TopK (the
        mask reads the same materialized gap its threshold was selected
        from) and within 1 f32 ulp for QSGD (the reconstruction multiply
        chain may round differently across separately-compiled kernels —
        the quantization level picked is identical). See
        tests/test_kernels.py and docs/ARCHITECTURE.md."""
        from repro.core.compression import QSGD, TopK

        if not (self.use_kernels and isinstance(comp, (QSGD, TopK))):
            return super().choco_step(comp, x, y, mixed_y, gamma, keys)
        from repro.kernels import ops as kernel_ops

        leaves_x, treedef = jax.tree_util.tree_flatten(x)
        leaves_y = jax.tree_util.tree_leaves(y)
        leaves_my = jax.tree_util.tree_leaves(mixed_y)
        leaf_keys = jax.random.split(keys, max(len(leaves_x), 1))
        moved = []
        for lx, ly, lmy, k in zip(leaves_x, leaves_y, leaves_my, leaf_keys):
            if isinstance(comp, TopK):
                moved.append(kernel_ops.choco_topk_move(
                    lx, ly, lmy, gamma, comp._k(lx.size)))
            else:
                noise = jax.random.uniform(k, lx.shape)
                moved.append(kernel_ops.choco_qsgd_move(
                    lx, ly, lmy, gamma, noise, levels=comp.levels))
        x_new = jax.tree_util.tree_unflatten(treedef, [m[0] for m in moved])
        y_new = jax.tree_util.tree_unflatten(treedef, [m[1] for m in moved])
        return x_new, y_new

    def mean_over_nodes(self, x):
        return jax.lax.pmean(x, self.axis)

    def sum_per_node(self, x):
        return jnp.sum(x)

    def mean_tree(self, tree):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.pmean(x.astype(jnp.float32), self.axis), tree)

    def node_mask_local(self, node_mask):
        return node_mask[self.node_index()]

    def select_nodes(self, mask_local, new, old):
        keep = mask_local.astype(bool)
        return jax.tree_util.tree_map(
            lambda nw, od: jnp.where(keep, nw, od), new, old)
