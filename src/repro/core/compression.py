"""Compression operators Q for C-DFL (paper Sec. V-A).

Each operator satisfies Assumption 2:  E_Q ||Q(x) - x||^2 <= (1 - delta) ||x||^2
with compression ratio delta in (0, 1]. Operators act leaf-wise on pytrees
(each leaf treated as one vector x in R^d, matching the paper's per-model
compression) and return a *dense* array with the compression applied — the
paper's own simulation does the same; actual wire savings are accounted
analytically via ``bits_per_value`` / ``wire_bits``.

Operators implemented (paper Sec. V-A list):
  * ``TopK``            — k = ceil(frac * d) largest-magnitude coords.
  * ``RandK``           — k random coords (unbiased up to scaling; the plain
                          projected version used by CHOCO satisfies Asm. 2).
  * ``QSGD``            — random s-level quantization, rescaled (delta = 1/c).
  * ``RandomizedGossip``— Q(x) = x w.p. p else 0 (delta = p).
  * ``Identity``        — delta = 1 (plain DFL).

The QSGD and TopK hot loops have Pallas TPU kernels in ``repro.kernels``;
this module is the pure-jnp reference implementation used by the algorithm
layer (and as the kernels' oracle). ``TopK(use_kernels=True)`` (or
``make_compressor("top_k", use_kernels=True)``) routes ``__call__``
through the kernel-backed two-pass select+mask (``ops.top_k_compress``),
which is BITWISE-equal to the reference here — threshold, inclusive tie
handling and all — so flipping the flag never changes trajectories. The
sharded engine's ``use_kernels`` hot path additionally fuses compression
into the CHOCO move (``substrate.ShardedSubstrate.choco_step``); see
docs/ARCHITECTURE.md for the dispatch path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = [
    "Compressor",
    "Identity",
    "TopK",
    "RandK",
    "QSGD",
    "RandomizedGossip",
    "make_compressor",
    "compress_tree",
    "tree_wire_bits",
]


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base compression operator."""

    name: str = "identity"

    def delta(self, d: int) -> float:
        """Compression ratio delta of Assumption 2 for dimension d."""
        return 1.0

    def bits_per_value(self, d: int) -> float:
        """Average wire bits per *original* coordinate (fp32 baseline = 32)."""
        return 32.0

    def __call__(self, x: jnp.ndarray, key: Optional[jax.Array]) -> jnp.ndarray:
        return x


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    name: str = "identity"


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Keep the ceil(frac*d) largest-|.| coordinates; zero the rest.

    ``delta = k/d`` (Assumption 2 holds with equality in the worst case);
    wire cost is value + index bits per kept coordinate. ``use_kernels``
    dispatches ``__call__`` to the Pallas two-pass kernel
    (``repro.kernels.ops.top_k_compress``) — bitwise-identical output
    (same k-th-largest threshold, same inclusive tie handling), kernel
    tiling off the hot loop's critical path on TPU.
    """

    name: str = "top_k"
    frac: float = 0.5
    use_kernels: bool = False

    def _k(self, d: int) -> int:
        return max(1, int(np.ceil(self.frac * d)))

    def delta(self, d: int) -> float:
        return self._k(d) / d

    def bits_per_value(self, d: int) -> float:
        # value + index per kept coordinate.
        k = self._k(d)
        return (32.0 + np.ceil(np.log2(max(d, 2)))) * k / d

    def __call__(self, x: jnp.ndarray, key: Optional[jax.Array]) -> jnp.ndarray:
        flat = x.reshape(-1)
        k = self._k(flat.size)
        if self.use_kernels:
            from repro.kernels import ops as kernel_ops

            return kernel_ops.top_k_compress(x, k)
        # threshold = k-th largest magnitude; ties keep >= threshold (may keep
        # a few extra ties — still satisfies Assumption 2).
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
        return kept.reshape(x.shape).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """Keep k = ceil(frac*d) uniformly random coordinates."""

    name: str = "rand_k"
    frac: float = 0.5

    def _k(self, d: int) -> int:
        return max(1, int(np.ceil(self.frac * d)))

    def delta(self, d: int) -> float:
        return self._k(d) / d

    def bits_per_value(self, d: int) -> float:
        # shared PRNG seed => only values travel.
        return 32.0 * self._k(d) / d

    def __call__(self, x: jnp.ndarray, key: Optional[jax.Array]) -> jnp.ndarray:
        assert key is not None, "RandK requires a PRNG key"
        flat = x.reshape(-1)
        k = self._k(flat.size)
        scores = jax.random.uniform(key, flat.shape)
        thresh = jax.lax.top_k(scores, k)[0][-1]
        kept = jnp.where(scores >= thresh, flat, 0.0)
        return kept.reshape(x.shape).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class QSGD(Compressor):
    """Random quantization qsgd_s (paper eq. in Sec. V-A), rescaled by 1/c so
    that Assumption 2 holds with delta = 1/c, c = 1 + min(d/s^2, sqrt(d)/s).
    """

    name: str = "qsgd"
    levels: int = 16  # s

    def _c(self, d: int) -> float:
        s = float(self.levels)
        return 1.0 + min(d / (s * s), np.sqrt(d) / s)

    def delta(self, d: int) -> float:
        return 1.0 / self._c(d)

    def bits_per_value(self, d: int) -> float:
        # sign + level index per coordinate + one fp32 norm per vector.
        return 1.0 + np.ceil(np.log2(self.levels + 1)) + 32.0 / d

    def __call__(self, x: jnp.ndarray, key: Optional[jax.Array]) -> jnp.ndarray:
        assert key is not None, "QSGD requires a PRNG key"
        flat = x.reshape(-1).astype(jnp.float32)
        d = flat.size
        s = float(self.levels)
        norm = jnp.linalg.norm(flat)
        xi = jax.random.uniform(key, flat.shape)
        safe = jnp.where(norm > 0, norm, 1.0)
        lvl = jnp.floor(s * jnp.abs(flat) / safe + xi)
        q = jnp.sign(flat) * safe * lvl / (s * self._c(d))
        q = jnp.where(norm > 0, q, 0.0)
        return q.reshape(x.shape).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class RandomizedGossip(Compressor):
    """Q(x) = x with probability p else 0 (per vector); delta = p."""

    name: str = "rand_gossip"
    p: float = 0.8

    def delta(self, d: int) -> float:
        return self.p

    def bits_per_value(self, d: int) -> float:
        return 32.0 * self.p

    def __call__(self, x: jnp.ndarray, key: Optional[jax.Array]) -> jnp.ndarray:
        assert key is not None, "RandomizedGossip requires a PRNG key"
        keep = jax.random.bernoulli(key, self.p)
        return jnp.where(keep, x, jnp.zeros_like(x))


_REGISTRY = {
    "identity": Identity,
    "top_k": TopK,
    "rand_k": RandK,
    "qsgd": QSGD,
    "rand_gossip": RandomizedGossip,
}


def make_compressor(name: str, **kwargs) -> Compressor:
    """Build a registered compressor by name with its dataclass kwargs.

    Names: "identity", "top_k" (``frac``, ``use_kernels``), "rand_k"
    (``frac``), "qsgd" (``levels``), "rand_gossip" (``p``). The planner
    (``repro.planner.cost``) prices wire bits through the instance's
    ``bits_per_value``/``delta`` contracts (see docs/THEORY.md).
    """
    try:
        return _REGISTRY[name](**kwargs)
    except KeyError:
        raise ValueError(
            f"unknown compressor {name!r}; options: {sorted(_REGISTRY)}"
        ) from None


def compress_tree(comp: Compressor, tree: PyTree, key: Optional[jax.Array]) -> PyTree:
    """Apply Q leaf-wise with independent fold_in'ed keys per leaf."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = (
        [None] * len(leaves)
        if key is None
        else list(jax.random.split(key, max(len(leaves), 1)))
    )
    out = [comp(leaf, k) for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_wire_bits(comp: Compressor, tree: PyTree) -> float:
    """Total wire bits to transmit one compressed copy of ``tree``."""
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(tree):
        d = int(np.prod(leaf.shape)) if leaf.shape else 1
        total += comp.bits_per_value(d) * d
    return total
