"""Model-averaging (gossip) primitives over a stacked node axis.

Parameters of N DFL nodes are carried as pytrees whose every leaf has a
leading node dimension of size N (the paper's X_t = [w^(1) ... w^(N)],
transposed to rows). One gossip step is X <- X @ C along that axis.

Two implementations:

* ``mix_dense``     — literal matrix form (einsum over the node axis).
                      Correct for ANY doubly stochastic C. Under pjit with
                      the node axis sharded, XLA lowers this to all-gather +
                      local contraction: the paper-faithful baseline.
* ``mix_ppermute``  — exploits sparsity: for a circulant (shift-structured)
                      C, one ``jax.lax.ppermute`` per shift inside
                      ``shard_map``, i.e. neighbor-only traffic on the ICI
                      ring. The beyond-paper optimized path.

Both agree to float tolerance (tested); the dry-run roofline records the
collective-byte difference.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology
from repro.core.substrate import axis_size

PyTree = Any

__all__ = [
    "mix_dense",
    "mix_dense_power",
    "mix_ppermute_shifts",
    "masked_mixing_matrix",
    "masked_shift_weights",
    "gossip_copies_per_step",
    "mixing_bytes_per_step",
]


def _as_mixing_array(topology: Topology, dtype) -> jnp.ndarray:
    return jnp.asarray(topology.mixing, dtype=dtype)


def _edge_tables(topology: Topology) -> Tuple[np.ndarray, np.ndarray]:
    """(has_edge [N,N] bool, eidx [N,N] int32) for the canonical edge list."""
    n = topology.num_nodes
    has_edge = np.zeros((n, n), dtype=bool)
    eidx = np.zeros((n, n), dtype=np.int32)
    for e, (a, b) in enumerate(topology.edges()):
        has_edge[a, b] = has_edge[b, a] = True
        eidx[a, b] = eidx[b, a] = e
    return has_edge, eidx


def masked_mixing_matrix(
    topology: Topology, edge_mask: jnp.ndarray, dtype
) -> jnp.ndarray:
    """The runtime confusion matrix for a round with masked edges.

    ``edge_mask`` is a traced [E] 0/1 vector over ``topology.edges()``. A
    masked edge carries no gossip: its off-diagonal entries are zeroed and
    the lost weight moves onto BOTH endpoints' diagonals, so the result
    stays symmetric doubly stochastic. With all-ones masks the arithmetic
    is exact (multiply by 1.0, add 0.0) and the matrix is bitwise equal to
    ``topology.mixing`` — the participation path degrades to the plain
    round with no numerical drift.
    """
    cm = jnp.asarray(topology.mixing, dtype=dtype)
    if topology.num_edges == 0:
        return cm
    has_edge, eidx = _edge_tables(topology)
    gate = jnp.where(jnp.asarray(has_edge),
                     edge_mask.astype(dtype)[jnp.asarray(eidx)],
                     jnp.ones((), dtype))
    masked = cm * gate
    # removed[i] = sum_j C[j, i] (1 - gate[j, i]) — the weight node i no
    # longer receives, returned to its self loop.
    removed = jnp.sum(cm * (jnp.ones((), dtype) - gate), axis=0)
    return masked + jnp.diag(removed)


def mix_dense(
    params: PyTree, topology: Topology,
    edge_mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """One gossip step, X <- X C, as a dense contraction over the node axis.

    Every leaf: [N, ...] -> [N, ...] with out[i] = sum_j C[j, i] leaf[j].
    ``edge_mask`` (traced [E] over ``topology.edges()``) replaces C with
    ``masked_mixing_matrix`` — bitwise-identical at all ones.
    """
    c = topology.mixing

    def mix_leaf(x: jnp.ndarray) -> jnp.ndarray:
        # ellipsis einsum keeps the trailing-dim shardings intact (an
        # explicit reshape-to-2D here makes GSPMD all-gather whole stacked
        # weight trees — observed 200 GiB/device before this was fixed).
        dtype = jnp.promote_types(x.dtype, jnp.float32)
        if edge_mask is None:
            cm = _as_mixing_array(topology, dtype)
        else:
            cm = masked_mixing_matrix(topology, edge_mask, dtype)
        mixed = jnp.einsum("ji,j...->i...", cm, x.astype(cm.dtype))
        return mixed.astype(x.dtype)

    del c
    return jax.tree_util.tree_map(mix_leaf, params)


def mix_dense_power(params: PyTree, topology: Topology, tau2: int) -> PyTree:
    """tau2 gossip steps collapsed into one contraction with C^tau2.

    Mathematically identical to applying ``mix_dense`` tau2 times (for
    uncompressed DFL only — C-DFL must iterate because compression is
    interleaved). Saves (tau2-1) rounds of collectives: a legitimate
    beyond-paper optimization for plain DFL, recorded in §Perf.
    """
    # repro-lint: disable=no-host-coercion-of-device-scalars (tau2 is a static trace-time int here: dense_power bakes C^tau2 in, and make_round_fn rejects dynamic_taus for it)
    cpow = np.linalg.matrix_power(topology.mixing, int(tau2))
    topo_pow = Topology(
        name=f"{topology.name}^%d" % tau2,
        mixing=cpow,
        neighbors=topology.neighbors,  # unused by the dense path
        self_weights=np.diag(cpow).copy(),
    )
    return mix_dense(params, topo_pow)


def masked_shift_weights(
    shifts: Sequence[Tuple[int, float]],
    self_weight: float,
    shift_masks: Sequence[jnp.ndarray],
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, ...]]:
    """(effective self weight, per-shift effective weights) for one node.

    ``shift_masks[k]`` is this node's traced 0/1 scalar for shift k's edge.
    A masked shift contributes weight 0 and its weight returns to the self
    loop: ``w_self + sum_k w_k (1 - m_k)``. With all-ones masks each term
    is an exact ``+ 0.0`` / ``* 1.0`` so the weights are bitwise the static
    ones — the masked sparse gossip then matches the legacy path bitwise.
    """
    one = jnp.float32(1.0)
    w_self = jnp.float32(self_weight)
    for (_, w), m in zip(shifts, shift_masks):
        w_self = w_self + jnp.float32(w) * (one - m.astype(jnp.float32))
    eff = tuple(jnp.float32(w) * m.astype(jnp.float32)
                for (_, w), m in zip(shifts, shift_masks))
    return w_self, eff


def mix_ppermute_shifts(
    params: PyTree,
    shifts: Sequence[Tuple[int, float]],
    self_weight: float,
    axis_name: str | Tuple[str, ...],
    shift_masks: Optional[Sequence[jnp.ndarray]] = None,
) -> PyTree:
    """One gossip step for a circulant C, inside shard_map.

    Must be called from within a ``shard_map`` whose mesh axis ``axis_name``
    enumerates the nodes and over which every leaf is sharded to a single
    node per device slice (leading node dim of local size 1).

    shifts: [(s, w)] meaning node i receives weight w from node (i - s) mod N
    (equivalently sends to i + s). self_weight: diagonal of C. An empty
    shift list is the degenerate no-edge topology (C = I): no traffic, every
    node keeps self_weight (= 1) of itself.

    shift_masks: optional per-shift traced 0/1 scalars for THIS node (one
    per entry of ``shifts``, gathered from the round's edge mask by the
    substrate). The ppermutes still run on every shift — masking gates the
    accumulation weight, not the collective, so the compiled HLO (and the
    ``collective-matching`` audit) is identical across masks and the
    superstep never recompiles.
    """
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    n_total = axis_size(names)

    if shift_masks is not None:
        assert len(shift_masks) == len(shifts)
        w_self, w_shift = masked_shift_weights(shifts, self_weight,
                                               shift_masks)
    else:
        w_self, w_shift = self_weight, tuple(w for (_, w) in shifts)

    def perm_for(shift: int):
        return [(src, (src + shift) % n_total) for src in range(n_total)]

    def mix_leaf(x: jnp.ndarray) -> jnp.ndarray:
        acc = (w_self * x.astype(jnp.float32))
        for (s, _), w in zip(shifts, w_shift):
            moved = jax.lax.ppermute(x, names if len(names) > 1 else names[0],
                                     perm=perm_for(int(s)))
            acc = acc + w * moved.astype(jnp.float32)
        return acc.astype(x.dtype)

    return jax.tree_util.tree_map(mix_leaf, params)


def gossip_copies_per_step(topology: Topology, engine: str) -> int:
    """Model copies each node RECEIVES per gossip step — THE accounting
    helper; every wire-cost number in the repo derives from it.

    engine:
      "sparse" — per-neighbor traffic (the ppermute engine, and what a real
                 network deployment ships): max_degree copies.
      "dense"  — the dense einsum's all-gather lowering: N - 1 copies,
                 regardless of how sparse C itself is.
      "auto"   — whichever engine the launcher would select for this
                 topology (sparse iff shift-structured).
    """
    if engine == "auto":
        engine = "sparse" if topology.is_shift_structured() else "dense"
    if engine == "sparse":
        return topology.max_degree
    if engine == "dense":
        return max(topology.num_nodes - 1, 0)
    raise ValueError(f"unknown engine {engine!r}")


def mixing_bytes_per_step(
    topology: Topology, param_bytes: int, sparse: bool
) -> int:
    """Bytes on the wire per node per gossip step (analytic accounting).

    dense (all-gather lowering): every node receives the other N-1 models.
    sparse (ppermute): every node receives deg models.
    """
    engine = "sparse" if sparse else "dense"
    return gossip_copies_per_step(topology, engine) * param_bytes
