"""Model-averaging (gossip) primitives over a stacked node axis.

Parameters of N DFL nodes are carried as pytrees whose every leaf has a
leading node dimension of size N (the paper's X_t = [w^(1) ... w^(N)],
transposed to rows). One gossip step is X <- X @ C along that axis.

Two implementations:

* ``mix_dense``     — literal matrix form (einsum over the node axis).
                      Correct for ANY doubly stochastic C. Under pjit with
                      the node axis sharded, XLA lowers this to all-gather +
                      local contraction: the paper-faithful baseline.
* ``mix_ppermute``  — exploits sparsity: for a circulant (shift-structured)
                      C, one ``jax.lax.ppermute`` per shift inside
                      ``shard_map``, i.e. neighbor-only traffic on the ICI
                      ring. The beyond-paper optimized path.

Both agree to float tolerance (tested); the dry-run roofline records the
collective-byte difference.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology
from repro.core.substrate import axis_size

PyTree = Any

__all__ = [
    "mix_dense",
    "mix_dense_power",
    "mix_ppermute_shifts",
    "gossip_copies_per_step",
    "mixing_bytes_per_step",
]


def _as_mixing_array(topology: Topology, dtype) -> jnp.ndarray:
    return jnp.asarray(topology.mixing, dtype=dtype)


def mix_dense(params: PyTree, topology: Topology) -> PyTree:
    """One gossip step, X <- X C, as a dense contraction over the node axis.

    Every leaf: [N, ...] -> [N, ...] with out[i] = sum_j C[j, i] leaf[j].
    """
    c = topology.mixing

    def mix_leaf(x: jnp.ndarray) -> jnp.ndarray:
        # ellipsis einsum keeps the trailing-dim shardings intact (an
        # explicit reshape-to-2D here makes GSPMD all-gather whole stacked
        # weight trees — observed 200 GiB/device before this was fixed).
        cm = _as_mixing_array(topology, jnp.promote_types(x.dtype, jnp.float32))
        mixed = jnp.einsum("ji,j...->i...", cm, x.astype(cm.dtype))
        return mixed.astype(x.dtype)

    del c
    return jax.tree_util.tree_map(mix_leaf, params)


def mix_dense_power(params: PyTree, topology: Topology, tau2: int) -> PyTree:
    """tau2 gossip steps collapsed into one contraction with C^tau2.

    Mathematically identical to applying ``mix_dense`` tau2 times (for
    uncompressed DFL only — C-DFL must iterate because compression is
    interleaved). Saves (tau2-1) rounds of collectives: a legitimate
    beyond-paper optimization for plain DFL, recorded in §Perf.
    """
    # repro-lint: disable=no-host-coercion-of-device-scalars (tau2 is a static trace-time int here: dense_power bakes C^tau2 in, and make_round_fn rejects dynamic_taus for it)
    cpow = np.linalg.matrix_power(topology.mixing, int(tau2))
    topo_pow = Topology(
        name=f"{topology.name}^%d" % tau2,
        mixing=cpow,
        neighbors=topology.neighbors,  # unused by the dense path
        self_weights=np.diag(cpow).copy(),
    )
    return mix_dense(params, topo_pow)


def mix_ppermute_shifts(
    params: PyTree,
    shifts: Sequence[Tuple[int, float]],
    self_weight: float,
    axis_name: str | Tuple[str, ...],
) -> PyTree:
    """One gossip step for a circulant C, inside shard_map.

    Must be called from within a ``shard_map`` whose mesh axis ``axis_name``
    enumerates the nodes and over which every leaf is sharded to a single
    node per device slice (leading node dim of local size 1).

    shifts: [(s, w)] meaning node i receives weight w from node (i - s) mod N
    (equivalently sends to i + s). self_weight: diagonal of C. An empty
    shift list is the degenerate no-edge topology (C = I): no traffic, every
    node keeps self_weight (= 1) of itself.
    """
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    n_total = axis_size(names)

    def perm_for(shift: int):
        return [(src, (src + shift) % n_total) for src in range(n_total)]

    def mix_leaf(x: jnp.ndarray) -> jnp.ndarray:
        acc = (self_weight * x.astype(jnp.float32))
        for (s, w) in shifts:
            moved = jax.lax.ppermute(x, names if len(names) > 1 else names[0],
                                     perm=perm_for(int(s)))
            acc = acc + w * moved.astype(jnp.float32)
        return acc.astype(x.dtype)

    return jax.tree_util.tree_map(mix_leaf, params)


def gossip_copies_per_step(topology: Topology, engine: str) -> int:
    """Model copies each node RECEIVES per gossip step — THE accounting
    helper; every wire-cost number in the repo derives from it.

    engine:
      "sparse" — per-neighbor traffic (the ppermute engine, and what a real
                 network deployment ships): max_degree copies.
      "dense"  — the dense einsum's all-gather lowering: N - 1 copies,
                 regardless of how sparse C itself is.
      "auto"   — whichever engine the launcher would select for this
                 topology (sparse iff shift-structured).
    """
    if engine == "auto":
        engine = "sparse" if topology.is_shift_structured() else "dense"
    if engine == "sparse":
        return topology.max_degree
    if engine == "dense":
        return max(topology.num_nodes - 1, 0)
    raise ValueError(f"unknown engine {engine!r}")


def mixing_bytes_per_step(
    topology: Topology, param_bytes: int, sparse: bool
) -> int:
    """Bytes on the wire per node per gossip step (analytic accounting).

    dense (all-gather lowering): every node receives the other N-1 models.
    sparse (ppermute): every node receives deg models.
    """
    engine = "sparse" if sparse else "dense"
    return gossip_copies_per_step(topology, engine) * param_bytes
