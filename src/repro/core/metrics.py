"""Consensus/communication analytics used by the paper's illustrations.

Fig. 2/3 of the paper track, at a given node, the *coefficients* that each
initial parameter w_1..w_N contributes after t gossip steps — i.e. the
node's column of C^t — and show their variance decaying monotonically
(Proposition 1's mechanism). These are trace-time NumPy utilities.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.topology import Topology

__all__ = [
    "coefficient_variance_trajectory",
    "consensus_error_trajectory",
    "rounds_to_consensus",
    "comm_compute_cost",
]


def coefficient_variance_trajectory(
    topology: Topology, node: int, steps: int
) -> np.ndarray:
    """Variance across nodes of column ``node`` of C^t for t = 0..steps.

    Reproduces Fig. 3: monotone decay toward 0 (consensus = uniform 1/N).
    """
    c = topology.mixing
    n = c.shape[0]
    col = np.eye(n)[:, node]
    out = []
    for _ in range(steps + 1):
        out.append(float(np.var(col)))
        col = c.T @ col
    return np.asarray(out)


def consensus_error_trajectory(topology: Topology, steps: int) -> np.ndarray:
    """||C^t - J||_2 = zeta^t for t = 0..steps (Lemma 7)."""
    n = topology.num_nodes
    j = np.full((n, n), 1.0 / n)
    c_t = np.eye(n)
    out = []
    for _ in range(steps + 1):
        out.append(float(np.linalg.norm(c_t - j, ord=2)))
        c_t = c_t @ topology.mixing
    return np.asarray(out)


def rounds_to_consensus(topology: Topology, eps: float = 1e-2) -> int:
    """Smallest t with zeta^t <= eps (analytic, from Lemma 7)."""
    z = topology.zeta
    if z <= 0:
        return 1
    if z >= 1:
        return -1  # never
    return int(np.ceil(np.log(eps) / np.log(z)))


def comm_compute_cost(
    tau1: int,
    tau2: int,
    rounds: int,
    *,
    step_flops: float,
    model_bytes: float,
    degree: int,
    flops_per_s: float,
    link_bytes_per_s: float,
    bits_per_value_ratio: float = 1.0,
) -> Dict[str, float]:
    """DEPRECATED shim: use ``repro.planner.cost.comm_compute_cost``.

    The analytic time model for the paper's 'balancing' trade-off
    (total time = rounds * (tau1 * t_compute + tau2 * t_comm), t_comm =
    degree * model_bytes * bits_ratio / link_bw) moved into the planner
    subsystem, which generalizes it to topology-aware, per-engine,
    per-compressor ``CostModel`` objects. This wrapper delegates and will
    be removed once no caller remains.

    Example: step_flops=1e9, model_bytes=4e6, degree=2, flops_per_s=1e12,
    link_bytes_per_s=1e9 gives t_compute=1e-3 s, t_comm=8e-3 s.
    """
    import warnings

    warnings.warn(
        "repro.core.metrics.comm_compute_cost is deprecated; use "
        "repro.planner.cost.comm_compute_cost (or planner.cost.CostModel)",
        DeprecationWarning, stacklevel=2)
    from repro.planner.cost import comm_compute_cost as _planner_cost

    return _planner_cost(
        tau1, tau2, rounds, step_flops=step_flops, model_bytes=model_bytes,
        degree=degree, flops_per_s=flops_per_s,
        link_bytes_per_s=link_bytes_per_s,
        bits_per_value_ratio=bits_per_value_ratio)
