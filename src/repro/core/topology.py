"""Gossip topologies and their confusion (mixing) matrices.

The paper (Sec. II, Assumption 1.6) requires a doubly-stochastic, symmetric
confusion matrix C whose second-largest-magnitude eigenvalue
``zeta = max{|lambda_2|, |lambda_N|} < 1``. This module constructs the
standard graph families used in the paper (ring, quasi-ring, fully connected)
plus the families natural to a TPU mesh (torus, hypercube) and exposes the
spectral quantities the theory needs (zeta, beta = ||I - C||_2, spectral gap
rho = 1 - zeta).

All matrices are small (N x N with N = #DFL nodes, typically 10..32) and are
built in NumPy at trace time; they enter jitted code as constants.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = [
    "Topology",
    "ring",
    "quasi_ring",
    "fully_connected",
    "disconnected",
    "torus",
    "hypercube",
    "star",
    "from_adjacency",
    "paper_quasi_ring",
    "zeta",
    "beta",
    "spectral_gap",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gossip topology: confusion matrix + sparse neighbor structure.

    Attributes:
      name: human-readable family name.
      mixing: (N, N) float64 doubly-stochastic symmetric confusion matrix C.
        ``mixing[j, i]`` is the contribution of node j to the average at
        node i (paper's c_ji).
      neighbors: for each node i, the list of (j, weight) pairs with
        nonzero C[j, i], EXCLUDING the self entry. Used by the sparse
        ppermute mixing path.
      self_weights: (N,) diagonal of C.
    """

    name: str
    mixing: np.ndarray
    neighbors: Tuple[Tuple[Tuple[int, float], ...], ...]
    self_weights: np.ndarray

    @property
    def num_nodes(self) -> int:
        return self.mixing.shape[0]

    @property
    def zeta(self) -> float:
        return zeta(self.mixing)

    @property
    def beta(self) -> float:
        return beta(self.mixing)

    @property
    def spectral_gap(self) -> float:
        return spectral_gap(self.mixing)

    @property
    def max_degree(self) -> int:
        return max((len(n) for n in self.neighbors), default=0)

    def is_shift_structured(self) -> bool:
        """True if every node's neighbor set is {i+s mod N} for a common set
        of shifts with shift-invariant weights (circulant C). Such topologies
        lower to one ``ppermute`` per shift on a TPU ring, and are exactly
        the ones the sparse engine (``core.sharded``) accepts — this
        predicate is THE engine-eligibility test, so it must agree with
        ``shifts()``: non-empty shifts, or the explicit degenerate no-edge
        case C = I (zero shifts — a doubly stochastic matrix with no
        off-diagonal mass is the identity), where the sparse engine's gossip
        is a no-op rather than an error."""
        if self.num_nodes == 0:
            return False
        if self.max_degree == 0:
            return bool(np.allclose(self.mixing,
                                    np.eye(self.num_nodes), atol=1e-12))
        return len(self.shifts()) > 0

    def edges(self) -> Tuple[Tuple[int, int], ...]:
        """Canonical undirected edge list: sorted (i, j) pairs with i < j.

        This ordering is THE edge enumeration contract for participation
        masks: ``edge_mask[e]`` in ``round_body`` / ``FaultPlan`` refers to
        ``edges()[e]``, and both directions of an undirected edge share the
        one mask entry (masking is symmetric, so the confusion matrix stays
        symmetric doubly stochastic after renormalization).
        """
        out = set()
        for i, nbrs in enumerate(self.neighbors):
            for (j, _) in nbrs:
                out.add((min(i, j), max(i, j)))
        return tuple(sorted(out))

    @property
    def num_edges(self) -> int:
        return len(self.edges())

    def edge_index(self) -> Dict[Tuple[int, int], int]:
        """Map (i, j) with i < j -> position in ``edges()``."""
        return {e: k for k, e in enumerate(self.edges())}

    def shifts(self) -> List[Tuple[int, float]]:
        """Common (shift, weight) structure if C is circulant, else []."""
        n = self.num_nodes
        if n == 0:
            return []
        base: Dict[int, float] = {}
        for (j, w) in self.neighbors[0]:
            base[(j - 0) % n] = w
        for i in range(1, n):
            cur: Dict[int, float] = {}
            for (j, w) in self.neighbors[i]:
                cur[(j - i) % n] = w
            if set(cur) != set(base):
                return []
            for s, w in cur.items():
                if abs(w - base[s]) > 1e-12:
                    return []
        return sorted(base.items())

    def validate(self) -> None:
        c = self.mixing
        n = c.shape[0]
        assert c.shape == (n, n), "C must be square"
        assert np.allclose(c, c.T, atol=1e-12), "C must be symmetric"
        assert np.allclose(c.sum(axis=0), 1.0, atol=1e-10), "C must be stochastic"
        assert (c >= -1e-12).all(), "C must be nonnegative"


def _neighbors_from_matrix(c: np.ndarray) -> Tuple[Tuple[Tuple[int, float], ...], ...]:
    n = c.shape[0]
    out: List[Tuple[Tuple[int, float], ...]] = []
    for i in range(n):
        row = tuple(
            (j, float(c[j, i])) for j in range(n) if j != i and c[j, i] > 1e-15
        )
        out.append(row)
    return tuple(out)


def _make(name: str, c: np.ndarray) -> Topology:
    c = np.asarray(c, dtype=np.float64)
    topo = Topology(
        name=name,
        mixing=c,
        neighbors=_neighbors_from_matrix(c),
        self_weights=np.diag(c).copy(),
    )
    topo.validate()
    return topo


def from_adjacency(name: str, adj: np.ndarray, scheme: str = "uniform") -> Topology:
    """Build a doubly stochastic C from a 0/1 symmetric adjacency matrix.

    scheme:
      "uniform"    — node i averages itself and its neighbors with equal
                     weight 1/(deg_max+1) and keeps the remainder on the
                     diagonal (lazy Metropolis with global max degree; always
                     doubly stochastic for symmetric adj).
      "metropolis" — Metropolis-Hastings weights 1/(1+max(deg_i, deg_j)).
    """
    adj = np.asarray(adj)
    n = adj.shape[0]
    assert (adj == adj.T).all(), "adjacency must be symmetric"
    assert (np.diag(adj) == 0).all(), "no self loops in adjacency"
    deg = adj.sum(axis=1)
    c = np.zeros((n, n), dtype=np.float64)
    if scheme == "uniform":
        dmax = max(int(deg.max()), 1)
        w = 1.0 / (dmax + 1)
        c = adj * w
        np.fill_diagonal(c, 1.0 - c.sum(axis=1))
    elif scheme == "metropolis":
        for i in range(n):
            for j in range(n):
                if adj[i, j]:
                    c[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        np.fill_diagonal(c, 1.0 - c.sum(axis=1))
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return _make(name, c)


def ring(n: int) -> Topology:
    """Ring of n nodes; each node averages itself + 2 neighbors with 1/3.

    This is the paper's main experimental topology (Fig. 6 left; with n=10,
    zeta = (1 + 2 cos(2 pi/10)) / 3 ~= 0.873, matching the paper's 0.87).
    """
    assert n >= 2
    adj = np.zeros((n, n), dtype=np.int64)
    for i in range(n):
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = 1
    return from_adjacency(f"ring-{n}", adj)


def quasi_ring(n: int, chords: Sequence[Tuple[int, int]] = ()) -> Topology:
    """Ring plus chord edges (paper Fig. 6 right adds shortcuts to the ring;
    with one chord on a 10-ring zeta drops to ~0.85 as the paper reports).

    Default chord set for even n: one diameter chord (0, n//2).
    """
    assert n >= 4
    adj = np.zeros((n, n), dtype=np.int64)
    for i in range(n):
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = 1
    use = list(chords) if chords else [(0, n // 2)]
    for (a, b) in use:
        adj[a % n, b % n] = adj[b % n, a % n] = 1
    return from_adjacency(f"quasi-ring-{n}", adj)


def paper_quasi_ring() -> Topology:
    """The 10-node quasi-ring calibrated to the paper's reported zeta = 0.85.

    The paper (Sec. VI-A / Fig. 6 right) states zeta = 0.85 for its
    quasi-ring but does not give the exact weights. We take the 10-ring with
    1/3 edge weights plus two diameter-ish chords (0,5), (2,7) whose weight
    w* ~= 0.0447 is bisected so that zeta = 0.8500 exactly (see
    tests/test_topology.py).
    """
    n = 10
    w = 0.04469696969697019
    c = np.zeros((n, n))
    for i in range(n):
        c[i, (i + 1) % n] = c[(i + 1) % n, i] = 1.0 / 3.0
    for (a, b) in ((0, 5), (2, 7)):
        c[a, b] = c[b, a] = w
    for i in range(n):
        c[i, i] = 1.0 - c[i].sum()
    return _make("paper-quasi-ring-10", c)


def fully_connected(n: int) -> Topology:
    """C = J: perfect averaging in one step (zeta = 0). Paper's synchronous
    SGD benchmark (Corollary 2)."""
    c = np.full((n, n), 1.0 / n)
    return _make(f"full-{n}", c)


def disconnected(n: int) -> Topology:
    """C = I: no communication at all (zeta = 1, worst case of Remark 2)."""
    return _make(f"disconnected-{n}", np.eye(n))


def torus(rows: int, cols: int) -> Topology:
    """2-D torus matching a TPU ICI mesh slice; 4 neighbors per node."""
    n = rows * cols
    adj = np.zeros((n, n), dtype=np.int64)

    def idx(r: int, c: int) -> int:
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            i = idx(r, c)
            for (dr, dc) in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                j = idx(r + dr, c + dc)
                if i != j:
                    adj[i, j] = adj[j, i] = 1
    return from_adjacency(f"torus-{rows}x{cols}", adj)


def hypercube(dim: int) -> Topology:
    """2^dim nodes; neighbors differ in one bit. log-diameter gossip."""
    n = 1 << dim
    adj = np.zeros((n, n), dtype=np.int64)
    for i in range(n):
        for b in range(dim):
            j = i ^ (1 << b)
            adj[i, j] = adj[j, i] = 1
    return from_adjacency(f"hypercube-{dim}", adj)


def star(n: int) -> Topology:
    """Hub-and-spoke (centralized FL's implicit topology, for comparison)."""
    assert n >= 2
    adj = np.zeros((n, n), dtype=np.int64)
    for i in range(1, n):
        adj[0, i] = adj[i, 0] = 1
    return from_adjacency(f"star-{n}", adj)


def zeta(c: np.ndarray) -> float:
    """max{|lambda_2|, |lambda_N|}: the paper's mixing parameter."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(np.asarray(c, dtype=np.float64))))
    if len(ev) < 2:
        return 0.0
    return float(ev[-2])


def beta(c: np.ndarray) -> float:
    """||I - C||_2 in [0, 2] (Assumption 1.6)."""
    c = np.asarray(c, dtype=np.float64)
    return float(np.linalg.norm(np.eye(c.shape[0]) - c, ord=2))


def spectral_gap(c: np.ndarray) -> float:
    """rho = 1 - zeta in (0, 1] (used by C-DFL's Prop. 2)."""
    return 1.0 - zeta(c)
