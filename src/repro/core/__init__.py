"""Core DFL library: the paper's contribution as composable JAX modules."""
from repro.core.topology import (
    Topology,
    ring,
    quasi_ring,
    paper_quasi_ring,
    fully_connected,
    disconnected,
    torus,
    hypercube,
    star,
    from_adjacency,
    zeta,
    beta,
    spectral_gap,
)
from repro.core.compression import (
    Compressor,
    Identity,
    TopK,
    RandK,
    QSGD,
    RandomizedGossip,
    make_compressor,
    compress_tree,
    tree_wire_bits,
)
from repro.core.dfl import (
    DFLConfig,
    DFLState,
    d_sgd_config,
    c_sgd_config,
    sync_sgd_config,
    replicate,
    average_model,
    consensus_distance,
    init_state,
    make_round_fn,
    round_wire_bits,
    sparse_engine_eligible,
)
from repro.core.executor import (
    HostPrefetcher,
    MetricsBuffer,
    RoundExecutor,
    stack_round_batches,
)
from repro.core.substrate import (
    BatchedSubstrate,
    DenseSubstrate,
    NodeSubstrate,
    ShardedSubstrate,
)
from repro.core import mixing, metrics, substrate

__all__ = [
    "Topology", "ring", "quasi_ring", "paper_quasi_ring", "fully_connected", "disconnected",
    "torus", "hypercube", "star", "from_adjacency", "zeta", "beta",
    "spectral_gap",
    "Compressor", "Identity", "TopK", "RandK", "QSGD", "RandomizedGossip",
    "make_compressor", "compress_tree", "tree_wire_bits",
    "DFLConfig", "DFLState", "d_sgd_config", "c_sgd_config",
    "sync_sgd_config", "replicate", "average_model", "consensus_distance",
    "init_state", "make_round_fn", "round_wire_bits",
    "sparse_engine_eligible",
    "RoundExecutor", "HostPrefetcher", "MetricsBuffer",
    "stack_round_batches",
    "NodeSubstrate", "DenseSubstrate", "BatchedSubstrate", "ShardedSubstrate",
    "mixing", "metrics", "substrate",
]
