"""Production sharded DFL round: node axis manual, model axes auto.

``make_sharded_round_fn`` builds the sparse engine behind
``core.dfl.make_round_fn(..., engine="sparse")``: each DFL node's local
updates run as ordinary (GSPMD-partitioned) JAX under a ``shard_map`` that
is manual ONLY over the node mesh axes; the gossip stage is per-neighbor
``collective-permute`` (ring traffic = deg copies instead of the dense
path's N-1-copy all-gather). Supports plain DFL and CHOCO-G C-DFL
(compression applied node-locally, neighbor estimates fetched by ppermute —
equivalent to Alg. 2's replicated w_hat bookkeeping), plus the Pallas
kernel hot path (``use_kernels=True``: kernel gossip accumulate and the
FUSED CHOCO compress-and-move for QSGD/TopK via
``ShardedSubstrate.choco_step`` — dispatch rules in
``repro.kernels.registry``, path diagram in docs/ARCHITECTURE.md).

This module owns ONLY the shard_map plumbing (specs, squeeze/unsqueeze of
the local node dim). The round itself — local-update scan, CHOCO step, RNG
folding, metrics — is ``core.dfl.round_body`` running on a
``ShardedSubstrate``, i.e. the exact same code the dense engine executes,
which is what keeps the engines from drifting apart again.

Engine selection rule (applied by ``launch.steps`` / ``launch.train`` when
engine="auto"): sparse iff ``cfg.topology.is_shift_structured()`` (circulant
C: ring/torus rows of the mesh; includes the degenerate no-edge C = I),
no dense-only features (schedules, dense_power), and the node mesh axes
enumerate exactly the N > 1 nodes. The dense engine (``core.dfl``) remains
the general-topology path and the numerical reference
(tests/test_multidevice.py checks they agree bit-for-bit-ish, compressed
and uncompressed). Supported JAX: 0.4.37 (pinned) and newer, via
``repro.core.substrate``.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import substrate as substrate_lib
from repro.core.dfl import (DFLConfig, DFLState, pipeline_drain_body,
                            pipeline_round_body, round_body)
from repro.core.substrate import ShardedSubstrate

PyTree = Any


def make_sharded_round_fn(
    cfg: DFLConfig,
    loss_fn: Callable,
    opt,
    mesh,
    *,
    node_axes: Sequence[str] = ("data",),
    use_kernels: bool = False,
    dynamic_taus: bool = False,
    participation: bool = False,
    constrain=None,
) -> Callable[..., Tuple[DFLState, dict]]:
    """Sparse-gossip round; call under jax.jit. State leaves carry the
    stacked node dim sharded over ``node_axes`` (local size 1).

    ``dynamic_taus``: round_fn(state, batches, tau1, tau2) with replicated
    int32 step-count scalars riding through the shard_map boundary;
    cfg.tau1/cfg.tau2 are the compiled maxima (see core.dfl.make_round_fn).
    The trip counts are identical on every node shard — whether broadcast
    from two device scalars or sliced per round from a [K, 2] trajectory
    scanned as xs (``core.executor.dispatch_trajectory``) — so the
    per-shift ppermutes inside the dynamic loops stay collectively matched.

    ``participation``: round_fn(state, batches, tau1, tau2, node_mask,
    edge_mask) — the masks ride through the shard_map boundary REPLICATED
    (P()), like the tau scalars: every node sees the full [N]/[E] vectors
    and takes its local view via ``ShardedSubstrate.node_mask_local`` /
    ``shift_masks``. The ppermutes still run on masked edges (masks gate
    accumulation weights, not collectives), so the program stays
    collectively matched and mask changes never retrace.

    ``constrain``: the dense engine's stacked-param sharding re-assertion.
    The sparse engine cannot honor it on its auto (GSPMD) axes — the specs
    name the manual node axes, and shard_map strips those — so a mesh with
    a >1-sized auto axis RAISES here rather than silently dropping the
    constraint (the silent drop was only ever safe because such meshes
    fall back to dense on the pinned jaxlib; see ROADMAP). Size-1 auto
    axes carry nothing to re-assert, so the argument is accepted and
    ignored there.
    """
    from jax.sharding import PartitionSpec as P

    topo = cfg.topology
    if constrain is not None:
        unconstrained = [a for a in mesh.axis_names
                        if a not in node_axes and mesh.shape[a] > 1]
        if unconstrained:
            raise NotImplementedError(
                "the sparse engine drops the `constrain` sharding "
                f"re-assertion on its auto (GSPMD) mesh axes "
                f"{unconstrained}: GSPMD may then resolve scan carries / "
                "vmapped grads to replicated and all-gather entire stacked "
                "weight trees (core.dfl._local_updates). Use the dense "
                "engine on tensor-parallel meshes, or teach "
                "ShardedSubstrate an auto-axis constrain first.")
    assert topo.is_shift_structured(), (
        f"{topo.name} is not circulant; use the dense engine "
        "(core.dfl.make_round_fn) for arbitrary topologies")
    mesh_n = substrate_lib.mesh_axis_size(mesh, tuple(node_axes))
    assert mesh_n == topo.num_nodes, (
        f"node mesh axes {tuple(node_axes)} enumerate {mesh_n} devices but "
        f"{topo.name} has {topo.num_nodes} nodes — the size-1-per-node "
        "shard_map layout would silently drop nodes")
    node_entry = tuple(node_axes) if len(node_axes) > 1 else node_axes[0]
    state_specs = DFLState(
        params=P(node_entry),
        opt_state=P(node_entry),
        hat_params=P(node_entry) if cfg.is_compressed else None,
        rng=P(),
        round_idx=P(),
    )
    batch_spec = P(None, node_entry)

    def body(state: DFLState, batches: PyTree, taus=None, masks=None):
        # local leaves: params [1, ...]; batches [tau1, 1, B, ...]
        squeeze = lambda t: jax.tree_util.tree_map(lambda x: x[0], t)
        unsqueeze = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)
        sub = ShardedSubstrate(topo, node_axes, use_kernels=use_kernels)
        params, opt_state, hat, metrics = round_body(
            cfg, loss_fn, opt, sub,
            squeeze(state.params),
            squeeze(state.opt_state),
            squeeze(state.hat_params) if cfg.is_compressed else None,
            state.rng, state.round_idx,
            # drop the local (size-1) node dim, keeping the leading tau1 dim
            jax.tree_util.tree_map(lambda x: x[:, 0], batches),
            taus=taus, masks=masks)
        new_state = DFLState(
            params=unsqueeze(params),
            opt_state=unsqueeze(opt_state),
            hat_params=unsqueeze(hat) if cfg.is_compressed else None,
            rng=None,  # typed key re-attached outside (see below)
            round_idx=state.round_idx + 1,
        )
        return new_state, metrics

    # The base PRNG key never advances (the folding discipline derives all
    # keys from round_idx), so it is NOT returned through the shard_map
    # boundary: XLA rejects partially-manual shardings on the typed key's
    # trailing u32[2] layout. It rides through as None and is re-attached.
    out_specs = (state_specs._replace(rng=None), P())

    if participation:
        assert dynamic_taus, (
            "participation masks ride the dynamic schedule-as-data path")
        mapped = substrate_lib.shard_map(
            lambda st, b, t1, t2, nm, em: body(st, b, (t1, t2), (nm, em)),
            mesh, (state_specs, batch_spec, P(), P(), P(), P()), out_specs,
            manual_axes=tuple(node_axes), check=False)

        def round_fn(state: DFLState, batches: PyTree, tau1, tau2,
                     node_mask, edge_mask):
            new_state, metrics = mapped(
                state, batches, jnp.asarray(tau1, jnp.int32),
                jnp.asarray(tau2, jnp.int32),
                jnp.asarray(node_mask, jnp.int32),
                jnp.asarray(edge_mask, jnp.int32))
            return new_state._replace(rng=state.rng), metrics

        return round_fn

    if dynamic_taus:
        mapped = substrate_lib.shard_map(
            lambda st, b, t1, t2: body(st, b, (t1, t2)),
            mesh, (state_specs, batch_spec, P(), P()), out_specs,
            manual_axes=tuple(node_axes), check=False)

        def round_fn(state: DFLState, batches: PyTree, tau1, tau2):
            new_state, metrics = mapped(
                state, batches, jnp.asarray(tau1, jnp.int32),
                jnp.asarray(tau2, jnp.int32))
            return new_state._replace(rng=state.rng), metrics

        return round_fn

    mapped = substrate_lib.shard_map(
        body, mesh, (state_specs, batch_spec), out_specs,
        manual_axes=tuple(node_axes), check=False)

    def round_fn(state: DFLState, batches: PyTree):
        new_state, metrics = mapped(state, batches)
        return new_state._replace(rng=state.rng), metrics

    return round_fn


def make_sharded_pipeline_fns(
    cfg: DFLConfig,
    loss_fn: Callable,
    opt,
    mesh,
    *,
    node_axes: Sequence[str] = ("data",),
    use_kernels: bool = False,
    participation: bool = False,
    constrain=None,
):
    """Sparse-engine pipelined-round pair behind
    ``core.dfl.make_pipeline_fns(..., engine="sparse")`` — the shard_map
    plumbing for ``pipeline_round_body`` / ``pipeline_drain_body``
    (signatures documented there). The in-flight gossip buffer ``buf`` is a
    params-like tree sharded over the node axes; ``have`` / ``prev_tau2``
    and the masks ride REPLICATED (P()) exactly like the dynamic round
    path's tau scalars, so the stale exchange's per-shift ppermutes stay
    collectively matched on every scan iteration (including the discarded
    first one). The base key rides through as None and is re-attached, as
    in ``make_sharded_round_fn``.
    """
    from jax.sharding import PartitionSpec as P

    topo = cfg.topology
    if constrain is not None:
        unconstrained = [a for a in mesh.axis_names
                        if a not in node_axes and mesh.shape[a] > 1]
        if unconstrained:
            raise NotImplementedError(
                "the sparse engine drops the `constrain` sharding "
                f"re-assertion on its auto (GSPMD) mesh axes "
                f"{unconstrained} (see make_sharded_round_fn)")
    assert topo.is_shift_structured(), (
        f"{topo.name} is not circulant; use the dense engine "
        "(core.dfl.make_pipeline_fns) for arbitrary topologies")
    mesh_n = substrate_lib.mesh_axis_size(mesh, tuple(node_axes))
    assert mesh_n == topo.num_nodes, (
        f"node mesh axes {tuple(node_axes)} enumerate {mesh_n} devices but "
        f"{topo.name} has {topo.num_nodes} nodes")
    node_entry = tuple(node_axes) if len(node_axes) > 1 else node_axes[0]
    state_specs = DFLState(
        params=P(node_entry),
        opt_state=P(node_entry),
        hat_params=P(node_entry) if cfg.is_compressed else None,
        rng=P(),
        round_idx=P(),
    )
    buf_spec = P(node_entry)
    batch_spec = P(None, node_entry)
    squeeze = lambda t: jax.tree_util.tree_map(lambda x: x[0], t)
    unsqueeze = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)

    def pipe_body(state: DFLState, buf, have, prev_tau2, batches, tau1,
                  node_mask=None, prev_edge_mask=None):
        sub = ShardedSubstrate(topo, node_axes, use_kernels=use_kernels)
        params, opt_state, hat, z, metrics = pipeline_round_body(
            cfg, loss_fn, opt, sub,
            squeeze(state.params),
            squeeze(state.opt_state),
            squeeze(state.hat_params) if cfg.is_compressed else None,
            state.rng, state.round_idx,
            squeeze(buf), have, tau1, prev_tau2,
            jax.tree_util.tree_map(lambda x: x[:, 0], batches),
            constrain, node_mask=node_mask, prev_edge_mask=prev_edge_mask)
        new_state = DFLState(
            params=unsqueeze(params),
            opt_state=unsqueeze(opt_state),
            hat_params=unsqueeze(hat) if cfg.is_compressed else None,
            rng=None,  # typed key re-attached outside (see round path)
            round_idx=state.round_idx + 1,
        )
        return new_state, unsqueeze(z), metrics

    def drain_body(state: DFLState, buf, prev_tau2, prev_edge_mask=None):
        sub = ShardedSubstrate(topo, node_axes, use_kernels=use_kernels)
        params, hat = pipeline_drain_body(
            cfg, sub,
            squeeze(state.params),
            squeeze(state.hat_params) if cfg.is_compressed else None,
            state.rng, state.round_idx,
            squeeze(buf), prev_tau2, constrain,
            prev_edge_mask=prev_edge_mask)
        return DFLState(
            params=unsqueeze(params),
            opt_state=state.opt_state,
            hat_params=unsqueeze(hat) if cfg.is_compressed else None,
            rng=None,
            round_idx=state.round_idx,
        )

    pipe_out = (state_specs._replace(rng=None), buf_spec, P())
    drain_out = state_specs._replace(rng=None)

    if participation:
        pipe_mapped = substrate_lib.shard_map(
            lambda st, bf, hv, pt2, pem, b, t1, nm: pipe_body(
                st, bf, hv, pt2, b, t1, node_mask=nm, prev_edge_mask=pem),
            mesh,
            (state_specs, buf_spec, P(), P(), P(), batch_spec, P(), P()),
            pipe_out, manual_axes=tuple(node_axes), check=False)
        drain_mapped = substrate_lib.shard_map(
            lambda st, bf, pt2, pem: drain_body(
                st, bf, pt2, prev_edge_mask=pem),
            mesh, (state_specs, buf_spec, P(), P()), drain_out,
            manual_axes=tuple(node_axes), check=False)

        def pipe_fn(state, buf, have, prev_tau2, prev_edge_mask, batches,
                    tau1, node_mask):
            new_state, z, metrics = pipe_mapped(
                state, buf, jnp.asarray(have, jnp.int32),
                jnp.asarray(prev_tau2, jnp.int32),
                jnp.asarray(prev_edge_mask, jnp.int32), batches,
                jnp.asarray(tau1, jnp.int32),
                jnp.asarray(node_mask, jnp.int32))
            return new_state._replace(rng=state.rng), z, metrics

        def drain_fn(state, buf, prev_tau2, prev_edge_mask):
            new_state = drain_mapped(
                state, buf, jnp.asarray(prev_tau2, jnp.int32),
                jnp.asarray(prev_edge_mask, jnp.int32))
            return new_state._replace(rng=state.rng)

        return pipe_fn, drain_fn

    pipe_mapped = substrate_lib.shard_map(
        lambda st, bf, hv, pt2, b, t1: pipe_body(st, bf, hv, pt2, b, t1),
        mesh, (state_specs, buf_spec, P(), P(), batch_spec, P()),
        pipe_out, manual_axes=tuple(node_axes), check=False)
    drain_mapped = substrate_lib.shard_map(
        lambda st, bf, pt2: drain_body(st, bf, pt2),
        mesh, (state_specs, buf_spec, P()), drain_out,
        manual_axes=tuple(node_axes), check=False)

    def pipe_fn(state, buf, have, prev_tau2, batches, tau1):
        new_state, z, metrics = pipe_mapped(
            state, buf, jnp.asarray(have, jnp.int32),
            jnp.asarray(prev_tau2, jnp.int32), batches,
            jnp.asarray(tau1, jnp.int32))
        return new_state._replace(rng=state.rng), z, metrics

    def drain_fn(state, buf, prev_tau2):
        new_state = drain_mapped(state, buf,
                                 jnp.asarray(prev_tau2, jnp.int32))
        return new_state._replace(rng=state.rng)

    return pipe_fn, drain_fn
