"""Production sharded DFL round: node axis manual, model axes auto.

``make_sharded_round_fn`` builds the beyond-paper optimized round: each DFL
node's local updates run as ordinary (GSPMD-partitioned) JAX under a
``jax.shard_map`` that is manual ONLY over the node mesh axes; the gossip
stage is per-neighbor ``collective-permute`` (ring traffic = deg copies
instead of the dense path's N-1-copy all-gather). Supports plain DFL and
CHOCO-G C-DFL (compression applied node-locally, neighbor estimates
fetched by ppermute — equivalent to Alg. 2's replicated w_hat bookkeeping).

Requires a circulant topology (ring/torus rows of the mesh); the dense
engine (`core.dfl`) remains the general-topology path and the numerical
reference (tests/test_multidevice.py checks they agree).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import compress_tree
from repro.core.dfl import DFLConfig, DFLState
from repro.core.mixing import mix_ppermute_shifts

PyTree = Any


def _node_axis_arg(node_axes: Sequence[str]):
    return tuple(node_axes) if len(node_axes) > 1 else node_axes[0]


def _axis_index(node_axes: Sequence[str]) -> jnp.ndarray:
    idx = jnp.zeros((), jnp.int32)
    for a in node_axes:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _pmean(x, node_axes):
    return jax.lax.pmean(x, _node_axis_arg(node_axes))


def make_sharded_round_fn(
    cfg: DFLConfig,
    loss_fn: Callable,
    opt,
    mesh,
    *,
    node_axes: Sequence[str] = ("data",),
) -> Callable[[DFLState, PyTree], Tuple[DFLState, dict]]:
    """Sparse-gossip round; call under jax.jit. State leaves carry the
    stacked node dim sharded over ``node_axes`` (local size 1)."""
    from jax.sharding import PartitionSpec as P

    topo = cfg.topology
    shifts = topo.shifts()
    assert shifts, (f"{topo.name} is not circulant; use core.dfl's dense "
                    "engine for arbitrary topologies")
    self_w = float(topo.self_weights[0])
    axis = _node_axis_arg(node_axes)
    n = topo.num_nodes

    node_entry = tuple(node_axes) if len(node_axes) > 1 else node_axes[0]
    state_specs = DFLState(
        params=P(node_entry),
        opt_state=P(node_entry),
        hat_params=P(node_entry) if cfg.is_compressed else None,
        rng=P(),
        round_idx=P(),
    )
    batch_spec = P(None, node_entry)

    def body(state: DFLState, batches: PyTree):
        # local leaves: params [1, ...]; batches [tau1, 1, B, ...]
        squeeze = lambda t: jax.tree_util.tree_map(lambda x: x[0], t)
        unsqueeze = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)
        params = squeeze(state.params)
        opt_state = squeeze(state.opt_state)
        hat = squeeze(state.hat_params) if cfg.is_compressed else None
        me = _axis_index(node_axes)

        grad_fn = jax.value_and_grad(loss_fn)

        def local_step(carry, batch_t):
            p, o, k = carry
            k, sub = jax.random.split(k)
            loss, g = grad_fn(p, squeeze(batch_t), jax.random.fold_in(sub, me))
            upd, o = opt.update(g, o, p)
            p = jax.tree_util.tree_map(
                lambda a, u: (a + u).astype(a.dtype), p, upd)
            return (p, o, k), loss

        rng = jax.random.fold_in(state.rng, me)
        (params, opt_state, rng), losses = jax.lax.scan(
            local_step, (params, opt_state, rng), batches)

        if cfg.is_compressed:
            comp = cfg.compression

            def comm_step(carry, t):
                x, y = carry
                mixed_y = mix_ppermute_shifts(y, shifts, self_w, axis)
                x = jax.tree_util.tree_map(
                    lambda a, my, yy: (a.astype(jnp.float32) + cfg.gamma *
                                       (my.astype(jnp.float32) -
                                        yy.astype(jnp.float32))
                                       ).astype(a.dtype),
                    x, mixed_y, y)
                key = jax.random.fold_in(jax.random.fold_in(rng, t), me)
                diff = jax.tree_util.tree_map(lambda a, b: a - b, x, y)
                q = compress_tree(comp, diff, key)
                y = jax.tree_util.tree_map(lambda b, qq: b + qq, y, q)
                return (x, y), None

            (params, hat), _ = jax.lax.scan(
                comm_step, (params, hat), jnp.arange(cfg.tau2))
        else:
            def comm_step(_, p):
                return mix_ppermute_shifts(p, shifts, self_w, axis)

            params = jax.lax.fori_loop(0, cfg.tau2, comm_step, params)

        mean_loss = _pmean(jnp.mean(losses), node_axes)
        # consensus ||X(I-J)||_F^2 / N via pmean of per-node deviation.
        mean_params = jax.tree_util.tree_map(
            lambda x: _pmean(x.astype(jnp.float32), node_axes), params)
        dev = sum(
            jnp.sum((a.astype(jnp.float32) - m) ** 2)
            for a, m in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(mean_params)))
        consensus = _pmean(dev, node_axes)

        new_state = DFLState(
            params=unsqueeze(params),
            opt_state=unsqueeze(opt_state),
            hat_params=unsqueeze(hat) if cfg.is_compressed else None,
            rng=jax.random.fold_in(state.rng, 1),
            round_idx=state.round_idx + 1,
        )
        return new_state, {"loss": mean_loss, "consensus_sq": consensus}

    in_specs = (
        DFLState(params=state_specs.params, opt_state=state_specs.opt_state,
                 hat_params=state_specs.hat_params, rng=state_specs.rng,
                 round_idx=state_specs.round_idx),
        batch_spec,
    )
    out_specs = (in_specs[0], P())

    return jax.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        axis_names=set(node_axes), check_vma=False)
